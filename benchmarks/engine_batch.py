"""Batched query engine sweep: per-query latency vs looped single-source.

The engine's claim (DESIGN.md §9): S traversal queries batched into one
frontier-matrix launch cost far less per query than S single-source runs,
because A's tiles stream once for the whole batch and the per-call
dispatch/sync overhead amortises. This sweep measures multi-source BFS and
batched PPR against loops of ``algorithms.bfs`` / ``algorithms.ppr`` across
batch width × skew × tile_dim on hub-skewed and R-MAT graphs, plus the
plan-cache effect (cold trace vs warm hit) at serving steady-state.

Wall-clock on this container is jitted-CPU; the structural win (one A sweep
per iteration instead of S, one launch instead of S) transfers to TPU
unchanged. ``results/engine_batch.json`` records the full detail; the
``batchN`` rows report per-query microseconds and the speedup over the
looped baseline.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, save_json, time_fn
from repro.algorithms import bfs, ppr
from repro.core import GraphMatrix
from repro.data import graphs as G
from repro.engine import PlanCache, queries


def _hub_coo(n: int, skew: int, base_deg: int = 2, hub_frac: float = 1 / 64,
             tile_dim: int = 8, seed: int = 0):
    """Directed COO with a controlled tile-level skew knob (see
    benchmarks/kernels_bucketed.py for the construction)."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n, dtype=np.int64), base_deg)
    cols = rng.integers(0, n, rows.size)
    n_tile_rows = -(-n // tile_dim)
    hub_tile_rows = rng.choice(n_tile_rows, max(int(n_tile_rows * hub_frac), 1),
                               replace=False)
    hub_deg = int(1.5 * skew * base_deg * tile_dim)
    for tr in hub_tile_rows:
        hr = np.full(hub_deg, tr * tile_dim, np.int64)
        rows = np.concatenate([rows, hr])
        cols = np.concatenate([cols, rng.integers(0, n, hub_deg)])
    return rows, cols


# The looped baseline's per-query cost is constant in S (independent runs,
# each re-tracing its own loop — no plan cache on the single-source path),
# so it is *sampled* on at most this many sources and scaled; timing all S
# single-source runs at every width would only re-measure the same number.
LOOP_SAMPLE = 6


def _bench_case(name: str, g: GraphMatrix, sources: np.ndarray,
                ppr_iters: int, rows_out: List[BenchRow],
                detail: dict) -> None:
    s = sources.size
    sample = sources[: min(s, LOOP_SAMPLE)]
    planner = PlanCache()

    def batched_bfs():
        return queries.msbfs(g, sources, planner=planner).levels

    def looped_bfs():
        return [bfs(g, int(src)).levels for src in sample]

    def batched_ppr_fn():
        return queries.batched_ppr(g, sources, max_iters=ppr_iters,
                                   eps=0.0, planner=planner).ranks

    def looped_ppr():
        return [ppr(g, int(src), max_iters=ppr_iters, eps=0.0).ranks
                for src in sample]

    t_bfs_batch = time_fn(batched_bfs, warmup=1, iters=3)
    t_bfs_loop = time_fn(looped_bfs, warmup=0, iters=2) / sample.size
    t_ppr_batch = time_fn(batched_ppr_fn, warmup=1, iters=3)
    t_ppr_loop = time_fn(looped_ppr, warmup=0, iters=2) / sample.size

    entry = {
        "batch_width": s,
        "loop_sample": int(sample.size),
        "bfs_batched_us_per_query": t_bfs_batch * 1e6 / s,
        "bfs_looped_us_per_query": t_bfs_loop * 1e6,
        "bfs_speedup": t_bfs_loop * s / t_bfs_batch,
        "ppr_batched_us_per_query": t_ppr_batch * 1e6 / s,
        "ppr_looped_us_per_query": t_ppr_loop * 1e6,
        "ppr_speedup": t_ppr_loop * s / t_ppr_batch,
        "plan_cache": {"hits": planner.hits, "misses": planner.misses},
    }
    detail[name] = entry
    rows_out.append(BenchRow(
        f"engine/{name}/msbfs", entry["bfs_batched_us_per_query"],
        f"speedup={entry['bfs_speedup']:.2f}x "
        f"loop={entry['bfs_looped_us_per_query']:.0f}us/q"))
    rows_out.append(BenchRow(
        f"engine/{name}/ppr", entry["ppr_batched_us_per_query"],
        f"speedup={entry['ppr_speedup']:.2f}x "
        f"loop={entry['ppr_looped_us_per_query']:.0f}us/q"))


def run(tiny: bool = False) -> List[BenchRow]:
    rows_out: List[BenchRow] = []
    detail: dict = {"mode": "tiny" if tiny else "full"}
    rng = np.random.default_rng(42)

    n = 256 if tiny else 2048
    widths = (4, 16, 32) if tiny else (4, 16, 64)
    skews = (16,) if tiny else (4, 64)
    tile_dims = (8,) if tiny else (8, 16)
    ppr_iters = 5 if tiny else 10

    # -- batch width × skew × tile_dim on controlled hub graphs ---------------
    for t in tile_dims:
        for skew in skews:
            r, c = _hub_coo(n, skew, tile_dim=t, seed=skew)
            g = GraphMatrix.from_coo(r, c, n, n, tile_dim=t)
            for s in widths:
                sources = rng.integers(0, n, s)
                _bench_case(f"hub/skew{skew}/t{t}/batch{s}", g, sources,
                            ppr_iters, rows_out, detail)

    # -- R-MAT (the serving-shaped power-law graph) ---------------------------
    t = tile_dims[0]
    r, c = G.rmat_graph(n, avg_degree=8, seed=3, symmetric=False)
    g = GraphMatrix.from_coo(r, c, n, n, tile_dim=t)
    for s in widths:
        sources = rng.integers(0, n, s)
        _bench_case(f"rmat/t{t}/batch{s}", g, sources, ppr_iters,
                    rows_out, detail)

    # -- plan-cache effect: cold build vs warm steady-state -------------------
    planner = PlanCache()
    sources = rng.integers(0, n, widths[-1])
    t_cold = time_fn(lambda: queries.msbfs(g, sources, planner=planner).levels,
                     warmup=0, iters=1)
    t_warm = time_fn(lambda: queries.msbfs(g, sources, planner=planner).levels,
                     warmup=1, iters=3)
    detail["plan_cache_effect"] = {
        "cold_trace_us": t_cold * 1e6,
        "warm_hit_us": t_warm * 1e6,
        "trace_amortisation": t_cold / t_warm,
        "hits": planner.hits, "misses": planner.misses,
    }
    rows_out.append(BenchRow("engine/plan_cache/warm", t_warm * 1e6,
                             f"cold={t_cold * 1e6:.0f}us "
                             f"amort={t_cold / t_warm:.1f}x"))

    # acceptance: batch width >= 16 beats the looped baseline per query
    wide = [e for k, e in detail.items()
            if isinstance(e, dict) and e.get("batch_width", 0) >= 16]
    detail["batch_ge16_beats_looped"] = bool(wide) and all(
        e["bfs_speedup"] > 1.0 and e["ppr_speedup"] > 1.0 for e in wide)

    save_json("engine_batch.json", detail)
    return rows_out


if __name__ == "__main__":
    import sys
    for row in run(tiny="--tiny" in sys.argv):
        print(row.csv())
