"""B2SR: Bit-Block Compressed Sparse Row format (the paper's core contribution).

Two-level representation of a *binary* sparse matrix:
  - upper level: CSR over fixed-size square tiles (tile_row_ptr / tile_col_idx)
  - lower level: each non-empty tile is a dense bit matrix; bit-row ``r`` of a
    tile is packed LSB-first into one machine word (bit ``j`` of word ``r`` is
    element ``[r, j]`` of the tile).

Tile sizes 4/8/16/32 are supported (B2SR-4 .. B2SR-32, Table I of the paper).
Storage accounting uses the paper's packing dtypes (uint8/uint8/uint16/uint32);
the *compute* representation is always uint32 words (TPU lanes are 32-bit).

TPU adaptation (see DESIGN.md §2): kernels consume a padded ELL view
(``B2SREll``) with a static ``max_tiles_per_row`` so Pallas BlockSpecs are
static; CSR top level remains the storage/interchange format.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TILE_DIMS = (4, 8, 16, 32)

# Paper Table I packing dtypes (for storage accounting + host storage).
_STORE_DTYPE = {4: np.uint8, 8: np.uint8, 16: np.uint16, 32: np.uint32}
_STORE_BYTES = {4: 1, 8: 1, 16: 2, 32: 4}
_INDEX_BYTES = 4  # int32 indices, as in the paper


def _pytree(cls):
    """Register a dataclass as a pytree: array fields are leaves, the rest aux."""
    meta = tuple(f.name for f in dataclasses.fields(cls) if f.metadata.get("static"))
    data = tuple(f.name for f in dataclasses.fields(cls) if not f.metadata.get("static"))

    def flatten(obj):
        return tuple(getattr(obj, n) for n in data), tuple(getattr(obj, n) for n in meta)

    def unflatten(aux, children):
        return cls(**dict(zip(data, children)), **dict(zip(meta, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


@_pytree
@dataclasses.dataclass(frozen=True)
class B2SR:
    """CSR-over-tiles with bit-packed tiles (compute words are uint32)."""

    tile_row_ptr: jax.Array  # int32[n_tile_rows + 1]
    tile_col_idx: jax.Array  # int32[n_tiles]
    bit_tiles: jax.Array     # uint32[n_tiles, tile_dim]; low tile_dim bits used
    tile_dim: int = static_field()
    n_rows: int = static_field()
    n_cols: int = static_field()
    nnz: int = static_field()

    @property
    def n_tile_rows(self) -> int:
        return ceil_div(self.n_rows, self.tile_dim)

    @property
    def n_tile_cols(self) -> int:
        return ceil_div(self.n_cols, self.tile_dim)

    @property
    def n_tiles(self) -> int:
        return int(self.tile_col_idx.shape[0])

    def storage_bytes(self) -> int:
        """Byte size in the paper's on-disk packing (Table I dtypes)."""
        idx = _INDEX_BYTES * (self.n_tile_rows + 1) + _INDEX_BYTES * self.n_tiles
        tiles = self.n_tiles * self.tile_dim * _STORE_BYTES[self.tile_dim]
        return idx + tiles


@_pytree
@dataclasses.dataclass(frozen=True)
class B2SREll:
    """Padded (ELL-style) view of B2SR: static tiles-per-row for TPU kernels.

    ``tile_col_idx`` uses ``-1`` as the padding sentinel; gathers clip to 0 and
    a validity mask kills the padded lanes.
    """

    tile_col_idx: jax.Array  # int32[n_tile_rows, max_tiles_per_row]
    bit_tiles: jax.Array     # uint32[n_tile_rows, max_tiles_per_row, tile_dim]
    row_n_tiles: jax.Array   # int32[n_tile_rows]
    tile_dim: int = static_field()
    n_rows: int = static_field()
    n_cols: int = static_field()

    @property
    def n_tile_rows(self) -> int:
        return int(self.tile_col_idx.shape[0])

    @property
    def n_tile_cols(self) -> int:
        return ceil_div(self.n_cols, self.tile_dim)

    @property
    def max_tiles_per_row(self) -> int:
        return int(self.tile_col_idx.shape[1])

    def valid_mask(self) -> jax.Array:
        return self.tile_col_idx >= 0


@_pytree
@dataclasses.dataclass(frozen=True)
class B2SRBucketedEll:
    """Row-bucketed (SELL-style) ELL view: per-bucket static tiles-per-row.

    The single-``max_tiles_per_row`` ``B2SREll`` makes every tile-row pay
    hub-row cost on skewed (power-law) graphs. Here tile-rows are sorted by
    tile count into length-buckets (power-of-two boundaries, slab width =
    the bucket's own max count); each bucket is a dense ``[rows_b, k_b]``
    ELL slab plus ``rows`` — the original tile-row ids, i.e. the
    row-permutation that restores output order. Empty tile-rows belong to
    no bucket (consumers initialise outputs to the ⊕-identity). See
    DESIGN.md §2 for the bucketing decision.

    Per-bucket arrays (parallel tuples, one entry per bucket):
      col_idx[b]   int32[rows_b, k_b]   (-1 = padding sentinel, as in ELL)
      bit_tiles[b] uint32[rows_b, k_b, tile_dim]
      rows[b]      int32[rows_b]        original tile-row index per slab row
    """

    col_idx: Tuple[jax.Array, ...]
    bit_tiles: Tuple[jax.Array, ...]
    rows: Tuple[jax.Array, ...]
    tile_dim: int = static_field()
    n_rows: int = static_field()
    n_cols: int = static_field()
    n_tile_rows: int = static_field()

    @property
    def n_buckets(self) -> int:
        return len(self.col_idx)

    @property
    def n_tile_cols(self) -> int:
        return ceil_div(self.n_cols, self.tile_dim)

    @property
    def bucket_widths(self) -> Tuple[int, ...]:
        return tuple(int(c.shape[1]) for c in self.col_idx)

    @property
    def bucket_sizes(self) -> Tuple[int, ...]:
        return tuple(int(c.shape[0]) for c in self.col_idx)

    def padded_words(self) -> int:
        """Tile slots held (incl. padding) across all bucket slabs."""
        return sum(int(c.shape[0] * c.shape[1]) for c in self.col_idx)

    def real_words(self) -> int:
        """Non-padding tile slots (equals the B2SR tile count)."""
        return sum(int((np.asarray(c) >= 0).sum()) for c in self.col_idx)

    def fill_ratio(self) -> float:
        """real/padded tile slots; 1.0 == no padded work at all."""
        p = self.padded_words()
        return 1.0 if p == 0 else self.real_words() / p


def ell_fill_ratio(ell: "B2SREll") -> float:
    """real/padded tile slots of the single-max ELL view (for comparison)."""
    padded = int(ell.tile_col_idx.shape[0] * ell.tile_col_idx.shape[1])
    if padded == 0:
        return 1.0
    return int((np.asarray(ell.tile_col_idx) >= 0).sum()) / padded


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Host-side conversion (the cusparseXcsr2bsrNnz / csr2bsr analogue)
# ---------------------------------------------------------------------------

def coo_to_b2sr(
    rows: np.ndarray,
    cols: np.ndarray,
    n_rows: int,
    n_cols: int,
    tile_dim: int = 32,
) -> B2SR:
    """Convert a binary COO matrix to B2SR. Duplicate entries are OR-ed."""
    if tile_dim not in TILE_DIMS:
        raise ValueError(f"tile_dim must be one of {TILE_DIMS}, got {tile_dim}")
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    if rows.size and (rows.min() < 0 or rows.max() >= n_rows):
        raise ValueError("row index out of range")
    if cols.size and (cols.min() < 0 or cols.max() >= n_cols):
        raise ValueError("col index out of range")
    t = tile_dim
    n_tile_rows = ceil_div(n_rows, t)
    n_tile_cols = ceil_div(n_cols, t)

    tr = rows // t
    tc = cols // t
    key = tr * n_tile_cols + tc
    order = np.argsort(key, kind="stable")
    key_sorted = key[order]
    uniq_keys, inverse_sorted = np.unique(key_sorted, return_inverse=True)
    n_tiles = int(uniq_keys.shape[0])

    # inverse map for original nnz order
    inverse = np.empty_like(inverse_sorted)
    inverse[order] = inverse_sorted

    tile_tr = (uniq_keys // n_tile_cols).astype(np.int64)
    tile_tc = (uniq_keys % n_tile_cols).astype(np.int64)

    tile_row_ptr = np.zeros(n_tile_rows + 1, dtype=np.int32)
    np.add.at(tile_row_ptr, tile_tr + 1, 1)
    tile_row_ptr = np.cumsum(tile_row_ptr, dtype=np.int64).astype(np.int32)

    bit_tiles = np.zeros((max(n_tiles, 1), t), dtype=np.uint32)
    word_idx = (rows % t).astype(np.int64)
    bit = (np.uint32(1) << (cols % t).astype(np.uint32)).astype(np.uint32)
    np.bitwise_or.at(bit_tiles, (inverse, word_idx), bit)
    if n_tiles == 0:
        bit_tiles = np.zeros((0, t), dtype=np.uint32)

    return B2SR(
        tile_row_ptr=jnp.asarray(tile_row_ptr),
        tile_col_idx=jnp.asarray(tile_tc.astype(np.int32)),
        bit_tiles=jnp.asarray(bit_tiles),
        tile_dim=t,
        n_rows=n_rows,
        n_cols=n_cols,
        nnz=int(rows.shape[0]),
    )


def csr_to_b2sr(row_ptr: np.ndarray, col_idx: np.ndarray, n_cols: int,
                tile_dim: int = 32) -> B2SR:
    row_ptr = np.asarray(row_ptr)
    n_rows = row_ptr.shape[0] - 1
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(row_ptr))
    return coo_to_b2sr(rows, np.asarray(col_idx), n_rows, n_cols, tile_dim)


def dense_to_b2sr(mat: np.ndarray, tile_dim: int = 32) -> B2SR:
    mat = np.asarray(mat)
    rows, cols = np.nonzero(mat)
    return coo_to_b2sr(rows, cols, mat.shape[0], mat.shape[1], tile_dim)


def b2sr_to_dense(m: B2SR) -> np.ndarray:
    """Densify (oracle / tests only)."""
    t = m.tile_dim
    out = np.zeros((m.n_tile_rows * t, m.n_tile_cols * t), dtype=np.uint8)
    ptr = np.asarray(m.tile_row_ptr)
    tci = np.asarray(m.tile_col_idx)
    tiles = np.asarray(m.bit_tiles)
    for i in range(m.n_tile_rows):
        for p in range(int(ptr[i]), int(ptr[i + 1])):
            j = int(tci[p])
            block = (tiles[p][:, None] >> np.arange(t, dtype=np.uint32)[None, :]) & 1
            out[i * t:(i + 1) * t, j * t:(j + 1) * t] |= block.astype(np.uint8)
    return out[: m.n_rows, : m.n_cols]


def to_ell(m: B2SR, max_tiles_per_row: Optional[int] = None,
           pad_tile_rows_to: int = 1) -> B2SREll:
    """CSR-over-tiles -> padded ELL view (static shapes for TPU kernels)."""
    ptr = np.asarray(m.tile_row_ptr)
    counts = np.diff(ptr)
    k = int(counts.max()) if counts.size else 1
    if max_tiles_per_row is not None:
        if max_tiles_per_row < k:
            raise ValueError(f"max_tiles_per_row={max_tiles_per_row} < required {k}")
        k = max_tiles_per_row
    k = max(k, 1)
    n_tr = m.n_tile_rows
    n_tr_pad = ceil_div(n_tr, pad_tile_rows_to) * pad_tile_rows_to
    t = m.tile_dim

    col = np.full((n_tr_pad, k), -1, dtype=np.int32)
    tiles = np.zeros((n_tr_pad, k, t), dtype=np.uint32)
    tci = np.asarray(m.tile_col_idx)
    bt = np.asarray(m.bit_tiles)
    for i in range(n_tr):
        s, e = int(ptr[i]), int(ptr[i + 1])
        col[i, : e - s] = tci[s:e]
        tiles[i, : e - s] = bt[s:e]
    return B2SREll(
        tile_col_idx=jnp.asarray(col),
        bit_tiles=jnp.asarray(tiles),
        row_n_tiles=jnp.asarray(
            np.pad(counts.astype(np.int32), (0, n_tr_pad - n_tr))),
        tile_dim=t,
        n_rows=m.n_rows,
        n_cols=m.n_cols,
    )


def to_bucketed(ell: B2SREll, max_buckets: int = 8) -> B2SRBucketedEll:
    """ELL view -> row-bucketed (SELL-style) view.

    Tile-rows are grouped by tile count into power-of-two ranges
    ``(2^(b-1), 2^b]``; each group's slab width is its own max count (so
    per-row padding is < 2x even inside a bucket). If the count histogram
    spans more than ``max_buckets`` ranges, the widest ranges are merged
    into one slab of width ``max(counts)`` — hubs are few, so the merged
    bucket's padding is paid by few rows. Empty tile-rows are dropped.
    """
    counts = np.asarray(ell.row_n_tiles, dtype=np.int64)
    n_tr = int(ell.tile_col_idx.shape[0])
    col_np = np.asarray(ell.tile_col_idx)
    tiles_np = np.asarray(ell.bit_tiles)

    nonempty = np.flatnonzero(counts > 0)
    cols_out, tiles_out, rows_out = [], [], []
    if nonempty.size:
        # power-of-two bucket index per row: 1 -> 0, 2 -> 1, 3..4 -> 2, ...
        bidx = np.ceil(np.log2(counts[nonempty])).astype(np.int64)
        uniq = np.sort(np.unique(bidx))
        if uniq.size > max_buckets:
            # merge the widest ranges into one hub bucket
            keep = uniq[: max_buckets - 1]
            bidx = np.where(np.isin(bidx, keep), bidx, uniq[max_buckets - 1])
            uniq = np.sort(np.unique(bidx))
        # ensure_compile_time_eval: the bucketed view is built lazily and
        # memoized on the GraphMatrix — when the first use happens inside a
        # jit trace, plain jnp.asarray would mint tracers and poison the
        # cache for every later (outside-trace) call
        with jax.ensure_compile_time_eval():
            for b in uniq:
                rows_b = nonempty[bidx == b]
                k_b = int(counts[rows_b].max())
                cols_out.append(jnp.asarray(col_np[rows_b, :k_b]))
                tiles_out.append(jnp.asarray(tiles_np[rows_b, :k_b]))
                rows_out.append(jnp.asarray(rows_b.astype(np.int32)))
    return B2SRBucketedEll(
        col_idx=tuple(cols_out),
        bit_tiles=tuple(tiles_out),
        rows=tuple(rows_out),
        tile_dim=ell.tile_dim,
        n_rows=ell.n_rows,
        n_cols=ell.n_cols,
        n_tile_rows=n_tr,
    )


def transpose(m: B2SR) -> B2SR:
    """B2SR transpose: swap tile coords (CSR->CSC relabel) + bit-transpose tiles.

    The paper uses cusparseScsr2csc for the top level; tiles are transposed by
    re-packing. We transpose tiles with the word-level bit transpose below.
    """
    t = m.tile_dim
    ptr = np.asarray(m.tile_row_ptr)
    tile_tr = np.repeat(np.arange(m.n_tile_rows, dtype=np.int64), np.diff(ptr))
    tile_tc = np.asarray(m.tile_col_idx, dtype=np.int64)
    tiles = np.asarray(m.bit_tiles)

    order = np.argsort(tile_tc * m.n_tile_rows + tile_tr, kind="stable")
    new_tr = tile_tc[order]
    new_tc = tile_tr[order].astype(np.int32)
    new_tiles = bit_transpose_np(tiles[order], t)

    new_ptr = np.zeros(m.n_tile_cols + 1, dtype=np.int64)
    np.add.at(new_ptr, new_tr + 1, 1)
    new_ptr = np.cumsum(new_ptr).astype(np.int32)
    return B2SR(
        tile_row_ptr=jnp.asarray(new_ptr),
        tile_col_idx=jnp.asarray(new_tc),
        bit_tiles=jnp.asarray(new_tiles),
        tile_dim=t,
        n_rows=m.n_cols,
        n_cols=m.n_rows,
        nnz=m.nnz,
    )


def bit_transpose_np(tiles: np.ndarray, t: int) -> np.ndarray:
    """Transpose each t-row bit tile (numpy, conversion-time)."""
    bits = (tiles[..., :, None] >> np.arange(t, dtype=np.uint32)) & 1  # [..., t(row), t(col)]
    bits_t = np.swapaxes(bits, -1, -2)
    return (bits_t.astype(np.uint32) << np.arange(t, dtype=np.uint32)).sum(
        axis=-1, dtype=np.uint32)


# ---------------------------------------------------------------------------
# Device-side bit packing/unpacking (jnp; kernels/bitpack has the Pallas twin)
# ---------------------------------------------------------------------------

def pack_bitvector(x: jax.Array, tile_dim: int, n_cols: Optional[int] = None) -> jax.Array:
    """Pack a dense 0/1 vector into per-tile words (uint32, low tile_dim bits).

    ``x``: bool/int/float vector of length n; returns uint32[ceil(n/t)].
    The paper's column-major vector binarization (Sec. IV, Listing 1 setup).
    """
    t = tile_dim
    n = x.shape[0] if n_cols is None else n_cols
    n_pad = ceil_div(n, t) * t
    xb = (x != 0).astype(jnp.uint32)
    xb = jnp.pad(xb, (0, n_pad - x.shape[0]))
    xb = xb.reshape(-1, t)
    shifts = jnp.arange(t, dtype=jnp.uint32)
    return jnp.sum(xb << shifts[None, :], axis=1, dtype=jnp.uint32)


def unpack_bitvector(words: jax.Array, tile_dim: int, n: int,
                     dtype=jnp.float32) -> jax.Array:
    t = tile_dim
    shifts = jnp.arange(t, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(dtype)


# Frontier *matrices* (engine/): the source axis packs into full uint32
# words regardless of tile_dim — tile_dim tiles the node axis, the batch
# axis is lane-packed at machine width (DESIGN.md §9).
SOURCE_WORD_BITS = 32


def pack_frontier_matrix(x: jax.Array, tile_dim: int,
                         n_rows: Optional[int] = None) -> jax.Array:
    """Binarize + bit-pack a batch of frontiers ``[n, S]`` along the S axis.

    Returns ``uint32[ceil(n/t), t, W]`` with ``W = ceil(S/32)``: entry
    ``[T, r, w]`` packs sources ``32w..32w+31`` of node ``T*t + r``,
    LSB-first. Node rows are tile-grouped so B2SR schemes gather one
    ``[t, W]`` panel per tile-column index (the multi-frontier twin of
    ``pack_bitvector``); the trailing node pad and source pad are zero bits.
    """
    t = tile_dim
    n = x.shape[0] if n_rows is None else n_rows
    s = x.shape[1]
    n_tiles = ceil_div(n, t)
    w = ceil_div(max(s, 1), SOURCE_WORD_BITS)
    xb = (x != 0).astype(jnp.uint32)
    xb = jnp.pad(xb, ((0, n_tiles * t - x.shape[0]),
                      (0, w * SOURCE_WORD_BITS - s)))
    xb = xb.reshape(n_tiles, t, w, SOURCE_WORD_BITS)
    shifts = jnp.arange(SOURCE_WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(xb << shifts, axis=-1, dtype=jnp.uint32)


def unpack_frontier_matrix(words: jax.Array, n: int, n_sources: int,
                           dtype=jnp.float32) -> jax.Array:
    """Inverse of ``pack_frontier_matrix``: ``uint32[T, t, W]`` -> ``[n, S]``."""
    tiles, t, w = words.shape
    shifts = jnp.arange(SOURCE_WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)     # [T, t, W, 32]
    return bits.reshape(tiles * t,
                        w * SOURCE_WORD_BITS)[:n, :n_sources].astype(dtype)


def unpack_tiles(tiles: jax.Array, tile_dim: int, dtype=jnp.float32) -> jax.Array:
    """uint32[..., t] words -> dense 0/1 [..., t, t] (row, col)."""
    t = tile_dim
    shifts = jnp.arange(t, dtype=jnp.uint32)
    bits = (tiles[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.astype(dtype)


def or_reduce_words(words: jax.Array, axes) -> jax.Array:
    """Bitwise-OR reduction of uint32 words over ``axes``.

    The ∨-monoid over packed words (kernel-body safe) — shared by the jnp
    mxm/spmm schemes and the Pallas kernels.
    """
    return jax.lax.reduce(words, np.uint32(0), jax.lax.bitwise_or,
                          tuple(axes))


def bit_transpose_words(tiles: jax.Array, tile_dim: int) -> jax.Array:
    """In-device bit transpose of packed tiles (jnp path).

    Unpack/swap/repack; the Pallas kernel uses the same formulation — on TPU
    the unpack is VPU shift/AND work over VREGs, the paper's
    ``__ballot_sync``+``__brev`` analogue.
    """
    t = tile_dim
    bits = unpack_tiles(tiles, t, dtype=jnp.uint32)
    bits_t = jnp.swapaxes(bits, -1, -2)
    shifts = jnp.arange(t, dtype=jnp.uint32)
    return jnp.sum(bits_t << shifts[None, :], axis=-1, dtype=jnp.uint32)


def pack_dense_tiles(dense: jax.Array, tile_dim: int) -> jax.Array:
    """Dense [R*t, C*t] 0/1 matrix -> packed tiles uint32[R, C, t] (jnp path)."""
    t = tile_dim
    r_pad = ceil_div(dense.shape[0], t) * t
    c_pad = ceil_div(dense.shape[1], t) * t
    d = jnp.pad((dense != 0).astype(jnp.uint32),
                ((0, r_pad - dense.shape[0]), (0, c_pad - dense.shape[1])))
    d = d.reshape(r_pad // t, t, c_pad // t, t).transpose(0, 2, 1, 3)
    shifts = jnp.arange(t, dtype=jnp.uint32)
    return jnp.sum(d << shifts, axis=-1, dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# Output-tile packing / accumulation (the SpGEMM C-side, paper Table III)
# ---------------------------------------------------------------------------

def pack_tile_bits(bits: jax.Array, tile_dim: int) -> jax.Array:
    """Dense 0/1 tiles [..., t(row), t(col)] -> packed words uint32[..., t].

    Inverse of ``unpack_tiles``: bit ``j`` of word ``r`` is element
    ``[r, j]``. This is the dense-tile -> bit-tile repack used when an mxm
    accumulates output tiles densely before re-emitting B2SR.
    """
    shifts = jnp.arange(tile_dim, dtype=jnp.uint32)
    return jnp.sum((bits != 0).astype(jnp.uint32) << shifts, axis=-1,
                   dtype=jnp.uint32)


def ell_to_packed_grid(ell: B2SREll) -> jax.Array:
    """ELL view -> dense tile grid uint32[n_tile_rows, n_tile_cols, t].

    The tile-row merge: all slots of a tile row land at their tile-column
    position; padding slots (col ``-1``) clip to column 0 with an all-zero
    word, so the elementwise-max scatter is an OR-merge (a legal ELL row has
    distinct tile columns, hence each grid cell sees one real word + zeros).
    """
    R, _ = ell.tile_col_idx.shape
    C = ell.n_tile_cols
    cols = jnp.clip(ell.tile_col_idx, 0, C - 1)
    tiles = jnp.where((ell.tile_col_idx >= 0)[:, :, None], ell.bit_tiles,
                      jnp.uint32(0))
    grid = jnp.zeros((R, C, ell.tile_dim), jnp.uint32)
    return grid.at[jnp.arange(R)[:, None], cols].max(tiles)


def packed_grid_to_b2sr(grid: np.ndarray, n_rows: int, n_cols: int) -> B2SR:
    """Dense tile grid uint32[R, C, t] -> B2SR (drop all-zero tiles).

    Host-side compression step after an mxm: the output grid has static
    shape under jit; the sparse top level (which tiles survived) is data-
    dependent and is rebuilt here, mirroring ``coo_to_b2sr``.
    """
    grid = np.asarray(grid)
    R, C, t = grid.shape
    if t not in TILE_DIMS:
        raise ValueError(f"tile_dim must be one of {TILE_DIMS}, got {t}")
    if R != ceil_div(n_rows, t) or C < ceil_div(n_cols, t):
        raise ValueError(f"grid {grid.shape} inconsistent with "
                         f"({n_rows}, {n_cols}) at tile_dim {t}")
    tr, tc = np.nonzero(grid.any(axis=-1))
    tiles = grid[tr, tc].astype(np.uint32)
    ptr = np.zeros(R + 1, dtype=np.int64)
    np.add.at(ptr, tr + 1, 1)
    ptr = np.cumsum(ptr).astype(np.int32)
    if not tiles.size:
        nnz = 0
    elif hasattr(np, "bitwise_count"):        # numpy >= 2.0
        nnz = int(np.bitwise_count(tiles).sum())
    else:
        nnz = int(np.unpackbits(tiles.view(np.uint8)).sum())
    return B2SR(
        tile_row_ptr=jnp.asarray(ptr),
        tile_col_idx=jnp.asarray(tc.astype(np.int32)),
        bit_tiles=jnp.asarray(tiles),
        tile_dim=t,
        n_rows=n_rows,
        n_cols=n_cols,
        nnz=nnz,
    )


def b2sr_to_coo(m: B2SR) -> Tuple[np.ndarray, np.ndarray]:
    """B2SR -> (rows, cols) COO arrays (host-side, for re-ingestion)."""
    t = m.tile_dim
    ptr = np.asarray(m.tile_row_ptr)
    tile_tr = np.repeat(np.arange(m.n_tile_rows, dtype=np.int64), np.diff(ptr))
    tile_tc = np.asarray(m.tile_col_idx, dtype=np.int64)
    tiles = np.asarray(m.bit_tiles)
    bits = (tiles[:, :, None] >> np.arange(t, dtype=np.uint32)) & 1  # [n, t, t]
    p, r, c = np.nonzero(bits)
    return tile_tr[p] * t + r, tile_tc[p] * t + c


# ---------------------------------------------------------------------------
# Storage accounting (paper §VI.B) for format comparisons
# ---------------------------------------------------------------------------

def csr_storage_bytes(n_rows: int, nnz: int, value_bytes: int = 4) -> int:
    """CSR with fp32 values (the GraphBLAST/cuSPARSE baseline layout)."""
    return _INDEX_BYTES * (n_rows + 1) + _INDEX_BYTES * nnz + value_bytes * nnz


def compression_ratio(m: B2SR, value_bytes: int = 4) -> float:
    """B2SR_size / CSR_size (paper's metric; < 1.0 means B2SR is smaller)."""
    return m.storage_bytes() / max(csr_storage_bytes(m.n_rows, m.nnz, value_bytes), 1)


def occupancy(m: B2SR) -> float:
    """Average fraction of set bits inside non-empty tiles (paper Fig. 3b)."""
    if m.n_tiles == 0:
        return 0.0
    return float(m.nnz) / (m.n_tiles * m.tile_dim * m.tile_dim)


def best_tile_dim(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int,
                  value_bytes: int = 4) -> Tuple[int, dict]:
    """Exact (non-sampled) optimal tile size by total storage (paper Fig. 5b)."""
    sizes = {}
    for t in TILE_DIMS:
        m = coo_to_b2sr(rows, cols, n_rows, n_cols, t)
        sizes[t] = m.storage_bytes()
    best = min(sizes, key=sizes.get)
    return best, sizes
