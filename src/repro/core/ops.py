"""GraphBLAS-style operations over B2SR (jnp reference path).

This module is the device-side *algorithm* layer: every scheme from the paper
(Tables II & III) implemented with word-level bit operations in pure jnp. The
Pallas kernels in ``repro.kernels`` implement the same schemes with explicit
VMEM tiling; both paths are interchangeable behind ``repro.core.graphblas``.

Scheme naming follows the paper:
  bmv_bin_bin_bin     A:1-bit, x:1-bit, y:1-bit        (boolean semiring)
  bmv_bin_bin_full    A:1-bit, x:1-bit, y:32-bit       (counts)
  bmv_bin_full_full   A:1-bit, x:full,  y:full          (any semiring)
  *_masked            mask applied right before the output store (paper §V)
  bmm_bin_bin_sum     A,B:1-bit, out: scalar sum        (+ masked, for TC)
  mxm_bin_bin_bin     A,B:1-bit, C:1-bit packed grid     (boolean SpGEMM)
  mxm_bin_bin_full    A,B:1-bit, C:32-bit dense counts   (count SpGEMM)

TPU mapping: AND+popcount over uint32 words == the paper's __popc(a & b);
everything is batched over the ELL view so shapes are static.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.b2sr import (
    B2SRBucketedEll,
    B2SREll,
    ceil_div,
    ell_to_packed_grid,
    or_reduce_words,
    pack_bitvector,
    unpack_bitvector,
    unpack_tiles,
)
from repro.core.semiring import Semiring, ARITHMETIC, BOOLEAN, MIN_PLUS


def _popcount(x: jax.Array) -> jax.Array:
    return jax.lax.population_count(x)

def shard_map_compat(*args, **kwargs):
    """jax.shard_map where it exists (jax >= 0.5), experimental fallback."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(*args, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(*args, **kwargs)


def _reduce(semiring: Semiring, arr: jax.Array, axis) -> jax.Array:
    """⊕-reduction along ``axis`` for the supported monoids."""
    if semiring.add is jnp.add:
        return jnp.sum(arr, axis=axis)
    if semiring.add is jnp.minimum:
        return jnp.min(arr, axis=axis)
    if semiring.add is jnp.maximum:
        return jnp.max(arr, axis=axis)
    if semiring.add is jnp.logical_or:
        return jnp.any(arr, axis=axis)
    raise NotImplementedError(semiring.name)


def _gather_words(x_words: jax.Array, col_idx: jax.Array) -> jax.Array:
    """Gather packed vector words by tile-col index; padding (-1) -> word 0."""
    safe = jnp.clip(col_idx, 0, x_words.shape[0] - 1)
    g = x_words[safe]
    return jnp.where(col_idx >= 0, g, jnp.uint32(0))


def _row_chunks(n_rows: int, row_chunk: Optional[int]) -> int:
    if row_chunk is None or row_chunk >= n_rows:
        return n_rows
    return row_chunk


def _mapped_over_rows(fn, arrays, n_rows: int, row_chunk: Optional[int]):
    """Apply ``fn`` to row-chunks of the leading axis and concatenate.

    Bounded-memory evaluation for large graphs (lax.map over chunks).
    """
    c = _row_chunks(n_rows, row_chunk)
    if c == n_rows:
        return fn(*arrays)
    if n_rows % c != 0:
        raise ValueError(f"row_chunk {c} must divide n_rows {n_rows} (pad the ELL view)")
    nb = n_rows // c
    reshaped = tuple(a.reshape((nb, c) + a.shape[1:]) for a in arrays)
    out = jax.lax.map(lambda xs: fn(*xs), reshaped)
    return jax.tree_util.tree_map(
        lambda o: o.reshape((nb * c,) + o.shape[2:]) if o.ndim >= 2 else o.reshape(-1),
        out,
    )


# ---------------------------------------------------------------------------
# BMV schemes
#
# Each scheme's per-slab math lives in a ``_*_block`` helper taking raw
# ``(col_idx, tiles)`` ELL arrays, so the single-ELL path (mapped over row
# chunks) and the bucketed path (one call per bucket slab, scatter-merged
# through the row permutation) run the exact same computation.
# ---------------------------------------------------------------------------

def _bmv_bbb_block(col_idx: jax.Array, tiles: jax.Array, x_packed: jax.Array,
                   t: int) -> jax.Array:
    """bin·bin→bin on one ELL slab: packed words uint32[R]."""
    xw = _gather_words(x_packed, col_idx)              # [R, K]
    hit = (tiles & xw[:, :, None]) != 0                # [R, K, t]
    bits = jnp.any(hit, axis=1)                        # [R, t]
    shifts = jnp.arange(t, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << shifts[None, :], axis=1,
                   dtype=jnp.uint32)


def bmv_bin_bin_bin(ell: B2SREll, x_packed: jax.Array,
                    row_chunk: Optional[int] = None) -> jax.Array:
    """Boolean mxv (Table II row bin·bin→bin): packed frontier in/out.

    y_bit[i*t+r] = OR_j A[i*t+r, j] & x[j]  == any(word_r & x_word != 0).
    """
    def chunk(col_idx, tiles):
        return _bmv_bbb_block(col_idx, tiles, x_packed, ell.tile_dim)
    return _mapped_over_rows(chunk, (ell.tile_col_idx, ell.bit_tiles),
                             ell.n_tile_rows, row_chunk)


def bmv_bin_bin_bin_bucketed(b: B2SRBucketedEll, x_packed: jax.Array) -> jax.Array:
    """Bucketed boolean mxv: per-bucket slabs, outputs scattered by row id.

    Empty tile-rows are in no bucket and keep the zero word (OR-identity).
    """
    out = jnp.zeros((b.n_tile_rows,), jnp.uint32)
    for col, tiles, rows in zip(b.col_idx, b.bit_tiles, b.rows):
        out = out.at[rows].set(_bmv_bbb_block(col, tiles, x_packed, b.tile_dim))
    return out


def bmv_bin_bin_bin_bucketed_masked(b: B2SRBucketedEll, x_packed: jax.Array,
                                    mask_packed: jax.Array,
                                    complement: bool = True) -> jax.Array:
    """Masked bucketed boolean mxv (§V mask ANDed right before the store)."""
    y = bmv_bin_bin_bin_bucketed(b, x_packed)
    m = mask_packed if not complement else ~mask_packed
    return y & m


def bmv_bin_bin_bin_masked(ell: B2SREll, x_packed: jax.Array,
                           mask_packed: jax.Array, complement: bool = True,
                           row_chunk: Optional[int] = None) -> jax.Array:
    """Masked boolean mxv (Table II bin·bin→bin + §V mask): the BFS kernel.

    The mask is ANDed right before the output store; ``complement=True``
    keeps bits where the mask bit is 0 (unvisited).
    """
    y = bmv_bin_bin_bin(ell, x_packed, row_chunk)
    m = mask_packed if not complement else ~mask_packed
    return y & m


def bmv_bin_bin_bin_pull(ell: B2SREll, x_packed: jax.Array,
                         mask_packed: jax.Array, complement: bool = True,
                         row_chunk: Optional[int] = None) -> jax.Array:
    """Pull-direction boolean mxv: the jnp twin of the fused pull kernel.

    Pull traversal is the *same* bin·bin→bin reduction over the transposed
    operand the caller already passes (``direction`` never re-transposes);
    what differs is the evaluation order — the Pallas twin
    (``kernels.bmv.bmv_bin_bin_bin_pull_pallas``) walks each output row's
    k-axis through an early-exit loop and stops on the first set bit of
    every §V-allowed lane. jnp has no data-dependent row exit (SIMD over
    the whole slab), so this twin runs the identical ``_bmv_bbb_block``
    math as masked push — which is exactly what makes the pull row
    bit-exact against push by construction (DESIGN.md §12).
    """
    return bmv_bin_bin_bin_masked(ell, x_packed, mask_packed, complement,
                                  row_chunk)


def bmv_bin_bin_bin_pull_bucketed(b: B2SRBucketedEll, x_packed: jax.Array,
                                  mask_packed: jax.Array,
                                  complement: bool = True) -> jax.Array:
    """Bucketed jnp pull twin — same `_bmv_bbb_block` math, same parity."""
    return bmv_bin_bin_bin_bucketed_masked(b, x_packed, mask_packed,
                                           complement)


def _bmv_bbf_block(col_idx: jax.Array, tiles: jax.Array, x_packed: jax.Array,
                   out_dtype) -> jax.Array:
    """bin·bin→full on one ELL slab: counts [R, t]."""
    xw = _gather_words(x_packed, col_idx)               # [R, K]
    counts = _popcount(tiles & xw[:, :, None])          # [R, K, t]
    return jnp.sum(counts, axis=1).astype(out_dtype)    # [R, t]


def bmv_bin_bin_full(ell: B2SREll, x_packed: jax.Array,
                     out_dtype=jnp.float32,
                     row_chunk: Optional[int] = None) -> jax.Array:
    """Count mxv (Table II row bin·bin→full): per-row AND+popcount sums.

    y[i*t+r] = Σ popcount(word_r & x_word) — the paper's __popc(a & b)
    over uint32 VREG lanes.
    """
    def chunk(col_idx, tiles):
        return _bmv_bbf_block(col_idx, tiles, x_packed, out_dtype)

    out = _mapped_over_rows(chunk, (ell.tile_col_idx, ell.bit_tiles),
                            ell.n_tile_rows, row_chunk)
    return out.reshape(-1)[: ell.n_rows]


def bmv_bin_bin_full_bucketed(b: B2SRBucketedEll, x_packed: jax.Array,
                              out_dtype=jnp.float32) -> jax.Array:
    """Bucketed count mxv: empty tile-rows keep the 0 count (Σ-identity)."""
    out = jnp.zeros((b.n_tile_rows, b.tile_dim), out_dtype)
    for col, tiles, rows in zip(b.col_idx, b.bit_tiles, b.rows):
        out = out.at[rows].set(_bmv_bbf_block(col, tiles, x_packed, out_dtype))
    return out.reshape(-1)[: b.n_rows]


def bmv_bin_bin_full_masked(ell: B2SREll, x_packed: jax.Array, mask: jax.Array,
                            complement: bool = False, out_dtype=jnp.float32,
                            row_chunk: Optional[int] = None) -> jax.Array:
    """Masked count mxv (Table II bin·bin→full + §V mask-at-store)."""
    y = bmv_bin_bin_full(ell, x_packed, out_dtype, row_chunk)
    keep = (mask == 0) if complement else (mask != 0)
    return jnp.where(keep, y, jnp.zeros((), out_dtype))


def bmv_bin_full_full(ell: B2SREll, x: jax.Array,
                      semiring: Semiring = ARITHMETIC,
                      a_value: float = 1.0,
                      row_chunk: Optional[int] = None) -> jax.Array:
    """General-semiring mxv (Table II row bin·full→full).

    y_i = ⊕_j  (A_ij ? a_value ⊗ x_j : ⊕-identity).
    The paper's SSSP/PR/CC workhorse (min-plus uses a_value=edge weight 1).
    Scans over the K (tiles-per-row) axis for bounded memory.
    """
    x3, ident, av = _bff_setup(ell.n_tile_cols, ell.tile_dim, x, semiring,
                               a_value)

    def chunk(col_idx, tiles):
        return _bmv_bff_block(col_idx, tiles, x3, semiring, av, ident,
                              ell.tile_dim)

    out = _mapped_over_rows(chunk, (ell.tile_col_idx, ell.bit_tiles),
                            ell.n_tile_rows, row_chunk)
    return out.reshape(-1)[: ell.n_rows]


def _bff_setup(n_tc: int, t: int, x: jax.Array, semiring: Semiring,
               a_value: float):
    """Shared bin·full→full operand prep: padded x tiles, identity, a_value."""
    ident = semiring.identity_for(x.dtype)
    x_pad = jnp.pad(x, (0, n_tc * t - x.shape[0]), constant_values=ident)
    return x_pad.reshape(n_tc, t), ident, jnp.asarray(a_value, dtype=x.dtype)


def _bmv_bff_block(col_idx: jax.Array, tiles: jax.Array, x3: jax.Array,
                   semiring: Semiring, av: jax.Array, ident, t: int) -> jax.Array:
    """bin·full→full on one ELL slab: ⊕-accumulated values [R, t]."""
    n_tc = x3.shape[0]
    K = col_idx.shape[1]

    def step(acc, k):
        cols = col_idx[:, k]                                # [R]
        words = tiles[:, k]                                 # [R, t]
        bits = unpack_tiles(words, t, dtype=jnp.bool_)      # [R, t(row), t(col)]
        xk = x3[jnp.clip(cols, 0, n_tc - 1)]                # [R, t]
        xk = jnp.where((cols >= 0)[:, None], xk, ident)
        contrib = jnp.where(bits, semiring.mul(av, xk[:, None, :]), ident)
        red = _reduce(semiring, contrib, axis=2)
        return semiring.add(acc, red), None

    acc0 = jnp.full((col_idx.shape[0], t), ident, dtype=x3.dtype)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(K))
    return acc


def bmv_bin_full_full_bucketed(b: B2SRBucketedEll, x: jax.Array,
                               semiring: Semiring = ARITHMETIC,
                               a_value: float = 1.0) -> jax.Array:
    """Bucketed general-semiring mxv: empty tile-rows keep the ⊕-identity."""
    x3, ident, av = _bff_setup(b.n_tile_cols, b.tile_dim, x, semiring, a_value)
    out = jnp.full((b.n_tile_rows, b.tile_dim), ident, dtype=x.dtype)
    for col, tiles, rows in zip(b.col_idx, b.bit_tiles, b.rows):
        out = out.at[rows].set(
            _bmv_bff_block(col, tiles, x3, semiring, av, ident, b.tile_dim))
    return out.reshape(-1)[: b.n_rows]


def bmv_bin_full_full_masked(ell: B2SREll, x: jax.Array, mask: jax.Array,
                             semiring: Semiring = ARITHMETIC,
                             a_value: float = 1.0, complement: bool = False,
                             row_chunk: Optional[int] = None) -> jax.Array:
    """Masked general-semiring mxv (Table II bin·full→full + §V mask)."""
    y = bmv_bin_full_full(ell, x, semiring, a_value, row_chunk)
    keep = (mask == 0) if complement else (mask != 0)
    return jnp.where(keep, y, semiring.identity_for(y.dtype))


def vxm(ell_T: B2SREll, x, **kw):
    """vxm (Table II, pull direction): vᵀ·A == Aᵀ·v.

    Callers pass the transposed B2SR — the paper stores both layouts.
    """
    return bmv_bin_full_full(ell_T, x, **kw)


# ---------------------------------------------------------------------------
# SpMM: B2SR × dense feature matrix (GNN aggregation hot path)
# ---------------------------------------------------------------------------

def spmm_b2sr(ell: B2SREll, x: jax.Array, out_dtype=None,
              row_chunk: Optional[int] = None,
              vma_axes: tuple = ()) -> jax.Array:
    """Y = A @ X with binary A in B2SR and dense X [n_cols, d].

    The Table II bin·full→full scheme widened to a dense right-hand matrix
    (the GraphBLAST mxm-with-dense analogue; not a paper table row).

    TPU-native formulation: each bit tile is unpacked (VPU shifts) into a
    t×t 0/1 matrix that feeds the MXU against the gathered X tile — HBM
    traffic is 1 bit/element, compute is dense matmul. Scan over K bounds
    memory. This is the paper's technique promoted to the GNN hot path.
    """
    t = ell.tile_dim
    n_tc = ell.n_tile_cols
    d = x.shape[1]
    out_dtype = out_dtype or x.dtype
    x_pad = jnp.pad(x, ((0, n_tc * t - x.shape[0]), (0, 0)))
    x3 = x_pad.reshape(n_tc, t, d)

    def chunk(col_idx, tiles):
        return _spmm_block(col_idx, tiles, x3, t, out_dtype, vma_axes)

    out = _mapped_over_rows(chunk, (ell.tile_col_idx, ell.bit_tiles),
                            ell.n_tile_rows, row_chunk)
    return out.reshape(-1, d)[: ell.n_rows]


def _spmm_block(col_idx: jax.Array, tiles: jax.Array, x3: jax.Array, t: int,
                out_dtype, vma_axes: tuple = ()) -> jax.Array:
    """SpMM on one ELL slab: accumulated feature tiles [R, t, d]."""
    n_tc, _, d = x3.shape
    K = col_idx.shape[1]

    def step(acc, k):
        cols = col_idx[:, k]
        words = tiles[:, k]
        bits = unpack_tiles(words, t, dtype=x3.dtype)       # [R, t, t]
        xk = x3[jnp.clip(cols, 0, n_tc - 1)]                # [R, t, d]
        xk = jnp.where((cols >= 0)[:, None, None], xk, 0)
        return acc + jnp.einsum("rab,rbd->rad", bits, xk,
                                preferred_element_type=out_dtype), None

    acc0 = jnp.zeros((col_idx.shape[0], t, d), dtype=out_dtype)
    if vma_axes and hasattr(jax.lax, "pvary"):
        # under shard_map the body output varies over the mesh axes;
        # the init carry must be marked varying too (scan-vma rule,
        # jax >= 0.5; older jax has no vma tracking to satisfy)
        acc0 = jax.lax.pvary(acc0, tuple(vma_axes))
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(K))
    return acc


def spmm_b2sr_bucketed(b: B2SRBucketedEll, x: jax.Array,
                       out_dtype=None) -> jax.Array:
    """Bucketed SpMM: each bucket runs with its own static k_b, outputs
    scattered back through the row permutation. Empty tile-rows stay 0."""
    t = b.tile_dim
    n_tc = b.n_tile_cols
    d = x.shape[1]
    out_dtype = out_dtype or x.dtype
    x_pad = jnp.pad(x, ((0, n_tc * t - x.shape[0]), (0, 0)))
    x3 = x_pad.reshape(n_tc, t, d)
    out = jnp.zeros((b.n_tile_rows, t, d), dtype=out_dtype)
    for col, tiles, rows in zip(b.col_idx, b.bit_tiles, b.rows):
        out = out.at[rows].set(_spmm_block(col, tiles, x3, t, out_dtype))
    return out.reshape(-1, d)[: b.n_rows]


# the GNN-facing scheme name (ISSUE 9 / DESIGN.md §15): bin adjacency ×
# full activations → full output is exactly the widened Table II scheme
spmm_bin_full_full = spmm_b2sr
spmm_bin_full_full_bucketed = spmm_b2sr_bucketed


# ---------------------------------------------------------------------------
# SpMM over packed *activation* matrices: bin·bin→full with a wide RHS
# (the fully-binarized BitGNN layer, DESIGN.md §15)
# ---------------------------------------------------------------------------

def _spmm_bbf_block(col_idx: jax.Array, tiles: jax.Array, xw: jax.Array,
                    out_dtype) -> jax.Array:
    """bin·bin→full on one ELL slab against a BitMatrix word array.

    ``xw`` is ``uint32[n_tile_cols, d]`` (:class:`BitMatrix` words: node
    axis tile-packed, one word column per feature). Per output element:
    ``y[i*t+r, j] = Σ_k popcount(tile_word_r(i, k) & xw[col(i, k), j])``
    — the feature-wide generalisation of ``_bmv_bbf_block``, scanned over
    K for bounded memory. Returns counts ``[R, t, d]``.
    """
    n_tc = xw.shape[0]
    K = col_idx.shape[1]

    def step(acc, k):
        cols = col_idx[:, k]                                # [R]
        words = tiles[:, k]                                 # [R, t]
        xk = xw[jnp.clip(cols, 0, n_tc - 1)]                # [R, d]
        xk = jnp.where((cols >= 0)[:, None], xk, jnp.uint32(0))
        counts = _popcount(words[:, :, None] & xk[:, None, :])  # [R, t, d]
        return acc + counts.astype(out_dtype), None

    acc0 = jnp.zeros((col_idx.shape[0], tiles.shape[2], xw.shape[1]),
                     out_dtype)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(K))
    return acc


def spmm_bin_bin_full(ell: B2SREll, xw: jax.Array, out_dtype=jnp.float32,
                      row_chunk: Optional[int] = None) -> jax.Array:
    """BitGNN aggregation (Table II bin·bin→full, widened RHS).

    ``xw``: packed binarized activations ``uint32[n_tile_cols, d]``
    (:class:`~repro.core.operands.BitMatrix` words); returns the dense
    popcount-accumulated counts ``[n_rows, d]`` — the (+, AND) semiring of
    the XNOR formulation. α-scale/sign reconstruction is the caller's
    (``repro.gnn_bit``) affine epilogue, never the kernel's.
    """
    def chunk(col_idx, tiles):
        return _spmm_bbf_block(col_idx, tiles, xw, out_dtype)

    out = _mapped_over_rows(chunk, (ell.tile_col_idx, ell.bit_tiles),
                            ell.n_tile_rows, row_chunk)
    return out.reshape(-1, xw.shape[1])[: ell.n_rows]


def spmm_bin_bin_full_bucketed(b: B2SRBucketedEll, xw: jax.Array,
                               out_dtype=jnp.float32) -> jax.Array:
    """Bucketed BitGNN aggregation: empty tile-rows keep the 0 count."""
    out = jnp.zeros((b.n_tile_rows, b.tile_dim, xw.shape[1]), out_dtype)
    for col, tiles, rows in zip(b.col_idx, b.bit_tiles, b.rows):
        out = out.at[rows].set(_spmm_bbf_block(col, tiles, xw, out_dtype))
    return out.reshape(-1, xw.shape[1])[: b.n_rows]


# ---------------------------------------------------------------------------
# SpMM over packed frontier *matrices*: bin·bin→bin with a wide RHS
# (the engine/ multi-source traversal workhorse, DESIGN.md §9)
# ---------------------------------------------------------------------------

def _spmm_bbb_block(col_idx: jax.Array, tiles: jax.Array, f3: jax.Array,
                    t: int) -> jax.Array:
    """bin·bin→bin on one ELL slab against a packed frontier matrix.

    ``f3`` is ``uint32[n_tile_cols, t, W]`` (``pack_frontier_matrix``):
    source-axis words, node-axis tile grouping. Output word
    ``[i, r, w] = OR_c (A_tile[r, c] ? f3[col, c, w] : 0)`` — the mxm
    AND/shift word algorithm with a dense bit RHS: A's tiles stream once
    for *all* S sources instead of once per source (vs S bmv calls).
    Returns ``uint32[R, t, W]``.
    """
    n_tc = f3.shape[0]
    K = col_idx.shape[1]

    def step(acc, k):
        cols = col_idx[:, k]                                   # [R]
        a_bits = unpack_tiles(tiles[:, k], t, jnp.uint32)      # [R, t(r), t(c)]
        fk = f3[jnp.clip(cols, 0, n_tc - 1)]                   # [R, t(c), W]
        fk = jnp.where((cols >= 0)[:, None, None], fk, jnp.uint32(0))
        contrib = jnp.where((a_bits != 0)[..., None],
                            fk[:, None, :, :], jnp.uint32(0))  # [R, t, t, W]
        return acc | or_reduce_words(contrib, (2,)), None

    acc0 = jnp.zeros((col_idx.shape[0], t, f3.shape[2]), jnp.uint32)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(K))
    return acc


def spmm_bin_bin_bin(ell: B2SREll, f_packed: jax.Array,
                     row_chunk: Optional[int] = None) -> jax.Array:
    """Multi-frontier boolean traversal (Table II bin·bin→bin, widened RHS).

    ``f_packed``: packed frontier matrix ``uint32[n_tile_cols, t, W]``;
    returns the packed next-frontier matrix ``uint32[n_tile_rows, t, W]``.
    Column ``s`` equals ``bmv_bin_bin_bin`` on frontier ``s`` bit-for-bit —
    the batch amortises the A-tile traffic over all S sources.
    """
    def chunk(col_idx, tiles):
        return _spmm_bbb_block(col_idx, tiles, f_packed, ell.tile_dim)
    return _mapped_over_rows(chunk, (ell.tile_col_idx, ell.bit_tiles),
                             ell.n_tile_rows, row_chunk)


def apply_frontier_mask(y: jax.Array, mask_packed: jax.Array,
                        complement: bool) -> jax.Array:
    """AND a packed per-source visited mask into a frontier matrix (§V).

    Shared by every multi-frontier path (jnp, Pallas-bucketed, csr) so the
    mask semantics live in one place — the frontier-matrix twin of
    ``apply_grid_mask``.
    """
    return y & (~mask_packed if complement else mask_packed)


def spmm_bin_bin_bin_masked(ell: B2SREll, f_packed: jax.Array,
                            mask_packed: jax.Array, complement: bool = True,
                            row_chunk: Optional[int] = None) -> jax.Array:
    """Masked multi-frontier traversal (§V mask-at-store): the msBFS kernel.

    ``mask_packed`` has the output layout ``uint32[n_tile_rows, t, W]`` —
    per-source visited sets; ``complement=True`` keeps unvisited bits.
    """
    y = spmm_bin_bin_bin(ell, f_packed, row_chunk)
    return apply_frontier_mask(y, mask_packed, complement)


def spmm_bin_bin_bin_bucketed(b: B2SRBucketedEll,
                              f_packed: jax.Array) -> jax.Array:
    """Bucketed multi-frontier traversal: per-bucket slabs, scatter-merged.

    Empty tile-rows are in no bucket and keep the zero word (OR-identity).
    """
    out = jnp.zeros((b.n_tile_rows, b.tile_dim, f_packed.shape[2]),
                    jnp.uint32)
    for col, tiles, rows in zip(b.col_idx, b.bit_tiles, b.rows):
        out = out.at[rows].set(_spmm_bbb_block(col, tiles, f_packed,
                                               b.tile_dim))
    return out


def spmm_bin_bin_bin_bucketed_masked(b: B2SRBucketedEll, f_packed: jax.Array,
                                     mask_packed: jax.Array,
                                     complement: bool = True) -> jax.Array:
    """Masked bucketed multi-frontier traversal (mask ANDed post-merge, §V)."""
    y = spmm_bin_bin_bin_bucketed(b, f_packed)
    return apply_frontier_mask(y, mask_packed, complement)


def spmm_bin_bin_bin_pull(ell: B2SREll, f_packed: jax.Array,
                          mask_packed: jax.Array, complement: bool = True,
                          row_chunk: Optional[int] = None) -> jax.Array:
    """Pull-direction multi-frontier traversal, jnp twin.

    Same ``_spmm_bbb_block`` math as masked push — see
    :func:`bmv_bin_bin_bin_pull` for why the jnp pull twins share the
    push block (bit-exactness by construction; the early exit lives in
    the Pallas kernel only)."""
    return spmm_bin_bin_bin_masked(ell, f_packed, mask_packed, complement,
                                   row_chunk)


def spmm_bin_bin_bin_pull_bucketed(b: B2SRBucketedEll, f_packed: jax.Array,
                                   mask_packed: jax.Array,
                                   complement: bool = True) -> jax.Array:
    """Bucketed jnp pull twin of the multi-frontier traversal."""
    return spmm_bin_bin_bin_bucketed_masked(b, f_packed, mask_packed,
                                            complement)


# ---------------------------------------------------------------------------
# BMM: bin × bin -> masked scalar sum (the TC kernel, paper Listing 2)
# ---------------------------------------------------------------------------

def bmm_bin_bin_sum_masked(a: B2SREll, b: B2SREll, mask: B2SREll,
                           row_chunk: Optional[int] = None) -> jax.Array:
    """Fused masked BMM (Table III + §V, paper Listing 2): Σ mask ⊙ (A·B).

    For TC: A = L, B = Lᵀ (both in B2SR), mask = L; returns exactly
    Σ_{(r,c): L_rc=1} (L·Lᵀ)_rc, the paper's fused reduction — the scalar
    twin of ``mxm_bin_bin_full_masked`` (sum instead of materialise).

    Per output tile-row i: for each A tile (i, ka) with col a_c, walk B's
    tile-row a_c; each B tile (a_c, j) contributes to C tile (i, j); the mask
    tile (i, j) is found by matching j against mask's row-i col list.
    """
    t = a.tile_dim

    def chunk(a_col, a_tiles, m_col, m_tiles):
        # a_col [R, Ka]; a_tiles [R, Ka, t]; m_col [R, Km]; m_tiles [R, Km, t]
        Ka = a_col.shape[1]

        def step_ka(total, ka):
            ac = a_col[:, ka]                                    # [R]
            a_bits = unpack_tiles(a_tiles[:, ka], t, jnp.float32)  # [R, t, t]
            safe = jnp.clip(ac, 0, b.n_tile_rows - 1)
            b_cols = b.tile_col_idx[safe]                        # [R, Kb]
            b_tls = b.bit_tiles[safe]                            # [R, Kb, t]
            valid_a = (ac >= 0)[:, None]                         # [R, 1]

            def step_kb(tot, kb):
                bc = b_cols[:, kb]                               # [R]
                b_bits = unpack_tiles(b_tls[:, kb], t, jnp.float32)  # [R, t, t]
                # C tile (i, bc) partial product:
                prod = jnp.einsum("rab,rbc->rac", a_bits, b_bits)    # [R, t, t]
                # match bc against mask cols of row i -> mask bits (0 if absent)
                match = (m_col == bc[:, None]) & (m_col >= 0)        # [R, Km]
                m_words = jnp.sum(
                    jnp.where(match[:, :, None], m_tiles, jnp.uint32(0)),
                    axis=1, dtype=jnp.uint32)                        # [R, t]
                m_bits = unpack_tiles(m_words, t, jnp.float32)       # [R, t, t]
                ok = valid_a & (bc >= 0)[:, None]                    # [R, 1]
                contrib = jnp.sum(prod * m_bits, axis=(1, 2))
                return tot + jnp.sum(jnp.where(ok[:, 0], contrib, 0.0)), None

            tot, _ = jax.lax.scan(step_kb, total, jnp.arange(b_cols.shape[1]))
            return tot, None

        tot, _ = jax.lax.scan(step_ka, jnp.float32(0.0), jnp.arange(Ka))
        return tot

    c = _row_chunks(a.n_tile_rows, row_chunk)
    if c == a.n_tile_rows:
        return chunk(a.tile_col_idx, a.bit_tiles, mask.tile_col_idx, mask.bit_tiles)
    nb = a.n_tile_rows // c
    arrays = (a.tile_col_idx, a.bit_tiles, mask.tile_col_idx, mask.bit_tiles)
    reshaped = tuple(x.reshape((nb, c) + x.shape[1:]) for x in arrays)
    partials = jax.lax.map(lambda xs: chunk(*xs), reshaped)
    return jnp.sum(partials)


def bmm_bin_bin_sum(a: B2SREll, b: B2SREll,
                    row_chunk: Optional[int] = None) -> jax.Array:
    """Unmasked Σ (A·B) (Table III reduction): same walk, all-ones mask."""
    t = a.tile_dim

    def chunk(a_col, a_tiles):
        Ka = a_col.shape[1]

        def step_ka(total, ka):
            ac = a_col[:, ka]
            a_counts = _popcount(a_tiles[:, ka])                 # [R, t] row popcounts
            safe = jnp.clip(ac, 0, b.n_tile_rows - 1)
            b_tls = b.bit_tiles[safe]                            # [R, Kb, t]
            b_valid = (b.tile_col_idx[safe] >= 0)                # [R, Kb]
            # Σ_{r,c} (A·B)[r,c] = Σ_r Σ_m A[r,m] * (Σ_c B[m,c])
            b_row_pop = jnp.sum(
                jnp.where(b_valid[:, :, None], _popcount(b_tls), 0),
                axis=1)                                          # [R, t] per m
            a_bits = unpack_tiles(a_tiles[:, ka], t, jnp.float32)  # [R, t, t]
            contrib = jnp.einsum("ram,rm->r", a_bits,
                                 b_row_pop.astype(jnp.float32))
            ok = ac >= 0
            return total + jnp.sum(jnp.where(ok, contrib, 0.0)), None

        tot, _ = jax.lax.scan(step_ka, jnp.float32(0.0), jnp.arange(Ka))
        return tot

    c = _row_chunks(a.n_tile_rows, row_chunk)
    if c == a.n_tile_rows:
        return chunk(a.tile_col_idx, a.bit_tiles)
    nb = a.n_tile_rows // c
    arrays = (a.tile_col_idx, a.bit_tiles)
    reshaped = tuple(x.reshape((nb, c) + x.shape[1:]) for x in arrays)
    partials = jax.lax.map(lambda xs: chunk(*xs), reshaped)
    return jnp.sum(partials)


# ---------------------------------------------------------------------------
# MXM: bin × bin -> bin / full SpGEMM (paper Table III, the headline result)
# ---------------------------------------------------------------------------

def _check_mxm_dims(a: B2SREll, b: B2SREll):
    if a.tile_dim != b.tile_dim:
        raise ValueError(f"tile_dim mismatch: {a.tile_dim} vs {b.tile_dim}")
    if a.n_cols != b.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {a.n_rows}x{a.n_cols}, "
                         f"B is {b.n_rows}x{b.n_cols}")


def mxm_bin_bin_bin(a: B2SREll, b: B2SREll, mask: Optional[B2SREll] = None,
                    complement: bool = False,
                    row_chunk: Optional[int] = None) -> jax.Array:
    """Boolean SpGEMM (Table III row bin·bin→bin): C = A ∨.∧ B, packed output.

    The tile-level AND/shift word algorithm: for output tile (i, j), each
    A tile (i, m) selects B's tile-row m; C's bit-row r ORs in B's word-row
    k for every set bit k of A's word-row r —
    ``c_word[r] = OR_k (A[r, k] ? b_word[k] : 0)`` — the word formulation of
    the paper's shared-memory AND/shift inner loop.

    Returns the packed output tile grid ``uint32[a.n_tile_rows,
    b.n_tile_cols, t]`` (static shape under jit); compress to B2SR with
    ``b2sr.packed_grid_to_b2sr``. With ``mask``, computes C⟨M⟩ (or C⟨¬M⟩
    when ``complement``): the mask is expanded to grid words and ANDed
    before the return — applied right before the store, paper §V.
    """
    _check_mxm_dims(a, b)

    def chunk(a_col, a_tiles):
        return _mxm_bbb_block(a_col, a_tiles, b, a.tile_dim)

    out = _mapped_over_rows(chunk, (a.tile_col_idx, a.bit_tiles),
                            a.n_tile_rows, row_chunk)
    return apply_grid_mask(out, mask, complement)


def apply_grid_mask(grid: jax.Array, mask: Optional[B2SREll],
                    complement: bool) -> jax.Array:
    """AND a structural mask into a packed output grid (§V, before store).

    Shared by the jnp and Pallas-bucketed mxm paths so the mask semantics
    live in exactly one place.
    """
    if mask is None:
        return grid
    mg = ell_to_packed_grid(mask)
    return grid & (~mg if complement else mg)


def _mxm_bbb_block(a_col: jax.Array, a_tiles: jax.Array, b: B2SREll,
                   t: int) -> jax.Array:
    """Boolean SpGEMM for one A-side ELL slab: packed grid [R, n_tc_b, t]."""
    n_tc_b = b.n_tile_cols
    rb = b.tile_col_idx.shape[0]
    R = a_col.shape[0]
    Ka = a_col.shape[1]

    def step(acc, k):
        ac = a_col[:, k]                                     # [R]
        safe = jnp.clip(ac, 0, rb - 1)
        b_cols = b.tile_col_idx[safe]                        # [R, Kb]
        b_tls = b.bit_tiles[safe]                            # [R, Kb, t]
        a_bits = unpack_tiles(a_tiles[:, k], t, jnp.uint32)  # [R, t(r), t(k)]
        # AND/shift: broadcast B's word k where A bit (r, k) is set
        contrib = jnp.where(a_bits[:, None, :, :] != 0,
                            b_tls[:, :, None, :], jnp.uint32(0))
        c_words = or_reduce_words(contrib, (3,))             # [R, Kb, t(r)]
        ok = (ac >= 0)[:, None] & (b_cols >= 0)              # [R, Kb]
        c_words = jnp.where(ok[:, :, None], c_words, jnp.uint32(0))
        cols = jnp.clip(b_cols, 0, n_tc_b - 1)
        # tile-row merge: distinct cols per legal ELL row -> max == OR
        step_grid = jnp.zeros((R, n_tc_b, t), jnp.uint32).at[
            jnp.arange(R)[:, None], cols].max(c_words)
        return acc | step_grid, None

    acc0 = jnp.zeros((R, n_tc_b, t), jnp.uint32)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(Ka))
    return acc


def mxm_bin_bin_bin_bucketed(a: B2SRBucketedEll, b: B2SREll,
                             mask: Optional[B2SREll] = None,
                             complement: bool = False) -> jax.Array:
    """Bucketed boolean SpGEMM: A's tile-rows per-bucket, B stays one ELL.

    Same packed-grid contract as ``mxm_bin_bin_bin``; empty A tile-rows
    produce all-zero grid rows. The mask is ANDed after the scatter-merge —
    still right before the caller's store (§V).
    """
    t = a.tile_dim
    if t != b.tile_dim:
        raise ValueError(f"tile_dim mismatch: {t} vs {b.tile_dim}")
    if a.n_cols != b.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {a.n_rows}x{a.n_cols}, "
                         f"B is {b.n_rows}x{b.n_cols}")
    out = jnp.zeros((a.n_tile_rows, b.n_tile_cols, t), jnp.uint32)
    for col, tiles, rows in zip(a.col_idx, a.bit_tiles, a.rows):
        out = out.at[rows].set(_mxm_bbb_block(col, tiles, b, t))
    return apply_grid_mask(out, mask, complement)


def mxm_bin_bin_full(a: B2SREll, b: B2SREll, out_dtype=jnp.int32,
                     row_chunk: Optional[int] = None) -> jax.Array:
    """Count SpGEMM (Table III row bin·bin→full): C = A +.× B, dense output.

    C[i, j] = |N(i) ∩ N⁻(j)| — the common-neighbour count matrix that
    triangle counting and k-truss consume. Output tiles are accumulated
    densely (scatter-add over tile columns) and returned as the dense
    ``[n_rows, n_cols]`` count matrix.
    """
    _check_mxm_dims(a, b)
    t = a.tile_dim

    def chunk(a_col, a_tiles):
        return _mxm_bbf_block(a_col, a_tiles, b, t)

    grid = _mapped_over_rows(chunk, (a.tile_col_idx, a.bit_tiles),
                             a.n_tile_rows, row_chunk)
    dense = grid.transpose(0, 2, 1, 3).reshape(
        a.n_tile_rows * t, b.n_tile_cols * t)
    return dense[: a.n_rows, : b.n_cols].astype(out_dtype)


def _mxm_bbf_block(a_col: jax.Array, a_tiles: jax.Array, b: B2SREll,
                   t: int) -> jax.Array:
    """Count SpGEMM for one A-side ELL slab: count tiles [R, n_tc_b, t, t]."""
    n_tc_b = b.n_tile_cols
    rb = b.tile_col_idx.shape[0]
    R = a_col.shape[0]
    Ka = a_col.shape[1]

    def step(acc, k):
        ac = a_col[:, k]
        safe = jnp.clip(ac, 0, rb - 1)
        b_cols = b.tile_col_idx[safe]                        # [R, Kb]
        b_tls = b.bit_tiles[safe]                            # [R, Kb, t]
        a_bits = unpack_tiles(a_tiles[:, k], t, jnp.int32)   # [R, t(r), t(m)]
        b_bits = unpack_tiles(b_tls, t, jnp.int32)           # [R, Kb, t(m), t(c)]
        prod = jnp.einsum("ram,rnmc->rnac", a_bits, b_bits,
                          preferred_element_type=jnp.int32)  # [R, Kb, t, t]
        ok = (ac >= 0)[:, None] & (b_cols >= 0)
        prod = jnp.where(ok[:, :, None, None], prod, 0)
        cols = jnp.clip(b_cols, 0, n_tc_b - 1)
        return acc.at[jnp.arange(R)[:, None], cols].add(prod), None

    acc0 = jnp.zeros((R, n_tc_b, t, t), jnp.int32)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(Ka))
    return acc


def mxm_bin_bin_full_bucketed(a: B2SRBucketedEll, b: B2SREll,
                              out_dtype=jnp.int32) -> jax.Array:
    """Bucketed count SpGEMM: dense [n_rows, n_cols] counts, per-bucket k_b."""
    t = a.tile_dim
    if t != b.tile_dim:
        raise ValueError(f"tile_dim mismatch: {t} vs {b.tile_dim}")
    if a.n_cols != b.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {a.n_rows}x{a.n_cols}, "
                         f"B is {b.n_rows}x{b.n_cols}")
    n_tc_b = b.n_tile_cols
    grid = jnp.zeros((a.n_tile_rows, n_tc_b, t, t), jnp.int32)
    for col, tiles, rows in zip(a.col_idx, a.bit_tiles, a.rows):
        grid = grid.at[rows].set(_mxm_bbf_block(col, tiles, b, t))
    dense = grid.transpose(0, 2, 1, 3).reshape(
        a.n_tile_rows * t, n_tc_b * t)
    return dense[: a.n_rows, : b.n_cols].astype(out_dtype)


def mxm_bin_bin_full_masked(a: B2SREll, b: B2SREll, mask: B2SREll,
                            complement: bool = False, out_dtype=jnp.int32,
                            row_chunk: Optional[int] = None) -> jax.Array:
    """Masked count SpGEMM: C⟨M⟩ = A +.× B with a *structural* B2SR mask.

    The fused form ``sum(mxm_bin_bin_full_masked(L, Lᵀ, L))`` is the paper's
    triangle-count reduction (§V, Listing 2); ``bmm_bin_bin_sum_masked``
    is its fully-fused scalar twin.
    """
    counts = mxm_bin_bin_full(a, b, out_dtype, row_chunk)
    return _apply_dense_mask(counts, mask, complement, out_dtype)


def _apply_dense_mask(counts: jax.Array, mask: B2SREll, complement: bool,
                      out_dtype) -> jax.Array:
    t = mask.tile_dim
    mg = ell_to_packed_grid(mask)                               # [R, C, t]
    m_bits = unpack_tiles(mg, t, out_dtype)                     # [R, C, t, t]
    m_dense = m_bits.transpose(0, 2, 1, 3).reshape(
        mg.shape[0] * t, mg.shape[1] * t)[: mask.n_rows, : mask.n_cols]
    keep = (m_dense == 0) if complement else (m_dense != 0)
    return jnp.where(keep, counts, 0)


def mxm_bin_bin_full_masked_bucketed(a: B2SRBucketedEll, b: B2SREll,
                                     mask: B2SREll, complement: bool = False,
                                     out_dtype=jnp.int32) -> jax.Array:
    """Bucketed masked count SpGEMM (tri_count's workhorse on skewed graphs)."""
    counts = mxm_bin_bin_full_bucketed(a, b, out_dtype)
    return _apply_dense_mask(counts, mask, complement, out_dtype)


# ---------------------------------------------------------------------------
# Dispatch-registry entries for the "b2sr" backend (DESIGN.md §10).
#
# Each adapter binds one (op, rhs, out, bucketed, masked) Table II/III row to
# the scheme above. Adapters receive the GraphMatrix (duck-typed: only
# ``.ell`` / ``.buckets()`` are touched — no graphblas import, no cycle), the
# raw right-hand operand, and the normalized :class:`~repro.core.dispatch
# .OpCall`.
# ---------------------------------------------------------------------------

from repro.core.dispatch import apply_output_mask, register  # noqa: E402

# -- mxv: Table II ----------------------------------------------------------

@register("mxv", "dense", "full", "b2sr", bucketed=False, masked=False)
def _mxv_dense(g, x, call):
    return bmv_bin_full_full(g.ell, x, call.semiring, call.a_value,
                             call.row_chunk)


@register("mxv", "dense", "full", "b2sr", bucketed=False, masked=True)
def _mxv_dense_masked(g, x, call):
    return bmv_bin_full_full_masked(g.ell, x, call.mask, call.semiring,
                                    call.a_value, call.complement,
                                    call.row_chunk)


@register("mxv", "dense", "full", "b2sr", bucketed=True, masked=False)
def _mxv_dense_bucketed(g, x, call):
    return bmv_bin_full_full_bucketed(g.buckets(), x, call.semiring,
                                      call.a_value)


@register("mxv", "dense", "full", "b2sr", bucketed=True, masked=True)
def _mxv_dense_bucketed_masked(g, x, call):
    y = bmv_bin_full_full_bucketed(g.buckets(), x, call.semiring,
                                   call.a_value)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxv", "bitvec", "bin", "b2sr", bucketed=False, masked=False)
def _mxv_bitvec(g, xw, call):
    return bmv_bin_bin_bin(g.ell, xw, call.row_chunk)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=False, masked=True)
def _mxv_bitvec_masked(g, xw, call):
    return bmv_bin_bin_bin_masked(g.ell, xw, call.mask, call.complement,
                                  call.row_chunk)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=True, masked=False)
def _mxv_bitvec_bucketed(g, xw, call):
    return bmv_bin_bin_bin_bucketed(g.buckets(), xw)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=True, masked=True)
def _mxv_bitvec_bucketed_masked(g, xw, call):
    return bmv_bin_bin_bin_bucketed_masked(g.buckets(), xw, call.mask,
                                           call.complement)


@register("mxv_pull", "bitvec", "bin", "b2sr", bucketed=False, masked=True)
def _mxv_pull(g, xw, call):
    return bmv_bin_bin_bin_pull(g.ell, xw, call.mask, call.complement,
                                call.row_chunk)


@register("mxv_pull", "bitvec", "bin", "b2sr", bucketed=True, masked=True)
def _mxv_pull_bucketed(g, xw, call):
    return bmv_bin_bin_bin_pull_bucketed(g.buckets(), xw, call.mask,
                                         call.complement)


@register("mxv", "bitvec", "full", "b2sr", bucketed=False, masked=False)
def _mxv_count(g, xw, call):
    return bmv_bin_bin_full(g.ell, xw, call.out_dtype, call.row_chunk)


@register("mxv", "bitvec", "full", "b2sr", bucketed=False, masked=True)
def _mxv_count_masked(g, xw, call):
    return bmv_bin_bin_full_masked(g.ell, xw, call.mask, call.complement,
                                   call.out_dtype, call.row_chunk)


@register("mxv", "bitvec", "full", "b2sr", bucketed=True, masked=False)
def _mxv_count_bucketed(g, xw, call):
    return bmv_bin_bin_full_bucketed(g.buckets(), xw, call.out_dtype)


@register("mxv", "bitvec", "full", "b2sr", bucketed=True, masked=True)
def _mxv_count_bucketed_masked(g, xw, call):
    y = bmv_bin_bin_full_bucketed(g.buckets(), xw, call.out_dtype)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))


# -- mxm: Table III + widened-RHS rows --------------------------------------

@register("mxm", "dense", "full", "b2sr", bucketed=False, masked=False)
def _mxm_dense(g, x, call):
    return spmm_b2sr(g.ell, x, row_chunk=call.row_chunk)


@register("mxm", "dense", "full", "b2sr", bucketed=False, masked=True)
def _mxm_dense_masked(g, x, call):
    y = spmm_b2sr(g.ell, x, row_chunk=call.row_chunk)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "dense", "full", "b2sr", bucketed=True, masked=False)
def _mxm_dense_bucketed(g, x, call):
    return spmm_b2sr_bucketed(g.buckets(), x)


@register("mxm", "dense", "full", "b2sr", bucketed=True, masked=True)
def _mxm_dense_bucketed_masked(g, x, call):
    y = spmm_b2sr_bucketed(g.buckets(), x)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "frontier", "bin", "b2sr", bucketed=False, masked=False)
def _mxm_frontier(g, fw, call):
    return spmm_bin_bin_bin(g.ell, fw, call.row_chunk)


@register("mxm", "frontier", "bin", "b2sr", bucketed=False, masked=True)
def _mxm_frontier_masked(g, fw, call):
    return spmm_bin_bin_bin_masked(g.ell, fw, call.mask, call.complement,
                                   call.row_chunk)


@register("mxm", "frontier", "bin", "b2sr", bucketed=True, masked=False)
def _mxm_frontier_bucketed(g, fw, call):
    return spmm_bin_bin_bin_bucketed(g.buckets(), fw)


@register("mxm", "frontier", "bin", "b2sr", bucketed=True, masked=True)
def _mxm_frontier_bucketed_masked(g, fw, call):
    return spmm_bin_bin_bin_bucketed_masked(g.buckets(), fw, call.mask,
                                            call.complement)


def _bitmat_dtype(call):
    return call.out_dtype if call.out_dtype is not None else jnp.float32


@register("mxm", "bitmat", "full", "b2sr", bucketed=False, masked=False)
def _mxm_bitmat(g, xw, call):
    return spmm_bin_bin_full(g.ell, xw, _bitmat_dtype(call), call.row_chunk)


@register("mxm", "bitmat", "full", "b2sr", bucketed=False, masked=True)
def _mxm_bitmat_masked(g, xw, call):
    y = spmm_bin_bin_full(g.ell, xw, _bitmat_dtype(call), call.row_chunk)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "bitmat", "full", "b2sr", bucketed=True, masked=False)
def _mxm_bitmat_bucketed(g, xw, call):
    return spmm_bin_bin_full_bucketed(g.buckets(), xw, _bitmat_dtype(call))


@register("mxm", "bitmat", "full", "b2sr", bucketed=True, masked=True)
def _mxm_bitmat_bucketed_masked(g, xw, call):
    y = spmm_bin_bin_full_bucketed(g.buckets(), xw, _bitmat_dtype(call))
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm_pull", "frontier", "bin", "b2sr", bucketed=False, masked=True)
def _mxm_pull(g, fw, call):
    return spmm_bin_bin_bin_pull(g.ell, fw, call.mask, call.complement,
                                 call.row_chunk)


@register("mxm_pull", "frontier", "bin", "b2sr", bucketed=True, masked=True)
def _mxm_pull_bucketed(g, fw, call):
    return spmm_bin_bin_bin_pull_bucketed(g.buckets(), fw, call.mask,
                                          call.complement)


@register("mxm", "graph", "bin", "b2sr", bucketed=False)
def _mxm_graph(g, other, call):
    m_ell = call.mask.ell if call.mask is not None else None
    return mxm_bin_bin_bin(g.ell, other.ell, m_ell, call.complement,
                           call.row_chunk)


@register("mxm", "graph", "bin", "b2sr", bucketed=True)
def _mxm_graph_bucketed(g, other, call):
    m_ell = call.mask.ell if call.mask is not None else None
    return mxm_bin_bin_bin_bucketed(g.buckets(), other.ell, m_ell,
                                    call.complement)


@register("mxm", "graph", "full", "b2sr", bucketed=False, masked=False)
def _mxm_graph_count(g, other, call):
    return mxm_bin_bin_full(g.ell, other.ell, row_chunk=call.row_chunk)


@register("mxm", "graph", "full", "b2sr", bucketed=False, masked=True)
def _mxm_graph_count_masked(g, other, call):
    return mxm_bin_bin_full_masked(g.ell, other.ell, call.mask.ell,
                                   call.complement, row_chunk=call.row_chunk)


@register("mxm", "graph", "full", "b2sr", bucketed=True, masked=False)
def _mxm_graph_count_bucketed(g, other, call):
    return mxm_bin_bin_full_bucketed(g.buckets(), other.ell)


@register("mxm", "graph", "full", "b2sr", bucketed=True, masked=True)
def _mxm_graph_count_bucketed_masked(g, other, call):
    return mxm_bin_bin_full_masked_bucketed(g.buckets(), other.ell,
                                            call.mask.ell, call.complement)


# -- mxm_sum: the fused Σ mask ⊙ (A·B) reduction (tri_count, Listing 2) -----

@register("mxm_sum", "tri", "full", "b2sr", bucketed=False, masked=True)
def _tri_sum(g, tri, call):
    counts = mxm_bin_bin_full_masked(tri.ell, tri.ell_t, tri.ell,
                                     row_chunk=call.row_chunk)
    return jnp.sum(counts).astype(jnp.float32)


@register("mxm_sum", "tri", "full", "b2sr", bucketed=True, masked=True)
def _tri_sum_bucketed(g, tri, call):
    counts = mxm_bin_bin_full_masked_bucketed(tri.buckets(), tri.ell_t,
                                              tri.ell)
    return jnp.sum(counts).astype(jnp.float32)


# spmm_b2sr_shardmap moved next to the other shard_map code; re-exported
# here so callers keep one import point for the B2SR SpMM family
from repro.core.ops_sharded import spmm_b2sr_shardmap  # noqa: E402,F401
