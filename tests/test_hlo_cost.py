"""hlo_cost: hierarchical HLO cost model vs XLA cost_analysis ground truth."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compiled(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def _xla_cost(c):
    """cost_analysis() is a dict on new jax, a 1-element list on jax<=0.4."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


class TestPlainOps:
    def test_matmul_flops_match_xla(self):
        a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
        b = jax.ShapeDtypeStruct((512, 256), jnp.float32)
        c = _compiled(lambda a, b: a @ b, a, b)
        rep = analyze_hlo(c.as_text())
        assert rep.flops == pytest.approx(2 * 128 * 256 * 512, rel=0.01)
        assert rep.flops == pytest.approx(_xla_cost(c)["flops"], rel=0.01)

    def test_matmul_bytes_match_xla(self):
        a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
        b = jax.ShapeDtypeStruct((512, 256), jnp.float32)
        c = _compiled(lambda a, b: a @ b, a, b)
        rep = analyze_hlo(c.as_text())
        assert rep.hbm_bytes == pytest.approx(
            _xla_cost(c)["bytes accessed"], rel=0.05)

    def test_batched_dot_contracting_dims(self):
        a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
        c = _compiled(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
        rep = analyze_hlo(c.as_text())
        assert rep.flops == pytest.approx(2 * 4 * 32 * 16 * 64, rel=0.01)


class TestLoopMultipliers:
    def test_scan_multiplies_body_flops(self):
        L, D = 7, 128

        def g(x, ws):
            def body(h, w):
                return h @ w, ()
            h, _ = jax.lax.scan(body, x, ws)
            return h

        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        c = _compiled(g, x, ws)
        rep = analyze_hlo(c.as_text())
        model = L * 2 * D ** 3
        assert rep.flops == pytest.approx(model, rel=0.05)
        # and XLA's aggregate is the known undercount (body counted once)
        assert _xla_cost(c)["flops"] < 0.5 * model

    def test_scan_bytes_count_slices_not_stacks(self):
        # the loop body receives the full [L, D, D] stack; per-iteration
        # traffic must be one [D, D] slice, so total ≈ L × slice, not L × stack
        L, D = 16, 256

        def g(x, ws):
            def body(h, w):
                return h @ w, ()
            h, _ = jax.lax.scan(body, x, ws)
            return h

        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        c = _compiled(g, x, ws)
        rep = analyze_hlo(c.as_text())
        stack_bytes = L * D * D * 4
        # generous bound: well under L × stack (the naive accounting).
        # per-iteration traffic must scale with the slice; the one-time
        # while-boundary tuple (carry + stack in/out) is real and allowed.
        assert rep.hbm_bytes < 3 * L * (3 * D * D * 4) + 3 * stack_bytes
        assert rep.hbm_bytes >= stack_bytes  # at least reads every slice once

    def test_unannotated_while_reported(self):
        def g(x):
            def cond(state):
                return state[1] < state[0] * 0  # data-dependent-ish

            def body(state):
                x, i = state
                return (x @ x, i + 1)

            out, _ = jax.lax.while_loop(
                lambda s: s[1] < 5, lambda s: (s[0] * 1.0, s[1] + 1),
                (x, 0))
            return out

        x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
        c = _compiled(g, x)
        rep = analyze_hlo(c.as_text())
        # dynamic-trip while either annotated or flagged — never silently 0
        assert rep.unannotated_whiles >= 0


class TestCollectives:
    def test_psum_wire_bytes(self):
        n = len(jax.devices())
        if n < 2:
            pytest.skip("needs >1 device")

    def test_wire_bytes_zero_without_collectives(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = _compiled(lambda a: a * 2.0, a)
        rep = analyze_hlo(c.as_text())
        assert rep.wire_bytes == 0.0
