"""Shard-scaling sweep: the sharded dispatch path across mesh widths.

The sharded layer's claim (DESIGN.md §11): a row-partitioned graph runs
every Table II/III row under one ``jax.shard_map`` with a single tiled
all-gather per op, so a whole query batch is served per iteration by one
mesh. This sweep measures the batched engine (msBFS) and the single-shot
kernel rows (packed mxv, SpMM) across **shard count × skew × batch
width**, against the unsharded twin on the same graph, and records each
partition's balance / edge-cut stats next to the timings.

On this container the devices are forced-host *virtual* CPUs sharing one
socket, so sharded wall-clock includes real collective overhead but no
real parallel speedup — the numbers validate dispatch overhead and the
partition quality accounting; the speedup story is the roofline's. On a
single-device run (no ``XLA_FLAGS=--xla_force_host_platform_device_count``)
the sweep degrades to shard counts that fit (i.e. 1) and says so in the
JSON. The multi-device CI job runs this with 8 virtual devices.

``results/scaling_shards.json`` records the full detail.
"""

from __future__ import annotations

from typing import List

import jax
import numpy as np

from benchmarks.common import BenchRow, save_json, time_fn
from repro.core import GraphMatrix
from repro.data import graphs as G
from repro.engine import PlanCache, queries


def _mesh(n_devices: int):
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:n_devices]).reshape(n_devices)
    return Mesh(devs, ("data",))


def _graph(n: int, skew: int, tile_dim: int, seed: int) -> GraphMatrix:
    rows, cols = G.rmat_graph(n, avg_degree=4 + 2 * skew, seed=seed)
    return GraphMatrix.from_dense(
        _densify(rows, cols, n), tile_dim=tile_dim)


def _densify(rows, cols, n):
    d = np.zeros((n, n), np.uint8)
    d[rows % n, cols % n] = 1
    return d


def run(tiny: bool = False) -> List[BenchRow]:
    n_dev = len(jax.devices())
    shard_counts = [p for p in (1, 2, 4, 8) if p <= n_dev]
    n = 512 if tiny else 2048
    skews = (1, 8) if tiny else (1, 4, 16)
    widths = (32,) if tiny else (32, 256)
    t = 8

    rows_out: List[BenchRow] = []
    detail = {"n": n, "n_devices": n_dev, "shard_counts": shard_counts,
              "cases": []}
    from repro.core import BitVector
    for skew in skews:
        g = _graph(n, skew, t, seed=skew)
        rng = np.random.default_rng(skew)
        x_bv = BitVector.pack(
            jax.numpy.asarray(rng.random(n) > 0.5), t)
        X = jax.numpy.asarray(rng.random((n, 16)).astype(np.float32))
        for p in shard_counts:
            gg = g if p == 1 and n_dev == 1 else g.shard(_mesh(p))
            part = gg.partitioned
            case = {
                "skew": skew, "shards": p,
                "balance": part.balance() if part else 1.0,
                "edge_cut": part.edge_cut() if part else 0.0,
            }
            # kernel rows: packed mxv + feature SpMM (jit to strip the
            # python dispatch layer from the measurement)
            mxv = jax.jit(lambda v: gg.mxv(v).words)
            spmm = jax.jit(lambda m: gg.mxm(m))
            case["mxv_us"] = time_fn(mxv, x_bv) * 1e6
            case["spmm_us"] = time_fn(spmm, X) * 1e6
            # the engine path: one mesh serves the whole batch
            for s in widths:
                pc = PlanCache()
                srcs = np.arange(s) % n
                queries.msbfs(gg, srcs, planner=pc)      # compile plan
                sec = time_fn(lambda: queries.msbfs(gg, srcs, planner=pc))
                case[f"msbfs{s}_us_per_query"] = sec * 1e6 / s
                rows_out.append(BenchRow(
                    f"scaling/skew{skew}/p{p}/msbfs{s}",
                    sec * 1e6 / s,
                    f"balance={case['balance']:.2f} "
                    f"cut={case['edge_cut']:.2f}"))
            rows_out.append(BenchRow(
                f"scaling/skew{skew}/p{p}/mxv", case["mxv_us"],
                f"spmm_us={case['spmm_us']:.1f}"))
            detail["cases"].append(case)
    path = save_json("scaling_shards.json", detail)
    rows_out.append(BenchRow("scaling/json", 0.0, path))
    return rows_out
