"""BitGNN: binary GNN inference and training on the bit path (DESIGN.md §15).

``binarize`` — straight-through-estimator binarization, per-feature α
scales, and activation packing into :class:`~repro.core.operands.BitMatrix`
words. ``layers`` — registry-dispatched aggregation over a B2SR adjacency:
the float GCN hot path (``spmm_bin_full_full``), the fully packed
bin·bin→full path (``spmm_bin_bin_full``), and the XNOR-style
α·popcount reconstruction of ±1 aggregation.
"""

from repro.gnn_bit import binarize, layers  # noqa: F401
