"""Batched multi-source query engine (DESIGN.md §9).

Turns "millions of users each asking a reachability/ranking question" into
a handful of wide bit-matrix launches: frontier matrices (``queries``),
jitted launch-plan caching (``planner``), and request coalescing
(``batcher``).
"""

from repro.engine.batcher import (BatchFlushError, QueryBatcher,  # noqa: F401
                                  QueryGroupError, QueryHandle)
from repro.engine.planner import (DEFAULT_PLANNER, Plan, PlanCache,  # noqa: F401
                                  PlanKey, plan_key)
from repro.engine.queries import (BatchedPPRResult, MSBFSResult,  # noqa: F401
                                  MSSSSPResult, batched_ppr, ms_sssp,
                                  msbfs, mskhop)
