"""End-to-end telemetry: registry, trace spans, cost accounting (§14).

Covers the observability layer at three depths: the instruments alone
(label semantics, histogram quantiles, Prometheus round-trip, span
nesting), the instrumented serving stack (a real bfs query whose handle
trace covers queue-wait → plan-resolve → launch → scatter-back and whose
span time agrees with observed latency within 10%), and the disable
switch (the whole pipeline runs with observability off, recording
nothing). The dispatch observe hook is checked to fire *even when* the
fault-injector resolve hook aborts the resolution — injected faults land
in the registry like real ones.
"""

import json

import numpy as np
import pytest

from repro.algorithms import direction as direction_mod
from repro.core import GraphMatrix
from repro.engine import (CircuitBreaker, FaultInjector, GraphQueryServer,
                          PlanCache, ServerConfig, msbfs, plan_key)
from repro.engine.server import CLOSED, HALF_OPEN, OPEN
from repro.obs import cost as obs_cost
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs import disabled


def build(n=64, t=8, backend="b2sr", seed=0):
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n, dtype=np.int64), 4)
    cols = rng.integers(0, n, rows.size)
    return GraphMatrix.from_coo(rows, cols, n, n, tile_dim=t,
                                backend=backend)


@pytest.fixture(autouse=True)
def fresh_registry():
    """Every test runs against its own registry (and leaves obs enabled)."""
    reg = obs_metrics.MetricsRegistry()
    prev = obs_metrics.set_registry(reg)
    prev_enabled = obs_metrics.set_enabled(True)
    yield reg
    obs_metrics.set_enabled(prev_enabled)
    obs_metrics.set_registry(prev)


# ---------------------------------------------------------------------------
# metrics registry: label semantics, histograms, export round-trip
# ---------------------------------------------------------------------------

def test_counter_label_semantics(fresh_registry):
    c = fresh_registry.counter("reqs_total", "requests", ("kind",))
    c.inc(kind="bfs")
    c.inc(2, kind="bfs")
    c.inc(kind="ppr")
    assert c.value(kind="bfs") == 3 and c.value(kind="ppr") == 1
    with pytest.raises(ValueError):
        c.inc()                              # missing label
    with pytest.raises(ValueError):
        c.inc(kind="bfs", extra="x")         # unknown label
    with pytest.raises(ValueError):
        c.inc(-1, kind="bfs")                # counters are monotonic
    # label identity is textual: True and "True" are the same series
    c2 = fresh_registry.counter("flags_total", "", ("on",))
    c2.inc(on=True)
    c2.inc(on="True")
    assert c2.value(on=True) == 2


def test_registry_schema_conflicts(fresh_registry):
    fresh_registry.counter("m", "", ("a",))
    with pytest.raises(ValueError):
        fresh_registry.counter("m", "", ("b",))       # different labels
    with pytest.raises(ValueError):
        fresh_registry.gauge("m", "")                 # different type
    # identical re-registration is get-or-create
    assert fresh_registry.counter("m", "", ("a",)) is fresh_registry.get("m")


def test_histogram_quantiles_and_buckets(fresh_registry):
    h = fresh_registry.histogram("lat_s", "latency", ("op",),
                                 buckets=(0.1, 1.0, 10.0))
    for v in range(1, 101):
        h.observe(float(v), op="bfs")
    assert h.count(op="bfs") == 100
    assert h.total(op="bfs") == sum(range(1, 101))
    assert h.quantile(0.0, op="bfs") == 1.0
    assert h.quantile(1.0, op="bfs") == 100.0
    assert h.quantile(0.5, op="bfs") == 51.0
    assert h.quantile(0.5, op="nope") is None
    with pytest.raises(ValueError):
        h.quantile(1.5, op="bfs")
    snap = fresh_registry.snapshot()["histograms"]["lat_s"]
    series = snap['{op="bfs"}']
    # cumulative Prometheus buckets: le=1 holds 1, +Inf holds everything
    assert series["buckets"]["1.0"] == 1
    assert series["buckets"]["10.0"] == 10
    assert series["buckets"]["+Inf"] == 100
    assert series["p50"] == 51.0


def test_prometheus_round_trip(fresh_registry):
    fresh_registry.counter("a_total", "as", ("k",)).inc(3, k="x")
    fresh_registry.gauge("depth", "queue").set(7)
    h = fresh_registry.histogram("d_s", "dur", ("op",), buckets=(1.0, 5.0))
    h.observe(0.5, op="bfs")
    h.observe(2.0, op="bfs")
    text = fresh_registry.to_prometheus()
    parsed = obs_export.parse_prometheus(text)
    assert parsed["a_total"]['{k="x"}'] == 3
    assert parsed["depth"][""] == 7
    assert parsed["d_s_count"]['{op="bfs"}'] == 2
    assert parsed["d_s_sum"]['{op="bfs"}'] == 2.5
    assert parsed["d_s_bucket"]['{op="bfs",le="1.0"}'] == 1
    assert parsed["d_s_bucket"]['{op="bfs",le="+Inf"}'] == 2
    # second export parses to the same table: the format is stable
    assert obs_export.parse_prometheus(fresh_registry.to_prometheus()) \
        == parsed


def test_write_metrics_formats(fresh_registry, tmp_path):
    fresh_registry.counter("n_total", "").inc(5)
    jpath = obs_export.write_metrics(str(tmp_path / "m.json"),
                                     fresh_registry)
    assert json.load(open(jpath))["counters"]["n_total"][""] == 5
    ppath = obs_export.write_metrics(str(tmp_path / "m.prom"),
                                     fresh_registry)
    assert obs_export.parse_prometheus(open(ppath).read())["n_total"][""] \
        == 5


def test_event_log_bounded(fresh_registry):
    for i in range(5):
        fresh_registry.event("tick", i=i)
    assert [e["i"] for e in fresh_registry.events("tick")] == list(range(5))
    assert fresh_registry.events("other") == []


# ---------------------------------------------------------------------------
# trace spans: nesting, attrs, exclusive time, error stamping
# ---------------------------------------------------------------------------

def test_span_nesting_and_exclusive_time():
    tr = obs_trace.Trace("t")
    with tr.span("outer", who="me") as outer:
        with tr.span("inner") as inner:
            inner.set(deep=True)
    assert tr.span_names() == ["outer", "inner"]
    assert outer.children == [inner]
    assert inner.attrs == {"deep": True} and outer.attrs == {"who": "me"}
    assert outer.duration_s >= inner.duration_s
    assert abs(outer.exclusive_s
               - (outer.duration_s - inner.duration_s)) < 1e-9
    # summing exclusive time over the trace never double-counts
    assert abs(tr.total_exclusive_s() - outer.duration_s) < 1e-9
    d = tr.to_dict()
    assert d["spans"][0]["spans"][0]["name"] == "inner"


def test_span_error_stamped():
    tr = obs_trace.Trace("t")
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("kaput")
    span, = tr.find("boom")
    assert "kaput" in span.attrs["error"] and span.end_s is not None


def test_ambient_current_trace():
    tr = obs_trace.Trace("t")
    assert obs_trace.current() is None
    # no ambient trace -> the shared no-op span, not an error
    assert obs_trace.current_span("x") is obs_trace.NOOP_SPAN
    with obs_trace.use(tr):
        assert obs_trace.current() is tr
        with obs_trace.current_span("stage"):
            obs_trace.annotate(tagged=True)
    assert obs_trace.current() is None
    span, = tr.find("stage")
    assert span.attrs == {"tagged": True}


# ---------------------------------------------------------------------------
# the instrumented serving stack
# ---------------------------------------------------------------------------

def test_served_bfs_trace_covers_latency(fresh_registry):
    """The ISSUE acceptance check: one bfs through the server yields a
    trace whose spans name every pipeline stage, tag the plan-cache
    verdict, and whose exclusive time sums to the observed latency
    within 10%."""
    import time

    srv = GraphQueryServer(planner=PlanCache())
    g = build(backend="b2sr")
    t0 = time.monotonic()
    h = srv.bfs(g, 0)
    h.result()
    observed = h.completed_at - t0
    tr = h.trace
    assert tr is not None
    names = set(tr.span_names())
    assert {"submit", "queue_wait", "launch", "plan_resolve",
            "scatter_back"} <= names
    resolve, = tr.find("plan_resolve")
    assert resolve.attrs["cache_hit"] is False       # cold cache: a miss
    launch, = tr.find("launch")
    assert resolve in launch.children                # resolve nests in launch
    assert launch.attrs["first_call"] is True        # compile paid here
    covered = tr.total_exclusive_s()
    assert abs(covered - observed) <= 0.10 * observed, (covered, observed)
    assert tr.attrs["backend_used"] == "b2sr"
    assert tr.attrs["degraded"] is False

    # a second identical query is a cache hit, tagged as such
    h2 = srv.bfs(g, 1)
    h2.result()
    assert any(s.attrs.get("cache_hit") for s in h2.trace.find(
        "plan_resolve"))

    # and the registry saw the whole thing
    snap = fresh_registry.snapshot()
    assert sum(snap["counters"]["plan_cache_misses_total"].values()) == 1
    assert sum(snap["counters"]["plan_cache_hits_total"].values()) == 1
    assert sum(snap["counters"]["server_queries_completed_total"]
               .values()) == 2
    lat = snap["histograms"]["launch_latency_s"]
    assert sum(s["count"] for s in lat.values()) == 2


def test_server_stats_aggregates_everything(fresh_registry):
    srv = GraphQueryServer(planner=PlanCache())
    g = build(backend="csr")
    srv.bfs(g, 0).result()
    # historical dict access still works...
    assert srv.stats["completed"] == 1
    # ...and the callable form aggregates the whole stack
    snap = srv.stats()
    assert snap["counters"]["completed"] == 1
    assert snap["queue_depth"] == 0
    assert snap["plan_cache"]["misses"] == 1
    assert "bfs/csr" in snap["breakers"]
    assert snap["graphs"] == 1 and snap["traces_held"] == 1
    assert fresh_registry.gauge("server_queue_depth",
                                "pending").value() == 0


def test_dump_traces_jsonl(fresh_registry, tmp_path):
    srv = GraphQueryServer(planner=PlanCache())
    g = build(backend="csr")
    for s in (0, 1, 2):
        srv.bfs(g, s)
    srv.flush()
    path = str(tmp_path / "traces.jsonl")
    assert srv.dump_traces(path) == 3
    rows = [json.loads(line) for line in open(path)]
    assert len(rows) == 3
    assert all("queue_wait" in [s["name"] for s in r["spans"]]
               for r in rows)
    # the buffer drained: a second dump writes nothing new
    assert srv.dump_traces(path) == 0


def test_breaker_transitions_recorded():
    clk = [100.0]
    calls = []
    br = CircuitBreaker(fail_threshold=2, cooldown_s=1.0,
                        clock=lambda: clk[0],
                        on_transition=lambda o, n, ts: calls.append(
                            (o, n, ts)))
    br.record_failure()
    assert br.state == CLOSED and br.transitions == []
    br.record_failure()                      # threshold: open
    clk[0] = 102.0
    assert br.allow()                        # cooldown passed: half-open
    br.record_success()                      # probe ok: closed
    assert [(o, n) for _, o, n in br.transitions] == [
        (CLOSED, OPEN), (OPEN, HALF_OPEN), (HALF_OPEN, CLOSED)]
    assert calls == [(CLOSED, OPEN, 100.0), (OPEN, HALF_OPEN, 102.0),
                     (HALF_OPEN, CLOSED, 102.0)]
    st = br.stats()
    assert st["state"] == CLOSED
    assert st["state_counts"] == {CLOSED: 2, OPEN: 1, HALF_OPEN: 1}


def test_server_breaker_events_reach_registry(fresh_registry):
    inj = FaultInjector(seed=0).fail(op="bfs", backend="b2sr",
                                     script=[True, True])
    srv = GraphQueryServer(
        planner=PlanCache(),
        config=ServerConfig(fail_threshold=2, max_retries=0,
                            backoff_base_s=0.0),
        fault_injector=inj, sleep=lambda s: None)
    g = build(backend="b2sr")
    for s in (0, 1):                         # two b2sr faults: breaker opens
        h = srv.bfs(g, s)
        srv.flush()
        assert h.result() is not None        # csr fallback answered
        assert h.degraded and h.backend_used == "csr"
    assert srv.breaker("bfs", "b2sr").state == OPEN
    assert srv.stats()["breakers"]["bfs/b2sr"]["n_opens"] == 1
    snap = fresh_registry.snapshot()
    assert snap["counters"]["server_breaker_transitions_total"][
        '{kind="bfs",backend="b2sr",to="open"}'] == 1
    assert fresh_registry.gauge(
        "server_breaker_state", "0=closed 1=half_open 2=open",
        ("kind", "backend")).value(kind="bfs", backend="b2sr") == 2
    ev, = fresh_registry.events("breaker_transition")
    assert (ev["from_state"], ev["to_state"]) == (CLOSED, OPEN)


def test_observe_hook_fires_when_resolve_hook_faults(fresh_registry):
    """Hook ordering: the fault injector aborts the resolution through the
    resolve hook, and the observe hook still records that abort."""
    inj = FaultInjector(seed=0).fail(script=[True])   # every op/backend
    with inj:
        with pytest.raises(Exception) as ei:
            msbfs(build(backend="b2sr", seed=3), [0, 1],
                  planner=PlanCache())
        assert "injected fault" in str(ei.value)
    snap = fresh_registry.snapshot()
    faults = snap["counters"]["dispatch_faults_total"]
    assert sum(faults.values()) == 1
    assert all('error="InjectedFault"' in k for k in faults)
    ev, = fresh_registry.events("dispatch_fault")
    assert "injected fault" in ev["error"]


def test_dispatch_resolves_counted(fresh_registry):
    msbfs(build(backend="b2sr", seed=4), [0], planner=PlanCache())
    snap = fresh_registry.snapshot()
    assert sum(snap["counters"]["dispatch_resolves_total"].values()) >= 1
    assert sum(s["count"] for s in
               snap["histograms"]["dispatch_resolve_s"].values()) >= 1


# ---------------------------------------------------------------------------
# direction-switch telemetry
# ---------------------------------------------------------------------------

def test_direction_observe_trace(fresh_registry):
    direction_mod.observe_trace(("push", "pull", "pull", "push"),
                                kernel="bfs")
    iters = fresh_registry.counter("traversal_iterations_total", "",
                                   ("direction", "kernel"))
    assert iters.value(direction="push", kernel="bfs") == 2
    assert iters.value(direction="pull", kernel="bfs") == 2
    switches = fresh_registry.counter("direction_switches_total", "",
                                      ("transition",))
    assert switches.value(transition="push->pull") == 1
    assert switches.value(transition="pull->push") == 1
    evs = fresh_registry.events("direction_switch")
    assert [(e["iteration"], e["transition"]) for e in evs] == [
        (1, "push->pull"), (3, "pull->push")]
    # traversals report into the registry end to end
    msbfs(build(backend="b2sr", seed=5), [0], planner=PlanCache())
    assert iters.value(direction="push", kernel="msbfs") >= 1


# ---------------------------------------------------------------------------
# kernel cost accounting
# ---------------------------------------------------------------------------

def test_plan_cost_accounting_and_roofline(fresh_registry):
    prev = obs_cost.set_cost_accounting(True)
    try:
        pc = PlanCache()
        g = build(backend="b2sr", seed=6)
        msbfs(g, [0, 1], planner=pc)
        key = pc.keys()[0]
        assert key == plan_key(g, "msbfs", 32, desc=key.desc)
        plan = pc.get(key, lambda: None)
        assert plan.cost is not None
        assert plan.cost["flops"] > 0
        assert plan.cost["compile_s"] > 0
        snap = fresh_registry.snapshot()
        assert snap["gauges"]["plan_flops"]
        rows = obs_cost.roofline_table(fresh_registry)
        row, = [r for r in rows if r["op"] == "msbfs"]
        assert row["n_launches"] >= 1
        assert row["achieved_flops_s"] > 0
    finally:
        obs_cost.set_cost_accounting(prev)


def test_cost_accounting_off_by_default(fresh_registry):
    pc = PlanCache()
    msbfs(build(backend="b2sr", seed=7), [0], planner=pc)
    plan = pc.get(pc.keys()[0], lambda: None)
    assert plan.cost is None
    assert fresh_registry.get("plan_flops") is None


# ---------------------------------------------------------------------------
# the disable switch: no traces, no series, no-op spans
# ---------------------------------------------------------------------------

def test_disabled_mode_records_nothing(fresh_registry):
    with disabled():
        assert not obs_metrics.enabled()
        assert obs_trace.new_trace() is None
        tr = obs_trace.Trace("manual")
        assert tr.span("x") is obs_trace.NOOP_SPAN
        assert obs_trace.current_span("x") is obs_trace.NOOP_SPAN
        # the whole serving pipeline still answers correctly
        srv = GraphQueryServer(planner=PlanCache())
        g = build(backend="csr", seed=8)
        h = srv.bfs(g, 0)
        levels = np.asarray(h.result())
        assert levels[0] == 0
        assert h.trace is None
        assert len(srv.trace_log) == 0
        # plain-dict stats still count (they are not registry-backed)
        assert srv.stats["completed"] == 1
        assert srv.stats()["plan_cache"]["misses"] == 1
    assert obs_metrics.enabled()
    snap = fresh_registry.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}
    assert snap["events"] == []
