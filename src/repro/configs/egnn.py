"""egnn [arXiv:2102.09844]: 4L d=64 E(n)-equivariant message passing."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="egnn",
    family="egnn",
    n_layers=4,
    d_hidden=64,
    aggregator="sum",
    equivariance="E(n)",
    d_in=16,
    n_classes=8,
)


def reduced() -> GNNConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, name="egnn-smoke", n_layers=2,
                               d_hidden=16, d_in=4, n_classes=2)
