"""B2SR SpGEMM (mxm) vs the dense boolean-matmul oracle.

Covers the Table III bin·bin→bin scheme (packed B2SR output) and the
bin·bin→full count variant, across all tile dims, all three GraphMatrix
backends, masked/complement forms, the Pallas kernel vs its ref oracle,
the packing helpers, tri_count-via-mxm, and k-hop reachability.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import (
    TILE_DIMS, GraphMatrix, b2sr_to_coo, b2sr_to_dense, coo_to_b2sr,
    dense_to_b2sr, ell_to_packed_grid, pack_tile_bits, packed_grid_to_b2sr,
    to_ell, unpack_tiles,
)
from repro.core import csr as csr_mod
from repro.core import ops
from repro.kernels.spgemm import ops as spgemm_ops, ref as spgemm_ref


def random_dense(n, m, density, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n, m)) < density).astype(np.uint8)


def dense_bool_matmul(a, b):
    return (a.astype(np.int64) @ b.astype(np.int64) > 0).astype(np.uint8)


def grid_to_dense(grid, n, m):
    return b2sr_to_dense(packed_grid_to_b2sr(np.asarray(grid), n, m))


# ---------------------------------------------------------------------------
# packing / accumulation helpers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
def test_pack_tile_bits_roundtrip(t):
    d = random_dense(3 * t, 2 * t, 0.3, seed=t)
    mat = dense_to_b2sr(d, t)
    bits = unpack_tiles(mat.bit_tiles, t, jnp.uint32)
    assert np.array_equal(np.asarray(pack_tile_bits(bits, t)),
                          np.asarray(mat.bit_tiles))


@pytest.mark.parametrize("t", TILE_DIMS)
def test_ell_grid_roundtrip(t):
    d = random_dense(70, 50, 0.08, seed=t)
    mat = dense_to_b2sr(d, t)
    grid = ell_to_packed_grid(to_ell(mat))
    back = packed_grid_to_b2sr(np.asarray(grid), 70, 50)
    assert np.array_equal(b2sr_to_dense(back), d)
    assert back.nnz == int(d.sum())


@pytest.mark.parametrize("t", TILE_DIMS)
def test_b2sr_to_coo_roundtrip(t):
    d = random_dense(45, 61, 0.1, seed=t + 1)
    rows, cols = b2sr_to_coo(dense_to_b2sr(d, t))
    back = np.zeros_like(d)
    back[rows, cols] = 1
    assert np.array_equal(back, d)


# ---------------------------------------------------------------------------
# core mxm schemes vs dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("n,k,m,density", [(30, 40, 50, 0.15),
                                           (64, 64, 64, 0.05),
                                           (17, 33, 9, 0.3)])
def test_mxm_bin_bin_bin(t, n, k, m, density):
    da = random_dense(n, k, density, seed=n + t)
    db = random_dense(k, m, density, seed=m + t)
    grid = ops.mxm_bin_bin_bin(to_ell(dense_to_b2sr(da, t)),
                               to_ell(dense_to_b2sr(db, t)))
    assert np.array_equal(grid_to_dense(grid, n, m), dense_bool_matmul(da, db))


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("complement", [False, True])
def test_mxm_bin_bin_bin_masked(t, complement):
    da = random_dense(40, 56, 0.12, seed=t)
    db = random_dense(56, 40, 0.12, seed=2 * t)
    dm = random_dense(40, 40, 0.4, seed=3 * t)
    grid = ops.mxm_bin_bin_bin(
        to_ell(dense_to_b2sr(da, t)), to_ell(dense_to_b2sr(db, t)),
        mask=to_ell(dense_to_b2sr(dm, t)), complement=complement)
    want = dense_bool_matmul(da, db) * (1 - dm if complement else dm)
    assert np.array_equal(grid_to_dense(grid, 40, 40), want)


@pytest.mark.parametrize("t", TILE_DIMS)
def test_mxm_bin_bin_full_counts(t):
    da = random_dense(35, 42, 0.2, seed=t)
    db = random_dense(42, 28, 0.2, seed=t + 5)
    ea, eb = to_ell(dense_to_b2sr(da, t)), to_ell(dense_to_b2sr(db, t))
    counts = ops.mxm_bin_bin_full(ea, eb)
    want = da.astype(np.int64) @ db.astype(np.int64)
    assert np.array_equal(np.asarray(counts), want)
    assert np.array_equal(np.asarray(spgemm_ref.mxm_counts(ea, eb)), want)


@pytest.mark.parametrize("t", [4, 16])
@pytest.mark.parametrize("complement", [False, True])
def test_mxm_bin_bin_full_masked(t, complement):
    da = random_dense(32, 32, 0.2, seed=t)
    dm = random_dense(32, 32, 0.5, seed=t + 9)
    counts = ops.mxm_bin_bin_full_masked(
        to_ell(dense_to_b2sr(da, t)), to_ell(dense_to_b2sr(da, t)),
        to_ell(dense_to_b2sr(dm, t)), complement=complement)
    keep = (1 - dm) if complement else dm
    want = (da.astype(np.int64) @ da.astype(np.int64)) * keep
    assert np.array_equal(np.asarray(counts), want)


@pytest.mark.parametrize("t", [8, 32])
def test_mxm_row_chunked(t):
    da = random_dense(4 * t, 4 * t, 0.1, seed=t)
    ea = to_ell(dense_to_b2sr(da, t))
    full = ops.mxm_bin_bin_bin(ea, ea)
    chunked = ops.mxm_bin_bin_bin(ea, ea, row_chunk=2)
    assert np.array_equal(np.asarray(full), np.asarray(chunked))


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("n,density", [(30, 0.15), (64, 0.05)])
def test_spgemm_kernel_vs_ref(t, n, density):
    da = random_dense(n, n, density, seed=n + t)
    db = random_dense(n, n, density, seed=n + t + 1)
    ea, eb = to_ell(dense_to_b2sr(da, t)), to_ell(dense_to_b2sr(db, t))
    got = spgemm_ops.mxm(ea, eb)
    want = spgemm_ref.mxm(ea, eb)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t", [4, 32])
@pytest.mark.parametrize("complement", [False, True])
def test_spgemm_kernel_masked(t, complement):
    da = random_dense(40, 40, 0.1, seed=t)
    dm = random_dense(40, 40, 0.4, seed=t + 2)
    ea = to_ell(dense_to_b2sr(da, t))
    em = to_ell(dense_to_b2sr(dm, t))
    got = spgemm_ops.mxm(ea, ea, mask=em, complement=complement)
    want = spgemm_ref.mxm(ea, ea, mask=em, complement=complement)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_spgemm_dim_mismatch_raises():
    ea = to_ell(dense_to_b2sr(random_dense(8, 8, 0.3, 0), 4))
    eb = to_ell(dense_to_b2sr(random_dense(12, 8, 0.3, 1), 4))
    with pytest.raises(ValueError):
        spgemm_ops.mxm(ea, eb)
    with pytest.raises(ValueError):
        ops.mxm_bin_bin_bin(ea, eb)


# ---------------------------------------------------------------------------
# GraphMatrix.mxm across backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("backend", ["b2sr", "b2sr_pallas", "csr"])
def test_graphmatrix_mxm_backends(t, backend):
    d = random_dense(60, 60, 0.08, seed=t)
    g = GraphMatrix.from_dense(d, t, backend=backend)
    c = g.mxm()
    got = (csr_mod.to_dense(c.csr) > 0).astype(np.uint8)
    assert np.array_equal(got, dense_bool_matmul(d, d))
    assert c.backend == backend
    assert c.tile_dim == t


@pytest.mark.parametrize("backend", ["b2sr", "b2sr_pallas", "csr"])
@pytest.mark.parametrize("complement", [False, True])
def test_graphmatrix_mxm_masked(backend, complement):
    t = 8
    d = random_dense(48, 48, 0.1, seed=11)
    dm = random_dense(48, 48, 0.4, seed=12)
    g = GraphMatrix.from_dense(d, t, backend=backend)
    m = GraphMatrix.from_dense(dm, t, backend=backend)
    c = g.mxm(g, mask=m, complement=complement)
    got = (csr_mod.to_dense(c.csr) > 0).astype(np.uint8)
    want = dense_bool_matmul(d, d) * (1 - dm if complement else dm)
    assert np.array_equal(got, want)


def test_graphmatrix_mxm_rectangular():
    t = 8
    da = random_dense(24, 40, 0.15, seed=21)
    db = random_dense(40, 16, 0.15, seed=22)
    a = GraphMatrix.from_dense(da, t)
    b = GraphMatrix.from_dense(db, t)
    c = a.mxm(b)
    got = (csr_mod.to_dense(c.csr) > 0).astype(np.uint8)
    assert np.array_equal(got, dense_bool_matmul(da, db))
    assert (c.n_rows, c.n_cols) == (24, 16)


def test_graphmatrix_mxm_count():
    t = 8
    d = random_dense(40, 40, 0.15, seed=31)
    g = GraphMatrix.from_dense(d, t)
    counts = np.asarray(g.mxm_count())
    assert np.array_equal(counts, d.astype(np.int64) @ d.astype(np.int64))


# ---------------------------------------------------------------------------
# tri_count via mxm == algorithms.tc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("backend", ["b2sr", "b2sr_pallas", "csr"])
def test_tri_count_matches_tc(t, backend):
    from repro.algorithms.tc import triangle_count
    rng = np.random.default_rng(t)
    n = 50
    d = (rng.random((n, n)) < 0.12).astype(np.uint8)
    d = ((d + d.T) > 0).astype(np.uint8)
    np.fill_diagonal(d, 0)
    g = GraphMatrix.from_dense(d, t, backend=backend)
    assert int(g.tri_count()) == int(triangle_count(g))


def test_tri_count_known_graph():
    # K4 has 4 triangles
    d = 1 - np.eye(4, dtype=np.uint8)
    for backend in ("b2sr", "b2sr_pallas", "csr"):
        g = GraphMatrix.from_dense(d, 4, backend=backend)
        assert int(g.tri_count()) == 4


# ---------------------------------------------------------------------------
# k-hop reachability via repeated masked mxm
# ---------------------------------------------------------------------------

def dense_khop(d, k):
    dl = d.astype(np.int64)
    acc, p = dl.copy(), dl.copy()
    for _ in range(k - 1):
        p = (p @ dl > 0).astype(np.int64)
        acc = ((acc + p) > 0).astype(np.int64)
    return acc.astype(np.uint8)


@pytest.mark.parametrize("t", [4, 16])
@pytest.mark.parametrize("backend", ["b2sr", "b2sr_pallas", "csr"])
def test_khop_reachability(t, backend):
    from repro.algorithms.khop import khop_reachability
    d = random_dense(40, 40, 0.06, seed=t)
    np.fill_diagonal(d, 0)
    g = GraphMatrix.from_dense(d, t, backend=backend)
    for k in (1, 2, 4):
        r = khop_reachability(g, k)
        got = (csr_mod.to_dense(r.reach.csr) > 0).astype(np.uint8)
        assert np.array_equal(got, dense_khop(d, k)), (t, backend, k)


def test_khop_early_exit():
    from repro.algorithms.khop import khop_reachability
    # path graph 0->1->2: diameter 2, so k=10 stops after 2 iterations
    d = np.zeros((3, 3), np.uint8)
    d[0, 1] = d[1, 2] = 1
    g = GraphMatrix.from_dense(d, 4)
    r = khop_reachability(g, 10)
    assert r.n_iterations <= 3
    want = np.zeros((3, 3), np.uint8)
    want[0, 1] = want[1, 2] = want[0, 2] = 1
    got = (csr_mod.to_dense(r.reach.csr) > 0).astype(np.uint8)
    assert np.array_equal(got, want)


def test_khop_frontier_matches_matrix_row():
    from repro.algorithms.khop import khop_frontier
    d = random_dense(40, 40, 0.06, seed=9)
    np.fill_diagonal(d, 0)
    g = GraphMatrix.from_dense(d, 8)
    got = np.asarray(khop_frontier(g, 0, 3))
    want = dense_khop(d, 3)[0].astype(bool)
    want[0] = False   # BFS seed semantics: source excluded
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# property-based cross-check (hypothesis, optional)
# ---------------------------------------------------------------------------

@given(st.sampled_from(TILE_DIMS), st.integers(2, 70), st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_mxm_property(t, n, seed):
    da = random_dense(n, n, 0.1, seed=seed)
    db = random_dense(n, n, 0.1, seed=seed + 1)
    grid = ops.mxm_bin_bin_bin(to_ell(dense_to_b2sr(da, t)),
                               to_ell(dense_to_b2sr(db, t)))
    assert np.array_equal(grid_to_dense(grid, n, n), dense_bool_matmul(da, db))
