"""Deterministic fault injection for the serving stack (DESIGN.md §13).

Real kernel failures (device OOM, a miscompiled Pallas kernel, device
loss) are impossible to reproduce on demand, so every robustness behavior
in ``engine/server.py`` — retry, backend fallback, circuit-breaker
transitions — is driven in tests and benchmarks by this injector instead:

  - **rules** are keyed by ``(op, backend)`` with ``"*"`` wildcards; the
    most specific rule wins (exact, then ``(op, "*")``, then
    ``("*", backend)``, then ``("*", "*")``),
  - a rule is either a **script** (an explicit fail/pass sequence, for
    pinning breaker state machines) or a seeded **rate** (for statistical
    load tests); both are deterministic — each rule owns its own RNG
    seeded from (injector seed, op, backend), so outcomes never depend on
    the global order of unrelated checks,
  - ``install()`` threads the injector through the dispatch layer
    (:func:`repro.core.dispatch.set_resolve_hook`): every kernel
    resolution a plan trace performs can fault exactly where a broken
    kernel would. The server additionally calls :meth:`check` per group
    launch, so warm plans (which never re-resolve) stay faultable too.

Faults surface as :class:`repro.core.dispatch.InjectedFault`, which the
server treats like any other backend failure.
"""

from __future__ import annotations

import zlib
from collections import deque
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core import dispatch
from repro.core.dispatch import InjectedFault

__all__ = ["FaultInjector", "InjectedFault"]

WILDCARD = "*"


class _Rule:
    def __init__(self, seed: int, op: str, backend: str,
                 rate: float = 0.0,
                 script: Optional[Iterable[bool]] = None):
        if script is None and not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self.rate = float(rate)
        self.script = None if script is None else deque(bool(x)
                                                        for x in script)
        # stable per-rule stream: independent of other rules and of the
        # order unrelated (op, backend) pairs are checked in
        self.rng = np.random.default_rng(
            (seed, zlib.crc32(f"{op}/{backend}".encode())))
        self.n_checks = 0
        self.n_faults = 0

    def fires(self) -> bool:
        self.n_checks += 1
        if self.script is not None:
            fault = self.script.popleft() if self.script else False
        else:
            fault = self.rate > 0 and float(self.rng.random()) < self.rate
        if fault:
            self.n_faults += 1
        return fault


class FaultInjector:
    """Seeded per-(op, backend) fault source for serving tests/benchmarks."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._rules: Dict[Tuple[str, str], _Rule] = {}
        self._prev_hook = None
        self._installed = False
        self.n_checks = 0
        self.n_faults = 0

    # -- configuration ------------------------------------------------------
    def fail(self, op: str = WILDCARD, backend: str = WILDCARD,
             rate: float = 1.0,
             script: Optional[Iterable[bool]] = None) -> "FaultInjector":
        """Add/replace one rule. ``script`` (a fail/pass sequence, consumed
        left to right, then inert) beats ``rate``; returns self for
        chaining."""
        self._rules[(op, backend)] = _Rule(self.seed, op, backend,
                                           rate=rate, script=script)
        return self

    def clear(self, op: str = WILDCARD, backend: str = WILDCARD) -> None:
        self._rules.pop((op, backend), None)

    def clear_all(self) -> None:
        self._rules.clear()

    def script_remaining(self, op: str = WILDCARD,
                         backend: str = WILDCARD) -> int:
        """Unconsumed script length of one rule (0 for rate rules)."""
        rule = self._rules.get((op, backend))
        return len(rule.script) if rule is not None and rule.script else 0

    # -- the check ----------------------------------------------------------
    def _match(self, op: str, backend: str) -> Optional[_Rule]:
        for key in ((op, backend), (op, WILDCARD),
                    (WILDCARD, backend), (WILDCARD, WILDCARD)):
            rule = self._rules.get(key)
            if rule is not None:
                return rule
        return None

    def check(self, op: str, backend: str) -> None:
        """Raise :class:`InjectedFault` if the matching rule fires."""
        self.n_checks += 1
        rule = self._match(op, backend)
        if rule is not None and rule.fires():
            self.n_faults += 1
            raise InjectedFault(
                f"injected fault: {op!r} on backend {backend!r}")

    # -- dispatch-layer threading ------------------------------------------
    def _on_resolve(self, key) -> None:
        op, backend = key[0], key[3]
        self.check(op, backend)

    def install(self) -> "FaultInjector":
        """Hook the dispatch layer so kernel *resolution* can fault too."""
        if not self._installed:
            self._prev_hook = dispatch.set_resolve_hook(self._on_resolve)
            self._installed = True
        return self

    def uninstall(self) -> None:
        if self._installed:
            dispatch.set_resolve_hook(self._prev_hook)
            self._prev_hook = None
            self._installed = False

    def __enter__(self) -> "FaultInjector":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()
