"""Step-atomic checkpointing with msgpack + zstd.

Layout: <dir>/step_<N>/shard_<host>.ckpt  (single-host containers write one
shard; the format and restore path are host-count agnostic — elastic restore
re-shards onto whatever mesh is live, which is how node-failure recovery and
elastic rescale work: restart with fewer/more hosts and the arrays are
re-placed by ``device_put`` under the new sharding).

Writes are atomic (tmp file + rename + manifest-last) so a crash mid-write
never corrupts the latest checkpoint; ``latest_step`` only trusts directories
with a manifest.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # zstd preferred; zlib (stdlib) keeps containers without it working
    import zstandard as _zstd

    def _compress(raw: bytes) -> bytes:
        return _zstd.ZstdCompressor(level=3).compress(raw)

    def _decompress(data: bytes) -> bytes:
        if data[:4] != b"\x28\xb5\x2f\xfd":  # zlib-written ckpt (no-zstd host)
            import zlib
            return zlib.decompress(data)
        return _zstd.ZstdDecompressor().decompress(data)
except ImportError:
    import zlib as _zlib

    def _compress(raw: bytes) -> bytes:
        return _zlib.compress(raw, level=3)

    def _decompress(data: bytes) -> bytes:
        return _zlib.decompress(data)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3, host_id: int = 0) -> str:
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    flat = _flatten(tree)
    payload = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape),
            "data": v.tobytes()}
        for k, v in flat.items()
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(raw)
    shard_path = os.path.join(tmp_dir, f"shard_{host_id}.ckpt")
    with open(shard_path, "wb") as f:
        f.write(comp)

    manifest = {"step": step, "n_arrays": len(flat),
                "extra": extra or {}, "hosts": 1}
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like``; optionally re-shard (elastic)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(step_dir, "shard_0.ckpt"), "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)

    flat_like = _flatten(like)
    missing = set(flat_like) - set(payload)
    if missing:
        raise ValueError(f"checkpoint missing arrays: {sorted(missing)[:5]}")

    arrays = {}
    for k in flat_like:
        spec = payload[k]
        arr = np.frombuffer(spec["data"], dtype=np.dtype(spec["dtype"]))
        arrays[k] = arr.reshape(spec["shape"])

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(leaves_with_path))
    new_leaves = []
    for (path, leaf), shard in zip(leaves_with_path, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        if shard is not None:
            new_leaves.append(jax.device_put(arr, shard))
        else:
            new_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]
