"""End-to-end telemetry for the Bit-GraphBLAS serving stack (DESIGN.md §14).

Three legs, one import:

  - **metrics** — a process-local pull-based registry of labeled
    ``Counter`` / ``Gauge`` / ``Histogram`` series plus a bounded event
    log; snapshot-able to a dict, exportable as JSON or Prometheus text.
  - **trace** — per-query ``Trace``/``Span`` objects threaded through
    submit → queue-wait → plan-resolve → launch → scatter-back and
    surfaced on ``QueryHandle.trace``.
  - **cost** — per-plan FLOPs/bytes estimates from the HLO cost model, so
    launch-latency histograms read out as achieved-vs-roofline rates.

Importing :mod:`repro.obs` installs the **dispatch observer** — the
read-only sibling of :func:`repro.core.dispatch.set_resolve_hook` — which
counts and times every kernel resolution (and records injected/real
resolution faults) into the default registry. ``set_enabled(False)`` turns
every recording path into an early return and every span into a shared
no-op; the disabled fast path is what the serving stack pays when nobody
is looking.
"""

from __future__ import annotations

from repro.core import dispatch as _dispatch
from repro.obs import cost, export, trace  # noqa: F401
from repro.obs.cost import (cost_accounting_enabled,  # noqa: F401
                            roofline_table, set_cost_accounting)
from repro.obs.export import parse_prometheus, write_metrics  # noqa: F401
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, enabled, get_registry,
                               set_enabled, set_registry)
from repro.obs.trace import (NOOP_SPAN, Span, Trace,  # noqa: F401
                             current_span, new_trace, write_jsonl)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span", "Trace",
    "NOOP_SPAN", "enabled", "set_enabled", "disabled", "get_registry",
    "set_registry", "current_span", "new_trace", "write_jsonl",
    "write_metrics", "parse_prometheus", "set_cost_accounting",
    "cost_accounting_enabled", "roofline_table",
    "install_dispatch_observer", "uninstall_dispatch_observer",
]


class disabled:
    """``with obs.disabled():`` — scoped observability off-switch."""

    def __enter__(self):
        self._prev = set_enabled(False)
        return self

    def __exit__(self, *exc):
        set_enabled(self._prev)
        return None


# ---------------------------------------------------------------------------
# Dispatch observer: counts + times every kernel resolution
# ---------------------------------------------------------------------------

def _dispatch_observer(key, duration_s: float, err) -> None:
    """The default observe hook (see ``dispatch.set_observe_hook``).

    Fires on every :func:`repro.core.dispatch.resolve` — including ones the
    resolve hook (fault injector) aborts, so injected faults are visible in
    the registry exactly like real resolution failures would be.
    """
    if not enabled():
        return
    reg = get_registry()
    op, _rhs, _out, backend, bucketed, _masked, sharded = key
    reg.counter("dispatch_resolves_total",
                "kernel registry resolutions (trace-time)",
                ("op", "backend", "bucketed", "sharded")).inc(
        op=op, backend=backend, bucketed=bucketed, sharded=sharded)
    reg.histogram("dispatch_resolve_s",
                  "resolve() wall time incl. lazy backend import",
                  ("op", "backend")).observe(duration_s, op=op,
                                             backend=backend)
    if err is not None:
        reg.counter("dispatch_faults_total",
                    "resolutions aborted by the resolve hook",
                    ("op", "backend", "error")).inc(
            op=op, backend=backend, error=type(err).__name__)
        reg.event("dispatch_fault", op=op, backend=backend,
                  error=repr(err))


def install_dispatch_observer():
    """(Re-)install the default dispatch observer; returns the previous
    observe hook. Importing :mod:`repro.obs` does this once."""
    return _dispatch.set_observe_hook(_dispatch_observer)


def uninstall_dispatch_observer() -> None:
    if _dispatch._OBSERVE_HOOK is _dispatch_observer:
        _dispatch.set_observe_hook(None)


install_dispatch_observer()
