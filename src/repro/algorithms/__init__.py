"""Semiring graph algorithms over GraphMatrix (paper §V)."""

from repro.algorithms.bfs import bfs  # noqa: F401
from repro.algorithms.sssp import sssp  # noqa: F401
from repro.algorithms.pagerank import pagerank, ppr  # noqa: F401
from repro.algorithms.cc import connected_components  # noqa: F401
from repro.algorithms.tc import triangle_count  # noqa: F401
from repro.algorithms.khop import khop_frontier, khop_reachability  # noqa: F401
