"""Paper §VI.B / Table I / Figure 5: B2SR storage efficiency.

Reports, per corpus matrix × tile size: B2SR bytes, CSR(fp32) bytes,
compression ratio (B2SR/CSR — <1 is a win), optimal tile size, and the
counts that reproduce Fig. 5b ("optimal" and "compressed<100%" histograms).
Also verifies the Table I per-tile packing arithmetic (16×/32× savings).
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, corpus, save_json
from repro.core.b2sr import (
    TILE_DIMS, coo_to_b2sr, compression_ratio, csr_storage_bytes, occupancy,
)


def per_tile_saving(t: int) -> float:
    """Table I: CSR stores ≤ t*t (fp32 value + int32 col) per dense tile;
    B2SR stores t packed words of the paper's dtype."""
    csr = t * t * (4 + 4)
    b2sr = {4: 4 * 1, 8: 8 * 1, 16: 16 * 2, 32: 32 * 4}[t]
    return csr / b2sr


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    # --- Table I arithmetic (vs fp32-only values, as the paper counts) ---
    for t in TILE_DIMS:
        dense_fp32 = t * t * 4
        packed = {4: 4, 8: 8, 16: 32, 32: 128}[t]
        saving = dense_fp32 / packed
        rows.append(BenchRow(f"tableI/saving_per_tile_{t}x{t}", 0.0,
                             f"{saving:.0f}x"))
    # --- Fig 5a/5b over the corpus ---
    detail = {}
    optimal_hist = {t: 0 for t in TILE_DIMS}
    compressed_hist = {t: 0 for t in TILE_DIMS}
    for name, (r, c, n) in corpus().items():
        entry = {}
        sizes = {}
        for t in TILE_DIMS:
            m = coo_to_b2sr(r, c, n, n, t)
            ratio = compression_ratio(m)
            sizes[t] = m.storage_bytes()
            entry[f"b2sr{t}_bytes"] = m.storage_bytes()
            entry[f"b2sr{t}_ratio"] = round(ratio, 4)
            entry[f"b2sr{t}_occupancy"] = round(occupancy(m), 4)
            if ratio < 1.0:
                compressed_hist[t] += 1
        best = min(sizes, key=sizes.get)
        optimal_hist[best] += 1
        entry["csr_bytes"] = csr_storage_bytes(n, len(r))
        entry["optimal_tile"] = best
        detail[name] = entry
        rows.append(BenchRow(
            f"fig5/{name}", 0.0,
            f"best=B2SR-{best} ratio={entry[f'b2sr{best}_ratio']:.3f}"))
    rows.append(BenchRow("fig5b/optimal_hist", 0.0,
                         " ".join(f"t{t}:{v}" for t, v in optimal_hist.items())))
    rows.append(BenchRow("fig5b/compressed_hist", 0.0,
                         " ".join(f"t{t}:{v}" for t, v in compressed_hist.items())))
    save_json("compression.json",
              {"detail": detail, "optimal_hist": optimal_hist,
               "compressed_hist": compressed_hist})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
