"""B2SR beyond graphs: bit-tile block masks for block-sparse attention.

The paper's format stores a binary matrix as CSR-over-tiles with dense bit
tiles. An attention *block mask* — which [block_size × block_size] score
blocks a sparse-attention pattern touches — is exactly such a matrix over
the block grid. This module:

  - builds common sparse-attention patterns (causal-local + strided global)
    as B2SR over the block grid, reusing ``coo_to_b2sr``;
  - runs ``block_sparse_attention``: per query block, only the key blocks
    whose bits are set are gathered and scored — O(S·w) instead of O(S²) —
    with the block lists coming straight from the B2SR ELL rows.

This is the paper's technique feeding the LM family (DESIGN.md §4): the
same two-level representation, the same word-level bit unpacking, applied
to an attention workload instead of a graph traversal.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.b2sr import B2SR, B2SREll, ceil_div, coo_to_b2sr, to_ell


def local_strided_pattern(n_blocks: int, window: int = 4,
                          stride: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Causal local window + strided-global block pattern (COO over blocks)."""
    rows, cols = [], []
    for i in range(n_blocks):
        for j in range(max(0, i - window + 1), i + 1):
            rows.append(i)
            cols.append(j)
        for j in range(0, i, stride):       # strided global (causal)
            rows.append(i)
            cols.append(j)
    return np.asarray(rows), np.asarray(cols)


def pattern_to_b2sr(rows: np.ndarray, cols: np.ndarray, n_blocks: int,
                    tile_dim: int = 8) -> Tuple[B2SR, B2SREll]:
    mat = coo_to_b2sr(rows, cols, n_blocks, n_blocks, tile_dim)
    return mat, to_ell(mat)


def block_lists_from_ell(ell: B2SREll, max_blocks: int) -> jax.Array:
    """Per query-block active key-block ids, from the ELL bit rows.

    Returns int32[n_blocks, max_blocks], padded with -1. Unpacks the word-
    level rows exactly as the BMV kernels do (bit j of word r in tile (I, J)
    == block (I·t + r) attends to block (J·t + j)).
    """
    t = ell.tile_dim
    n_blocks = ell.n_rows
    R, K = ell.tile_col_idx.shape
    shifts = jnp.arange(t, dtype=jnp.uint32)
    # bits[R, K, t(row), t(col)]
    bits = (ell.bit_tiles[..., :, None] >> shifts) & jnp.uint32(1)
    # candidate block id for (tile K, col bit j) in tile-row I
    cand = ell.tile_col_idx[:, :, None] * t + jnp.arange(t)[None, None, :]
    cand = jnp.where(ell.tile_col_idx[:, :, None] >= 0, cand, -1)
    # for each row r in the tile-row: flatten (K, t) candidates
    cand_rows = jnp.broadcast_to(cand[:, None, :, :], (R, t, K, t))
    bits_rows = bits.transpose(0, 2, 1, 3)                  # [R, t, K, t]
    flat_ids = jnp.where(bits_rows > 0, cand_rows, -1).reshape(R * t, K * t)
    # compact the -1s to the right (stable sort by invalidity)
    order = jnp.argsort(flat_ids < 0, axis=1, stable=True)
    compacted = jnp.take_along_axis(flat_ids, order, axis=1)
    return compacted[:n_blocks, :max_blocks].astype(jnp.int32)


@partial(jax.jit, static_argnums=(4,))
def block_sparse_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           block_ids: jax.Array, block_size: int) -> jax.Array:
    """Attention restricted to the B2SR-indexed key blocks.

    q/k/v: [B, S, H, hd]; block_ids: int32[nq, W] (-1 padded, from
    ``block_lists_from_ell``). Causality inside the diagonal block is
    enforced; listed off-diagonal blocks are attended in full (the pattern
    generator is causal at block granularity).
    """
    B, S, H, hd = q.shape
    bs = block_size
    nq = S // bs
    W = block_ids.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qb = q.reshape(B, nq, bs, H, hd)
    kb = k.reshape(B, nq, bs, H, hd)
    vb = v.reshape(B, nq, bs, H, hd)

    def q_step(_, qi):
        ids = block_ids[qi]                                  # [W]
        valid = ids >= 0
        kg = kb[:, jnp.clip(ids, 0, nq - 1)]                 # [B, W, bs, H, hd]
        vg = vb[:, jnp.clip(ids, 0, nq - 1)]
        s = jnp.einsum("bqhd,bwthd->bhqwt", qb[:, qi], kg,
                       preferred_element_type=jnp.float32) * scale
        # causal within the diagonal block; padding blocks masked out
        q_pos = qi * bs + jnp.arange(bs)
        k_pos = ids[:, None] * bs + jnp.arange(bs)[None, :]    # [W, bs]
        mask = (valid[None, :, None]
                & (k_pos[None] <= q_pos[:, None, None]))       # [bs, W, bs]
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s.reshape(B, H, bs, W * bs), axis=-1)
        out = jnp.einsum("bhqm,bmhd->bqhd", p,
                         vg.reshape(B, W * bs, H, hd))
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))     # [nq,B,bs,H,hd]
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
