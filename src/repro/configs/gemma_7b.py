"""gemma-7b [arXiv:2403.08295; hf]: dense 28L GeGLU, head_dim=256."""

from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    tie_embeddings=True,
)


def reduced() -> TransformerConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="gemma-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=256, vocab_size=256,
        dtype="float32", max_seq_len=64)
