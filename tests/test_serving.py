"""Fault-tolerant serving layer: deadlines, fallback, breakers, warmup.

Covers DESIGN.md §13 end to end with a deterministic clock and the seeded
:class:`FaultInjector` (no real faults, no real sleeps): deadline-driven
flushing vs fill-driven flushing, bounded-queue rejection, submit-time
validation, circuit-breaker open/half-open/close transitions, the
``b2sr_pallas → b2sr → csr`` fall-through staying bit-exact (buckets
on/off, and on 8 forced host devices for the sharded path), in-flight
dedup, idempotent failure handles, and the restart-safe warmup
round-trip.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.algorithms import bfs, khop_frontier, sssp
from repro.core import GraphMatrix, dispatch
from repro.engine import (CircuitBreaker, FaultInjector, GraphQueryServer,
                          InjectedFault, PlanCache, QueryBatcher,
                          QueryGroupError, QueryRejected, ServerConfig,
                          batched_ppr)
from repro.engine import warmup as warmup_mod
from repro.engine.server import CLOSED, HALF_OPEN, OPEN


def skewed_coo(n, seed, hub_deg=15, base_deg=3):
    rng = np.random.default_rng(seed)
    rows = np.concatenate([
        np.repeat(np.arange(n, dtype=np.int64), base_deg),
        np.repeat(rng.choice(n, 2, replace=False).astype(np.int64), hub_deg),
    ])
    cols = rng.integers(0, n, rows.size)
    return rows, cols


def build(n=64, t=8, backend="b2sr", seed=0, use_buckets=True):
    rows, cols = skewed_coo(n, seed)
    g = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=t, backend=backend)
    return g.with_buckets(use_buckets)


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_server(clock=None, injector=None, **cfg_kw):
    cfg_kw.setdefault("backoff_base_s", 0.0)
    return GraphQueryServer(
        planner=PlanCache(), config=ServerConfig(**cfg_kw),
        fault_injector=injector,
        clock=clock if clock is not None else FakeClock(),
        sleep=lambda s: None)


# ---------------------------------------------------------------------------
# deadline-aware admission
# ---------------------------------------------------------------------------

def test_deadline_flush_fires_when_oldest_budget_nears():
    clk = FakeClock()
    srv = make_server(clock=clk, default_budget_s=0.1, flush_margin_s=0.005)
    g = build()
    h1 = srv.bfs(g, 3)
    clk.advance(0.050)
    h2 = srv.bfs(g, 7, budget_s=0.2)         # later deadline, same flush
    assert srv.poll() == 0 and srv.pending() == 2    # nothing near yet
    clk.advance(0.044)                       # oldest deadline 6ms away
    assert not srv.due() and srv.poll() == 0
    clk.advance(0.002)                       # now 4ms away: inside margin
    assert srv.due()
    assert srv.poll() == 2                   # flushes *everything* pending
    assert h1.done() and h2.done() and srv.pending() == 0
    assert srv.stats["deadline_flushes"] == 1
    assert srv.stats["fill_flushes"] == 0
    assert np.array_equal(np.asarray(h1.result()),
                          np.asarray(bfs(g, 3).levels))
    assert h1.completed_at == clk.t and not h1.degraded


def test_fill_flush_at_max_batch():
    srv = make_server(max_batch=4)
    g = build()
    handles = [srv.bfs(g, s) for s in (1, 2, 3)]
    assert srv.pending() == 3 and not handles[0].done()
    handles.append(srv.bfs(g, 4))            # 4th submit trips the fill flush
    assert srv.pending() == 0 and all(h.done() for h in handles)
    assert srv.stats["fill_flushes"] == 1
    for s, h in zip((1, 2, 3, 4), handles):
        assert np.array_equal(np.asarray(h.result()),
                              np.asarray(bfs(g, s).levels))


def test_bounded_queue_rejects_overflow():
    srv = make_server(max_queue=2)
    g = build()
    h1, h2 = srv.bfs(g, 1), srv.bfs(g, 2)
    with pytest.raises(QueryRejected, match=r"queue full \(2/2 pending\)"):
        srv.bfs(g, 3)
    assert srv.stats["rejected"] == 1 and srv.pending() == 2
    srv.flush()                              # accepted queries still resolve
    assert np.array_equal(np.asarray(h1.result()),
                          np.asarray(bfs(g, 1).levels))
    assert np.array_equal(np.asarray(h2.result()),
                          np.asarray(bfs(g, 2).levels))
    assert srv.bfs(g, 3).done() is False     # space freed: admitted again


def test_submit_time_validation_names_node_count():
    g = build(n=64)
    srv = make_server()
    with pytest.raises(ValueError, match=r"graph with 64 nodes.*0\.\.63"):
        srv.bfs(g, 64)
    with pytest.raises(ValueError, match=r"graph with 64 nodes"):
        srv.bfs(g, -1)
    with pytest.raises(ValueError, match="unknown query kind 'pagerank'"):
        srv.submit(g, "pagerank", 0)
    assert srv.pending() == 0                # nothing enqueued by rejects
    assert srv.stats["submitted"] == 0
    b = QueryBatcher()                       # same edge on the raw batcher
    with pytest.raises(ValueError, match=r"graph with 64 nodes"):
        b.bfs(g, 1000)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_state_machine():
    clk = FakeClock()
    br = CircuitBreaker(fail_threshold=2, cooldown_s=1.0, clock=clk)
    assert br.state == CLOSED and br.allow()
    br.record_failure()
    assert br.state == CLOSED and br.allow()
    br.record_failure()                      # 2nd consecutive: opens
    assert br.state == OPEN and not br.allow() and br.n_opens == 1
    clk.advance(0.999)
    assert not br.allow()
    clk.advance(0.001)                       # cooldown elapsed: half-open
    assert br.allow() and br.state == HALF_OPEN
    br.record_failure()                      # failed probe: re-open
    assert br.state == OPEN and br.n_opens == 2 and not br.allow()
    clk.advance(1.0)
    assert br.allow() and br.state == HALF_OPEN
    br.record_success()                      # probe succeeded: closed
    assert br.state == CLOSED and br.allow()
    br.record_failure()                      # success reset the count
    assert br.state == CLOSED


def test_breaker_opens_skips_and_recovers_through_server():
    clk = FakeClock()
    # 4 scripted faults: initial + retry (opens the breaker), then the
    # half-open probe + its retry... the probe is a single attempt, so
    # fault #3 re-opens; #4 is never consumed until the next half-open.
    inj = FaultInjector(seed=0).fail(op="bfs", backend="b2sr_pallas",
                                     script=[True, True, True, True])
    srv = make_server(clock=clk, injector=inj, max_retries=1,
                      fail_threshold=2, cooldown_s=1.0)
    g = build(backend="b2sr_pallas")
    ref = np.asarray(bfs(g.with_backend("b2sr"), 3).levels)

    h = srv.bfs(g, 3)
    srv.flush()                              # fault + retried fault: opens
    assert h.degraded and h.backend_used == "b2sr"
    assert np.array_equal(np.asarray(h.result()), ref)
    assert srv.breaker("bfs", "b2sr_pallas").state == OPEN
    assert srv.stats["retries"] == 1 and inj.script_remaining(
        "bfs", "b2sr_pallas") == 2

    h2 = srv.bfs(g, 3)
    srv.flush()                              # open breaker: pallas skipped
    assert h2.degraded and srv.stats["breaker_skips"] == 1
    assert inj.script_remaining("bfs", "b2sr_pallas") == 2  # not consulted

    clk.advance(1.0)                         # cooldown: half-open probe
    h3 = srv.bfs(g, 3)
    srv.flush()
    br = srv.breaker("bfs", "b2sr_pallas")
    assert h3.degraded and br.state == OPEN and br.n_opens == 2
    assert inj.script_remaining("bfs", "b2sr_pallas") == 1  # one probe shot

    clk.advance(1.0)
    h4 = srv.bfs(g, 3)
    srv.flush()                              # probe faults again, re-opens
    assert h4.degraded and br.n_opens == 3

    clk.advance(1.0)                         # script exhausted: probe passes
    h5 = srv.bfs(g, 3)
    srv.flush()
    assert br.state == CLOSED
    assert not h5.degraded and h5.backend_used == "b2sr_pallas"
    assert np.array_equal(np.asarray(h5.result()), ref)


# ---------------------------------------------------------------------------
# fallback chain: bit-exact degradation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_buckets", (True, False))
def test_fallback_to_b2sr_is_bit_exact(use_buckets):
    inj = FaultInjector(seed=0).fail(backend="b2sr_pallas", rate=1.0)
    srv = make_server(injector=inj)
    g = build(backend="b2sr_pallas", use_buckets=use_buckets)
    gb = srv._backend_view(g, "b2sr")
    hb = srv.bfs(g, 5)
    hk = srv.khop(g, 9, k=2)
    hs = srv.sssp(g, 4)
    hp = srv.ppr(g, 11, max_iters=4, eps=0.0)
    srv.flush()
    for h in (hb, hk, hs, hp):
        assert h.degraded and h.backend_used == "b2sr"
    assert np.array_equal(np.asarray(hb.result()),
                          np.asarray(bfs(gb, 5).levels))
    assert np.array_equal(np.asarray(hk.result()),
                          np.asarray(khop_frontier(gb, 9, 2)))
    assert np.array_equal(np.asarray(hs.result()),
                          np.asarray(sssp(gb, 4).distances))
    # float kind: bit-exact vs the identical healthy launch on b2sr
    assert np.array_equal(
        np.asarray(hp.result()),
        np.asarray(batched_ppr(gb, [11], max_iters=4, eps=0.0).ranks[:, 0]))
    assert srv.stats["degraded_launches"] == 4
    assert srv.stats["launches"] == 4        # pallas faulted pre-launch


def test_fallback_to_csr_last_resort():
    inj = (FaultInjector(seed=0)
           .fail(backend="b2sr_pallas", rate=1.0)
           .fail(backend="b2sr", rate=1.0))
    srv = make_server(injector=inj)
    g = build(backend="b2sr_pallas")
    gc = g.with_backend("csr")
    h = srv.bfs(g, 5)
    srv.flush()
    assert h.degraded and h.backend_used == "csr"
    assert np.array_equal(np.asarray(h.result()),
                          np.asarray(bfs(gc, 5).levels))
    assert np.array_equal(np.asarray(h.result()),
                          np.asarray(bfs(g.with_backend("b2sr"), 5).levels))


def test_fallback_exhausted_fails_handles_idempotently():
    inj = FaultInjector(seed=0).fail(rate=1.0)          # every backend
    srv = make_server(injector=inj, max_retries=1)
    g = build(backend="b2sr_pallas")
    h1, h2 = srv.bfs(g, 1), srv.bfs(g, 2)
    srv.flush()                              # quiet: verdicts on handles
    assert h1.done() and h2.done()
    assert srv.stats["failed_queries"] == 2 and srv.stats["completed"] == 0
    with pytest.raises(QueryGroupError, match="batched 'bfs' group") as e1:
        h1.result()
    assert isinstance(e1.value.__cause__, InjectedFault)
    cause = e1.value.__cause__
    for _ in range(3):                       # satellite: idempotent re-raise
        with pytest.raises(QueryGroupError) as e2:
            h1.result()
        assert e2.value is e1.value          # same object, no re-wrapping
        assert e2.value.__cause__ is cause   # __cause__ chain never grows
    h1._fail(RuntimeError("late"))           # first outcome wins
    h1._fulfill(np.zeros(3))
    with pytest.raises(QueryGroupError):
        h1.result()
    with pytest.raises(QueryGroupError):     # sibling got the same verdict
        h2.result()


# ---------------------------------------------------------------------------
# in-flight dedup
# ---------------------------------------------------------------------------

def test_inflight_duplicates_share_one_column():
    srv = make_server()
    g = build()
    dup = [srv.bfs(g, 13) for _ in range(3)] # a retry storm, same query
    other = srv.bfs(g, 2)
    srv.flush()
    assert srv.stats["deduped"] == 2         # 4 queries, 2 unique sources
    want = np.asarray(bfs(g, 13).levels)
    for h in dup:
        assert np.array_equal(np.asarray(h.result()), want)
    assert np.array_equal(np.asarray(other.result()),
                          np.asarray(bfs(g, 2).levels))
    rec = srv.launch_log[-1]
    assert len(rec.sources) == 2             # padded launch carried 2 cols


def test_batcher_dedup_counter():
    pc = PlanCache()
    b = QueryBatcher(planner=pc)
    g = build()
    hs = [b.ppr(g, 7, max_iters=3, eps=0.0) for _ in range(4)]
    b.flush()
    assert b.n_deduped == 3 and b.n_launches == 1
    first = np.asarray(hs[0].result())
    for h in hs[1:]:
        assert np.array_equal(np.asarray(h.result()), first)


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------

def test_injector_outcomes_are_rule_local_and_seeded():
    def outcomes(inj, op, n=40):
        out = []
        for _ in range(n):
            try:
                inj.check(op, "b2sr")
                out.append(False)
            except InjectedFault:
                out.append(True)
        return out

    a = FaultInjector(seed=5).fail(op="bfs", rate=0.3).fail(op="ppr",
                                                            rate=0.3)
    b = FaultInjector(seed=5).fail(op="bfs", rate=0.3).fail(op="ppr",
                                                            rate=0.3)
    seq = outcomes(a, "bfs")
    assert any(seq) and not all(seq)         # an actual 30% mix
    # interleaving an unrelated rule's checks must not perturb this one
    inter = []
    for _ in range(40):
        outcomes(b, "ppr", n=1)
        inter.extend(outcomes(b, "bfs", n=1))
    assert inter == seq
    reseeded = FaultInjector(seed=6).fail(op="bfs", rate=0.3)
    assert outcomes(reseeded, "bfs") != seq  # seed actually matters


def test_injector_threads_through_dispatch_resolve():
    g = build(backend="b2sr")
    bfs(g, 1)                                # healthy before
    with FaultInjector(seed=0).fail(backend="b2sr", rate=1.0):
        with pytest.raises(InjectedFault, match="backend 'b2sr'"):
            bfs(g, 1)
    assert np.asarray(bfs(g, 1).levels)[1] == 0   # hook removed: healthy
    inj = FaultInjector(seed=0).fail(op="no_such_op", rate=1.0)
    inj.install()
    try:
        bfs(g, 1)                            # non-matching rule: inert
    finally:
        inj.uninstall()
    assert dispatch.set_resolve_hook(None) is None  # fully unhooked


# ---------------------------------------------------------------------------
# restart-safe warmup
# ---------------------------------------------------------------------------

def test_warmup_roundtrip_precompiles_hot_plans(tmp_path):
    path = str(tmp_path / "warm.json")
    g = build(n=64, seed=3)
    srv = make_server()
    for s in (1, 9):
        srv.bfs(g, s)
    srv.ppr(g, 5, max_iters=3, eps=0.0)
    srv.flush()
    assert srv.save_warmup(path) == 2        # one bfs recipe + one ppr

    # "restart": same graph rebuilt from scratch, fresh plan cache
    g2 = build(n=64, seed=3)
    srv2 = make_server()
    srv2.register(g2)
    assert srv2.warmup(path) == 2
    compiles = srv2.planner.misses
    assert compiles == 2 and srv2.planner.hits == 0
    for s in (1, 9):
        srv2.bfs(g2, s)
    srv2.ppr(g2, 5, max_iters=3, eps=0.0)
    srv2.flush()                             # live traffic: pure cache hits
    assert srv2.planner.misses == compiles and srv2.planner.hits == 2
    assert srv2.stats["warmup_replayed"] == 2

    # unregistered graph fingerprints are skipped, never fatal
    srv3 = make_server()
    assert srv3.warmup(path) == 0
    assert srv3.stats["warmup_skipped"] == 2


def test_warmup_file_validation(tmp_path):
    with pytest.raises(FileNotFoundError):
        warmup_mod.load(str(tmp_path / "absent.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(ValueError, match="not a warmup file"):
        warmup_mod.load(str(bad))
    vers = tmp_path / "vers.json"
    vers.write_text('{"version": 99, "recipes": []}')
    with pytest.raises(ValueError, match="version 99"):
        warmup_mod.load(str(vers))
    field = tmp_path / "field.json"
    field.write_text('{"version": 1, "recipes": [{"kind": "bfs"}]}')
    with pytest.raises(ValueError, match="missing field 'graph_fp'"):
        warmup_mod.load(str(field))
    with pytest.raises(ValueError, match="missing field"):
        warmup_mod.save(str(tmp_path / "out.json"), [{"kind": "bfs"}])


# ---------------------------------------------------------------------------
# sharded fallback parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    from repro.algorithms.bfs import bfs
    from repro.core.graphblas import GraphMatrix
    from repro.engine import (FaultInjector, GraphQueryServer, PlanCache,
                              ServerConfig)
    from repro.engine.queries import batched_ppr
    from repro.launch.mesh import make_debug_mesh

    assert len(jax.devices()) == 8
    rng = np.random.RandomState(3)
    d = (rng.random((96, 96)) < 0.08).astype(np.uint8)
    g = GraphMatrix.from_dense(d, tile_dim=8)
    mesh = make_debug_mesh(8, model=2)
    gp = g.with_backend("b2sr_pallas").shard(mesh)
    cfg = ServerConfig(backoff_base_s=0.0)
    ref = np.asarray(bfs(g, 5).levels)

    # sharded pallas faults -> served by *sharded* b2sr, bit-exact
    inj = FaultInjector(seed=1).fail(backend="b2sr_pallas", rate=1.0)
    srv = GraphQueryServer(planner=PlanCache(), config=cfg,
                           fault_injector=inj)
    h = srv.bfs(gp, 5)
    hp = srv.ppr(gp, 7, max_iters=4, eps=0.0)
    srv.flush()
    assert h.degraded and h.backend_used == "b2sr"
    assert np.array_equal(np.asarray(h.result()), ref)
    gb = srv._backend_view(gp, "b2sr")
    assert gb.sharded                       # fallback stayed on the mesh
    assert np.array_equal(
        np.asarray(hp.result()),
        np.asarray(batched_ppr(gb, [7], max_iters=4, eps=0.0).ranks[:, 0]))
    print("SHARD_B2SR_OK")

    # both bit backends fault -> csr last resort (server unshards for it)
    inj2 = (FaultInjector(seed=2).fail(backend="b2sr_pallas", rate=1.0)
            .fail(backend="b2sr", rate=1.0))
    srv2 = GraphQueryServer(planner=PlanCache(), config=cfg,
                            fault_injector=inj2)
    h2 = srv2.bfs(gp, 5)
    srv2.flush()
    assert h2.degraded and h2.backend_used == "csr"
    assert not srv2._backend_view(gp, "csr").sharded
    assert np.array_equal(np.asarray(h2.result()), ref)
    print("SHARD_CSR_OK")

    # warmup recipes keep the sharded flag and replay on the mesh
    path = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                        "serving_warm_shard.json")
    assert srv.save_warmup(path) >= 1
    srv3 = GraphQueryServer(planner=PlanCache(), config=cfg)
    srv3.register(gp)
    assert srv3.warmup(path) >= 1 and srv3.stats["warmup_failed"] == 0
    print("SHARD_WARM_OK")
""")

_SHARD_MARKERS = ["SHARD_B2SR_OK", "SHARD_CSR_OK", "SHARD_WARM_OK"]


@pytest.fixture(scope="module")
def sharded_serving_run():
    return subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], capture_output=True,
        text=True, timeout=900, env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.parametrize("marker", _SHARD_MARKERS)
def test_sharded_fallback_parity(sharded_serving_run, marker):
    assert sharded_serving_run.returncode == 0, \
        sharded_serving_run.stderr[-4000:]
    assert marker in sharded_serving_run.stdout
