import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell: jax.jit(step, in_shardings, out_shardings).lower(*specs)
.compile(); record memory_analysis, cost_analysis, and the collective
schedule parsed from the post-SPMD HLO, into results/dryrun/*.json —
the roofline analysis (benchmarks/roofline.py) reads these.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all                  # every cell, both meshes
  python -m repro.launch.dryrun --all --mesh single    # single-pod only
"""

import argparse
import json
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro.configs import SKIPPED_CELLS, all_cells
from repro.launch import mesh as mesh_mod
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.specs import build_cell
from repro.sharding.rules import tree_shardings

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire-byte model from the post-SPMD module (DESIGN.md §8).

    result-type bytes × op-specific ring factor:
      all-reduce 2×, all-gather 1×, reduce-scatter ~group×result ≈ operand,
      all-to-all 1×, collective-permute 1×.
    """
    per_op = {}
    total = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (\([^)]*\)|\S+) ([\w\-]+)\(", line)
        if not m:
            continue
        result_type, op = m.groups()
        base = op.replace("-start", "")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue
        rb = _shape_bytes(result_type)
        if base == "all-reduce":
            wire = 2 * rb
        elif base == "reduce-scatter":
            g = re.search(r"replica_groups=\{?\{([\d,]+)\}", line)
            group = len(g.group(1).split(",")) if g else 1
            wire = rb * group
        else:
            wire = rb
        per_op[base] = per_op.get(base, 0) + wire
        total += wire
    return {"per_device_wire_bytes": total, "by_op": per_op}


def run_cell(arch: str, shape_id: str, multi_pod: bool, out_dir: str,
             overrides: dict | None = None, tag: str = "") -> dict:
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = build_cell(arch, shape_id, mesh, overrides=overrides)

    in_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        cell.in_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    out_shardings = (jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if s is not None else None,
        cell.out_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        or x is None) if cell.out_specs is not None else None)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.step, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(mem, attr):
                mem_info[attr] = int(getattr(mem, attr))
    cost = compiled.cost_analysis() or {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    # Hierarchical cost model: XLA's cost_analysis counts while bodies ONCE
    # (scan-over-layers undercount); analyze_hlo multiplies by trip counts.
    hlo = compiled.as_text()
    rep = analyze_hlo(hlo)
    coll = {"per_device_wire_bytes": rep.wire_bytes, "by_op": rep.wire_by_op}

    result = {
        "arch": arch, "shape": shape_id, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compile_seconds": round(compile_s, 1),
        "memory_analysis": mem_info,
        "hlo_flops_per_device": rep.flops,
        "hlo_bytes_per_device": rep.hbm_bytes,
        "xla_flops_once": xla_flops,          # raw cost_analysis (cross-check)
        "xla_bytes_once": xla_bytes,
        "unannotated_whiles": rep.unannotated_whiles,
        "collectives": coll,
        "meta": cell.meta,
        "status": "ok",
    }
    if overrides:
        result["overrides"] = {k: str(v) for k, v in overrides.items()}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = f"{arch}__{shape_id}__{result['mesh']}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    # the assignment contract: print the two analyses
    print(f"== {arch} × {shape_id} on {result['mesh']} "
          f"(compile {compile_s:.1f}s) ==")
    print(f"  memory: {mem_info}")
    print(f"  flops/device: {rep.flops:.3e}  bytes/device: {rep.hbm_bytes:.3e}"
          f"  (xla-once: {xla_flops:.3e}/{xla_bytes:.3e})")
    print(f"  collectives: {coll['by_op']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="config override key=value (hillclimb A/B runs)")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    args = ap.parse_args()

    overrides = {}
    for kv in args.overrides:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])

    failures = []
    for arch, shape_id in cells:
        if (arch, shape_id) in SKIPPED_CELLS:
            print(f"-- skipping {arch} × {shape_id} (DESIGN.md §6)")
            continue
        for mp in meshes:
            try:
                run_cell(arch, shape_id, mp, args.out,
                         overrides=overrides or None, tag=args.tag)
            except Exception as e:
                failures.append((arch, shape_id, mp, repr(e)))
                print(f"!! FAILED {arch} × {shape_id} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
