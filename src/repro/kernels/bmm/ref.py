"""Pure-jnp oracle for the masked BMM sum: densify everything, then matmul."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.b2sr import B2SREll
from repro.kernels.bmv.ref import dense_from_ell


def bmm_bin_bin_sum_masked(a: B2SREll, b: B2SREll, mask: B2SREll):
    da = dense_from_ell(a, jnp.float32)
    db = dense_from_ell(b, jnp.float32)
    dm = dense_from_ell(mask, jnp.float32)
    return jnp.sum((da @ db) * dm)


def bmm_bin_bin_sum(a: B2SREll, b: B2SREll):
    da = dense_from_ell(a, jnp.float32)
    db = dense_from_ell(b, jnp.float32)
    return jnp.sum(da @ db)
