"""Gradient compression for the data-parallel all-reduce.

int8 quantised all-reduce with a shared scale and error feedback:
  1. psum(max|g|) -> global scale (scalar collective, negligible)
  2. q = round(g / scale * 127) as int8, accumulate the psum in int32
  3. dequantise; the quantisation residual is fed back into the next step
     (error feedback keeps SGD convergence guarantees).

Payload shrinks 4× vs fp32 (2× vs bf16) on the wire; used inside shard_map
where the DP all-reduce is explicit. ``compressed_psum`` is semantically a
psum — tested against the exact psum in tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compressed_psum(x: jax.Array, axis_name: str,
                    error: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Approximate psum(x) over ``axis_name`` with int8 payload.

    Returns (sum_estimate, new_error). ``error`` is the per-device residual
    from the previous step (error feedback); pass zeros initially.
    """
    xc = x + error
    local_max = jnp.max(jnp.abs(xc))
    global_max = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(global_max, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xc / scale), -127, 127).astype(jnp.int8)
    total_q = jax.lax.psum(q.astype(jnp.int32), axis_name)
    dequant = total_q.astype(jnp.float32) * scale
    new_error = xc - q.astype(jnp.float32) * scale
    return dequant.astype(x.dtype), new_error.astype(x.dtype)


def compressed_psum_tree(grads: Any, axis_name: str,
                         errors: Any) -> Tuple[Any, Any]:
    """Tree version; errors tree must match grads."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    outs = [compressed_psum(g, axis_name, e) for g, e in zip(flat_g, flat_e)]
    sums = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    errs = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return sums, errs


def topk_compress(g: jax.Array, k_frac: float = 0.01):
    """Top-k sparsification (indices+values); returned dense for psum use.

    A building block for sparse all-reduce experiments; the fleet-scale wire
    format would send (idx, val) pairs — here we zero the rest and let the
    dense psum carry it (correctness-equivalent, bandwidth model only).
    """
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    kept = flat * mask
    return kept.reshape(g.shape), (flat - kept).reshape(g.shape)
