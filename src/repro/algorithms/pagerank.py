"""PageRank on the arithmetic semiring (paper §V).

The paper multiplies the *column-stochastic* adjacency by the rank vector
using ``bmv_bin_full_full`` with an auxiliary out-degree vector: each rank
entry is divided by its out-degree *before* the binary mxv — exactly the
refactoring that keeps the matrix binary. Dangling mass is redistributed
uniformly; parameters default to the paper's (alpha 0.85, 10 iters, eps 1e-9).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.semiring import ARITHMETIC


@dataclasses.dataclass
class PageRankResult:
    ranks: jax.Array
    n_iterations: int


def pagerank(g: GraphMatrix, alpha: float = 0.85, max_iters: int = 10,
             eps: float = 1e-9, row_chunk: Optional[int] = None) -> PageRankResult:
    n = g.n_rows
    gt = g.transposed()  # column-stochastic mxv == Aᵀ · (pr / outdeg)
    out_deg = g.degrees()
    dangling = out_deg == 0
    safe_deg = jnp.where(dangling, 1.0, out_deg)

    pr0 = jnp.full(n, 1.0 / n, jnp.float32)

    def cond(state):
        _, delta, it = state
        return (delta > eps) & (it < max_iters)

    def body(state):
        pr, _, it = state
        scaled = pr / safe_deg                      # the v_out_degree division
        contrib = gt.mxv(scaled, ARITHMETIC, Descriptor(row_chunk=row_chunk))
        dangle_mass = jnp.sum(jnp.where(dangling, pr, 0.0)) / n
        new = alpha * (contrib + dangle_mass) + (1.0 - alpha) / n
        return new, jnp.sum(jnp.abs(new - pr)), it + 1

    pr, _, it = jax.lax.while_loop(cond, body, (pr0, jnp.float32(jnp.inf),
                                                jnp.int32(0)))
    return PageRankResult(ranks=pr, n_iterations=int(it))


def ppr(g: GraphMatrix, seed: Union[int, jax.Array, np.ndarray],
        alpha: float = 0.85, max_iters: int = 10, eps: float = 1e-9,
        row_chunk: Optional[int] = None) -> PageRankResult:
    """Personalized PageRank: teleport to ``seed`` instead of uniformly.

    ``seed`` is a node id (one-hot restart) or a restart distribution
    ``[n]``. Dangling mass restarts into the same distribution, so rank mass
    stays within the seed's reachable set — the update is

        pr' = α·Aᵀ(pr/outdeg) + (α·dangling_mass + 1 − α)·r .

    The batched engine twin (``engine.queries.batched_ppr``) runs this
    per-column over a rank *matrix*; its columns are allclose to this loop.
    """
    n = g.n_rows
    if np.ndim(seed) == 0:
        if not 0 <= int(seed) < n:
            raise ValueError(f"seed {int(seed)} out of range [0, {n})")
        r = jnp.zeros(n, jnp.float32).at[int(seed)].set(1.0)
    else:
        r = jnp.asarray(seed, jnp.float32)
        if r.shape != (n,):
            raise ValueError(f"restart vector must have shape ({n},)")
    gt = g.transposed()
    out_deg = g.degrees()
    dangling = out_deg == 0
    safe_deg = jnp.where(dangling, 1.0, out_deg)

    def cond(state):
        _, delta, it = state
        return (delta > eps) & (it < max_iters)

    def body(state):
        pr, _, it = state
        scaled = pr / safe_deg
        contrib = gt.mxv(scaled, ARITHMETIC, Descriptor(row_chunk=row_chunk))
        dangle_mass = jnp.sum(jnp.where(dangling, pr, 0.0))
        new = alpha * contrib + (alpha * dangle_mass + (1.0 - alpha)) * r
        return new, jnp.sum(jnp.abs(new - pr)), it + 1

    pr, _, it = jax.lax.while_loop(cond, body, (r, jnp.float32(jnp.inf),
                                                jnp.int32(0)))
    return PageRankResult(ranks=pr, n_iterations=int(it))

