"""Per-query trace spans for the serving stack (DESIGN.md §14).

A :class:`Trace` is an append-only list of :class:`Span` intervals — named,
attributed, possibly nested — covering one query's life through the
serving pipeline: ``submit`` → ``queue_wait`` → ``plan_resolve`` →
``launch`` → ``scatter_back``. The trace rides on the query's handle
(``QueryHandle.trace``), so a slow query can be opened up after the fact
to see which stage ate the budget (plan compile vs kernel vs queue wait).

Group amortisation: the batching engine runs many queries as one launch,
so the group-level spans (plan_resolve / launch / scatter_back) are
*shared* Span objects adopted into every member handle's trace — N handles
reference one measurement, which is the truthful accounting (they really
did share that launch).

Timing discipline:

  - all span timestamps come from ``time.monotonic`` — the same clock the
    server stamps ``completed_at`` with by default — so span durations are
    directly comparable with observed handle latency. (The server's
    *injectable* clock governs deadlines and breaker cooldowns only; trace
    time is always real time.)
  - nested spans track their children; :attr:`Span.exclusive_s` is the
    self-time (duration minus direct children), so summing exclusive time
    over a whole trace never double-counts no matter how spans nest.

The ambient **current trace** (:func:`use` / :func:`current_span`) lets
deep layers (the plan cache, three frames below the server) attach spans
to whatever query group is in flight without threading a trace argument
through every signature. With observability disabled
(:func:`repro.obs.metrics.set_enabled`) ``span``/``current_span`` return
the shared :data:`NOOP_SPAN` and allocate nothing.
"""

from __future__ import annotations

import contextvars
import json
import time
from typing import Any, Dict, Iterable, List, Optional

from repro.obs import metrics as _metrics

__all__ = ["Span", "Trace", "NOOP_SPAN", "use", "current", "current_span",
           "annotate", "new_trace", "write_jsonl"]


class Span:
    """One named, attributed wall-time interval (monotonic seconds)."""

    __slots__ = ("name", "start_s", "end_s", "attrs", "children")

    def __init__(self, name: str, start_s: Optional[float] = None,
                 end_s: Optional[float] = None, **attrs):
        self.name = name
        self.start_s = time.monotonic() if start_s is None else start_s
        self.end_s = end_s
        self.attrs: Dict[str, Any] = dict(attrs)
        self.children: List["Span"] = []

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def finish(self) -> "Span":
        if self.end_s is None:
            self.end_s = time.monotonic()
        return self

    @property
    def duration_s(self) -> float:
        end = time.monotonic() if self.end_s is None else self.end_s
        return max(end - self.start_s, 0.0)

    @property
    def exclusive_s(self) -> float:
        """Self-time: duration minus direct children (never double-counts
        when summed over a nested trace)."""
        return max(self.duration_s
                   - sum(c.duration_s for c in self.children), 0.0)

    def to_dict(self) -> dict:
        d: dict = {"name": self.name, "start_s": self.start_s,
                   "duration_s": self.duration_s}
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["spans"] = [c.to_dict() for c in self.children]
        return d

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.3f}ms, "
                f"{self.attrs})")


class _NoOpSpan:
    """The disabled-mode span: every operation is a no-op on a singleton."""

    __slots__ = ()

    def __enter__(self) -> "_NoOpSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs) -> "_NoOpSpan":
        return self

    def finish(self) -> "_NoOpSpan":
        return self


NOOP_SPAN = _NoOpSpan()


class _SpanCtx:
    """Context manager that opens a span on enter, finishes on exit, and
    stamps an ``error`` attr when the body raises."""

    __slots__ = ("_trace", "_span")

    def __init__(self, trace: "Trace", span: Span):
        self._trace = trace
        self._span = span

    def __enter__(self) -> Span:
        self._trace._push(self._span)
        return self._span

    def __exit__(self, etype, evalue, tb) -> None:
        if evalue is not None:
            self._span.attrs["error"] = repr(evalue)
        self._span.finish()
        self._trace._pop(self._span)
        return None


class Trace:
    """One query's (or query group's) span collection."""

    def __init__(self, name: str = "query", **attrs):
        self.name = name
        self.attrs: Dict[str, Any] = dict(attrs)
        self.spans: List[Span] = []          # top-level spans, in order
        self._stack: List[Span] = []         # currently-open spans

    # -- recording -----------------------------------------------------------
    def span(self, name: str, **attrs):
        """Open a span as a context manager; nests under any open span."""
        if not _metrics.enabled():
            return NOOP_SPAN
        return _SpanCtx(self, Span(name, **attrs))

    def add_span(self, name: str, start_s: float, end_s: float,
                 **attrs) -> Optional[Span]:
        """Record an already-measured interval (e.g. queue wait) top-level."""
        if not _metrics.enabled():
            return None
        s = Span(name, start_s=start_s, end_s=end_s, **attrs)
        self.spans.append(s)
        return s

    def adopt(self, spans: Iterable[Span]) -> None:
        """Reference shared spans (one group measurement, many handles)."""
        self.spans.extend(spans)

    def _push(self, span: Span) -> None:
        if self._stack:
            self._stack[-1].children.append(span)
        else:
            self.spans.append(span)
        self._stack.append(span)

    def _pop(self, span: Span) -> None:
        if self._stack and self._stack[-1] is span:
            self._stack.pop()

    # -- inspection ----------------------------------------------------------
    def find(self, name: str) -> List[Span]:
        """All spans named ``name``, depth-first."""
        out: List[Span] = []

        def walk(spans: List[Span]) -> None:
            for s in spans:
                if s.name == name:
                    out.append(s)
                walk(s.children)

        walk(self.spans)
        return out

    def span_names(self) -> List[str]:
        out: List[str] = []

        def walk(spans: List[Span]) -> None:
            for s in spans:
                out.append(s.name)
                walk(s.children)

        walk(self.spans)
        return out

    def total_exclusive_s(self) -> float:
        """Summed self-time over every span (nesting never double-counts)."""
        total = 0.0

        def walk(spans: List[Span]) -> None:
            nonlocal total
            for s in spans:
                total += s.exclusive_s
                walk(s.children)

        walk(self.spans)
        return total

    def to_dict(self) -> dict:
        return {"name": self.name, "attrs": dict(self.attrs),
                "spans": [s.to_dict() for s in self.spans]}

    def __repr__(self) -> str:
        return f"Trace({self.name!r}, spans={self.span_names()})"


def new_trace(name: str = "query", **attrs) -> Optional[Trace]:
    """A fresh Trace, or None when observability is disabled (callers store
    the result on a handle and guard on None)."""
    return Trace(name, **attrs) if _metrics.enabled() else None


# ---------------------------------------------------------------------------
# Ambient current trace (contextvar: safe under nested groups)
# ---------------------------------------------------------------------------

_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_current_trace", default=None)


class _UseCtx:
    __slots__ = ("_trace", "_token")

    def __init__(self, trace: Optional[Trace]):
        self._trace = trace
        self._token = None

    def __enter__(self) -> Optional[Trace]:
        self._token = _CURRENT.set(self._trace)
        return self._trace

    def __exit__(self, *exc) -> None:
        _CURRENT.reset(self._token)
        return None


def use(trace: Optional[Trace]) -> _UseCtx:
    """Make ``trace`` the ambient current trace within the ``with`` body."""
    return _UseCtx(trace)


def current() -> Optional[Trace]:
    return _CURRENT.get()


def annotate(**attrs) -> None:
    """Set attrs on the innermost open span of the ambient trace (no-op
    when nothing is open) — lets deep layers tag the stage they run in."""
    tr = _CURRENT.get()
    if tr is not None and tr._stack and _metrics.enabled():
        tr._stack[-1].attrs.update(attrs)


def current_span(name: str, **attrs):
    """Open a span on the ambient trace (no-op span when there isn't one —
    the instrumented layer doesn't care whether anyone is watching)."""
    tr = _CURRENT.get()
    if tr is None or not _metrics.enabled():
        return NOOP_SPAN
    return tr.span(name, **attrs)


def write_jsonl(path: str, traces: Iterable[Trace],
                append: bool = False) -> int:
    """Dump traces one-JSON-object-per-line; returns how many were written."""
    n = 0
    with open(path, "a" if append else "w") as f:
        for tr in traces:
            f.write(json.dumps(tr.to_dict(), default=str) + "\n")
            n += 1
    return n
