"""Process-local metrics registry: labeled counters, gauges, histograms.

The single place every operational signal in the serving stack lands
(DESIGN.md §14). Instruments are *plain Python state* updated strictly
outside jit-traced code — an ``inc()`` is a dict lookup and a float add —
so the registry costs nothing measurable when nobody exports it, and a
module-level disable switch (:func:`set_enabled`) turns every record call
into an early return for the truly paranoid.

Model (pull-based, Prometheus-shaped):

  - a **metric** is (name, help, labelnames); a **series** is one concrete
    label-value assignment of it.  ``plan_cache_hits_total`` with
    ``labelnames=("kind", "backend")`` holds one float per observed
    (kind, backend) pair.
  - recording APIs take the labels as keyword arguments and *must* supply
    exactly the declared labelnames — a typo'd or missing label is a
    ``ValueError`` at the call site, never a silently separate series.
  - ``Histogram`` keeps cumulative buckets (Prometheus ``le`` semantics),
    count/sum, and a bounded sample window for exact quantiles.
  - the registry additionally carries a bounded **event log** (state
    transitions, direction switches, injected faults) — things that are
    moments, not rates.

Snapshots are plain dicts (:meth:`MetricsRegistry.snapshot`), exportable
as JSON (:meth:`to_json`) and Prometheus text format
(:meth:`to_prometheus`, round-trippable via
:func:`repro.obs.export.parse_prometheus`).
"""

from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_BUCKETS", "enabled", "set_enabled", "get_registry",
           "set_registry", "label_str"]

# ---------------------------------------------------------------------------
# Global enable switch + default registry
# ---------------------------------------------------------------------------

# REPRO_OBS_DISABLED=1 starts the process with observability off (the
# whole test suite passes either way — that property is itself a gate)
_ENABLED: List[bool] = [os.environ.get("REPRO_OBS_DISABLED", "")
                        not in ("1", "true")]


def enabled() -> bool:
    """Whether observability recording is globally on (default: yes)."""
    return _ENABLED[0]


def set_enabled(flag: bool) -> bool:
    """Flip the global switch; returns the previous value.

    Disabled means: counters/gauges/histograms ignore record calls, the
    event log ignores events, traces are not created, and spans are the
    shared no-op (``repro.obs.trace.NOOP_SPAN``).
    """
    prev = _ENABLED[0]
    _ENABLED[0] = bool(flag)
    return prev


#: Latency-oriented default histogram buckets (seconds), 1µs … 60s.
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2,
                   0.1, 0.5, 1.0, 5.0, 10.0, 60.0)

#: Exact-quantile sample window per histogram series.
SAMPLE_WINDOW = 2048


def label_str(labelnames: Tuple[str, ...], values: Tuple[str, ...]) -> str:
    """Prometheus-style label block: ``{k="v",k2="v2"}`` ('' if no labels)."""
    if not labelnames:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in zip(labelnames, values))
    return "{" + inner + "}"


class _Metric:
    """Shared series bookkeeping: label validation and get-or-create."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.labelnames: Tuple[str, ...] = tuple(labelnames)
        self._series: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.kind} {self.name!r} takes labels "
                f"{self.labelnames}, got {tuple(sorted(labels))}")
        # values coerced to str: label identity is textual (Prometheus
        # semantics), so True and "True" are the same series
        return tuple(str(labels[k]) for k in self.labelnames)

    def series(self) -> Dict[str, object]:
        """Snapshot: label block string -> value (subclass-shaped)."""
        return {label_str(self.labelnames, k): self._value(v)
                for k, v in sorted(self._series.items())}

    def _value(self, raw):
        return raw


class Counter(_Metric):
    """Monotonic labeled counter."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not _ENABLED[0]:
            return
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(amount={amount})")
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class Gauge(_Metric):
    """Labeled point-in-time value."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not _ENABLED[0]:
            return
        self._series[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not _ENABLED[0]:
            return
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + float(amount)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class _HistSeries:
    __slots__ = ("bucket_counts", "count", "sum", "samples")

    def __init__(self, n_buckets: int):
        self.bucket_counts = [0] * n_buckets   # per-bucket (not cumulative)
        self.count = 0
        self.sum = 0.0
        self.samples: deque = deque(maxlen=SAMPLE_WINDOW)


class Histogram(_Metric):
    """Labeled histogram: Prometheus buckets + exact windowed quantiles."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = (),
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))

    def _get(self, labels: Dict[str, object]) -> _HistSeries:
        key = self._key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.buckets) + 1)
        return s

    def observe(self, value: float, **labels) -> None:
        if not _ENABLED[0]:
            return
        s = self._get(labels)
        v = float(value)
        idx = len(self.buckets)              # +Inf bucket
        for i, le in enumerate(self.buckets):
            if v <= le:
                idx = i
                break
        s.bucket_counts[idx] += 1
        s.count += 1
        s.sum += v
        s.samples.append(v)

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return s.count if s is not None else 0

    def total(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return s.sum if s is not None else 0.0

    def quantile(self, q: float, **labels) -> Optional[float]:
        """Exact quantile over the bounded sample window (None if empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        s = self._series.get(self._key(labels))
        if s is None or not s.samples:
            return None
        xs = sorted(s.samples)
        return xs[min(int(q * len(xs)), len(xs) - 1)]

    def _value(self, raw: _HistSeries) -> dict:
        xs = sorted(raw.samples)

        def pct(q: float) -> Optional[float]:
            return xs[min(int(q * len(xs)), len(xs) - 1)] if xs else None

        cum, cum_counts = 0, []
        for c in raw.bucket_counts:
            cum += c
            cum_counts.append(cum)
        return {
            "count": raw.count, "sum": raw.sum,
            "mean": raw.sum / raw.count if raw.count else None,
            "p50": pct(0.50), "p90": pct(0.90), "p99": pct(0.99),
            "buckets": {("+Inf" if i == len(self.buckets)
                         else repr(self.buckets[i])): cum_counts[i]
                        for i in range(len(cum_counts))},
        }


class MetricsRegistry:
    """Name -> metric store with get-or-create, snapshots, and events."""

    def __init__(self, max_events: int = 1024,
                 clock=time.time):
        self._metrics: Dict[str, _Metric] = {}
        self._events: deque = deque(maxlen=max_events)
        self._clock = clock

    # -- instrument factories (get-or-create, schema-checked) ---------------
    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Iterable[str], **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, labelnames, **kw)
            return m
        if not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, not {cls.kind}")
        if tuple(labelnames) != m.labelnames:
            raise ValueError(
                f"metric {name!r} already registered with labels "
                f"{m.labelnames}, not {tuple(labelnames)}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    # -- events --------------------------------------------------------------
    def event(self, name: str, **attrs) -> None:
        """Record one moment-in-time occurrence (bounded ring buffer)."""
        if not _ENABLED[0]:
            return
        self._events.append({"ts": self._clock(), "event": name, **attrs})

    def events(self, name: Optional[str] = None) -> List[dict]:
        evs = list(self._events)
        return evs if name is None else [e for e in evs
                                         if e["event"] == name]

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """The whole registry as one plain (JSON-serialisable) dict."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {},
                     "events": list(self._events)}
        for name, m in sorted(self._metrics.items()):
            out[m.kind + "s"][name] = m.series()
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True,
                          default=str)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (events are not exported —
        they are moments, not scrapeable series)."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, raw in sorted(m._series.items()):
                    cum = 0
                    for i, le in enumerate(list(m.buckets) + ["+Inf"]):
                        cum += raw.bucket_counts[i]
                        lb = label_str(m.labelnames + ("le",),
                                       key + (str(le),))
                        lines.append(f"{name}_bucket{lb} {cum}")
                    lb = label_str(m.labelnames, key)
                    lines.append(f"{name}_sum{lb} {_fmt(raw.sum)}")
                    lines.append(f"{name}_count{lb} {raw.count}")
            else:
                for key, val in sorted(m._series.items()):
                    lines.append(
                        f"{name}{label_str(m.labelnames, key)} {_fmt(val)}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        self._metrics.clear()
        self._events.clear()


def _fmt(v: float) -> str:
    """Float formatting that round-trips and prints ints as ints."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# ---------------------------------------------------------------------------
# The process-default registry
# ---------------------------------------------------------------------------

_DEFAULT: List[MetricsRegistry] = [MetricsRegistry()]


def get_registry() -> MetricsRegistry:
    """The process-default registry (what instrumented components use when
    not handed an explicit one)."""
    return _DEFAULT[0]


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-default registry; returns the previous one (tests
    isolate themselves by swapping in a fresh registry and restoring)."""
    prev = _DEFAULT[0]
    _DEFAULT[0] = registry
    return prev
