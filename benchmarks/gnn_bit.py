"""BitGNN aggregation sweep: bit-path vs float GNN aggregation (DESIGN.md §15).

Two questions, one JSON (``results/gnn_bit.json``):

  **Latency** — the GCN hot loop is one neighborhood aggregation per
  layer. The float baselines (edge-wise ``segment_sum``, float-CSR SpMM)
  race the registry's bit rows: ``spmm_bin_full_full`` (packed adjacency ×
  dense features; jnp word scheme and the Pallas MXU kernel) and
  ``spmm_bin_bin_full`` (adjacency *and* activations packed, popcount
  accumulation). On community-dense graphs the bit rows win by feature
  reuse: A streams as 1 bit/edge and each tile's unpack feeds a t×t @ t×d
  multiply, while segment_sum gathers and scatters d floats per edge.

  **Accuracy at convergence** — a GCN trained with the registry bit-path
  aggregation vs the float segment-sum path on the same synthetic
  citation graph: same losses to the tolerance of float reduction order,
  so the latency win is not bought with model quality.
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, save_json, time_fn
from repro.core.graphblas import GraphMatrix
from repro.core.operands import BitMatrix
from repro.models.gnn.common import segment_agg

TILE_DIM = 32


def _agg_case(n: int, d_feat: int, density: float, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    A = (rng.random((n, n)) < density).astype(np.uint8)
    g = GraphMatrix.from_dense(A, tile_dim=TILE_DIM).with_buckets(False)
    gp = g.with_backend("b2sr_pallas")
    gc = g.with_backend("csr")
    r, c = np.nonzero(A)
    X = jnp.asarray(rng.standard_normal((n, d_feat)).astype(np.float32))
    send, recv = jnp.asarray(c), jnp.asarray(r)
    em = jnp.ones((r.size,), jnp.float32)
    bm = BitMatrix.pack(X > 0, TILE_DIM)

    paths = {
        "float_segment_sum": jax.jit(
            lambda x: segment_agg(x[send], recv, n, em, "sum")),
        "float_csr_spmm": jax.jit(lambda x: gc.mxm(x)),
        "bin_full_full_b2sr": jax.jit(lambda x: g.mxm(x)),
        "bin_full_full_pallas": jax.jit(lambda x: gp.mxm(x)),
    }
    case = {"n": n, "d_feat": d_feat, "density": density,
            "nnz": int(A.sum()), "tile_dim": TILE_DIM}
    for name, fn in paths.items():
        case[f"{name}_us"] = time_fn(fn, X) * 1e6
    # fully packed activations: both operands stay bit (popcount row)
    for name, gg in (("bin_bin_full_b2sr", g),
                     ("bin_bin_full_pallas", gp)):
        fn = jax.jit(lambda w, gg=gg: gg.mxm(
            BitMatrix.from_words(w, n, TILE_DIM)))
        case[f"{name}_us"] = time_fn(fn, bm.words) * 1e6
    case["speedup_bit_vs_segment_sum"] = (
        case["float_segment_sum_us"] / case["bin_full_full_b2sr_us"])
    case["speedup_pallas_vs_segment_sum"] = (
        case["float_segment_sum_us"] / case["bin_full_full_pallas_us"])
    return case


def _train_case(steps: int, nodes: int, use_b2sr: bool) -> dict:
    from repro.configs import get_config
    from repro.data.synthetic import full_graph_batch
    from repro.models.gnn import gcn
    from repro.training import optimizer as opt_mod
    from repro.training import train_steps

    cfg = get_config("gcn-cora")
    cfg = dataclasses.replace(cfg, d_in=32, n_classes=7, d_hidden=16,
                              use_b2sr=use_b2sr)
    batch = full_graph_batch(cfg, nodes, pattern="block", seed=3)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = opt_mod.OptimizerConfig(name="adamw", lr=5e-3)
    opt_state = opt_mod.init(opt_cfg, params)
    step = jax.jit(train_steps.gnn_train_step(cfg, opt_cfg))
    loss0 = loss = None
    for _ in range(steps):
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        loss0 = loss if loss0 is None else loss0
    logits = gcn.forward(params, batch, cfg)
    mask = np.asarray(batch.train_mask)
    acc = float((np.asarray(logits.argmax(-1))[mask]
                 == np.asarray(batch.labels)[mask]).mean())
    sec = time_fn(lambda: step(params, opt_state, batch)[2]["loss"])
    return {"aggregation": "bit_registry" if use_b2sr else "segment_sum",
            "steps": steps, "nodes": nodes, "loss_first": loss0,
            "loss_final": loss, "train_acc": acc,
            "step_us": sec * 1e6}


def run(tiny: bool = False) -> List[BenchRow]:
    n = 256 if tiny else 1024
    feats = (32,) if tiny else (64, 256)
    densities = (0.1,) if tiny else (0.05, 0.15)
    steps = 20 if tiny else 60

    detail = {"aggregation": [], "training": []}
    rows: List[BenchRow] = []
    for density in densities:
        for d_feat in feats:
            case = _agg_case(n, d_feat, density, seed=int(density * 100))
            detail["aggregation"].append(case)
            rows.append(BenchRow(
                f"gnn_bit/agg/n{n}/d{d_feat}/dens{density}",
                case["bin_full_full_b2sr_us"],
                f"seg_sum={case['float_segment_sum_us']:.0f}us "
                f"pallas={case['bin_full_full_pallas_us']:.0f}us "
                f"speedup={case['speedup_bit_vs_segment_sum']:.2f}x"))
    for use_b2sr in (True, False):
        tc = _train_case(steps, n, use_b2sr)
        detail["training"].append(tc)
        rows.append(BenchRow(
            f"gnn_bit/train/{tc['aggregation']}", tc["step_us"],
            f"loss={tc['loss_final']:.4f} acc={tc['train_acc']:.3f}"))
    path = save_json("gnn_bit.json", detail)
    rows.append(BenchRow("gnn_bit/json", 0.0, path))
    return rows
