"""Numerical equivalence of the §Perf shard_map aggregation paths.

The receiver-partitioned paths need >1 device, so the comparison runs in a
subprocess with 8 forced host devices (the main test process keeps the
default single device, per the dry-run-only rule for device forcing).

Data contract exercised here (and documented in DESIGN.md §8b): edges are
grouped by receiver block (block = receiver // (N / n_shards)) and padded
per block to a common count, so edge-shard i contains exactly the edges
whose receivers live in node-block i.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.configs.base import GNNConfig
    from repro.core import b2sr as b2sr_mod
    from repro.core import ops as b2sr_ops
    from repro.data import graphs as G
    from repro.models.gnn import gatedgcn
    from repro.models.gnn.common import GraphBatch

    P_SHARDS = 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    N, t = 256, 8
    n_local = N // P_SHARDS
    rng = np.random.default_rng(0)

    rows, cols = G.block_graph(N, n_blocks=8, intra_density=0.2, seed=1)

    # --- receiver-block partition + per-block padding (the data contract) --
    blk = cols // n_local
    per_block = [np.flatnonzero(blk == b) for b in range(P_SHARDS)]
    width = max(len(ix) for ix in per_block)
    pr = np.zeros((P_SHARDS, width), np.int64)
    pc = np.zeros((P_SHARDS, width), np.int64)
    msk = np.zeros((P_SHARDS, width), bool)
    for b, ix in enumerate(per_block):
        pr[b, :len(ix)] = rows[ix]
        pc[b, :len(ix)] = cols[ix]
        pc[b, len(ix):] = b * n_local          # padding stays in-block
        msk[b, :len(ix)] = True
    pr, pc, msk = pr.ravel(), pc.ravel(), msk.ravel()

    feat = rng.standard_normal((N, 16)).astype(np.float32)
    batch = GraphBatch(
        node_feat=jnp.asarray(feat),
        senders=jnp.asarray(pr.astype(np.int32)),
        receivers=jnp.asarray(pc.astype(np.int32)),
        node_mask=jnp.ones(N, bool),
        edge_mask=jnp.asarray(msk),
        labels=jnp.zeros(N, jnp.int32),
        train_mask=jnp.ones(N, bool),
        graph_ids=jnp.zeros(N, jnp.int32),
    )

    cfg0 = GNNConfig(name="t", family="gatedgcn", n_layers=2, d_hidden=16,
                     d_in=16, n_classes=4)
    cfg1 = dataclasses.replace(cfg0, shardmap_agg_axes=("data", "model"))
    params = gatedgcn.init_params(cfg0, jax.random.PRNGKey(0))

    with mesh:
        ref = gatedgcn.forward(params, batch, cfg0)
        out = gatedgcn.forward(params, batch, cfg1)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-4, atol=2e-4)
    print("GATEDGCN_OK")

    # --- B2SR shard_map SpMM vs local SpMM (tile-rows partitioned) --------
    mat = b2sr_mod.coo_to_b2sr(rows, cols, N, N, t)
    ell = b2sr_mod.to_ell(mat, pad_tile_rows_to=P_SHARDS)
    x = jnp.asarray(rng.standard_normal((N, 16)).astype(np.float32))
    x_pad = jnp.pad(x, ((0, ell.n_tile_rows * t - N), (0, 0)))
    ell_full = dataclasses.replace(ell, n_rows=ell.n_tile_rows * t,
                                   n_cols=ell.n_tile_rows * t)
    with mesh:
        ref2 = b2sr_ops.spmm_b2sr(ell_full, x_pad)
        out2 = b2sr_ops.spmm_b2sr_shardmap(ell_full, x_pad,
                                           ("data", "model"))
    np.testing.assert_allclose(np.asarray(ref2), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
    print("SPMM_OK")
""")


@pytest.fixture(scope="module")
def subprocess_run():
    return subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=420, env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.parametrize("marker", ["GATEDGCN_OK", "SPMM_OK"])
def test_shardmap_matches_dense(subprocess_run, marker):
    assert subprocess_run.returncode == 0, subprocess_run.stderr[-3000:]
    assert marker in subprocess_run.stdout
