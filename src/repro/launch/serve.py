"""Batched serving driver: prefill + decode loop with latency accounting.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --batch 4 --prompt-len 32 --gen 16

Reduced configs on CPU exercise the exact production code path (the full
configs serve on TPU slices through the same entry point, sharded by
``rules.lm_param_specs`` / ``lm_cache_specs``).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.configs.base import TransformerConfig
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full_config
           else get_reduced_config(args.arch))
    if not isinstance(cfg, TransformerConfig):
        raise SystemExit(f"{args.arch} is not an LM arch")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    B, S, G = args.batch, args.prompt_len, args.gen
    max_len = S + G
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)),
        jnp.int32)

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg, last_only=True))
    decode = jax.jit(lambda p, tok, ck, cv, n: T.decode_step(
        p, tok, ck, cv, n, cfg))

    # warmup compiles
    logits, (pk, pv) = prefill(params, prompts)
    ck, cv = T.init_cache(cfg, B, max_len)
    ck = ck.at[:, :, :S].set(pk)
    cv = cv.at[:, :, :S].set(pv)
    tok = logits.argmax(-1).reshape(B, 1).astype(jnp.int32)
    _ = decode(params, tok, ck, cv, jnp.int32(S))
    jax.block_until_ready(_)

    t0 = time.perf_counter()
    logits, (pk, pv) = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    ck = T.init_cache(cfg, B, max_len)[0].at[:, :, :S].set(pk)
    cv = T.init_cache(cfg, B, max_len)[1].at[:, :, :S].set(pv)
    tok = logits.argmax(-1).reshape(B, 1).astype(jnp.int32)
    out_tokens = [tok]
    lat = []
    pos = jnp.int32(S)
    for _ in range(G - 1):
        t0 = time.perf_counter()
        logits, ck, cv = decode(params, tok, ck, cv, pos)
        tok = logits.argmax(-1).reshape(B, 1).astype(jnp.int32)
        jax.block_until_ready(tok)
        lat.append(time.perf_counter() - t0)
        out_tokens.append(tok)
        pos = pos + 1

    lat_ms = np.asarray(lat) * 1e3
    print(f"arch={cfg.name} batch={B} prompt={S} gen={G}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")
    print(f"decode:  p50 {np.percentile(lat_ms, 50):.1f} ms  "
          f"p99 {np.percentile(lat_ms, 99):.1f} ms  "
          f"({B/np.mean(lat):.0f} tok/s)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("sample continuation:", np.asarray(gen[0])[:10].tolist())


if __name__ == "__main__":
    main()
