"""Training substrate: optimizers, schedules, checkpointing, fault tolerance,
gradient compression, train-step builders."""
