"""Paper Table IX: triangle counting via the fused masked BMM.

B2SR backend (bmm_bin_bin_sum_masked) vs the float baseline (dense masked
matmul, the CSR-path stand-in), cross-checked for exact counts.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, corpus, save_json, time_fn
from repro.algorithms.tc import triangle_count
from repro.core.graphblas import GraphMatrix


def run(n: int = 1024, tile_dim: int = 32) -> List[BenchRow]:
    rows: List[BenchRow] = []
    detail = {}
    for name, (r, c, nn) in corpus(n).items():
        g_bit = GraphMatrix.from_coo(r, c, nn, nn, tile_dim, backend="b2sr")
        g_csr = g_bit.with_backend("csr")
        n_bit = triangle_count(g_bit)
        n_csr = triangle_count(g_csr)
        agree = n_bit == n_csr
        t_bit = time_fn(triangle_count, g_bit, warmup=1, iters=3)
        t_csr = time_fn(triangle_count, g_csr, warmup=1, iters=3)
        detail[name] = {
            "triangles": n_bit, "b2sr_ms": t_bit * 1e3, "csr_ms": t_csr * 1e3,
            "speedup": t_csr / t_bit, "agree": agree,
        }
        rows.append(BenchRow(
            f"tableIX/tc/{name}", t_bit * 1e6,
            f"triangles={n_bit} speedup={t_csr / t_bit:.2f}x agree={agree}"))
        assert agree, f"TC mismatch on {name}: {n_bit} vs {n_csr}"
    save_json("triangle_counting.json", detail)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
