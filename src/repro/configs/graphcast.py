"""graphcast [arXiv:2212.12794]: encoder-processor-decoder mesh GNN.

16 processor layers, d=512, mesh refinement 6 (multimesh), 227 variables.
"""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="graphcast",
    family="graphcast",
    n_layers=16,
    d_hidden=512,
    aggregator="sum",
    mesh_refinement=6,
    n_vars=227,
    d_in=227,
    n_classes=227,  # decoder predicts the variables back
)


def reduced() -> GNNConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, name="graphcast-smoke", n_layers=2,
                               d_hidden=32, mesh_refinement=1, n_vars=8,
                               d_in=8, n_classes=8)
