"""din [arXiv:1706.06978]: target-attention over user behaviour sequence."""

from repro.configs.base import DINConfig

CONFIG = DINConfig(
    name="din",
    embed_dim=18,
    seq_len=100,
    attn_mlp=(80, 40),
    mlp=(200, 80),
    n_items=1_000_000,
    n_cates=10_000,
)


def reduced() -> DINConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="din-smoke", embed_dim=8, seq_len=10, attn_mlp=(16, 8),
        mlp=(32, 16), n_items=1000, n_cates=100, n_user_feats=2,
        user_feat_vocab=100)
