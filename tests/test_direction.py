"""Direction-optimizing traversal: the bit-exact differential harness.

A silently-wrong push/pull switch still returns *a* BFS tree, so every
(direction × backend × buckets × batch × sharded) combination is pinned
against a single oracle — forced-push on the plain b2sr backend — and the
per-iteration direction trace on the result object is asserted too: the
tests check *which* path ran, not just that the answer matched
(DESIGN.md §12).

Layout:
  - scheme-level parity: the registered ``mxv_pull`` rows (jnp, bucketed,
    Pallas early-exit kernel, csr) against the masked push row
  - algorithm differential: bfs / msbfs / cc under push / pull / auto
    across tile dims 4–32 × 3 backends × buckets on/off × batch widths
    1 / 8 / 33
  - the hysteresis property (hypothesis): auto == push oracle bit-exact
    and the trace is monotone (one pull regime, no flapping)
  - validation fixes: ``max_iters`` (0 and negative) handled identically
    on the single-source and batched paths
  - sharded parity: 8 forced host devices in a subprocess
    (test_partition.py pattern)
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from conftest import optional_hypothesis  # noqa: E402

from repro.algorithms import direction as direction_mod  # noqa: E402
from repro.algorithms.bfs import BFSResult, bfs  # noqa: E402
from repro.algorithms.cc import connected_components  # noqa: E402
from repro.algorithms.direction import DirectionConfig  # noqa: E402
from repro.core.descriptor import Descriptor  # noqa: E402
from repro.core.graphblas import GraphMatrix  # noqa: E402
from repro.core.operands import BitVector  # noqa: E402
from repro.data import graphs as G  # noqa: E402
from repro.engine.queries import msbfs  # noqa: E402

TILE_DIMS = (4, 8, 16, 32)
#: (backend, use_buckets) — csr has no bucketed path (registered BOTH).
BACKEND_CASES = (("b2sr", False), ("b2sr", True),
                 ("b2sr_pallas", False), ("b2sr_pallas", True),
                 ("csr", False))
BATCH_WIDTHS = (1, 8, 33)
N = 72                                   # not a multiple of any tile dim


def mixed_graph(n, seed=0, rmat_degree=6, erdos_density=0.02):
    """rmat skew × erdős scatter — the density mix the heuristic sees."""
    r1, c1 = G.rmat_graph(n, avg_degree=rmat_degree, seed=seed)
    r2, c2 = G.dot_graph(n, density=erdos_density, seed=seed + 1)
    rows = np.concatenate([r1, r2])
    cols = np.concatenate([c1, c2])
    key = np.unique(rows.astype(np.int64) * n + cols)
    return key // n, key % n


def build(backend="b2sr", tile_dim=8, buckets=False, n=N, seed=0, **kw):
    rows, cols = mixed_graph(n, seed=seed, **kw)
    g = GraphMatrix.from_coo(rows, cols, n_rows=n, n_cols=n,
                             tile_dim=tile_dim, backend=backend)
    return g.with_buckets(buckets)


def assert_trace_well_formed(res, mode):
    assert len(res.directions) == res.n_iterations
    assert all(d in ("push", "pull") for d in res.directions)
    if mode == "push":
        assert set(res.directions) <= {"push"}, res.directions
    elif mode == "pull":
        assert set(res.directions) <= {"pull"}, res.directions
    else:
        assert direction_mod.check_monotone(res.directions), res.directions


# ---------------------------------------------------------------------------
# scheme-level parity: every registered pull row == the masked push row
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile_dim", TILE_DIMS)
@pytest.mark.parametrize("backend,buckets", BACKEND_CASES)
def test_pull_row_parity(backend, buckets, tile_dim):
    g = build(backend, tile_dim, buckets, seed=tile_dim)
    rng = np.random.default_rng(tile_dim)
    x = BitVector.pack(jnp.asarray(rng.random(N) > 0.5, jnp.float32),
                       tile_dim, N)
    visited = BitVector.pack(jnp.asarray(rng.random(N) > 0.6, jnp.float32),
                             tile_dim, N)
    push = g.mxv(x, desc=Descriptor(mask=visited, complement=True))
    pull = g.mxv(x, desc=Descriptor(mask=visited, complement=True,
                                    direction="pull"))
    assert np.array_equal(np.asarray(push.words), np.asarray(pull.words))
    # non-complement masks ride the same row
    push = g.mxv(x, desc=Descriptor(mask=visited))
    pull = g.mxv(x, desc=Descriptor(mask=visited, direction="pull"))
    assert np.array_equal(np.asarray(push.words), np.asarray(pull.words))


def test_pull_pallas_kernel_against_oracle():
    """The early-exit kernel itself vs the densify-and-matmul oracle."""
    from repro.kernels.bmv import ops as bmv_ops, ref
    for t in TILE_DIMS:
        g = build("b2sr_pallas", t, False, seed=7 + t)
        rng = np.random.default_rng(t)
        x = BitVector.pack(jnp.asarray(rng.random(N) > 0.4, jnp.float32),
                           t, N)
        m = BitVector.pack(jnp.asarray(rng.random(N) > 0.5, jnp.float32),
                           t, N)
        for complement in (True, False):
            got = bmv_ops.bmv_bin_bin_bin_pull(g.ell, x.words, m.words,
                                               complement)
            want = ref.bmv_bin_bin_bin_pull(g.ell, x.words, m.words,
                                            complement)
            assert np.array_equal(np.asarray(got), np.asarray(want)), \
                (t, complement)


def test_pull_requires_masked_packed_row():
    g = build()
    x = BitVector.pack(jnp.ones(N, jnp.float32), 8, N)
    with pytest.raises(ValueError, match="masked packed"):
        g.mxv(x, desc=Descriptor(direction="pull"))       # no mask
    with pytest.raises(ValueError, match="direction"):
        g.mxv(x, desc=Descriptor(mask=x, direction="sideways"))
    with pytest.raises(ValueError, match="masked packed"):
        g.mxv(jnp.ones(N, jnp.float32),
              desc=Descriptor(mask=jnp.ones(N), direction="pull"))


def test_direction_config_validates():
    with pytest.raises(ValueError, match="mode"):
        DirectionConfig(mode="sideways")
    with pytest.raises(ValueError, match="positive"):
        DirectionConfig(alpha=-1.0)
    assert direction_mod.as_config(None).mode == "push"
    assert direction_mod.as_config("auto").mode == "auto"
    cfg = DirectionConfig(mode="pull", alpha=0.5)
    assert direction_mod.as_config(cfg) is cfg


# ---------------------------------------------------------------------------
# bfs differential: push oracle vs pull vs auto, all backends × tile dims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile_dim", TILE_DIMS)
@pytest.mark.parametrize("backend,buckets", BACKEND_CASES)
def test_bfs_direction_differential(backend, buckets, tile_dim):
    g = build(backend, tile_dim, buckets, seed=11)
    oracle = np.asarray(bfs(build("b2sr", tile_dim, False, seed=11), 0,
                            direction="push").levels)
    for mode in ("push", "pull", "auto"):
        res = bfs(g, 0, direction=mode)
        assert np.array_equal(np.asarray(res.levels), oracle), \
            (backend, buckets, tile_dim, mode)
        assert_trace_well_formed(res, mode)


def test_bfs_auto_actually_switches():
    """On a dense-frontier graph the heuristic must pick pull mid-run —
    otherwise the auto tests exercise nothing but push."""
    g = build("b2sr", 8, False, seed=3, rmat_degree=10, erdos_density=0.05)
    res = bfs(g, 0, direction="auto")
    assert "pull" in res.directions, res.directions
    assert res.directions[0] == "push", res.directions
    push = bfs(g, 0, direction="push")
    assert np.array_equal(np.asarray(res.levels), np.asarray(push.levels))


def test_bfs_custom_thresholds():
    g = build(seed=5)
    # alpha so large auto never leaves push; beta tiny keeps pull sticky
    never = bfs(g, 0, direction=DirectionConfig(mode="auto", alpha=1e9))
    assert set(never.directions) <= {"push"}
    eager = bfs(g, 0, direction=DirectionConfig(mode="auto", alpha=1e-9,
                                                beta=1e9))
    assert "pull" in eager.directions
    push = bfs(g, 0, direction="push")
    for res in (never, eager):
        assert np.array_equal(np.asarray(res.levels),
                              np.asarray(push.levels))


def test_bfs_row_chunk_direction_parity():
    g = build("b2sr", 8, False, seed=9)
    push = bfs(g, 0, direction="push", row_chunk=3)
    pull = bfs(g, 0, direction="pull", row_chunk=3)
    assert np.array_equal(np.asarray(push.levels), np.asarray(pull.levels))


# ---------------------------------------------------------------------------
# msbfs differential: batch widths 1 / 8 / 33, whole-batch switching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", BATCH_WIDTHS)
@pytest.mark.parametrize("backend,buckets", BACKEND_CASES)
def test_msbfs_direction_differential(backend, buckets, width):
    g = build(backend, 8, buckets, seed=21)
    srcs = [int(s) for s in
            np.random.default_rng(width).choice(N, width, replace=False)]
    push = msbfs(g, srcs, direction="push")
    assert set(push.directions) <= {"push"}
    # columns of the push batch match the single-source push oracle
    for j in (0, width - 1):
        single = bfs(g, srcs[j], direction="push")
        assert np.array_equal(np.asarray(push.levels[:, j]),
                              np.asarray(single.levels))
    for mode in ("pull", "auto"):
        res = msbfs(g, srcs, direction=mode)
        assert np.array_equal(np.asarray(res.levels),
                              np.asarray(push.levels)), \
            (backend, buckets, width, mode)
        assert_trace_well_formed(res, mode)


def test_bfs_batched_routes_with_direction():
    g = build(seed=2)
    res = bfs(g, [0, 5, 9], direction="pull")
    assert set(res.directions) <= {"pull"}
    push = bfs(g, [0, 5, 9], direction="push")
    assert np.array_equal(np.asarray(res.levels), np.asarray(push.levels))


def test_msbfs_plan_keys_isolate_direction():
    """push / pull / auto loops are different XLA programs — they must
    never share a cached plan (the descriptor key carries the config)."""
    from repro.engine.planner import PlanCache
    pc = PlanCache()
    g = build(seed=4)
    for mode in ("push", "pull", "auto"):
        msbfs(g, [0, 1], direction=mode, planner=pc)
    assert len(pc) == 3
    # same mode, different thresholds: also distinct
    msbfs(g, [0, 1], planner=pc,
          direction=DirectionConfig(mode="auto", alpha=0.5))
    assert len(pc) == 4


# ---------------------------------------------------------------------------
# cc differential: orientation switching on the symmetric adjacency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,buckets", BACKEND_CASES)
def test_cc_direction_differential(backend, buckets):
    g = build(backend, 8, buckets, seed=31)
    oracle = connected_components(build("b2sr", 8, False, seed=31),
                                  direction="push")
    for mode in ("push", "pull", "auto"):
        res = connected_components(g, direction=mode)
        assert np.array_equal(np.asarray(res.labels),
                              np.asarray(oracle.labels)), \
            (backend, buckets, mode)
        assert_trace_well_formed(res, mode)


def test_cc_without_transpose_falls_back_to_push():
    rows, cols = mixed_graph(N, seed=31)
    g = GraphMatrix.from_coo(rows, cols, n_rows=N, n_cols=N, tile_dim=8,
                             with_transpose=False)
    res = connected_components(g, direction="auto")
    assert set(res.directions) <= {"push"}
    ref = connected_components(build("b2sr", 8, False, seed=31),
                               direction="push")
    assert np.array_equal(np.asarray(res.labels), np.asarray(ref.labels))


# ---------------------------------------------------------------------------
# the max_iters fix: single-source and batched paths validate identically
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source", (5, [5, 7]))
def test_max_iters_zero_returns_zero_iteration_shape(source):
    g = build(seed=13)
    res = bfs(g, source, max_iters=0)
    assert res.n_iterations == 0
    assert res.directions == ()
    lv = np.asarray(res.levels)
    if np.ndim(source) > 0:
        assert lv.shape == (N, len(source))
        for j, s in enumerate(source):
            assert lv[s, j] == 0
        assert (lv >= 0).sum() == len(source)   # only the sources stamped
    else:
        assert lv.shape == (N,)
        assert lv[source] == 0 and (lv >= 0).sum() == 1


@pytest.mark.parametrize("source", (5, [5, 7]))
def test_max_iters_negative_raises(source):
    g = build(seed=13)
    with pytest.raises(ValueError, match="max_iters"):
        bfs(g, source, max_iters=-1)


def test_batched_row_chunk_still_raises():
    g = build(seed=13)
    with pytest.raises(ValueError, match="row_chunk"):
        bfs(g, [1, 2], row_chunk=4)


def test_max_iters_one_partial_levels():
    g = build(seed=13)
    one = bfs(g, 0, max_iters=1)
    full = bfs(g, 0)
    assert one.n_iterations == 1 and len(one.directions) == 1
    lv1, lvf = np.asarray(one.levels), np.asarray(full.levels)
    # exactly levels 0 and 1 are settled after one iteration
    assert np.array_equal(lv1[lv1 >= 0], lvf[lv1 >= 0])
    assert (lv1 >= 0).sum() == ((lvf >= 0) & (lvf <= 1)).sum()


# ---------------------------------------------------------------------------
# hypothesis: auto == push oracle + monotone trace across the density sweep
# ---------------------------------------------------------------------------

given, settings, st = optional_hypothesis()


@given(rmat_degree=st.integers(min_value=2, max_value=14),
       erdos_density=st.floats(min_value=0.0, max_value=0.12),
       seed=st.integers(min_value=0, max_value=2 ** 16))
@settings(max_examples=20, deadline=None, derandomize=True)
def test_auto_matches_push_and_trace_is_monotone(rmat_degree, erdos_density,
                                                 seed):
    g = build("b2sr", 8, False, n=64, seed=seed, rmat_degree=rmat_degree,
              erdos_density=erdos_density)
    push = bfs(g, int(seed) % 64, direction="push")
    auto = bfs(g, int(seed) % 64, direction="auto")
    assert np.array_equal(np.asarray(push.levels),
                          np.asarray(auto.levels)), \
        f"auto != push oracle; trace={auto.directions}"
    assert direction_mod.check_monotone(auto.directions), \
        f"direction flapping: {auto.directions}"
    assert len(auto.directions) == auto.n_iterations, \
        f"trace length mismatch: {auto.directions} vs {auto.n_iterations}"


# ---------------------------------------------------------------------------
# sharded parity: 8 forced host devices (test_partition.py pattern)
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    from repro.algorithms.bfs import bfs
    from repro.algorithms.cc import connected_components
    from repro.core.graphblas import GraphMatrix
    from repro.data import graphs as G
    from repro.engine.queries import msbfs
    from repro.launch.mesh import make_debug_mesh

    assert len(jax.devices()) == 8
    n = 128
    r1, c1 = G.rmat_graph(n, avg_degree=6, seed=17)
    r2, c2 = G.dot_graph(n, density=0.02, seed=18)
    key = np.unique(np.concatenate([r1, r2]).astype(np.int64) * n
                    + np.concatenate([c1, c2]))
    rows, cols = key // n, key % n
    mesh = make_debug_mesh(8, model=2)            # (data=4, model=2)

    for backend in ("b2sr", "b2sr_pallas"):
        for buckets in (False, True):
            g = GraphMatrix.from_coo(rows, cols, n_rows=n, n_cols=n,
                                     tile_dim=8, backend=backend
                                     ).with_buckets(buckets)
            gs = g.shard(mesh)
            oracle = np.asarray(bfs(g, 0, direction="push").levels)
            for mode in ("push", "pull", "auto"):
                res = bfs(gs, 0, direction=mode)
                assert np.array_equal(np.asarray(res.levels), oracle), \\
                    (backend, buckets, mode)
            auto = bfs(gs, 0, direction="auto")
            assert "pull" in auto.directions, auto.directions
    print("BFS_SHARDED_OK")

    g = GraphMatrix.from_coo(rows, cols, n_rows=n, n_cols=n, tile_dim=8)
    gs = g.shard(mesh)
    srcs = [0, 5, 9, 40]
    push = msbfs(g, srcs, direction="push")
    for mode in ("push", "pull", "auto"):
        res = msbfs(gs, srcs, direction=mode)
        assert np.array_equal(np.asarray(res.levels),
                              np.asarray(push.levels)), mode
    print("MSBFS_SHARDED_OK")

    ref = connected_components(g, direction="push")
    for mode in ("push", "pull", "auto"):
        res = connected_components(gs, direction=mode)
        assert np.array_equal(np.asarray(res.labels),
                              np.asarray(ref.labels)), mode
    print("CC_SHARDED_OK")
""")

MARKERS = ["BFS_SHARDED_OK", "MSBFS_SHARDED_OK", "CC_SHARDED_OK"]


@pytest.fixture(scope="module")
def sharded_direction_run():
    return subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=900, env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.parametrize("marker", MARKERS)
def test_sharded_direction_parity(sharded_direction_run, marker):
    assert sharded_direction_run.returncode == 0, \
        sharded_direction_run.stderr[-4000:]
    assert marker in sharded_direction_run.stdout
