"""Core: B2SR format, semirings, GraphBLAS ops, sampling profiler."""

from repro.core.b2sr import (  # noqa: F401
    B2SR,
    B2SRBucketedEll,
    B2SREll,
    TILE_DIMS,
    b2sr_to_coo,
    b2sr_to_dense,
    best_tile_dim,
    bit_transpose_words,
    compression_ratio,
    coo_to_b2sr,
    csr_storage_bytes,
    csr_to_b2sr,
    dense_to_b2sr,
    ell_fill_ratio,
    ell_to_packed_grid,
    occupancy,
    pack_bitvector,
    pack_dense_tiles,
    pack_frontier_matrix,
    pack_tile_bits,
    packed_grid_to_b2sr,
    to_bucketed,
    to_ell,
    transpose,
    unpack_bitvector,
    unpack_frontier_matrix,
    unpack_tiles,
)
from repro.core.descriptor import DEFAULT, Descriptor  # noqa: F401
from repro.core.graphblas import BACKENDS, GraphMatrix  # noqa: F401
from repro.core.operands import (  # noqa: F401
    BitVector,
    FrontierBatch,
    operand_kind,
)
from repro.core.partition import (  # noqa: F401
    PartitionedB2SR,
    mesh_fingerprint,
    partition_rows,
    shard_count,
    unpartition,
)
from repro.core.sampling import SampleProfile, sample_profile  # noqa: F401
from repro.core.semiring import (  # noqa: F401
    ARITHMETIC,
    BOOLEAN,
    MAX_TIMES,
    MIN_PLUS,
    SEMIRINGS,
    Semiring,
)
