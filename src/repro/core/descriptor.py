"""GraphBLAS operation descriptor (the spec's ``GrB_Descriptor``).

One frozen dataclass replaces the ad-hoc ``mask= / complement= /
row_chunk=`` kwargs that were threaded through every ``GraphMatrix``
method (DESIGN.md §10):

  mask         structural output mask, applied right before the store
               (paper §V). Its *type* must match the op's output: a
               ``BitVector`` for packed mxv, a ``FrontierBatch`` for
               multi-frontier mxm, a ``GraphMatrix`` for SpGEMM, a dense
               array for full-precision outputs.
  complement   use ⟨¬M⟩ instead of ⟨M⟩ (BFS keeps *unvisited* bits).
  transpose_a  operate on Aᵀ (the spec's INP0 transpose): ``vxm`` is
               ``mxv`` with ``transpose_a=True`` — resolved against the
               stored transposed representation, never materialised.
  replace      True (default): masked-out output entries are set to the
               ⊕-identity (zero bits / identity values) — the paper's
               mask-at-store. False: masked-out entries are taken from
               the previous output, passed as ``out=`` (the spec's
               C⟨M⟩ merge without REPLACE); requires ``out``.
  row_chunk    bounded-memory evaluation: map the op over row chunks
               instead of one launch (disables the bucketed path, which
               needs the whole row axis).
  direction    None (default) resolves the ordinary Table row; "pull"
               resolves the fused pull row (``mxv_pull``/``mxm_pull``):
               the complement-masked transposed traversal whose Pallas
               kernel early-exits per output row on the first set bit
               (DESIGN.md §12). Pull is only meaningful for the masked
               packed bin·bin→bin rows — the generic layer rejects it
               elsewhere. The push/pull *decision* lives in
               ``repro.algorithms.direction``; the descriptor only
               carries the resolved choice to dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

#: Sentinel distinguishing "kwarg not given" from an explicit None.
_UNSET = object()


@dataclasses.dataclass(frozen=True)
class Descriptor:
    mask: Any = None
    complement: bool = False
    transpose_a: bool = False
    replace: bool = True
    row_chunk: Optional[int] = None
    direction: Optional[str] = None

    def replace_with(self, **kw) -> "Descriptor":
        return dataclasses.replace(self, **kw)


#: The all-defaults descriptor (no mask, no transpose, replace semantics).
DEFAULT = Descriptor()


def merge_sugar(desc: Optional[Descriptor], mask=_UNSET, complement=_UNSET,
                row_chunk=_UNSET) -> Descriptor:
    """Fold convenience kwargs (``mask=``, ``complement=``, ``row_chunk=``)
    into a :class:`Descriptor`.

    The kwargs are sugar for one-off calls; composed/looped code passes a
    ``desc``. Passing both is ambiguous and raises.
    """
    sugar = {k: v for k, v in
             (("mask", mask), ("complement", complement),
              ("row_chunk", row_chunk)) if v is not _UNSET}
    if desc is None:
        return Descriptor(**sugar) if sugar else DEFAULT
    if sugar:
        raise ValueError(
            f"pass either desc= or the {sorted(sugar)} kwargs, not both")
    return desc
