"""Pure-jnp oracle for the SpMM kernel: densify, then dense matmul."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.b2sr import B2SREll
from repro.kernels.bmv.ref import dense_from_ell


def spmm(ell: B2SREll, x: jnp.ndarray) -> jnp.ndarray:
    a = dense_from_ell(ell, x.dtype)
    return a @ x
