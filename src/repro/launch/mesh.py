"""Production mesh construction (DESIGN.md §7).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; tests and
benches see the default single device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod ("data", "model"); 2 pods adds a leading "pod".

    Under the dry-run's 512 placeholder devices the single-pod mesh takes the
    first 256; on real hardware the defaults resolve to the attached slice.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) > n:
        devices = devices[:n]
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices).reshape(shape), axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small ("data", "model") mesh over whatever devices exist
    (multi-device CPU tests; the sharded-parity test mesh factory).

    ``n`` must divide evenly into ``(n // model, model)`` — the old
    floor-division silently built a mesh over fewer devices than asked
    (n=6, model=4 -> a (1, 4) mesh that dropped 2 devices), which turns a
    topology mistake into a quiet perf bug. Now it raises instead.
    """
    avail = len(jax.devices())
    n = n_devices or avail
    if n < 1 or n > avail:
        raise ValueError(f"make_debug_mesh: n_devices={n} out of range — "
                         f"{avail} device(s) available")
    if n % model != 0:
        raise ValueError(
            f"make_debug_mesh: n_devices={n} is not divisible by "
            f"model={model} — a ({n // model}, {model}) mesh would silently "
            f"drop {n - (n // model) * model} device(s); pick model from the "
            f"divisors of {n} or pass a matching n_devices")
    return jax.make_mesh((n // model, model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_LINK_BW = 50e9                # B/s per link
