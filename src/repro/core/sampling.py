"""Sampling profiler (paper Algorithm 1): estimate per-tile-size compression.

Samples N rows; for each sampled row counts the distinct tile-columns its
nonzeros fall into per tile size k ∈ {4, 8, 16, 32}. From the per-row
(nnz, occupied-tile-column) counts it estimates the B2SR byte size and
recommends a tile size (or CSR if nothing compresses).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.b2sr import TILE_DIMS, _STORE_BYTES, _INDEX_BYTES, ceil_div, csr_storage_bytes


@dataclasses.dataclass(frozen=True)
class SampleProfile:
    est_b2sr_bytes: Dict[int, float]      # tile_dim -> estimated total bytes
    est_compression: Dict[int, float]     # tile_dim -> est B2SR/CSR ratio
    recommended_tile_dim: Optional[int]   # None -> stay on CSR
    sampled_rows: int


def sample_profile(row_ptr: np.ndarray, col_idx: np.ndarray, n_rows: int,
                   n_cols: int, n_samples: int = 64,
                   seed: int = 0, value_bytes: int = 4) -> SampleProfile:
    """Algorithm 1 with byte-size estimation on top of the tile-col counts.

    Estimator: each sampled row anchors its whole *tile-row* — the k
    consecutive rows sharing its tiles. We union the tile-column sets of
    those k rows exactly (the paper's ``ColCounter[k][i][j/k]`` accumulation
    restricted to the sampled tile-rows), so the only error left is sampling
    error; no independence model. Overhead stays O(samples × k × nnz/row).
    """
    rng = np.random.default_rng(seed)
    row_ptr = np.asarray(row_ptr)
    col_idx = np.asarray(col_idx)
    n = min(n_samples, n_rows)
    sample = rng.choice(n_rows, size=n, replace=False)

    est_bytes: Dict[int, float] = {}
    est_ratio: Dict[int, float] = {}
    nnz_total = int(col_idx.shape[0])
    csr_bytes = csr_storage_bytes(n_rows, nnz_total, value_bytes)

    for k in TILE_DIMS:
        n_tile_rows = ceil_div(n_rows, k)
        # de-duplicate sampled rows into distinct tile-rows
        tile_rows = np.unique(sample // k)
        tiles_per_tile_row = np.empty(tile_rows.shape[0], dtype=np.float64)
        for idx, tr in enumerate(tile_rows):
            lo = int(tr) * k
            hi = min(lo + k, n_rows)
            s, e = int(row_ptr[lo]), int(row_ptr[hi])
            cols = col_idx[s:e]
            tiles_per_tile_row[idx] = (np.unique(cols // k).shape[0]
                                       if e > s else 0)
        est_tiles_per_tile_row = (tiles_per_tile_row.mean()
                                  if tile_rows.size else 0.0)
        est_n_tiles = est_tiles_per_tile_row * n_tile_rows
        b = (_INDEX_BYTES * (n_tile_rows + 1)
             + _INDEX_BYTES * est_n_tiles
             + est_n_tiles * k * _STORE_BYTES[k])
        est_bytes[k] = float(b)
        est_ratio[k] = float(b / max(csr_bytes, 1))

    best = min(est_ratio, key=est_ratio.get)
    rec = best if est_ratio[best] < 1.0 else None
    return SampleProfile(
        est_b2sr_bytes=est_bytes,
        est_compression=est_ratio,
        recommended_tile_dim=rec,
        sampled_rows=n,
    )
