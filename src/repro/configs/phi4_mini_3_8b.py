"""phi4-mini-3.8b [arXiv:2412.08905; hf]: dense 32L RoPE SwiGLU GQA."""

from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    activation="swiglu",
    tie_embeddings=True,
)


def reduced() -> TransformerConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="phi4-mini-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        dtype="float32", max_seq_len=64)
