"""Pure-jnp oracle for the SpGEMM kernel: densify, matmul, repack."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.b2sr import B2SREll, pack_dense_tiles
from repro.kernels.bmv.ref import dense_from_ell


def mxm(a: B2SREll, b: B2SREll, mask: Optional[B2SREll] = None,
        complement: bool = False) -> jnp.ndarray:
    """Packed boolean-product grid uint32[a.n_tile_rows, b.n_tile_cols, t]."""
    da = dense_from_ell(a, jnp.float32)
    db = dense_from_ell(b, jnp.float32)
    dc = (da @ db) > 0
    if mask is not None:
        dm = dense_from_ell(mask, jnp.float32) > 0
        dc = dc & (~dm if complement else dm)
    t = a.tile_dim
    return pack_dense_tiles(dc.astype(jnp.uint32), t)


def mxm_counts(a: B2SREll, b: B2SREll) -> jnp.ndarray:
    """Dense count matrix [a.n_rows, b.n_cols] = A +.× B."""
    da = dense_from_ell(a, jnp.float32)
    db = dense_from_ell(b, jnp.float32)
    return (da @ db).astype(jnp.int32)
