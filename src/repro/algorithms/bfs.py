"""BFS on the boolean semiring with bit-packed frontiers (paper §V).

Each iteration performs one-degree edge traversal with the visited mask
applied right before the output store (§V). The traversal is
*direction-optimizing* (DESIGN.md §12): push iterations run the classic
masked bin·bin→bin mxv (mask AND at the end — no divergence-like
predication on TPU); pull iterations dispatch the fused ``mxv_pull`` row,
whose Pallas kernel early-exits each output row on the first set allowed
bit. ``repro.algorithms.direction`` decides per iteration from popcount
density estimates; the choice is loop-carried traced state, so the whole
switching traversal stays one compiled ``while_loop``.

The frontier, visited set, and mask are bit-packed uint32 words end-to-end
on the b2sr backends; levels are materialised incrementally in an int32
vector.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import direction as direction_mod
from repro.algorithms.direction import DirectionConfig
from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.operands import BitVector


@dataclasses.dataclass
class BFSResult:
    """Result of a single-source traversal.

    ``levels`` is always ``int32[n]`` with ``levels[source] == 0`` and -1
    for unreachable vertices — including the ``max_iters=0`` case, which
    returns the 0-iteration shape: only the source stamped, zero
    iterations, empty ``directions``. ``directions`` records the
    direction *used* by each executed iteration (``"push"``/``"pull"``),
    so callers can observe which path the heuristic picked.
    """

    levels: jax.Array      # int32[n]; -1 = unreachable
    n_iterations: int
    directions: Tuple[str, ...] = ()


def _check_max_iters(max_iters: Optional[int], n: int) -> int:
    """Shared single-source/batched validation (both paths, same rules)."""
    if max_iters is None:
        return n
    max_iters = int(max_iters)
    if max_iters < 0:
        raise ValueError(f"max_iters must be >= 0, got {max_iters}")
    return max_iters


def bfs(g: GraphMatrix, source, max_iters: Optional[int] = None,
        row_chunk: Optional[int] = None,
        direction: Union[str, DirectionConfig, None] = "auto"):
    """Hop levels from ``source`` following out-edges.

    ``direction`` is ``"auto"`` (default: Beamer-style push/pull
    switching), ``"push"``, ``"pull"``, or a
    :class:`~repro.algorithms.direction.DirectionConfig` with explicit
    thresholds. All modes are bit-exact; the chosen schedule is recorded
    on ``BFSResult.directions``.

    ``source`` may also be an *array* of sources: the batch routes through
    the multi-source engine (one wide frontier-matrix traversal, plan-
    cached) and returns its ``MSBFSResult`` with ``levels[n, S]`` — column
    ``s`` bit-exact against the single-source run on ``source[s]``.
    """
    cfg = direction_mod.as_config(direction)
    n = g.n_rows
    max_iters = _check_max_iters(max_iters, n)
    if np.ndim(source) > 0:
        if row_chunk is not None:
            raise ValueError("row_chunk is not supported for batched "
                             "sources (the engine plans its own loop)")
        from repro.engine.queries import msbfs
        return msbfs(g, source, max_iters=max_iters, direction=cfg)
    source = int(source)
    t = g.tile_dim
    # both directions traverse Aᵀ · frontier over the stored transpose;
    # push/pull differ in schedule (and kernel), never in the operand
    gt = g.transposed()
    avg_degree = g.nnz / max(n, 1)

    src = jnp.zeros(n, jnp.float32).at[source].set(1.0)
    frontier = BitVector.pack(src, t, n)
    visited = frontier
    levels = jnp.full(n, -1, jnp.int32).at[source].set(0)

    def step_push(f, v):
        return gt.mxv(f, desc=Descriptor(mask=v, complement=True,
                                         row_chunk=row_chunk))

    def step_pull(f, v):
        return gt.mxv(f, desc=Descriptor(mask=v, complement=True,
                                         row_chunk=row_chunk,
                                         direction="pull"))

    def cond(state):
        # NOT jnp.sum(frontier.astype(uint64)): without x64 that silently
        # downcasts to uint32 and the word sum can wrap to exactly zero,
        # terminating BFS with a live frontier. any() is also cheaper.
        frontier, _, _, it, _, _, _ = state
        return frontier.any() & (it < max_iters)

    def body(state):
        frontier, visited, levels, it, d, locked, trace = state
        if cfg.mode == "auto":
            # direction is loop-carried traced state — both branches are
            # compiled once, the switch costs one predicate per iteration
            nxt = jax.lax.cond(d == direction_mod.PULL, step_pull,
                               step_push, frontier, visited)
        elif cfg.mode == "pull":
            nxt = step_pull(frontier, visited)
        else:
            nxt = step_push(frontier, visited)
        new_visited = visited | nxt
        new_bits = nxt.unpack(jnp.int32)
        levels_new = jnp.where((new_bits > 0) & (levels < 0), it + 1, levels)
        trace = direction_mod.record(trace, it, d)
        d_next, locked = direction_mod.next_direction(
            cfg, d, locked, direction_mod.nnz_words(nxt.words),
            direction_mod.nnz_words(new_visited.words), n, avg_degree)
        return (nxt, new_visited, levels_new, it + 1, d_next, locked, trace)

    state = (frontier, visited, levels, jnp.int32(0),
             direction_mod.initial_direction(cfg), jnp.bool_(False),
             direction_mod.empty_trace(max_iters))
    _, _, levels, it, _, _, trace = jax.lax.while_loop(cond, body, state)
    it = int(it)
    dirs = direction_mod.trace_tuple(trace, it)
    direction_mod.observe_trace(dirs, kernel="bfs")
    return BFSResult(levels=levels, n_iterations=it, directions=dirs)
