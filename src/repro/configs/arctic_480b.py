"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 128e top-2 + dense residual."""

from repro.configs.base import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,  # per-expert
    vocab_size=32000,
    activation="swiglu",
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual_d_ff=4864),
)


def reduced() -> TransformerConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="arctic-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=48, vocab_size=256, dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48,
                      dense_residual_d_ff=48), max_seq_len=64)
