"""Row-partitioned B2SR: per-device shards for multi-device execution.

The scale-out layer (DESIGN.md §11, §16): a graph's tile-row axis is split
into ``n_shards`` **contiguous ragged blocks** chosen by a greedy
prefix-sum split over per-tile-row tile counts — the bucketed SELL slabs
make per-row cost known in advance, so shard boundaries land where the
cumulative work crosses ``p/P`` of the total and ``balance()`` sits near
1.0 even on heavy-hub graphs (the v1 equal-block split reached 2.1+).
Every shard's ELL slab is padded to one **common padded row count**
(``rows_per_shard`` = the largest block) and one common slab width, so the
per-shard arrays still stack into single leading-axis-``P`` arrays that
``jax.shard_map`` splits across a mesh with one ``in_specs`` entry.

Because blocks are ragged, the concatenation of padded shard outputs is a
*permutation with padding holes* of the global packed layout; the static
``gather_idx`` map (global tile-row → stacked position) undoes it with one
replicated gather inside the shard_map body — no extra collective, and
``unpartition`` remains array-identical to the source B2SR.

Load skew *inside* a shard is what the SELL-style buckets handle — the
partition carries stacked per-bucket slabs with a bucket structure
harmonised across shards (same bucket count, same per-bucket width
everywhere) so the bucketed path also runs under one ``shard_map``.
Padding slab rows scatter to the **garbage row** ``rows_per_shard``
(consumers allocate ``rows_per_shard + 1`` output rows and drop the last).

:func:`build_exchange_plan` derives the communication-avoiding execution
schedule from a partition (DESIGN.md §16): per-shard column-word bitmaps
(which RHS words a shard's column space actually touches), the static
per-ring-offset ``ppermute`` send/recv index sets that move only those
words, and the output redistribution schedule that returns results as
equal-block device-sharded global arrays. Host-side construction mirrors
``to_ell``/``to_bucketed``; nothing here touches a mesh — placement
happens at execution time in ``repro.core.ops_sharded``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.b2sr import (B2SR, B2SREll, TILE_DIMS, _pytree, ceil_div,
                             static_field, to_ell)


@_pytree
@dataclasses.dataclass(frozen=True)
class PartitionedB2SR:
    """Stacked per-shard ELL (+ bucketed) slabs over ragged tile-row blocks.

    Shard ``p`` owns the contiguous global tile rows
    ``[row_starts[p], row_starts[p+1])``; every shard's slab is padded to
    the common ``rows_per_shard`` (the largest block). Padding rows have
    ``row_n_tiles == 0`` and all-``-1`` columns, so every scheme's
    ⊕-identity fills them; ``gather_idx`` maps each real global tile row
    to its stacked position ``p * rows_per_shard + local``.

    Bucketed slabs (built when ``with_buckets``) share one global bucket
    structure: bucket ``b`` has the same slab width ``k_b`` on every shard
    and every shard's slab is padded to the same row count; padding slab
    rows scatter to the **garbage row** ``rows_per_shard`` (consumers
    allocate ``rows_per_shard + 1`` output rows and drop the last).
    """

    tile_col_idx: jax.Array    # int32[P, R, K]; -1 = padding
    bit_tiles: jax.Array       # uint32[P, R, K, t]
    row_n_tiles: jax.Array     # int32[P, R]
    gather_idx: jax.Array      # int32[n_tile_rows] -> stacked position
    # harmonised bucket slabs (parallel tuples, empty when buckets off)
    bucket_col_idx: Tuple[jax.Array, ...]    # int32[P, rb, kb]
    bucket_bit_tiles: Tuple[jax.Array, ...]  # uint32[P, rb, kb, t]
    bucket_rows: Tuple[jax.Array, ...]       # int32[P, rb]; pad rows -> R
    tile_dim: int = static_field()
    n_rows: int = static_field()
    n_cols: int = static_field()
    n_tile_rows: int = static_field()        # real (unpadded) global count
    row_starts: Tuple[int, ...] = static_field()   # len P+1, ragged blocks
    shard_tiles: Tuple[int, ...] = static_field()  # real tiles per shard
    cut_tiles: int = static_field()          # tiles outside own row block

    @property
    def n_shards(self) -> int:
        return int(self.tile_col_idx.shape[0])

    @property
    def rows_per_shard(self) -> int:
        return int(self.tile_col_idx.shape[1])

    @property
    def slab_width(self) -> int:
        return int(self.tile_col_idx.shape[2])

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_col_idx)

    @property
    def n_tile_cols(self) -> int:
        return ceil_div(self.n_cols, self.tile_dim)

    def n_tiles(self) -> int:
        return sum(self.shard_tiles)

    def block_rows(self, p: int) -> int:
        """Real (unpadded) tile rows owned by shard ``p``."""
        return self.row_starts[p + 1] - self.row_starts[p]

    def balance(self) -> float:
        """max/mean tiles per shard; 1.0 == perfectly even load."""
        total = self.n_tiles()
        if total == 0:
            return 1.0
        return max(self.shard_tiles) / (total / self.n_shards)

    def edge_cut(self) -> float:
        """Fraction of tiles whose tile-column lies outside the owning
        shard's own row block — the traffic a 2D (row×col) tiling would
        localise and the row partition pays via the operand broadcast."""
        total = self.n_tiles()
        return 0.0 if total == 0 else self.cut_tiles / total


def _split_starts(counts: np.ndarray, n_shards: int,
                  balanced: bool) -> Tuple[int, ...]:
    """Block boundaries: greedy prefix-sum split over per-row tile counts.

    Boundary ``p`` lands where the cumulative tile count crosses ``p/P``
    of the total, so each shard's work is within one row's cost of even
    (the cost-model split of DESIGN.md §16). Degenerate inputs (no tiles,
    one shard, ``balanced=False``) fall back to the v1 equal-row blocks.
    """
    n_tr = int(counts.shape[0])
    total = int(counts.sum())
    if not balanced or n_shards == 1 or total == 0 or n_tr == 0:
        r_eq = max(ceil_div(n_tr, n_shards), 1)
        return tuple(min(p * r_eq, n_tr) for p in range(n_shards)) + (n_tr,)
    cum = np.cumsum(counts.astype(np.int64))
    targets = total * np.arange(1, n_shards, dtype=np.float64) / n_shards
    # the row whose cumulative cost first reaches the target ends the
    # block — then round each boundary to whichever side of the target is
    # closer, so no shard systematically absorbs the overshoot
    bounds = np.searchsorted(cum, targets, side="left") + 1
    bounds = np.minimum(np.maximum.accumulate(bounds), n_tr)
    for i, b in enumerate(bounds):
        if b >= 2 and abs(cum[b - 2] - targets[i]) < abs(cum[b - 1]
                                                         - targets[i]):
            bounds[i] = b - 1
    bounds = np.minimum(np.maximum.accumulate(bounds), n_tr)
    return (0, *(int(b) for b in bounds), n_tr)


def partition_rows(mat: Union[B2SR, B2SREll], n_shards: int,
                   with_buckets: bool = True, max_buckets: int = 8,
                   balanced: bool = True) -> PartitionedB2SR:
    """Split a B2SR (or its ELL view) into ``n_shards`` row-block shards.

    Tile rows are split into contiguous **nnz-balanced** ragged blocks
    (``balanced=False`` restores the v1 equal blocks); every shard's slab
    is padded to the largest block's row count and the global max slab
    width. Works for any ``n_shards >= 1`` including counts larger than
    the tile-row axis (trailing shards own empty blocks).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    ell = mat if isinstance(mat, B2SREll) else to_ell(mat)
    t = ell.tile_dim
    if t not in TILE_DIMS:
        raise ValueError(f"tile_dim must be one of {TILE_DIMS}, got {t}")
    n_tr = ell.n_tile_rows

    col_g = np.asarray(ell.tile_col_idx)
    tiles_g = np.asarray(ell.bit_tiles)
    counts_g = np.asarray(ell.row_n_tiles)
    starts = _split_starts(counts_g, n_shards, balanced)
    r_max = max(1, max(starts[p + 1] - starts[p] for p in range(n_shards)))

    k = ell.max_tiles_per_row
    col = np.full((n_shards, r_max, k), -1, np.int32)
    tiles = np.zeros((n_shards, r_max, k, t), np.uint32)
    counts = np.zeros((n_shards, r_max), np.int32)
    gidx = np.zeros(n_tr, np.int32)

    shard_tiles = []
    cut = 0
    for p in range(n_shards):
        lo, hi = starts[p], starts[p + 1]
        m = hi - lo
        col[p, :m] = col_g[lo:hi]
        tiles[p, :m] = tiles_g[lo:hi]
        counts[p, :m] = counts_g[lo:hi]
        gidx[lo:hi] = p * r_max + np.arange(m, dtype=np.int32)
        c = col_g[lo:hi]
        valid = c >= 0
        shard_tiles.append(int(valid.sum()))
        # a tile is "local" to shard p if its tile-col falls inside the
        # shard's own row block (square-matrix notion; rectangular graphs
        # count every tile as cut beyond the row range)
        local = (c >= lo) & (c < hi)
        cut += int((valid & ~local).sum())

    buckets = _harmonised_buckets(col, tiles, counts, t, max_buckets) \
        if with_buckets else ((), (), ())

    return PartitionedB2SR(
        tile_col_idx=jnp.asarray(col),
        bit_tiles=jnp.asarray(tiles),
        row_n_tiles=jnp.asarray(counts),
        gather_idx=jnp.asarray(gidx),
        bucket_col_idx=buckets[0],
        bucket_bit_tiles=buckets[1],
        bucket_rows=buckets[2],
        tile_dim=t,
        n_rows=ell.n_rows,
        n_cols=ell.n_cols,
        n_tile_rows=n_tr,
        row_starts=tuple(int(s) for s in starts),
        shard_tiles=tuple(shard_tiles),
        cut_tiles=cut,
    )


def _harmonised_buckets(col: np.ndarray, tiles: np.ndarray,
                        counts: np.ndarray, t: int, max_buckets: int):
    """Per-shard SELL buckets with one global bucket structure.

    Bucket boundaries (power-of-two count ranges, merged to ``max_buckets``)
    and slab widths come from the *global* count histogram, so bucket ``b``
    means the same range and width on every shard; each bucket's slab is
    padded to the max per-shard row count, padding rows pointing at the
    garbage row ``rows_per_shard``. Operates on the already-stacked
    ``[P, R, ...]`` arrays, so slab rows index shard-locally.
    """
    n_shards, r_max = counts.shape
    nonempty = counts > 0
    if not nonempty.any():
        return (), (), ()
    bidx = np.full(counts.shape, -1, np.int64)
    bidx[nonempty] = np.ceil(np.log2(counts[nonempty])).astype(np.int64)
    uniq = np.sort(np.unique(bidx[nonempty]))
    if uniq.size > max_buckets:
        keep = uniq[: max_buckets - 1]
        hub = uniq[max_buckets - 1]
        sel = nonempty & ~np.isin(bidx, keep)
        bidx[sel] = hub
        uniq = np.sort(np.unique(bidx[nonempty]))

    cols_out, tiles_out, rows_out = [], [], []
    for b in uniq:
        per_shard = []
        k_b = 1
        for p in range(n_shards):
            local = np.flatnonzero(bidx[p] == b)
            per_shard.append(local)
            if local.size:
                k_b = max(k_b, int(counts[p, local].max()))
        rb = max(max(len(ix) for ix in per_shard), 1)
        c_slab = np.full((n_shards, rb, k_b), -1, np.int32)
        t_slab = np.zeros((n_shards, rb, k_b, t), np.uint32)
        r_slab = np.full((n_shards, rb), r_max, np.int32)
        for p, local in enumerate(per_shard):
            if not local.size:
                continue
            c_slab[p, : local.size] = col[p, local, :k_b]
            t_slab[p, : local.size] = tiles[p, local, :k_b]
            r_slab[p, : local.size] = local
        cols_out.append(jnp.asarray(c_slab))
        tiles_out.append(jnp.asarray(t_slab))
        rows_out.append(jnp.asarray(r_slab))
    return tuple(cols_out), tuple(tiles_out), tuple(rows_out)


def unpartition(part: PartitionedB2SR) -> B2SR:
    """Reassemble the global B2SR from the stacked shard slabs.

    The exact inverse of ``partition_rows`` for any shard count and any
    (ragged or equal) block layout: each shard's real rows are read back
    through ``row_starts``, tile order within each row is preserved, so
    the result is array-identical to the source B2SR.
    """
    t = part.tile_dim
    col_s = np.asarray(part.tile_col_idx)
    tiles_s = np.asarray(part.bit_tiles)
    col = np.empty((part.n_tile_rows, part.slab_width), np.int32)
    tiles = np.empty((part.n_tile_rows, part.slab_width, t), np.uint32)
    for p in range(part.n_shards):
        lo, hi = part.row_starts[p], part.row_starts[p + 1]
        col[lo:hi] = col_s[p, : hi - lo]
        tiles[lo:hi] = tiles_s[p, : hi - lo]

    valid = col >= 0
    row_counts = valid.sum(axis=1)
    ptr = np.zeros(part.n_tile_rows + 1, np.int64)
    np.cumsum(row_counts, out=ptr[1:])
    tci = col[valid].astype(np.int32)
    bt = tiles[valid].astype(np.uint32)
    if bt.size == 0:
        nnz = 0
    elif hasattr(np, "bitwise_count"):
        nnz = int(np.bitwise_count(bt).sum())
    else:
        nnz = int(np.unpackbits(bt.view(np.uint8)).sum())
    return B2SR(
        tile_row_ptr=jnp.asarray(ptr.astype(np.int32)),
        tile_col_idx=jnp.asarray(tci),
        bit_tiles=jnp.asarray(bt.reshape(-1, t)),
        tile_dim=t,
        n_rows=part.n_rows,
        n_cols=part.n_cols,
        nnz=nnz,
    )


# ---------------------------------------------------------------------------
# Exchange plans: static communication schedules for combine="exchange"
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Static ``ppermute`` schedule for the frontier-word exchange path.

    Built host-side from a partition's column-word bitmaps (DESIGN.md §16).
    The exchange-mode contract in ``ops_sharded``: the RHS arrives
    device-sharded in equal leading-axis blocks of ``c_eq`` tile-columns;
    each device assembles only the words its slab touches (own block +
    per-ring-offset pairwise sends), computes its ragged row block, and
    redistributes the output rows to their equal-block owners, returning a
    device-sharded global array (``r_eq`` tile rows per device).

    All index arrays are ``[P, W]`` — device ``p`` reads row ``p`` via
    ``axis_index`` — with padding lanes pointing at a garbage slot (source
    garbage: the appended zero row; destination garbage: the appended
    drop row), so every hop has one static width per offset.
    """

    n_shards: int
    c_eq: int                  # RHS tile-columns per equal device block
    r_eq: int                  # output tile-rows per equal device block
    n_tc_pad: int              # n_shards * c_eq
    # RHS word exchange: one ppermute hop per (nonempty) ring offset
    rhs_offsets: Tuple[int, ...]
    rhs_send_idx: Tuple[jax.Array, ...]   # int32[P, W_o] into own block
    rhs_recv_pos: Tuple[jax.Array, ...]   # int32[P, W_o] into the buffer
    # output redistribution: ragged compute blocks -> equal owner blocks
    out_offsets: Tuple[int, ...]
    out_send_idx: Tuple[jax.Array, ...]   # int32[P, W_o] into local rows
    out_recv_pos: Tuple[jax.Array, ...]   # int32[P, W_o] into owner block
    self_src: jax.Array                   # int32[P, W_s] local overlap copy
    self_dst: jax.Array
    # static comm accounting (lanes = leading-axis rows moved on the wire)
    rhs_lanes: int
    out_lanes: int
    gather_lanes: int          # what the all-gather path would move

    def exchanged_lanes(self) -> int:
        return self.rhs_lanes + self.out_lanes


def build_exchange_plan(part: PartitionedB2SR) -> Optional[ExchangePlan]:
    """Derive the static exchange schedule from a partition's bitmaps.

    Returns None for a single shard (nothing to exchange — the gather path
    is already collective-free there).
    """
    P = part.n_shards
    if P == 1:
        return None
    n_tc = part.n_tile_cols
    n_tr = part.n_tile_rows
    r_max = part.rows_per_shard
    c_eq = max(ceil_div(n_tc, P), 1)
    r_eq = max(ceil_div(n_tr, P), 1)
    n_tc_pad = P * c_eq

    # per-shard column-word bitmap: the RHS words shard p's slab touches
    # (bucket slabs reference the same tiles, so the ELL slab covers them)
    col = np.asarray(part.tile_col_idx)
    need = [np.unique(col[p][col[p] >= 0]).astype(np.int64)
            for p in range(P)]

    # need[p] split by owner q = word // c_eq; ring offset o sends q -> q+o
    need_from = [[n_p[(n_p // c_eq) == q] for q in range(P)]
                 for n_p in need]
    rhs_offsets, rhs_send, rhs_recv = [], [], []
    rhs_lanes = 0
    for o in range(1, P):
        w_o = max(len(need_from[(q + o) % P][q]) for q in range(P))
        if w_o == 0:
            continue
        send = np.full((P, w_o), c_eq, np.int32)        # garbage: pad row
        recv = np.full((P, w_o), n_tc_pad, np.int32)    # garbage: drop row
        for q in range(P):
            dst = (q + o) % P
            words = need_from[dst][q]
            send[q, : len(words)] = words - q * c_eq
            recv[dst, : len(words)] = words
        rhs_offsets.append(o)
        rhs_send.append(jnp.asarray(send))
        rhs_recv.append(jnp.asarray(recv))
        rhs_lanes += P * w_o

    # output redistribution: shard q computed global rows
    # [row_starts[q], row_starts[q+1]); owner p holds [p*r_eq, (p+1)*r_eq)
    overlaps = {}
    for q in range(P):
        lo_q, hi_q = part.row_starts[q], part.row_starts[q + 1]
        for p in range(P):
            lo = max(lo_q, p * r_eq)
            hi = min(hi_q, (p + 1) * r_eq)
            if hi > lo:
                overlaps[(q, p)] = (lo, hi)
    w_s = max((hi - lo for (q, p), (lo, hi) in overlaps.items() if q == p),
              default=0)
    self_src = np.full((P, max(w_s, 1)), r_max, np.int32)
    self_dst = np.full((P, max(w_s, 1)), r_eq, np.int32)
    for p in range(P):
        lo, hi = overlaps.get((p, p), (0, 0))
        m = hi - lo
        if m:
            self_src[p, :m] = np.arange(lo, hi) - part.row_starts[p]
            self_dst[p, :m] = np.arange(lo, hi) - p * r_eq

    out_offsets, out_send, out_recv = [], [], []
    out_lanes = 0
    for o in range(1, P):
        pairs = [(q, (q + o) % P) for q in range(P)]
        w_o = max((overlaps[(q, p)][1] - overlaps[(q, p)][0]
                   for (q, p) in pairs if (q, p) in overlaps), default=0)
        if w_o == 0:
            continue
        send = np.full((P, w_o), r_max, np.int32)
        recv = np.full((P, w_o), r_eq, np.int32)
        for q, p in pairs:
            if (q, p) not in overlaps:
                continue
            lo, hi = overlaps[(q, p)]
            m = hi - lo
            send[q, :m] = np.arange(lo, hi) - part.row_starts[q]
            recv[p, :m] = np.arange(lo, hi) - p * r_eq
        out_offsets.append(o)
        out_send.append(jnp.asarray(send))
        out_recv.append(jnp.asarray(recv))
        out_lanes += P * w_o

    return ExchangePlan(
        n_shards=P, c_eq=c_eq, r_eq=r_eq, n_tc_pad=n_tc_pad,
        rhs_offsets=tuple(rhs_offsets), rhs_send_idx=tuple(rhs_send),
        rhs_recv_pos=tuple(rhs_recv),
        out_offsets=tuple(out_offsets), out_send_idx=tuple(out_send),
        out_recv_pos=tuple(out_recv),
        self_src=jnp.asarray(self_src), self_dst=jnp.asarray(self_dst),
        rhs_lanes=rhs_lanes, out_lanes=out_lanes,
        gather_lanes=P * (P - 1) * r_max,
    )


def mesh_fingerprint(mesh, axes: Tuple[str, ...]) -> Tuple:
    """Hashable identity of (mesh, shard axes) for plan-cache keys.

    Two meshes that differ in axis names, shape, or member devices must
    never share a compiled plan — the shard_map trace bakes all three in.
    """
    return (
        tuple(mesh.axis_names),
        tuple(int(s) for s in mesh.devices.shape),
        tuple(axes),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def shard_count(mesh, axes: Tuple[str, ...]) -> int:
    """Product of the mesh-axis sizes the partition shards over."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    missing = [a for a in axes if a not in sizes]
    if missing:
        raise ValueError(f"mesh has no axis {missing}; axes are "
                         f"{tuple(mesh.axis_names)}")
    p = 1
    for a in axes:
        p *= int(sizes[a])
    return p
