"""Typed operand wrappers for the unified GraphBLAS operation API.

The paper's Table II/III rows differ only in the *types* of the operands:
a bin·bin→bin mxv and a bin·full→full mxv are the same ``mxv`` with a
packed vs dense right-hand side. These wrappers carry that type so the
generic ``GraphMatrix.mxv`` / ``GraphMatrix.mxm`` can resolve the table
row from the operand instead of the caller picking among method names
(DESIGN.md §10):

  ``BitVector``      packed uint32 frontier / visited-set vector
                     (``pack_bitvector`` words + logical length)
  ``FrontierBatch``  packed frontier *matrix* ``uint32[tiles, t, W]``
                     (``pack_frontier_matrix`` words, 32 sources/word)
  ``BitMatrix``      packed binarized activation matrix
                     ``uint32[ceil(n/t), d]`` — node axis tile-packed, one
                     full word column per feature (BitGNN; DESIGN.md §15)
  plain arrays       dense full-precision vectors / feature matrices

Both wrappers are frozen pytree dataclasses, so they flow through
``jax.jit`` / ``lax.while_loop`` state unchanged — BFS loops carry the
typed frontier, not raw words. Word-level set algebra (``|``, ``&``,
``~``) is defined on the wrappers so masked-traversal updates like
``visited | frontier`` read the same as before.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.b2sr import (SOURCE_WORD_BITS, _pytree, ceil_div,
                             pack_bitvector, pack_frontier_matrix,
                             static_field, unpack_bitvector,
                             unpack_frontier_matrix)


@_pytree
@dataclasses.dataclass(frozen=True)
class BitVector:
    """A bit-packed boolean vector: one uint32 word per ``tile_dim`` entries.

    ``words[i]`` packs entries ``i*t .. i*t + t-1`` LSB-first (only the low
    ``tile_dim`` bits are used — the ``pack_bitvector`` layout the b2sr
    traversal schemes consume directly).
    """

    words: jax.Array            # uint32[ceil(n / tile_dim)]
    n: int = static_field()     # logical length (trailing pad bits are 0)
    tile_dim: int = static_field()

    @classmethod
    def pack(cls, x: jax.Array, tile_dim: int,
             n: Optional[int] = None) -> "BitVector":
        """Binarize + pack a dense vector (paper §IV, Listing 1 setup)."""
        n = int(x.shape[0]) if n is None else n
        return cls(words=pack_bitvector(x, tile_dim, n), n=n,
                   tile_dim=tile_dim)

    @classmethod
    def from_words(cls, words: jax.Array, n: int,
                   tile_dim: int) -> "BitVector":
        return cls(words=jnp.asarray(words, jnp.uint32), n=n,
                   tile_dim=tile_dim)

    def unpack(self, dtype=jnp.float32) -> jax.Array:
        return unpack_bitvector(self.words, self.tile_dim, self.n, dtype)

    def any(self) -> jax.Array:
        """Whether any bit is set (traced-safe; BFS termination test)."""
        return jnp.any(self.words != 0)

    def _like(self, words: jax.Array) -> "BitVector":
        return BitVector(words=words, n=self.n, tile_dim=self.tile_dim)

    def __or__(self, other: "BitVector") -> "BitVector":
        return self._like(self.words | other.words)

    def __and__(self, other: "BitVector") -> "BitVector":
        return self._like(self.words & other.words)

    def __invert__(self) -> "BitVector":
        # NOTE: pad bits above ``n`` flip to 1; the b2sr schemes never read
        # them (ELL gathers stop at n_tile_cols) and ``unpack`` drops them.
        return self._like(~self.words)


@_pytree
@dataclasses.dataclass(frozen=True)
class FrontierBatch:
    """A bit-packed batch of S boolean vectors (``pack_frontier_matrix``).

    ``words[T, r, w]`` packs sources ``32w .. 32w+31`` of node ``T*t + r``
    LSB-first: the node axis is tile-grouped for B2SR gathers, the batch
    axis is lane-packed at machine width (DESIGN.md §9).
    """

    words: jax.Array            # uint32[ceil(n/t), t, W]
    n: int = static_field()     # logical node count
    n_sources: int = static_field()  # logical batch width S (<= 32*W)
    tile_dim: int = static_field()

    @classmethod
    def pack(cls, x: jax.Array, tile_dim: int,
             n: Optional[int] = None) -> "FrontierBatch":
        """Binarize + pack a dense ``[n, S]`` batch along the S axis."""
        n = int(x.shape[0]) if n is None else n
        return cls(words=pack_frontier_matrix(x, tile_dim, n), n=n,
                   n_sources=int(x.shape[1]), tile_dim=tile_dim)

    @classmethod
    def from_words(cls, words: jax.Array, n: int, n_sources: int,
                   tile_dim: int) -> "FrontierBatch":
        return cls(words=jnp.asarray(words, jnp.uint32), n=n,
                   n_sources=n_sources, tile_dim=tile_dim)

    @property
    def padded_width(self) -> int:
        """Batch width after word padding (32 * W)."""
        return int(self.words.shape[2]) * SOURCE_WORD_BITS

    def unpack(self, dtype=jnp.float32) -> jax.Array:
        return unpack_frontier_matrix(self.words, self.n, self.n_sources,
                                      dtype)

    def any(self) -> jax.Array:
        return jnp.any(self.words != 0)

    def _like(self, words: jax.Array) -> "FrontierBatch":
        return FrontierBatch(words=words, n=self.n, n_sources=self.n_sources,
                             tile_dim=self.tile_dim)

    def __or__(self, other: "FrontierBatch") -> "FrontierBatch":
        return self._like(self.words | other.words)

    def __and__(self, other: "FrontierBatch") -> "FrontierBatch":
        return self._like(self.words & other.words)

    def __invert__(self) -> "FrontierBatch":
        return self._like(~self.words)


@_pytree
@dataclasses.dataclass(frozen=True)
class BitMatrix:
    """A bit-packed binarized activation matrix (BitGNN layer input).

    ``words[c, j]`` packs entries ``X[c*t .. c*t + t-1, j]`` LSB-first
    (only the low ``tile_dim`` bits are used), i.e. the node axis shares
    the ``pack_bitvector`` tile layout so B2SR column gathers index
    straight into word rows, while each feature keeps its own word column.
    The bin·bin→full mxm rows accumulate ``popcount(tile & word)`` over
    these words — the (+ , AND) semiring of the XNOR/BitGNN formulation;
    sign decoding and α-scale reconstruction live in ``repro.gnn_bit``.
    """

    words: jax.Array            # uint32[ceil(n / tile_dim), d]
    n: int = static_field()     # logical node count (trailing pad bits 0)
    tile_dim: int = static_field()

    @classmethod
    def pack(cls, x: jax.Array, tile_dim: int,
             n: Optional[int] = None) -> "BitMatrix":
        """Binarize (``x != 0``) + pack a dense ``[n, d]`` along the n axis."""
        n = int(x.shape[0]) if n is None else n
        t = tile_dim
        nt = ceil_div(n, t)
        bits = (x != 0)
        pad = nt * t - int(x.shape[0])
        if pad:
            bits = jnp.pad(bits, ((0, pad), (0, 0)))
        b3 = bits.reshape(nt, t, -1).astype(jnp.uint32)
        shifts = jnp.arange(t, dtype=jnp.uint32)[None, :, None]
        words = jnp.sum(b3 << shifts, axis=1, dtype=jnp.uint32)
        return cls(words=words, n=n, tile_dim=tile_dim)

    @classmethod
    def from_words(cls, words: jax.Array, n: int,
                   tile_dim: int) -> "BitMatrix":
        return cls(words=jnp.asarray(words, jnp.uint32), n=n,
                   tile_dim=tile_dim)

    @property
    def d(self) -> int:
        """Feature width (one uint32 word column per feature)."""
        return int(self.words.shape[1])

    def unpack(self, dtype=jnp.float32) -> jax.Array:
        t = self.tile_dim
        shifts = jnp.arange(t, dtype=jnp.uint32)[None, :, None]
        bits = (self.words[:, None, :] >> shifts) & jnp.uint32(1)
        return bits.reshape(-1, self.words.shape[1])[:self.n].astype(dtype)

    def any(self) -> jax.Array:
        return jnp.any(self.words != 0)

    def _like(self, words: jax.Array) -> "BitMatrix":
        return BitMatrix(words=words, n=self.n, tile_dim=self.tile_dim)

    def __or__(self, other: "BitMatrix") -> "BitMatrix":
        return self._like(self.words | other.words)

    def __and__(self, other: "BitMatrix") -> "BitMatrix":
        return self._like(self.words & other.words)

    def __invert__(self) -> "BitMatrix":
        # pad bits above ``n`` flip to 1 — harmless for the same reason as
        # BitVector: the packed schemes never read past n_tile_cols and
        # ``unpack`` slices them off.
        return self._like(~self.words)


def operand_kind(x) -> str:
    """Classify a right-hand operand for dispatch: the Table II/III column.

    ``GraphMatrix`` is detected structurally (it lives above this module in
    the import graph); anything that is not a typed wrapper or a
    GraphMatrix is treated as a dense array.
    """
    if isinstance(x, BitVector):
        return "bitvec"
    if isinstance(x, FrontierBatch):
        return "frontier"
    if isinstance(x, BitMatrix):
        return "bitmat"
    if hasattr(x, "ell") and hasattr(x, "csr"):   # GraphMatrix, structurally
        return "graph"
    return "dense"


def check_operand(x, tile_dim: int, n: int, what: str) -> None:
    """Validate a packed operand's static metadata against the matrix."""
    if x.tile_dim != tile_dim:
        raise ValueError(f"{what} tile_dim {x.tile_dim} != matrix tile_dim "
                         f"{tile_dim}")
    if x.n != n:
        raise ValueError(f"{what} length {x.n} != expected {n}")


def pad_leading(arr: jax.Array, n: int) -> jax.Array:
    """Zero-pad the leading (tile-column/word) axis of an operand to ``n``.

    The shard-local word view behind ``combine="exchange"``: the operand's
    word axis is rounded up to the exchange plan's ``n_shards × c_eq`` so
    equal contiguous blocks shard evenly; the appended words correspond to
    tile-columns past the matrix edge, which no slab references. Zero is
    the safe fill for every scheme — packed words OR/AND against set bits
    only, and the dense blocks select through the bit tiles before the
    ⊕-reduction, so unreferenced lanes never contribute.
    """
    if arr.shape[0] >= n:
        return arr
    pad = [(0, n - arr.shape[0])] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pad)
