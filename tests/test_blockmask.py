"""B2SR block-sparse attention vs dense masked attention (beyond-paper demo)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.b2sr import b2sr_to_dense
from repro.core.blockmask import (block_lists_from_ell, block_sparse_attention,
                                  local_strided_pattern, pattern_to_b2sr)


def _dense_reference(q, k, v, block_mask, block_size):
    B, S, H, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    # expand block mask to element mask + causal
    el = np.kron(block_mask, np.ones((block_size, block_size))) > 0
    causal = np.tril(np.ones((S, S))) > 0
    mask = jnp.asarray(el & causal)
    s = jnp.einsum("bqhd,bthd->bhqt", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,bthd->bqhd", p, v).astype(q.dtype)


class TestBlockSparseAttention:
    @pytest.mark.parametrize("tile_dim", [4, 8])
    def test_matches_dense_masked(self, tile_dim):
        B, S, H, hd, bs = 2, 256, 2, 16, 32
        nb = S // bs
        rows, cols = local_strided_pattern(nb, window=2, stride=3)
        mat, ell = pattern_to_b2sr(rows, cols, nb, tile_dim)
        block_mask = b2sr_to_dense(mat)

        rng = np.random.default_rng(0)
        q, k, v = (jnp.asarray(rng.standard_normal((B, S, H, hd)),
                               jnp.float32) for _ in range(3))
        ids = block_lists_from_ell(ell, max_blocks=nb)
        out = block_sparse_attention(q, k, v, ids, bs)
        ref = _dense_reference(q, k, v, block_mask, bs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_block_lists_roundtrip(self):
        nb = 40
        rows, cols = local_strided_pattern(nb, window=3, stride=5)
        mat, ell = pattern_to_b2sr(rows, cols, nb, 8)
        ids = np.asarray(block_lists_from_ell(ell, max_blocks=nb))
        dense = b2sr_to_dense(mat)
        for i in range(nb):
            got = sorted(x for x in ids[i] if x >= 0)
            want = sorted(np.flatnonzero(dense[i]).tolist())
            assert got == want, f"row {i}"

    def test_work_reduction(self):
        # the point of the exercise: W ≪ nb key blocks per query block
        nb = 64
        rows, cols = local_strided_pattern(nb, window=4, stride=8)
        mat, _ = pattern_to_b2sr(rows, cols, nb, 8)
        dense = b2sr_to_dense(mat)
        avg_blocks = dense.sum() / nb
        assert avg_blocks < nb / 4          # ≥4× fewer score blocks
