"""BitGNN subsystem tests (ISSUE 9, DESIGN.md §15).

Covers:
  - bit-exact parity of the new bitmat mxm rows (spmm_bin_bin_full)
    across tile dims × all 3 backends × buckets on/off (+ masked rows),
  - BitMatrix pack/unpack round-trips and the Pallas activation packer,
  - STE binarization: forward values and the clipped straight-through
    gradient against a finite difference of the hardtanh surrogate,
  - the α·popcount ±1 reconstruction (exact on binary inputs),
  - GCN forward: registry-dispatched aggregation parity vs the float
    segment-sum baseline, the sharded (shardmap_agg_axes) path, and the
    bit-path aggregation staying within binarization tolerance,
  - gnn_infer serving: batched round-trip, warmup replay, backend
    fallback under injected faults.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.graphblas import BACKENDS, GraphMatrix
from repro.core.operands import BitMatrix
from repro.gnn_bit import binarize, layers

SETUPS = [(b, u) for b in BACKENDS for u in (False, True)]


def build(n=48, t=8, density=0.15, seed=3, backend="b2sr",
          use_buckets=True):
    rng = np.random.RandomState(seed)
    d = (rng.random((n, n)) < density).astype(np.uint8)
    g = GraphMatrix.from_dense(d, tile_dim=t, backend=backend)
    return g.with_buckets(use_buckets), d


def rand_feats(n, d, seed=7):
    return np.random.RandomState(seed).randn(n, d).astype(np.float32)


# -- the bitmat registry rows ------------------------------------------------

@pytest.mark.parametrize("t", [4, 8, 16, 32])
@pytest.mark.parametrize("backend,use_buckets", SETUPS)
def test_spmm_bin_bin_full_parity(t, backend, use_buckets):
    g, d = build(t=t, backend=backend, use_buckets=use_buckets)
    x = rand_feats(48, 10)
    bits = (x != 0).astype(np.float32)      # randn: all-ones in practice,
    x[x < 0.3] = 0.0                        # so zero a majority out
    bits = (x != 0).astype(np.float32)
    bm = BitMatrix.pack(jnp.asarray(x), t)
    out = g.mxm(bm)
    ref = d.astype(np.float32) @ bits
    assert np.array_equal(np.asarray(out), ref)
    key = dispatch.last_key
    assert key[:3] == ("mxm", "bitmat", "full") and key[3] == backend


@pytest.mark.parametrize("backend,use_buckets", SETUPS)
def test_spmm_bin_bin_full_masked(backend, use_buckets):
    g, d = build(backend=backend, use_buckets=use_buckets)
    x = (rand_feats(48, 6) > 0.4).astype(np.float32)
    mask = np.random.RandomState(11).rand(48) > 0.5
    bm = BitMatrix.pack(jnp.asarray(x), 8)
    out = np.asarray(g.mxm(bm, mask=jnp.asarray(mask)))
    ref = d.astype(np.float32) @ x
    assert np.array_equal(out[mask], ref[mask])
    assert np.all(out[~mask] == 0.0)


@pytest.mark.parametrize("backend", ["b2sr", "b2sr_pallas"])
def test_spmm_bin_bin_full_sharded(backend):
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(1, model=1)
    g, d = build(backend=backend, use_buckets=False)
    x = (rand_feats(48, 6, seed=5) > 0.2).astype(np.float32)
    bm = BitMatrix.pack(jnp.asarray(x), 8)
    with mesh:
        out = g.shard(mesh, axes=("data",)).mxm(bm)
    assert np.array_equal(np.asarray(out), d.astype(np.float32) @ x)
    assert dispatch.last_key[-1] is True     # the sharded row answered


def test_bitmatrix_roundtrip_and_kernel_packer():
    x = rand_feats(50, 9, seed=2)
    x[x < 0] = 0.0
    for t in (4, 8, 32):
        bm = BitMatrix.pack(jnp.asarray(x), t)
        assert np.array_equal(np.asarray(bm.unpack()),
                              (x != 0).astype(np.float32))
        # the Pallas row-packing kernel produces the same words
        pk = binarize.pack_activations(jnp.asarray(x), t)
        assert np.array_equal(np.asarray(pk.words), np.asarray(bm.words))
        assert pk.n == bm.n == 50


# -- STE binarization --------------------------------------------------------

def test_ste_forward_values():
    x = jnp.asarray([-2.0, -0.1, 0.0, 0.4, 3.0])
    assert np.array_equal(np.asarray(binarize.ste_sign(x)),
                          [-1.0, -1.0, 1.0, 1.0, 1.0])
    assert np.array_equal(np.asarray(binarize.ste_step(x)),
                          [0.0, 0.0, 0.0, 1.0, 1.0])


def test_ste_gradient_matches_surrogate_finite_diff():
    # the clipped STE's backward IS the gradient of the hardtanh
    # surrogate s(x) = clip(x, -1, 1): check it against a central finite
    # difference of s, entry-wise (points chosen away from the |x|=1 kinks)
    x = jnp.asarray([-1.7, -0.6, -0.2, 0.3, 0.8, 2.4])
    w = jnp.asarray([0.5, -1.0, 2.0, 1.5, -0.7, 3.0])
    g_ste = jax.grad(lambda v: jnp.sum(binarize.ste_sign(v) * w))(x)

    def surrogate(v):
        return np.sum(np.clip(v, -1.0, 1.0) * np.asarray(w))

    eps = 1e-4
    xn = np.asarray(x, np.float64)
    fd = np.array([(surrogate(xn + eps * e) - surrogate(xn - eps * e))
                   / (2 * eps)
                   for e in np.eye(x.shape[0])])
    assert np.allclose(np.asarray(g_ste), fd, atol=1e-5)
    # and ste_step shares the same clipped backward
    g_step = jax.grad(lambda v: jnp.sum(binarize.ste_step(v) * w))(x)
    assert np.allclose(np.asarray(g_step), fd, atol=1e-5)


def test_signed_aggregate_exact_on_binary():
    g, d = build(use_buckets=False)
    x = np.where(rand_feats(48, 7, seed=9) >= 0, 1.0, -1.0).astype(
        np.float32)
    rowsum = jnp.asarray(d.sum(axis=1).astype(np.float32))
    out = layers.signed_aggregate(g.ell, jnp.asarray(x), rowsum,
                                  alpha=jnp.ones((7,), jnp.float32))
    assert np.array_equal(np.asarray(out), d.astype(np.float32) @ x)


# -- GCN through the registry ------------------------------------------------

def _gcn_setup(shardmap_axes=()):
    from repro.configs import get_config
    from repro.data.synthetic import full_graph_batch
    cfg = get_config("gcn-cora")
    cfg = dataclasses.replace(cfg, d_in=16, n_classes=5, d_hidden=8,
                              use_b2sr=True,
                              shardmap_agg_axes=shardmap_axes)
    batch = full_graph_batch(cfg, 96, pattern="block", seed=3)
    from repro.models.gnn import gcn
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, batch, params, gcn


def test_gcn_forward_registry_vs_segment_sum():
    cfg, batch, params, gcn = _gcn_setup()
    r0 = dispatch.stats["resolves"]
    logits_bit = gcn.forward(params, batch, cfg)
    assert dispatch.stats["resolves"] > r0
    assert dispatch.last_key[:4] == ("mxm", "dense", "full", "b2sr")
    cfg_f = dataclasses.replace(cfg, use_b2sr=False)
    logits_float = gcn.forward(params, batch, cfg_f)
    assert np.allclose(np.asarray(logits_bit), np.asarray(logits_float),
                       atol=1e-4)


def test_gcn_sharded_axes_through_registry():
    from repro.launch.mesh import make_debug_mesh
    cfg, batch, params, gcn = _gcn_setup(shardmap_axes=("data",))
    mesh = make_debug_mesh(1, model=1)
    layers.prepare_sharded(batch.ell, ("data",), mesh=mesh)
    logits_sharded = gcn.forward(params, batch, cfg)
    assert dispatch.last_key[-1] is True     # sharded registry row
    cfg_u = dataclasses.replace(cfg, shardmap_agg_axes=())
    logits = gcn.forward(params, batch, cfg_u)
    assert np.allclose(np.asarray(logits_sharded), np.asarray(logits),
                       atol=1e-5)
    # under jit the cached prepared graph serves the traced lookup too
    step = jax.jit(lambda p, b: gcn.forward(p, b, cfg))
    assert np.allclose(np.asarray(step(params, batch)), np.asarray(logits),
                       atol=1e-5)


def test_gcn_bit_path_within_binarization_tolerance():
    # one α-reconstructed binarized aggregation vs the float aggregation:
    # not exact (that is the point of 1-bit activations) but close in a
    # relative-error sense on well-scaled inputs
    g, d = build(n=96, t=8, density=0.2, seed=5, use_buckets=False)
    x = rand_feats(96, 32, seed=21)
    rowsum = jnp.asarray(d.sum(axis=1).astype(np.float32))
    approx = np.asarray(layers.signed_aggregate(g.ell, jnp.asarray(x),
                                                rowsum))
    exact = d.astype(np.float32) @ np.asarray(x)
    rel = (np.linalg.norm(approx - exact)
           / max(np.linalg.norm(exact), 1e-6))
    assert rel < 0.8, f"binarized aggregation drifted: rel error {rel:.3f}"
    # and the binarized forward is exactly the α-scaled ±1 aggregation
    xb = np.where(x >= 0, 1.0, -1.0) * np.asarray(
        binarize.alpha_scale(jnp.asarray(x)))[None, :]
    assert np.allclose(approx, d.astype(np.float32) @ xb, atol=1e-3)


# -- gnn_infer serving -------------------------------------------------------

def _serving_setup(binarize_model=True, name="gnn-test"):
    from repro.engine import queries
    rng = np.random.RandomState(4)
    n, t, d_in, d_h, n_cls = 64, 8, 12, 8, 4
    d = (rng.rand(n, n) < 0.12).astype(np.uint8)
    g = GraphMatrix.from_dense(d, tile_dim=t)
    feats = rng.randn(n, d_in).astype(np.float32)
    params = [(rng.randn(d_in, d_h).astype(np.float32) * 0.3,
               np.zeros(d_h, np.float32)),
              (rng.randn(d_h, n_cls).astype(np.float32) * 0.3,
               np.zeros(n_cls, np.float32))]
    queries.register_gnn_model(name, params, feats,
                               binarize=binarize_model)
    return g, queries


def test_gnn_infer_direct_and_served_parity():
    from repro.engine.server import GraphQueryServer
    g, queries = _serving_setup()
    direct = queries.gnn_infer(g, [3, 9, 41, 9], "gnn-test")
    assert direct.logits.shape == (4, 4) and direct.n_layers == 2
    srv = GraphQueryServer()
    handles = [srv.gnn_infer(g, s, "gnn-test") for s in (3, 9, 41, 9)]
    srv.flush()
    for h, col in zip(handles, range(4)):
        assert np.allclose(np.asarray(h.result()),
                           np.asarray(direct.logits[:, col]), atol=1e-5)
        assert h.backend_used == "b2sr" and not h.degraded
    assert srv.stats["deduped"] == 1         # the repeated node 9


def test_gnn_infer_warmup_roundtrip():
    from repro.engine.server import GraphQueryServer
    g, queries = _serving_setup()
    srv = GraphQueryServer()
    srv.gnn_infer(g, 7, "gnn-test")
    srv.gnn_infer(g, 12, "gnn-test")
    srv.flush()
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "warmup.json")
        assert srv.save_warmup(path) >= 1
        fresh = GraphQueryServer()
        fresh.register(g)
        assert fresh.warmup(path) >= 1       # replays the gnn_infer recipe
        assert fresh.stats["warmup_failed"] == 0
        assert fresh.planner.stats()["size"] >= 1


def test_gnn_infer_fallback_chain():
    from repro.engine.faults import FaultInjector
    from repro.engine.server import GraphQueryServer
    g, queries = _serving_setup()
    ref = queries.gnn_infer(g, [5], "gnn-test").logits[:, 0]
    inj = FaultInjector().fail("gnn_infer", "b2sr", rate=1.0)
    srv = GraphQueryServer(fault_injector=inj)
    h = srv.gnn_infer(g, 5, "gnn-test")
    srv.flush()
    assert h.degraded and h.backend_used == "csr"
    assert np.allclose(np.asarray(h.result()), np.asarray(ref), atol=1e-4)


def test_gnn_infer_unknown_model_and_bad_node():
    g, queries = _serving_setup()
    with pytest.raises(ValueError, match="no GNN model registered"):
        queries.gnn_infer(g, [0], "nope")
    from repro.engine.batcher import validate_query
    with pytest.raises(ValueError, match="out of range"):
        validate_query(g, "gnn_infer", 10_000)
