"""Minimal neural-net substrate: params-as-pytrees, layers as pure functions.

No Flax/Haiku — parameters are nested dicts of jnp arrays, initialisers take
explicit PRNG keys, and every layer is `apply(params, x, ...)`. This keeps
pjit sharding rules trivially mappable onto the tree paths.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype) * jnp.asarray(0.02, dtype)


def zeros_init(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def dense(params: Params, x: jax.Array) -> jax.Array:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def dense_params(key, d_in: int, d_out: int, bias: bool = True,
                 dtype=jnp.float32) -> Params:
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def mlp_params(key, dims: Sequence[int], bias: bool = True,
               dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    return {f"layer_{i}": dense_params(k, dims[i], dims[i + 1], bias, dtype)
            for i, k in enumerate(keys)}


def mlp(params: Params, x: jax.Array, act: Callable = jax.nn.relu,
        final_act: bool = False) -> jax.Array:
    n = len(params)
    for i in range(n):
        x = dense(params[f"layer_{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def rms_norm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def rms_norm_params(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def layer_norm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


def layer_norm_params(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params))


def tree_cast(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
