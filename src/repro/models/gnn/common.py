"""Shared GNN substrate: static-shape graph batches + segment message passing.

JAX has no native sparse message passing — per the assignment, it is built
here from ``jnp.take`` + ``jax.ops.segment_sum`` over an edge-index list.
All shapes are static: graphs are padded to fixed (N, E) with masks.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.b2sr import B2SREll, _pytree, static_field


@_pytree
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """A (possibly padded, possibly batched-disjoint-union) graph.

    Registered pytree with ``n_graphs`` static so batches pass through jit
    boundaries (num_segments must be a python int).
    """

    node_feat: jax.Array               # [N, d_in]
    senders: jax.Array                 # [E] int32 (padded with 0)
    receivers: jax.Array               # [E] int32
    node_mask: jax.Array               # [N] bool
    edge_mask: jax.Array               # [E] bool
    labels: jax.Array                  # [N] int32 or [G] int32/float
    train_mask: jax.Array              # [N] bool (nodes contributing to loss)
    graph_ids: jax.Array               # [N] int32 (graph membership, pooling)
    coords: Optional[jax.Array] = None     # [N, 3] (egnn)
    edge_feat: Optional[jax.Array] = None  # [E, d_e]
    ell: Optional[B2SREll] = None          # B2SR adjacency (paper technique)
    degrees: Optional[jax.Array] = None    # [N] float (incl. self loop if any)
    n_graphs: int = static_field(default=1)

    def replace(self, **kw) -> "GraphBatch":
        return dataclasses.replace(self, **kw)


def segment_agg(messages: jax.Array, receivers: jax.Array, n_nodes: int,
                edge_mask: jax.Array, aggregator: str = "sum") -> jax.Array:
    """⊕_j m_ij grouped by receiver, with padding killed via the mask."""
    m = jnp.where(edge_mask[:, None], messages, 0)
    if aggregator == "sum":
        return jax.ops.segment_sum(m, receivers, num_segments=n_nodes)
    if aggregator == "mean":
        s = jax.ops.segment_sum(m, receivers, num_segments=n_nodes)
        cnt = jax.ops.segment_sum(edge_mask.astype(m.dtype), receivers,
                                  num_segments=n_nodes)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if aggregator == "max":
        neg = jnp.where(edge_mask[:, None], messages, -jnp.inf)
        out = jax.ops.segment_max(neg, receivers, num_segments=n_nodes)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(aggregator)


def batch_graphs(graphs: list) -> GraphBatch:
    """Disjoint-union batching of small graphs (molecule shape)."""
    n_off = 0
    feats, snd, rcv, gids, labels, coords = [], [], [], [], [], []
    for gi, g in enumerate(graphs):
        feats.append(g["node_feat"])
        snd.append(g["senders"] + n_off)
        rcv.append(g["receivers"] + n_off)
        gids.append(np.full(g["node_feat"].shape[0], gi, np.int32))
        labels.append(g["label"])
        if "coords" in g:
            coords.append(g["coords"])
        n_off += g["node_feat"].shape[0]
    node_feat = np.concatenate(feats)
    n = node_feat.shape[0]
    e = sum(len(s) for s in snd)
    return GraphBatch(
        node_feat=jnp.asarray(node_feat),
        senders=jnp.asarray(np.concatenate(snd).astype(np.int32)),
        receivers=jnp.asarray(np.concatenate(rcv).astype(np.int32)),
        node_mask=jnp.ones(n, bool),
        edge_mask=jnp.ones(e, bool),
        labels=jnp.asarray(np.asarray(labels)),
        train_mask=jnp.ones(n, bool),
        graph_ids=jnp.asarray(np.concatenate(gids)),
        n_graphs=len(graphs),
        coords=jnp.asarray(np.concatenate(coords)) if coords else None,
    )


def graph_pool(h: jax.Array, graph_ids: jax.Array, n_graphs: int,
               node_mask: jax.Array, how: str = "mean") -> jax.Array:
    hm = jnp.where(node_mask[:, None], h, 0)
    s = jax.ops.segment_sum(hm, graph_ids, num_segments=n_graphs)
    if how == "sum":
        return s
    cnt = jax.ops.segment_sum(node_mask.astype(h.dtype), graph_ids,
                              num_segments=n_graphs)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def node_ce_loss(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    per = logz - gold
    return jnp.sum(jnp.where(mask, per, 0)) / jnp.maximum(jnp.sum(mask), 1)
