"""Paper Fig. 8 analogue: B2SR×B2SR SpGEMM (mxm) vs a float SpGEMM baseline.

The paper's biggest single result (§VI, up to 6555× over cuSPARSE csrgemm)
is SpGEMM on B2SR. This sweep measures the jnp word-level ``mxm_bin_bin_bin``
(packed grid out) across tile dims {4, 8, 16, 32} × edge densities against
the float baseline (CSR SpMM into the densified right operand + threshold —
the cusparseScsrgemm stand-in used throughout the benches). Wall-clock on
this container is jitted-CPU; relative behaviour is what transfers.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, save_json, time_fn
from repro.core import csr as csr_mod
from repro.core import ops
from repro.core.b2sr import b2sr_to_dense, coo_to_b2sr, to_ell

TILE_SWEEP = (4, 8, 16, 32)
DENSITY_SWEEP = (0.005, 0.02, 0.08)


def _random_coo(n: int, density: float, seed: int):
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) < density
    rows, cols = np.nonzero(m)
    return rows, cols


def run(n: int = 512) -> List[BenchRow]:
    rows_out: List[BenchRow] = []
    detail = {}
    for density in DENSITY_SWEEP:
        r, c = _random_coo(n, density, seed=int(density * 1e4))
        csr = csr_mod.from_coo(r, c, n, n)
        dense_b = jnp.asarray(
            b2sr_to_dense(coo_to_b2sr(r, c, n, n, 32)).astype(np.float32))

        def csr_gemm(m, db):
            return csr_mod.spmm(m, db) > 0

        f_csr = jax.jit(csr_gemm)
        t_csr = time_fn(f_csr, csr, dense_b)

        entry = {"n": n, "density": density, "nnz": int(r.size),
                 "csr_gemm_us": t_csr * 1e6}
        for t in TILE_SWEEP:
            a = coo_to_b2sr(r, c, n, n, t)
            ea = to_ell(a)
            f_mxm = jax.jit(ops.mxm_bin_bin_bin)
            t_mxm = time_fn(f_mxm, ea, ea)
            entry[f"t{t}_us"] = t_mxm * 1e6
            entry[f"t{t}_speedup"] = t_csr / t_mxm
            rows_out.append(BenchRow(
                f"fig8/spgemm/d{density}/B2SR-{t}", t_mxm * 1e6,
                f"speedup={t_csr / t_mxm:.2f}x nnz={r.size}"))
        detail[f"d{density}"] = entry
    save_json("kernels_spgemm.json", detail)
    return rows_out


if __name__ == "__main__":
    for row in run():
        print(row.csv())
