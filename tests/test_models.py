"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (the assignment's per-arch contract)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.data import synthetic as syn
from repro.models import transformer as T
from repro.models.gnn import egnn, gatedgcn, gcn, graphcast
from repro.models.recsys import din as din_mod

KEY = jax.random.PRNGKey(0)
LM_ARCHS = ["phi4-mini-3.8b", "gemma-7b", "minitron-4b", "qwen3-moe-30b-a3b",
            "arctic-480b"]


def _finite(tree):
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_forward_and_grad(arch):
    cfg = get_reduced_config(arch)
    params = T.init_params(cfg, KEY)
    tokens, labels = syn.lm_batch(cfg, batch=2, seq=16)
    logits, aux = T.forward(params, tokens, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert _finite(logits)
    loss, _ = T.loss_fn(params, tokens, labels, cfg)
    assert _finite(loss)
    grads = jax.grad(lambda p: T.loss_fn(p, tokens, labels, cfg)[0])(params)
    assert _finite(grads)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    if cfg.moe is not None:
        # capacity drops differ between a 24-token forward and a 2-token
        # decode (expected MoE semantics) — remove drops for the parity check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(cfg, KEY)
    tokens, _ = syn.lm_batch(cfg, batch=2, seq=12)
    full, _ = T.forward(params, tokens, cfg)
    _, (ck, cv) = T.prefill(params, tokens[:, :-1], cfg)
    K0, V0 = T.init_cache(cfg, 2, 12)
    K0 = K0.at[:, :, :11].set(ck)
    V0 = V0.at[:, :, :11].set(cv)
    dec, _, _ = T.decode_step(params, tokens[:, -1:], K0, V0,
                              jnp.int32(11), cfg)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_lm_generate():
    cfg = get_reduced_config("phi4-mini-3.8b")
    params = T.init_params(cfg, KEY)
    prompt = jnp.ones((1, 4), jnp.int32)
    out = T.generate(params, prompt, n_steps=5, cfg=cfg)
    assert out.shape == (1, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()


def test_gcn_b2sr_equals_baseline():
    cfg = get_reduced_config("gcn-cora")
    batch = syn.full_graph_batch(cfg, 100, "block", with_b2sr=True)
    params = gcn.init_params(cfg, KEY)
    l_b2sr, _ = gcn.loss_fn(params, batch, cfg)
    l_base, _ = gcn.loss_fn(params, batch,
                            dataclasses.replace(cfg, use_b2sr=False))
    assert abs(float(l_b2sr) - float(l_base)) < 1e-4
    assert _finite(l_b2sr)


@pytest.mark.parametrize("shape_kind", ["full", "minibatch", "molecule"])
def test_gatedgcn_shapes(shape_kind):
    cfg = get_reduced_config("gatedgcn")
    if shape_kind == "full":
        batch = syn.full_graph_batch(cfg, 90, "hybrid")
    elif shape_kind == "minibatch":
        batch = syn.minibatch_batch(cfg, 1500, 16, fanout=(4, 3))
    else:
        batch = syn.molecule_batch(cfg, n_graphs=4)
    params = gatedgcn.init_params(cfg, KEY)
    logits = gatedgcn.forward(params, batch, cfg)
    assert logits.shape == (batch.node_feat.shape[0], cfg.n_classes)
    loss, _ = gatedgcn.loss_fn(params, batch, cfg)
    assert _finite(loss)
    grads = jax.grad(lambda p: gatedgcn.loss_fn(p, batch, cfg)[0])(params)
    assert _finite(grads)


def test_egnn_equivariance():
    cfg = get_reduced_config("egnn")
    batch = syn.molecule_batch(cfg, n_graphs=3)
    params = egnn.init_params(cfg, KEY)
    h1, x1 = egnn.forward(params, batch, cfg)
    # translation: h invariant, x translates
    shifted = batch.replace(coords=batch.coords + 7.0)
    h2, x2 = egnn.forward(params, shifted, cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(x2 - x1), 7.0, atol=1e-4)
    # rotation: h invariant, x rotates
    theta = 0.7
    R = jnp.asarray([[np.cos(theta), -np.sin(theta), 0],
                     [np.sin(theta), np.cos(theta), 0], [0, 0, 1.0]],
                    jnp.float32)
    rotated = batch.replace(coords=batch.coords @ R.T)
    h3, x3 = egnn.forward(params, rotated, cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h3), atol=1e-4)
    np.testing.assert_allclose(np.asarray(x1 @ R.T), np.asarray(x3), atol=1e-4)


def test_egnn_train_step():
    cfg = get_reduced_config("egnn")
    batch = syn.molecule_batch(cfg, n_graphs=4)
    params = egnn.init_params(cfg, KEY)
    loss, _ = egnn.loss_fn(params, batch, cfg)
    grads = jax.grad(lambda p: egnn.loss_fn(p, batch, cfg)[0])(params)
    assert _finite(loss) and _finite(grads)


def test_graphcast_forward():
    cfg = get_reduced_config("graphcast")
    mesh = graphcast.build_mesh(n_grid=150, refinement=cfg.mesh_refinement)
    params = graphcast.init_params(cfg, KEY)
    feat = jax.random.normal(KEY, (150, cfg.d_in))
    out = graphcast.forward(params, feat, mesh, cfg)
    assert out.shape == (150, cfg.n_classes)
    loss, _ = graphcast.loss_fn(params, feat, feat, mesh, cfg)
    grads = jax.grad(lambda p: graphcast.loss_fn(p, feat, feat, mesh, cfg)[0])(params)
    assert _finite(loss) and _finite(grads)


def test_din_train_and_retrieval():
    cfg = get_reduced_config("din")
    params = din_mod.init_params(cfg, KEY)
    batch = syn.din_batch(cfg, 32)
    logits = din_mod.forward(params, batch, cfg)
    assert logits.shape == (32,)
    loss, _ = din_mod.loss_fn(params, batch, cfg)
    grads = jax.grad(lambda p: din_mod.loss_fn(p, batch, cfg)[0])(params)
    assert _finite(loss) and _finite(grads)
    # retrieval: one user vs candidate set, single batched op
    one = syn.din_batch(cfg, 1, seed=3)
    cands = jnp.arange(64, dtype=jnp.int32) % cfg.n_items
    scores = din_mod.score_candidates(params, one, cands,
                                      cands % cfg.n_cates, cfg)
    assert scores.shape == (1, 64)
    assert _finite(scores)


def test_all_arch_ids_have_configs():
    assert len(ARCH_IDS) == 10
    for arch in ARCH_IDS:
        cfg = get_reduced_config(arch)
        assert cfg.name
