"""Paper Fig. 6d / 7d: BMM (bin·bin→sum) vs a float SpGEMM-reduce baseline.

The paper's BMM computes Σ nonzeros of (A·B) fused with the product. The
float baseline mirrors cusparseScsrgemm + reduce: CSR SpMM against the dense
unpacked B (row-block streamed) then a global sum. Measured per corpus
matrix × tile size on the jnp word-level path.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, corpus, save_json, time_fn
from repro.core import csr as csr_mod
from repro.core import ops
from repro.core.b2sr import b2sr_to_dense, coo_to_b2sr, to_ell, transpose

TILE_SWEEP = (8, 16, 32)


def run(n: int = 1024) -> List[BenchRow]:
    rows: List[BenchRow] = []
    detail = {}
    for name, (r, c, nn) in corpus(n).items():
        csr = csr_mod.from_coo(r, c, nn, nn)
        dense_b = jnp.asarray(
            b2sr_to_dense(coo_to_b2sr(r, c, nn, nn, 32)).astype(np.float32))

        def csr_gemm_sum(m, bd):
            return jnp.sum(csr_mod.spmm(m, bd))

        f_csr = jax.jit(csr_gemm_sum)
        t_csr = time_fn(f_csr, csr, dense_b)

        entry = {"csr_gemm_sum_us": t_csr * 1e6}
        for t in TILE_SWEEP:
            a = coo_to_b2sr(r, c, nn, nn, t)
            b = transpose(a)
            ea, eb = to_ell(a), to_ell(b)
            f_bmm = jax.jit(ops.bmm_bin_bin_sum)
            t_bmm = time_fn(f_bmm, ea, eb)
            entry[f"t{t}_us"] = t_bmm * 1e6
            entry[f"t{t}_speedup"] = t_csr / t_bmm
            rows.append(BenchRow(
                f"fig6d/bmm/{name}/B2SR-{t}", t_bmm * 1e6,
                f"speedup={t_csr / t_bmm:.2f}x"))
        detail[name] = entry
    save_json("kernels_bmm.json", detail)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
