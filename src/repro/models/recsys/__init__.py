"""RecSys: DIN with from-scratch EmbeddingBag (take + segment_sum)."""
