"""Pallas TPU kernel: B2SR × B2SR boolean SpGEMM (paper Table III, mxm).

Computes the packed output tile grid  C[i, j] = OR_m A(i, m) ∧ B(m, j)
where A and B are binary matrices in B2SR-ELL (row-major packed words).
The tile-level product uses the AND/shift word algorithm: C's bit-row r
ORs in B's word-row k for every set bit k of A's word-row r — the word
formulation of the paper's shared-memory inner loop (no popcount here;
the boolean semiring needs only OR/AND).

Like the BMM kernel, the double indirection of SpGEMM (A's tile column
selects B's tile-row) is an in-VMEM gather over the full B arrays — B must
fit VMEM. The output is the *dense* tile grid uint32[R, C, t] (static shape;
empty tiles are all-zero words): compression back to sparse B2SR is a host
step (``b2sr.packed_grid_to_b2sr``), mirroring cusparseXcsrgemmNnz's
two-phase structure with the nnz phase moved off-device (DESIGN.md §2).

Accumulation is OR into the program's private output block; the optional
mask (C⟨M⟩, paper §V) is expanded to grid words in-kernel and ANDed right
before the store.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import unpack_words


def _expand_grid(col, tiles, n_tile_cols):
    """ELL row block -> dense word grid [BR, C, t] via one-hot OR-select."""
    BR, K = col.shape
    t = tiles.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (BR, n_tile_cols), 1)

    def body(k, grid):
        c = col[:, k]                                          # [BR]
        onehot = (iota == c[:, None]) & (c >= 0)[:, None]      # [BR, C]
        return grid | jnp.where(onehot[:, :, None],
                                tiles[:, k][:, None, :], jnp.uint32(0))

    return jax.lax.fori_loop(
        0, K, body, jnp.zeros((BR, n_tile_cols, t), jnp.uint32))


def _spgemm_kernel(a_col_ref, a_tiles_ref, b_col_ref, b_tiles_ref,
                   m_col_ref, m_tiles_ref, out_ref, *, t: int, mask_mode: str):
    a_col = a_col_ref[...]          # [BR, Ka]
    a_tiles = a_tiles_ref[...]      # [BR, Ka, t]
    b_col = b_col_ref[...]          # [Rb, Kb]
    b_tiles = b_tiles_ref[...]      # [Rb, Kb, t]
    BR, Ka = a_col.shape
    Kb = b_col.shape[1]
    C = out_ref.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (BR, C), 1)

    def body_ka(ka, acc):
        ac = a_col[:, ka]                                      # [BR]
        valid_a = ac >= 0
        safe = jnp.clip(ac, 0, b_col.shape[0] - 1)
        bc_all = jnp.take(b_col, safe, axis=0)                 # [BR, Kb]
        bt_all = jnp.take(b_tiles, safe, axis=0)               # [BR, Kb, t]
        a_bits = unpack_words(a_tiles[:, ka], t, jnp.uint32)   # [BR, t(r), t(k)]

        def body_kb(kb, acc2):
            bc = bc_all[:, kb]                                 # [BR]
            bw = bt_all[:, kb]                                 # [BR, t(k)]

            # AND/shift: c_word[r] = OR_k (A[r, k] ? b_word[k] : 0)
            def body_k(k, cw):
                term = jnp.where(a_bits[:, :, k] != 0,
                                 bw[:, k][:, None], jnp.uint32(0))
                return cw | term

            cw = jax.lax.fori_loop(0, t, body_k,
                                   jnp.zeros((BR, t), jnp.uint32))
            ok = valid_a & (bc >= 0)
            cw = jnp.where(ok[:, None], cw, jnp.uint32(0))
            onehot = iota == bc[:, None]                       # [BR, C]
            return acc2 | jnp.where(onehot[:, :, None],
                                    cw[:, None, :], jnp.uint32(0))

        return jax.lax.fori_loop(0, Kb, body_kb, acc)

    acc = jax.lax.fori_loop(0, Ka, body_ka,
                            jnp.zeros((BR, C, t), jnp.uint32))
    if mask_mode != "none":
        mg = _expand_grid(m_col_ref[...], m_tiles_ref[...], C)
        acc = acc & (~mg if mask_mode == "complement" else mg)
    out_ref[...] = acc


def mxm_bin_bin_bin_pallas(a_col, a_tiles, b_col, b_tiles, m_col, m_tiles, *,
                           t: int, n_tile_cols: int, mask_mode: str = "none",
                           block_r: int = 8, interpret: bool = True):
    """Packed boolean SpGEMM grid: uint32[R, n_tile_cols, t]."""
    R, Ka = a_col.shape
    assert R % block_r == 0
    assert mask_mode in ("none", "keep", "complement")
    grid = (R // block_r,)
    Rb, Kb = b_col.shape
    Km = m_col.shape[1]
    return pl.pallas_call(
        functools.partial(_spgemm_kernel, t=t, mask_mode=mask_mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, Ka), lambda i: (i, 0)),
            pl.BlockSpec((block_r, Ka, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((Rb, Kb), lambda i: (0, 0)),
            pl.BlockSpec((Rb, Kb, t), lambda i: (0, 0, 0)),
            pl.BlockSpec((block_r, Km), lambda i: (i, 0)),
            pl.BlockSpec((block_r, Km, t), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, n_tile_cols, t), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, n_tile_cols, t), jnp.uint32),
        interpret=interpret,
    )(a_col, a_tiles, b_col, b_tiles, m_col, m_tiles)
