"""Word-level jnp GraphBLAS ops vs dense oracles (all schemes, all tile sizes)."""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import (
    ARITHMETIC, BOOLEAN, MAX_TIMES, MIN_PLUS, TILE_DIMS, GraphMatrix,
    dense_to_b2sr, pack_bitvector, to_ell, unpack_bitvector,
)
from repro.core import ops


def random_dense(n, m, density, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n, m)) < density).astype(np.uint8)


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("n", [16, 65, 130])
def test_bmv_bin_bin_full(t, n):
    d = random_dense(n, n, 0.1, seed=n + t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(t)
    x = rng.random(n) < 0.4
    xp = pack_bitvector(jnp.asarray(x), t, n)
    y = ops.bmv_bin_bin_full(ell, xp)
    assert np.allclose(np.asarray(y), d.astype(np.float64) @ x)


@pytest.mark.parametrize("t", TILE_DIMS)
def test_bmv_bin_bin_bin_masked(t):
    n = 90
    d = random_dense(n, n, 0.15, seed=t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(t + 1)
    x = rng.random(n) < 0.3
    visited = rng.random(n) < 0.5
    xp = pack_bitvector(jnp.asarray(x), t, n)
    vp = pack_bitvector(jnp.asarray(visited), t, n)
    y = ops.bmv_bin_bin_bin_masked(ell, xp, vp, complement=True)
    got = np.asarray(unpack_bitvector(y, t, n, jnp.int32))
    ref = ((d @ x) > 0) & ~visited
    assert np.array_equal(got, ref.astype(np.int32))


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("semiring,a_value", [
    (ARITHMETIC, 1.0), (MIN_PLUS, 1.0), (MIN_PLUS, 2.5), (MAX_TIMES, 1.0),
])
def test_bmv_bin_full_full(t, semiring, a_value):
    n = 75
    d = random_dense(n, n, 0.12, seed=t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(t + 2)
    x = rng.random(n).astype(np.float32) + 0.1
    y = np.asarray(ops.bmv_bin_full_full(ell, jnp.asarray(x), semiring, a_value))
    if semiring is ARITHMETIC:
        ref = d @ (a_value * x)
    elif semiring is MIN_PLUS:
        ref = np.where(d > 0, x[None, :] + a_value, np.inf).min(axis=1)
    else:
        ref = np.where(d > 0, x[None, :] * a_value, -np.inf).max(axis=1)
    assert np.allclose(y, ref, rtol=1e-5)


@pytest.mark.parametrize("t", [8, 32])
def test_bmv_masked_full(t):
    n = 66
    d = random_dense(n, n, 0.1, seed=t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(5)
    x = rng.random(n).astype(np.float32)
    mask = (rng.random(n) < 0.5).astype(np.float32)
    y = np.asarray(ops.bmv_bin_full_full_masked(
        ell, jnp.asarray(x), jnp.asarray(mask), MIN_PLUS, 1.0, complement=False))
    full = np.where(d > 0, x[None, :] + 1.0, np.inf).min(axis=1)
    ref = np.where(mask != 0, full, np.inf)
    assert np.allclose(y, ref)


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("dfeat", [1, 7, 32])
def test_spmm(t, dfeat):
    n = 70
    d = random_dense(n, n, 0.1, seed=t + dfeat)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(7)
    X = rng.standard_normal((n, dfeat)).astype(np.float32)
    y = np.asarray(ops.spmm_b2sr(ell, jnp.asarray(X)))
    assert np.allclose(y, d @ X, rtol=1e-4, atol=1e-4)


def test_row_chunked_paths_match():
    n = 128
    t = 8
    d = random_dense(n, n, 0.1, seed=0)
    ell = to_ell(dense_to_b2sr(d, t), pad_tile_rows_to=4)
    rng = np.random.default_rng(1)
    x = rng.random(n).astype(np.float32)
    full = ops.bmv_bin_full_full(ell, jnp.asarray(x), ARITHMETIC)
    chunked = ops.bmv_bin_full_full(ell, jnp.asarray(x), ARITHMETIC, row_chunk=4)
    assert np.allclose(np.asarray(full), np.asarray(chunked), rtol=1e-6)
    X = rng.random((n, 5)).astype(np.float32)
    f2 = ops.spmm_b2sr(ell, jnp.asarray(X))
    c2 = ops.spmm_b2sr(ell, jnp.asarray(X), row_chunk=8)
    assert np.allclose(np.asarray(f2), np.asarray(c2), rtol=1e-6)


@pytest.mark.parametrize("t", TILE_DIMS)
def test_bmm_masked_triangle(t):
    n = 60
    d = random_dense(n, n, 0.15, seed=t)
    d = np.triu(d, 1)
    d = d + d.T  # symmetric simple graph
    L = np.tril(d, -1)
    eL = to_ell(dense_to_b2sr(L, t))
    eLT = to_ell(dense_to_b2sr(L.T, t))
    got = float(ops.bmm_bin_bin_sum_masked(eL, eLT, eL))
    ref = float(((L @ L.T) * L).sum())
    assert got == ref


@given(st.sampled_from(TILE_DIMS), st.integers(2, 90), st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_property_bmv_semiring_agreement(t, n, seed):
    """Property: count scheme == arithmetic bin_full_full on a 0/1 vector."""
    d = random_dense(n, n, 0.2, seed)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(seed + 1)
    x = rng.random(n) < 0.5
    xp = pack_bitvector(jnp.asarray(x), t, n)
    counts = np.asarray(ops.bmv_bin_bin_full(ell, xp))
    full = np.asarray(ops.bmv_bin_full_full(
        ell, jnp.asarray(x.astype(np.float32)), ARITHMETIC))
    assert np.allclose(counts, full)


@given(st.integers(2, 64), st.integers(0, 300))
@settings(max_examples=15, deadline=None)
def test_property_backend_parity(n, seed):
    """Property: b2sr and csr GraphMatrix backends agree on mxv."""
    d = random_dense(n, n, 0.25, seed)
    g = GraphMatrix.from_dense(d, tile_dim=8)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(n).astype(np.float32))
    yb = np.asarray(g.with_backend("b2sr").mxv(x, ARITHMETIC))
    yc = np.asarray(g.with_backend("csr").mxv(x, ARITHMETIC))
    assert np.allclose(yb, yc, rtol=1e-5)
    ybm = np.asarray(g.with_backend("b2sr").mxv(x, MIN_PLUS))
    ycm = np.asarray(g.with_backend("csr").mxv(x, MIN_PLUS))  # csr values are 1.0
    refm = np.where(d > 0, np.asarray(x)[None, :] + 1.0, np.inf).min(axis=1)
    assert np.allclose(ybm, refm)
    assert np.allclose(ycm, refm)
