"""Batched multi-source graph queries over packed frontier matrices.

The single-source algorithms in ``repro.algorithms`` pay one full matrix
sweep per query. Here a batch of S queries shares every sweep: frontiers
live in one bit-packed :class:`~repro.core.operands.FrontierBatch`
(``uint32[tiles, t, W]`` with 32 sources per word) and each iteration is
one generic ``GraphMatrix.mxm`` launch — the FrontierBatch operand selects
the multi-frontier Table row, and A's tiles stream once for the whole
batch. Every query loop is compiled once per (graph, kernel, batch width,
descriptor) and cached by ``engine.planner``. A sharded graph
(``GraphMatrix.shard(mesh)``) routes every iteration through the
shard_map rows — one mesh serves the whole batch per sweep — and the plan
key carries the mesh fingerprint, so plans never leak across mesh shapes
(DESIGN.md §11).

Parity contracts (pinned by tests/test_engine.py):
  - ``msbfs`` / ``mskhop`` / ``ms_sssp`` column ``s`` is **bit-exact**
    against the single-source run on ``sources[s]`` (boolean ops are
    order-insensitive).
  - ``batched_ppr`` column ``s`` is **allclose** against
    ``algorithms.pagerank.ppr`` (the batched spmm sums features in a
    different float order than the scanned bmv).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import direction as direction_mod
from repro.algorithms.bfs import _check_max_iters
from repro.algorithms.direction import DirectionConfig
from repro.core.b2sr import (SOURCE_WORD_BITS, ceil_div,
                             unpack_frontier_matrix)
from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.operands import FrontierBatch
from repro.engine import planner as planner_mod
from repro.engine.planner import PlanCache, descriptor_key, plan_key

#: The descriptor every masked-traversal loop bakes into its trace: the
#: per-source visited sets as a complement mask (loop-carried, so mask
#: presence is pinned via ``masked=True`` at key time).
_TRAVERSAL_DESC = descriptor_key(Descriptor(complement=True), masked=True)


def _traversal_desc(cfg: DirectionConfig):
    """Plan-key descriptor component for a direction-switching loop.

    The msbfs loop's Descriptor direction is *loop-internal* (each
    iteration picks push or pull), so the baked-in policy — mode and
    thresholds — must reach the key here; two configs are two XLA
    programs and must never share a cached plan.
    """
    return _TRAVERSAL_DESC + (cfg.mode, cfg.alpha, cfg.beta)


@dataclasses.dataclass
class MSBFSResult:
    levels: jax.Array        # int32[n, S]; -1 = unreachable from sources[s]
    n_iterations: int        # max over the batch (columns finish together)
    directions: tuple = ()   # per-iteration direction used (whole batch)


@dataclasses.dataclass
class MSSSSPResult:
    distances: jax.Array     # float32[n, S]; +inf = unreachable
    n_iterations: int


@dataclasses.dataclass
class BatchedPPRResult:
    ranks: jax.Array         # float32[n, S]; column s = PPR from seeds[s]
    n_iterations: int


@dataclasses.dataclass
class GNNInferResult:
    logits: jax.Array        # float32[n_classes, S]; column s = node sources[s]
    n_layers: int


def _check_sources(sources, n: int) -> np.ndarray:
    src = np.asarray(sources, dtype=np.int64).reshape(-1)
    if src.size == 0:
        raise ValueError("need at least one source")
    bad = src[(src < 0) | (src >= n)]
    if bad.size:
        shown = ", ".join(str(b) for b in bad[:5])
        more = ", ..." if bad.size > 5 else ""
        raise ValueError(
            f"source id(s) {shown}{more} out of range for a graph with "
            f"{n} nodes (valid ids are 0..{n - 1})")
    return src


def _padded_width(n_sources: int) -> int:
    return ceil_div(n_sources, SOURCE_WORD_BITS) * SOURCE_WORD_BITS


def _one_hot_frontier(g: GraphMatrix, src: np.ndarray,
                      s_pad: int) -> FrontierBatch:
    """Packed one-hot frontier matrix [tiles, t, W] for a source batch.

    Built directly in the packed layout — S word-writes instead of
    materialising (and shipping) the dense ``[n, s_pad]`` matrix that
    ``FrontierBatch.pack`` would consume (hot on the serving path).
    """
    t = g.tile_dim
    words = np.zeros((ceil_div(g.n_rows, t), t, s_pad // SOURCE_WORD_BITS),
                     np.uint32)
    idx = np.arange(src.size)
    np.bitwise_or.at(
        words, (src // t, src % t, idx // SOURCE_WORD_BITS),
        np.uint32(1) << (idx % SOURCE_WORD_BITS).astype(np.uint32))
    return FrontierBatch.from_words(jnp.asarray(words), g.n_rows, s_pad, t)


def _planner(planner: Optional[PlanCache]) -> PlanCache:
    return planner_mod.DEFAULT_PLANNER if planner is None else planner


# ---------------------------------------------------------------------------
# multi-source BFS: per-source depth via iteration-stamped updates
# ---------------------------------------------------------------------------

def _build_msbfs_plan(g: GraphMatrix, cfg: DirectionConfig):
    gt = g.transposed()
    n = g.n_rows
    avg_degree = g.nnz / max(n, 1)

    def step_push(f, v):
        # FrontierBatch operand -> the multi-frontier bin·bin→bin mxm
        # row, with the per-source visited sets as the §V mask
        return gt.mxm(f, desc=Descriptor(mask=v, complement=True))

    def step_pull(f, v):
        return gt.mxm(f, desc=Descriptor(mask=v, complement=True,
                                         direction="pull"))

    def loop(f0, levels0, max_iters, n_active):
        def cond(state):
            frontier, _, _, it, _, _, _ = state
            return frontier.any() & (it < max_iters)

        def body(state):
            frontier, visited, levels, it, d, locked, trace = state
            if cfg.mode == "auto":
                nxt = jax.lax.cond(d == direction_mod.PULL, step_pull,
                                   step_push, frontier, visited)
            elif cfg.mode == "pull":
                nxt = step_pull(frontier, visited)
            else:
                nxt = step_push(frontier, visited)
            new_bits = unpack_frontier_matrix(nxt.words, n, levels.shape[1],
                                              jnp.bool_)
            levels = jnp.where(new_bits & (levels < 0), it + 1, levels)
            new_visited = visited | nxt
            trace = direction_mod.record(trace, it, d)
            # n_active (not the padded width) scales the summed counts to
            # per-query magnitudes: padded columns are all-zero and would
            # dilute the density estimate; traced so one cached plan
            # serves every batch size sharing this padded width
            d_next, locked = direction_mod.next_direction(
                cfg, d, locked, direction_mod.nnz_words(nxt.words),
                direction_mod.nnz_words(new_visited.words), n, avg_degree,
                batch=n_active)
            return (nxt, new_visited, levels, it + 1, d_next, locked,
                    trace)

        state = (f0, f0, levels0, jnp.int32(0),
                 direction_mod.initial_direction(cfg), jnp.bool_(False),
                 direction_mod.empty_trace(n))
        _, _, levels, it, _, _, trace = jax.lax.while_loop(cond, body,
                                                           state)
        return levels, it, trace

    return jax.jit(loop)


def msbfs(g: GraphMatrix, sources: Sequence[int],
          max_iters: Optional[int] = None,
          planner: Optional[PlanCache] = None,
          direction=None) -> MSBFSResult:
    """Hop levels from every source in one batched traversal.

    Column ``s`` of ``levels`` is bit-exact against
    ``algorithms.bfs(g, sources[s]).levels`` for every ``direction``
    mode; the whole batch switches direction together (one shared sweep
    per iteration is the point of batching), steered by the summed
    density scaled back to per-query magnitudes. ``direction=None``
    defaults to auto switching to match ``bfs``.
    """
    cfg = (direction_mod.as_config(direction) if direction is not None
           else DirectionConfig(mode="auto"))
    n = g.n_rows
    src = _check_sources(sources, n)
    max_iters = _check_max_iters(max_iters, n)
    s_pad = _padded_width(src.size)
    plan = _planner(planner).get(plan_key(g, "msbfs", s_pad,
                                          desc=_traversal_desc(cfg)),
                                 lambda: _build_msbfs_plan(g, cfg))
    f0 = _one_hot_frontier(g, src, s_pad)
    levels0 = jnp.asarray(_stamp_zero(n, s_pad, src))
    levels, it, trace = plan(f0, levels0, jnp.int32(max_iters),
                             jnp.float32(src.size))
    it = int(it)
    dirs = direction_mod.trace_tuple(trace, it)
    direction_mod.observe_trace(dirs, kernel="msbfs")
    return MSBFSResult(levels=levels[:, : src.size], n_iterations=it,
                       directions=dirs)


def _stamp_zero(n: int, s_pad: int, src: np.ndarray) -> np.ndarray:
    lv = np.full((n, s_pad), -1, np.int32)
    lv[src, np.arange(src.size)] = 0
    return lv


# ---------------------------------------------------------------------------
# multi-source k-hop neighborhoods
# ---------------------------------------------------------------------------

def _build_mskhop_plan(g: GraphMatrix):
    gt = g.transposed()

    def loop(f0, k):
        def body(_, state):
            frontier, visited = state
            nxt = gt.mxm(frontier, desc=Descriptor(mask=visited,
                                                   complement=True))
            return nxt, visited | nxt

        _, visited = jax.lax.fori_loop(0, k, body, (f0, f0))
        return visited & ~f0              # exclude the sources themselves

    return jax.jit(loop)


def mskhop(g: GraphMatrix, sources: Sequence[int], k: int,
           planner: Optional[PlanCache] = None) -> jax.Array:
    """<=k-hop neighborhoods of every source, as ``bool[n, S]``.

    Column ``s`` is bit-exact against
    ``algorithms.khop_frontier(g, sources[s], k)``.
    """
    if k < 1:
        raise ValueError("k must be >= 1")
    n = g.n_rows
    src = _check_sources(sources, n)
    s_pad = _padded_width(src.size)
    plan = _planner(planner).get(plan_key(g, "mskhop", s_pad,
                                          desc=_TRAVERSAL_DESC),
                                 lambda: _build_mskhop_plan(g))
    reached = plan(_one_hot_frontier(g, src, s_pad), jnp.int32(k))
    return unpack_frontier_matrix(reached.words, n, src.size, jnp.bool_)


# ---------------------------------------------------------------------------
# multi-source SSSP (uniform edge weight — hop distances × weight)
# ---------------------------------------------------------------------------

def ms_sssp(g: GraphMatrix, sources: Sequence[int], edge_weight: float = 1.0,
            max_iters: Optional[int] = None,
            planner: Optional[PlanCache] = None,
            direction=None) -> MSSSSPResult:
    """Batched SSSP on the binary adjacency: ``levels × edge_weight``.

    B2SR edges are unweighted, so min-plus distances are hop counts scaled
    by the uniform weight — one msbfs serves the whole batch. Matches the
    looped ``algorithms.sssp`` exactly for dyadic weights (1.0, 0.5, 2.0,
    ...), where k repeated float adds equal ``k * w``.
    """
    res = msbfs(g, sources, max_iters=max_iters, planner=planner,
                direction=direction)
    dist = jnp.where(res.levels >= 0,
                     res.levels.astype(jnp.float32) * edge_weight, jnp.inf)
    return MSSSSPResult(distances=dist, n_iterations=res.n_iterations)


# ---------------------------------------------------------------------------
# batched personalized PageRank (arithmetic semiring, per-column restarts)
# ---------------------------------------------------------------------------

def _build_ppr_plan(g: GraphMatrix):
    gt = g.transposed()
    out_deg = g.degrees()
    dangling = out_deg == 0
    safe_deg = jnp.where(dangling, 1.0, out_deg)

    def loop(restart, alpha, eps, max_iters):
        def cond(state):
            _, delta, it = state
            return (delta > eps) & (it < max_iters)

        def body(state):
            pr, _, it = state
            scaled = pr / safe_deg[:, None]           # out-degree division
            contrib = gt.mxm(scaled)                  # [n, S] multi-vector
            dangle = jnp.sum(jnp.where(dangling[:, None], pr, 0.0), axis=0)
            new = alpha * contrib + (alpha * dangle[None, :]
                                     + (1.0 - alpha)) * restart
            delta = jnp.max(jnp.sum(jnp.abs(new - pr), axis=0))
            return new, delta, it + 1

        pr, _, it = jax.lax.while_loop(
            cond, body, (restart, jnp.float32(jnp.inf), jnp.int32(0)))
        return pr, it

    return jax.jit(loop)


# ---------------------------------------------------------------------------
# batched GNN inference (BitGNN forward on the bit path, DESIGN.md §15)
# ---------------------------------------------------------------------------

#: Served models by name: weights + input features + the bit-path flag.
#: Names (not arrays) travel in the query params, so groups coalesce and
#: warmup recipes stay JSON-serialisable; re-register after a restart.
_GNN_MODELS: dict = {}


@dataclasses.dataclass
class GNNModel:
    """A registered inference model: per-layer (W, b) + node features.

    ``binarize=True`` routes every hidden layer's aggregation through the
    packed bin·bin→full row — activations are sign-binarized, packed to
    :class:`~repro.core.operands.BitMatrix` words, and aggregated as
    α·(2·popcount − rowsum) (``repro.gnn_bit``); the input layer always
    aggregates dense (float features). ``version`` feeds the plan key so
    re-registering a name never serves a stale compiled forward.
    """

    name: str
    params: tuple            # ((w, b), ...) per layer
    features: jax.Array      # float[n, d_in]
    binarize: bool = True
    version: int = 0


def register_gnn_model(name: str, params, features,
                       binarize: bool = True) -> GNNModel:
    """Register (or replace) a model for ``gnn_infer`` serving."""
    prev = _GNN_MODELS.get(name)
    model = GNNModel(
        name=name,
        params=tuple((jnp.asarray(w), jnp.asarray(b)) for w, b in params),
        features=jnp.asarray(features), binarize=binarize,
        version=prev.version + 1 if prev is not None else 0)
    _GNN_MODELS[name] = model
    return model


def _gnn_model(name: str) -> GNNModel:
    m = _GNN_MODELS.get(name)
    if m is None:
        raise ValueError(
            f"no GNN model registered under {name!r}; call "
            f"engine.queries.register_gnn_model first "
            f"(registered: {sorted(_GNN_MODELS) or 'none'})")
    return m


def _build_gnn_plan(g: GraphMatrix, model: GNNModel):
    from repro.gnn_bit import binarize as binarize_mod

    rowsum = g.degrees().astype(jnp.float32)      # A's row-sums (neighbors)
    params = model.params
    n_last = len(params) - 1

    def fwd(idx):
        h = model.features
        for li, (w, b) in enumerate(params):
            if model.binarize and li > 0:
                # hidden layers ride the packed path: sign-binarize, pack,
                # one bin·bin→full mxm, α·popcount reconstruction — the
                # adjacency *and* the activations stay bit-packed
                alpha = binarize_mod.alpha_scale(h)
                bm = binarize_mod.pack_activations(h, g.tile_dim)
                counts = g.mxm(bm)
                agg = alpha[None, :] * (2.0 * counts - rowsum[:, None]) + h
            else:
                agg = g.mxm(h) + h                # dense row + self loop
            h = agg @ w + b
            if li < n_last:
                h = jax.nn.relu(h)
        return h[idx].T                           # [n_classes, s_pad]

    return jax.jit(fwd)


def gnn_infer(g: GraphMatrix, sources: Sequence[int], model: str,
              planner: Optional[PlanCache] = None) -> GNNInferResult:
    """Class scores for a batch of nodes through one full-graph forward.

    One compiled plan per (graph, model version, padded width) serves every
    batch: the forward computes logits for all nodes (the aggregation
    launches are shared — that is the batching win) and gathers the
    requested rows. Column ``s`` of ``logits`` belongs to ``sources[s]``.
    The model's hidden aggregations run on the packed bit path when it was
    registered with ``binarize=True``; every mxm row involved exists on all
    three backends, so the serving fallback chain applies unchanged.
    """
    m = _gnn_model(model)
    n = g.n_rows
    if int(m.features.shape[0]) != n:
        raise ValueError(
            f"model {model!r} features cover {int(m.features.shape[0])} "
            f"nodes but the graph has {n}")
    src = _check_sources(sources, n)
    s_pad = _padded_width(src.size)
    padded = np.concatenate(
        [src, np.full(s_pad - src.size, src[0], np.int64)])
    plan = _planner(planner).get(
        plan_key(g, "gnn_infer", s_pad,
                 desc=("gnn", m.name, m.version, m.binarize)),
        lambda: _build_gnn_plan(g, m))
    logits = plan(jnp.asarray(padded, jnp.int32))
    return GNNInferResult(logits=logits[:, : src.size],
                          n_layers=len(m.params))


def batched_ppr(g: GraphMatrix,
                seeds: Union[Sequence[int], jax.Array, np.ndarray],
                alpha: float = 0.85, max_iters: int = 10, eps: float = 1e-9,
                planner: Optional[PlanCache] = None) -> BatchedPPRResult:
    """Personalized PageRank for S seeds in one multi-vector iteration.

    ``seeds`` is either an int array ``[S]`` (one-hot restarts) or a dense
    restart matrix ``[n, S]`` (per-column restart distributions). Dangling
    mass restarts into each column's own distribution — the same update as
    ``algorithms.pagerank.ppr``, so column ``s`` is allclose against the
    single-seed run. Stops when the worst column's L1 delta is <= ``eps``
    (a batch iterates until its slowest member converges).
    """
    n = g.n_rows
    seeds_arr = np.asarray(seeds)
    if seeds_arr.ndim == 2:
        if seeds_arr.shape[0] != n:
            raise ValueError(f"restart matrix must be [n={n}, S]")
        s = seeds_arr.shape[1]
        s_pad = _padded_width(s)
        restart = np.zeros((n, s_pad), np.float32)
        restart[:, :s] = seeds_arr
    else:
        src = _check_sources(seeds_arr, n)
        s = src.size
        s_pad = _padded_width(s)
        restart = np.zeros((n, s_pad), np.float32)
        restart[src, np.arange(s)] = 1.0
    plan = _planner(planner).get(plan_key(g, "ppr", s_pad),
                                 lambda: _build_ppr_plan(g))
    ranks, it = plan(jnp.asarray(restart), jnp.float32(alpha),
                     jnp.float32(eps), jnp.int32(max_iters))
    return BatchedPPRResult(ranks=ranks[:, :s], n_iterations=int(it))
