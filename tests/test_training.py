"""Fault-tolerance substrate: checkpointing, restart, stragglers, compression."""

import itertools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.data.synthetic import full_graph_batch
from repro.models.gnn import gcn
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt_mod
from repro.training import train_steps
from repro.training.trainer import (SimulatedFailure, TrainerConfig,
                                    TrainState, run)


@pytest.fixture
def small_setup():
    cfg = GNNConfig(name="t", family="gcn", n_layers=2, d_hidden=8,
                    norm="sym", d_in=16, n_classes=4)
    batch = full_graph_batch(cfg, 128, pattern="block", seed=0)
    opt_cfg = opt_mod.OptimizerConfig(name="adamw", lr=1e-2)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt_mod.init(opt_cfg, params)
    step = jax.jit(train_steps.gnn_train_step(cfg, opt_cfg))
    return params, opt_state, step, batch


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, small_setup):
        params, opt_state, _, _ = small_setup
        tree = {"params": params, "opt": opt_state}
        ckpt.save(str(tmp_path), 7, tree, extra={"data": {"seed": 3}})
        assert ckpt.latest_step(str(tmp_path)) == 7
        restored, extra = ckpt.restore(str(tmp_path), 7, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert extra == {"data": {"seed": 3}}

    def test_torn_write_invisible(self, tmp_path, small_setup):
        params, opt_state, _, _ = small_setup
        tree = {"p": params}
        ckpt.save(str(tmp_path), 1, tree)
        # simulate a crash mid-write: tmp dir without manifest
        os.makedirs(tmp_path / "step_00000002.tmp")
        (tmp_path / "step_00000002.tmp" / "shard_0.ckpt").write_bytes(b"junk")
        # and a renamed dir missing its manifest
        os.makedirs(tmp_path / "step_00000003")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_gc_keeps_newest(self, tmp_path, small_setup):
        params, _, _, _ = small_setup
        for s in range(6):
            ckpt.save(str(tmp_path), s, {"p": params}, keep=2)
        assert ckpt.all_steps(str(tmp_path)) == [4, 5]


class TestTrainerRecovery:
    def test_restart_matches_uninterrupted(self, tmp_path, small_setup):
        params, opt_state, step, batch = small_setup
        data = lambda: itertools.repeat((batch,))

        # uninterrupted reference
        ref = run(TrainerConfig(total_steps=20, ckpt_every=100, log_every=0),
                  step, TrainState(params, opt_state), data())

        # failure at step 10, then restart-from-latest
        d = str(tmp_path)
        with pytest.raises(SimulatedFailure):
            run(TrainerConfig(total_steps=20, ckpt_every=5, ckpt_dir=d,
                              log_every=0, fail_at_step=10),
                step, TrainState(params, opt_state), data())
        out = run(TrainerConfig(total_steps=20, ckpt_every=5, ckpt_dir=d,
                                log_every=0),
                  step, TrainState(params, opt_state), data())
        assert out["final_step"] == 20
        for a, b in zip(jax.tree_util.tree_leaves(ref["state"].params),
                        jax.tree_util.tree_leaves(out["state"].params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_straggler_counter(self, small_setup):
        params, opt_state, step, batch = small_setup
        out = run(TrainerConfig(total_steps=3, log_every=0,
                                step_deadline_s=1e-9),
                  step, TrainState(params, opt_state),
                  itertools.repeat((batch,)))
        assert out["stragglers"] == 3


_COMPRESSION_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.training.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("dp",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))

    def exact(x):
        return jax.lax.psum(x, "dp")

    def approx(x, e):
        return compressed_psum(x, "dp", e)

    with mesh:
        from repro.core.ops import shard_map_compat
        ref = shard_map_compat(exact, mesh=mesh, in_specs=P("dp", None),
                            out_specs=P("dp", None))(g)[0]
        e = jnp.zeros((8, 256))
        total_err = []
        # error feedback: residual carried across steps shrinks the bias
        for _ in range(4):
            s, e = shard_map_compat(approx, mesh=mesh,
                                 in_specs=(P("dp", None), P("dp", None)),
                                 out_specs=(P("dp", None), P("dp", None)))(g, e)
            total_err.append(float(jnp.max(jnp.abs(s[0] - ref))))
    rel = total_err[0] / float(jnp.max(jnp.abs(ref)))
    assert rel < 0.1, f"one-shot int8 psum error too large: {rel}"
    print("COMPRESS_OK", rel)
""")


def test_compressed_psum_close_to_exact():
    r = subprocess.run([sys.executable, "-c", _COMPRESSION_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2500:]
    assert "COMPRESS_OK" in r.stdout
