"""Config dataclasses for all supported architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual_d_ff: Optional[int] = None  # Arctic: parallel dense FFN
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "swiglu"          # swiglu | geglu | relu2
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    qk_norm: bool = False
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    dtype: str = "bfloat16"             # compute dtype
    remat: bool = True
    scan_layers: bool = True
    max_seq_len: int = 32768
    # mesh axes the batch dim shards over; () disables activation-sharding
    # constraints (single-device tests). Set by the launcher per mesh.
    batch_axes: tuple = ()
    # --- §Perf hillclimb knobs (EXPERIMENTS.md) ---
    # shard chunked-attention q-blocks over the "model" axis instead of
    # (unevenly) sharding GQA heads; k/v replicate across model for the
    # attention inner product (kills score-contraction all-reduces).
    # AUTO: applies only when the head counts do NOT divide tp_width —
    # archs with evenly-dividing heads (gemma: 16/16) keep head sharding,
    # which is strictly better there (hillclimb iteration 5, EXPERIMENTS.md
    # §Perf). --set attn_seq_shard=false reproduces the baseline.
    attn_seq_shard: bool = True
    tp_width: int = 0                   # set by the launcher from the mesh
    # shard_map expert-parallel MoE dispatch: local routing against
    # model-replicated activations + one psum combine, instead of GSPMD's
    # global one-hot gather/scatter (models/moe.py, EXPERIMENTS.md §Perf)
    moe_shardmap_dispatch: bool = True
    # store flash-attention probability blocks in bf16 (m/l stats stay f32)
    attn_probs_bf16: bool = True
    # Megatron-style sequence parallelism: residual stream [B, S, d] sharded
    # on S over "model" between blocks — remat-saved layer inputs, norms and
    # residual adds all shrink ×TP; the per-block all-reduce pair becomes
    # reduce-scatter + all-gather (same ring wire)
    seq_parallel_residual: bool = True

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def n_params(self) -> int:
        """Total parameter count (for 6ND model-FLOPs accounting)."""
        d, L = self.d_model, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.moe is None:
            ffn = 3 * d * self.d_ff if self.activation in ("swiglu", "geglu") \
                else 2 * d * self.d_ff
        else:
            per_expert = 3 * d * self.moe.d_ff_expert
            ffn = self.moe.n_experts * per_expert + d * self.moe.n_experts
            if self.moe.dense_residual_d_ff:
                ffn += 3 * d * self.moe.dense_residual_d_ff
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn) + embed

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = self.moe.top_k * 3 * d * self.moe.d_ff_expert \
            + d * self.moe.n_experts
        if self.moe.dense_residual_d_ff:
            ffn += 3 * d * self.moe.dense_residual_d_ff
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn) + embed


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str                         # gcn | gatedgcn | egnn | graphcast
    n_layers: int
    d_hidden: int
    aggregator: str = "sum"             # sum | mean | max | gated
    norm: str = "none"                  # sym (GCN D^-1/2 A D^-1/2) | none
    d_in: int = 128
    n_classes: int = 16
    # egnn
    equivariance: Optional[str] = None  # "E(n)"
    # graphcast
    mesh_refinement: Optional[int] = None
    n_vars: Optional[int] = None
    # B2SR integration (paper technique) for binary-adjacency aggregation
    use_b2sr: bool = False
    tile_dim: int = 32
    dtype: str = "float32"
    # --- §Perf hillclimb knobs (EXPERIMENTS.md) ---
    # shard_map receiver-partitioned aggregation: each device owns a node
    # block + the edges whose receivers land in it (data-pipeline contract:
    # edges are receiver-sorted); scatter-adds become local, cross-device
    # traffic collapses to one feature all-gather (fwd) / reduce-scatter
    # (bwd) per layer. () disables (single-device tests).
    shardmap_agg_axes: tuple = ()
    # gather/message dtype for aggregation ("bfloat16" halves gather and
    # all-gather traffic on TPU; REFUTED on the CPU dry-run lowering — float
    # normalization upcasts bf16 collectives, see EXPERIMENTS.md §Perf)
    message_dtype: str = "float32"
    # remat each GNN layer: recompute gathered features in the backward
    # instead of saving the [N, d] all-gather per layer
    remat: bool = False


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    n_items: int = 1_000_000
    n_cates: int = 10_000
    n_user_feats: int = 8               # extra categorical fields
    user_feat_vocab: int = 100_000
    dtype: str = "float32"


ArchConfig = TransformerConfig | GNNConfig | DINConfig
