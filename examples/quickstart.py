"""Quickstart: the paper's pipeline end-to-end on one small graph.

  1. build a binary adjacency matrix (road pattern),
  2. profile it with the sampling profiler (paper Algorithm 1),
  3. convert to B2SR at the recommended tile size,
  4. run BFS / PageRank / triangle counting on the bit backend,
  5. drive the unified operation API directly: typed operands + a
     Descriptor select the paper's Table II/III row (DESIGN.md §10),
  6. cross-check against the float-CSR (GraphBLAST stand-in) backend,
  7. serve a batch of BFS queries through the multi-source engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.algorithms.bfs import bfs
from repro.algorithms.pagerank import pagerank
from repro.algorithms.tc import triangle_count
from repro.core import BitVector, Descriptor, GraphMatrix
from repro.core import csr as csr_mod
from repro.core.b2sr import coo_to_b2sr, compression_ratio, csr_storage_bytes
from repro.core.sampling import sample_profile
from repro.core.semiring import ARITHMETIC
from repro.data import graphs


def main():
    # 1. a 64×64 grid "road" graph (paper Table V pattern)
    rows, cols = graphs.road_graph(64)
    n = 64 * 64
    print(f"graph: {n} nodes, {len(rows)} directed edges")

    # 2. sampling profiler (Algorithm 1)
    csr = csr_mod.from_coo(rows, cols, n, n)
    prof = sample_profile(np.asarray(csr.row_ptr), np.asarray(csr.col_idx),
                          n, n, n_samples=64)
    print("estimated compression per tile size:",
          {t: round(r, 3) for t, r in prof.est_compression.items()})
    t = prof.recommended_tile_dim or 32
    print(f"profiler recommends: B2SR-{t}")

    # 3. convert and report actual storage
    mat = coo_to_b2sr(rows, cols, n, n, t)
    print(f"CSR(fp32) {csr_storage_bytes(n, mat.nnz):,} B -> "
          f"B2SR-{t} {mat.storage_bytes():,} B "
          f"(ratio {compression_ratio(mat):.3f})")

    # 4. graph algorithms on the bit backend
    g = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=t, backend="b2sr")
    lv = bfs(g, source=0)              # direction="auto": push/pull switching
    pr = pagerank(g, max_iters=10)
    tri = triangle_count(g)
    n_pull = lv.directions.count("pull")
    print(f"BFS: {int((lv.levels >= 0).sum())} reachable, "
          f"eccentricity {int(lv.levels.max())}, "
          f"directions {len(lv.directions) - n_pull} push / {n_pull} pull "
          f"(bit-exact vs direction='push')")
    print(f"PageRank: top node {int(pr.ranks.argmax())} "
          f"(rank {float(pr.ranks.max()):.5f})")
    print(f"triangles: {tri}")

    # 5. the unified operation API: the operand type + semiring select the
    #    Table II/III row, a Descriptor carries mask/complement/transpose
    #    (DESIGN.md §10). One traversal step of BFS, written by hand:
    frontier = BitVector.pack(
        np.eye(n, 1, dtype=np.float32).reshape(-1), t, n)
    nxt = g.mxv(frontier,                      # BitVector -> bin·bin→bin
                desc=Descriptor(mask=frontier, complement=True,
                                transpose_a=True))
    counts = g.mxv(nxt, ARITHMETIC)            # same operand, count row
    print(f"unified API: {int(nxt.unpack().sum())} nodes at hop 1, "
          f"{int(counts.sum())} incident frontier edges")

    # 6. cross-check against the float-CSR baseline backend
    gc = g.with_backend("csr")
    assert np.array_equal(np.asarray(bfs(gc, 0).levels), np.asarray(lv.levels))
    assert np.allclose(np.asarray(pagerank(gc, max_iters=10).ranks),
                       np.asarray(pr.ranks), atol=1e-5)
    assert triangle_count(gc) == tri
    assert np.array_equal(np.asarray(gc.mxv(frontier).words),
                          np.asarray(g.mxv(frontier).words))
    print("backend cross-check: OK (bit path == float path)")

    # 7. batched multi-source queries: one frontier-matrix traversal for
    #    the whole batch (engine/, DESIGN.md §9)
    sources = np.array([0, 63, n // 2, n - 1])
    ms = g.msbfs(sources)
    print(f"msbfs x{len(sources)}: {ms.n_iterations} shared iterations, "
          f"reachable per source "
          f"{[int((ms.levels[:, i] >= 0).sum()) for i in range(len(sources))]}")
    assert np.array_equal(np.asarray(ms.levels[:, 0]), np.asarray(lv.levels))
    print("engine cross-check: OK (batched column == single-source BFS)")


if __name__ == "__main__":
    main()
