"""Synthetic graph generators matching the paper's pattern taxonomy (Table V).

Categories: dot (random scatter), diagonal (banded), block, stripe, road
(regular grid), hybrid. All generators return undirected simple graphs as
(rows, cols) COO with both edge directions, suitable for the binary
adjacency matrices the paper studies.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

Coo = Tuple[np.ndarray, np.ndarray]


def _dedup_sym(rows: np.ndarray, cols: np.ndarray, n: int) -> Coo:
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    key = r.astype(np.int64) * n + c
    _, idx = np.unique(key, return_index=True)
    return r[idx], c[idx]


def dot_graph(n: int, density: float = 0.01, seed: int = 0) -> Coo:
    """Random scatter ('Dot' pattern, Erdős–Rényi)."""
    rng = np.random.default_rng(seed)
    m = int(n * n * density / 2)
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    return _dedup_sym(rows, cols, n)


def diagonal_graph(n: int, bandwidth: int = 3, seed: int = 0,
                   fill: float = 0.6) -> Coo:
    """Banded matrix ('Diagonal' pattern: meshes, discretizations)."""
    rng = np.random.default_rng(seed)
    rows_list, cols_list = [], []
    for off in range(1, bandwidth + 1):
        i = np.arange(n - off)
        keep = rng.random(n - off) < fill
        rows_list.append(i[keep])
        cols_list.append(i[keep] + off)
    return _dedup_sym(np.concatenate(rows_list), np.concatenate(cols_list), n)


def block_graph(n: int, n_blocks: int = 8, intra_density: float = 0.3,
                inter_edges: int = 16, seed: int = 0) -> Coo:
    """Dense diagonal blocks + sparse inter-block edges ('Block' pattern)."""
    rng = np.random.default_rng(seed)
    bs = n // n_blocks
    rows_list, cols_list = [], []
    for b in range(n_blocks):
        lo = b * bs
        hi = min(lo + bs, n)
        m = int((hi - lo) ** 2 * intra_density / 2)
        rows_list.append(rng.integers(lo, hi, m))
        cols_list.append(rng.integers(lo, hi, m))
    rows_list.append(rng.integers(0, n, inter_edges))
    cols_list.append(rng.integers(0, n, inter_edges))
    return _dedup_sym(np.concatenate(rows_list), np.concatenate(cols_list), n)


def stripe_graph(n: int, n_stripes: int = 4, seed: int = 0) -> Coo:
    """A few off-diagonal lines ('Stripe' pattern)."""
    rng = np.random.default_rng(seed)
    offsets = rng.integers(1, max(n // 2, 2), n_stripes)
    rows_list, cols_list = [], []
    for off in offsets:
        i = np.arange(n - off)
        rows_list.append(i)
        cols_list.append(i + off)
    return _dedup_sym(np.concatenate(rows_list), np.concatenate(cols_list), n)


def road_graph(side: int) -> Coo:
    """2-D grid ('Road' pattern: regular planar distribution)."""
    n = side * side
    idx = np.arange(n).reshape(side, side)
    rows_list = [idx[:, :-1].ravel(), idx[:-1, :].ravel()]
    cols_list = [idx[:, 1:].ravel(), idx[1:, :].ravel()]
    return _dedup_sym(np.concatenate(rows_list), np.concatenate(cols_list), n)


def hybrid_graph(n: int, seed: int = 0) -> Coo:
    """Combination of ≥2 patterns ('Hybrid')."""
    r1, c1 = diagonal_graph(n, bandwidth=2, seed=seed)
    r2, c2 = dot_graph(n, density=4.0 / n, seed=seed + 1)
    return _dedup_sym(np.concatenate([r1, r2]), np.concatenate([c1, c2]), n)


def powerlaw_graph(n: int, avg_degree: int = 8, seed: int = 0) -> Coo:
    """Preferential-attachment-ish power-law graph (for sampling tests)."""
    rng = np.random.default_rng(seed)
    m = n * avg_degree // 2
    # degree-biased endpoints via zipf-like sampling
    p = 1.0 / np.arange(1, n + 1)
    p /= p.sum()
    rows = rng.choice(n, size=m, p=p)
    cols = rng.integers(0, n, m)
    return _dedup_sym(rows, cols, n)


def rmat_graph(n: int, avg_degree: int = 8, a: float = 0.57, b: float = 0.19,
               c: float = 0.19, seed: int = 0,
               symmetric: bool = True) -> Coo:
    """R-MAT / Graph500-style recursive power-law generator (Kronecker).

    Each edge picks one quadrant per bit level with probabilities
    (a, b, c, d = 1-a-b-c); the defaults are the Graph500 parameters, which
    give the 100-1000x row-degree skew the paper's load-balancing targets.
    ``symmetric=False`` keeps the raw directed edges (dedup'd, no self
    loops) so row-side skew is preserved exactly — that's the shape the
    bucketed-ELL benchmarks measure.
    """
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    m = max(n * avg_degree // (2 if symmetric else 1), 1)
    rows = np.zeros(m, np.int64)
    cols = np.zeros(m, np.int64)
    for _ in range(scale):
        r = rng.random(m)
        row_bit = (r >= a + b).astype(np.int64)            # quadrants c, d
        col_bit = (((r >= a) & (r < a + b)) | (r >= a + b + c)).astype(np.int64)
        rows = (rows << 1) | row_bit
        cols = (cols << 1) | col_bit
    # non-pow2 n: fold the 2^scale domain back instead of dropping edges,
    # or the delivered degree silently falls short of avg_degree
    rows %= n
    cols %= n
    if symmetric:
        return _dedup_sym(rows, cols, n)
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    key = rows * n + cols
    _, idx = np.unique(key, return_index=True)
    return rows[idx], cols[idx]


PATTERNS = {
    "dot": lambda n, seed=0: dot_graph(n, density=min(0.02, 200 / n ** 2 + 0.005), seed=seed),
    "diagonal": lambda n, seed=0: diagonal_graph(n, seed=seed),
    "block": lambda n, seed=0: block_graph(n, seed=seed),
    "stripe": lambda n, seed=0: stripe_graph(n, seed=seed),
    "road": lambda n, seed=0: road_graph(int(np.sqrt(n))),
    "hybrid": lambda n, seed=0: hybrid_graph(n, seed=seed),
    "rmat": lambda n, seed=0: rmat_graph(n, seed=seed),
}


def partition_edges_by_receiver_block(rows: np.ndarray, cols: np.ndarray,
                                      n_nodes: int, n_shards: int) -> Tuple[
                                          np.ndarray, np.ndarray, np.ndarray]:
    """Receiver-block edge partition (the shard_map aggregation contract).

    Groups edges by ``cols // (n_nodes/n_shards)`` and pads each group to a
    common width (padding receivers stay in-block, senders 0, mask False).
    Returns (senders, receivers, edge_mask) with len == n_shards × width —
    edge-shard i then contains exactly node-block i's incoming edges.
    """
    n_local = n_nodes // n_shards
    blk = cols // n_local
    groups = [np.flatnonzero(blk == b) for b in range(n_shards)]
    width = max((len(g) for g in groups), default=1)
    width = max(width, 1)
    pr = np.zeros((n_shards, width), np.int64)
    pc = np.zeros((n_shards, width), np.int64)
    mask = np.zeros((n_shards, width), bool)
    for b, g in enumerate(groups):
        pr[b, :len(g)] = rows[g]
        pc[b, :len(g)] = cols[g]
        pc[b, len(g):] = b * n_local
        mask[b, :len(g)] = True
    return pr.ravel(), pc.ravel(), mask.ravel()
