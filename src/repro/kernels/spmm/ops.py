"""Jitted wrapper for the Pallas SpMM kernel (pad + dispatch + unpad)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ops as core_ops
from repro.core.b2sr import B2SRBucketedEll, B2SREll, ceil_div
from repro.kernels import common
from repro.kernels.spmm import spmm as kernels


@partial(jax.jit, static_argnames=("n_rows", "block_r", "block_k", "block_d",
                                   "interpret"))
def _spmm(col, tiles, x3, n_rows, block_r, block_k, block_d, interpret):
    t = tiles.shape[-1]
    out = kernels.spmm_pallas(col, tiles, x3, t=t, block_r=block_r,
                              block_k=block_k, block_d=block_d,
                              interpret=interpret)
    return out.reshape(-1, out.shape[-1])[:n_rows]


def spmm(ell: B2SREll, x: jax.Array, block_r: int = 8, block_k: int = 4,
         block_d: int = 128, interpret: Optional[bool] = None) -> jax.Array:
    """Y = A @ X for dense X [n_cols, d]."""
    interpret = common.interpret_default() if interpret is None else interpret
    t = ell.tile_dim
    n_tc = ell.n_tile_cols
    d = x.shape[1]
    block_d = min(block_d, -(-d // 1))
    x_pad = jnp.pad(x, ((0, n_tc * t - x.shape[0]), (0, 0)))
    x3 = common.pad_to(x_pad.reshape(n_tc, t, d), 2, block_d)
    col = common.pad_to(common.pad_to(ell.tile_col_idx, 0, block_r, fill=-1),
                        1, block_k, fill=-1)
    tiles = common.pad_to(common.pad_to(ell.bit_tiles, 0, block_r), 1, block_k)
    out = _spmm(col, tiles, x3, ell.n_rows, block_r, block_k, block_d,
                interpret)
    return out[:, :d]


def spmm_bucketed(b: B2SRBucketedEll, x: jax.Array, block_r: int = 8,
                  block_k: int = 4, block_d: int = 128,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Y = A @ X with bucketed A: one pallas_call per bucket (k_b-sized
    grids), feature rows scatter-merged through the row permutation."""
    d = x.shape[1]
    out = jnp.zeros((b.n_tile_rows, b.tile_dim, d), x.dtype)
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        y = spmm(e, x, block_r, bk, block_d, interpret)     # [rows_b*t, d]
        out = out.at[rows].set(y.reshape(-1, b.tile_dim, d))
    return out.reshape(-1, d)[: b.n_rows]


# ---------------------------------------------------------------------------
# Packed-RHS path: activation matrices (bin·bin→full, BitGNN layers)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_rows", "out_dtype", "block_r",
                                   "block_k", "block_d", "interpret"))
def _spmm_bbf(col, tiles, xw, n_rows, out_dtype, block_r, block_k, block_d,
              interpret):
    t = tiles.shape[-1]
    out = kernels.spmm_bbf_pallas(col, tiles, xw, t=t, out_dtype=out_dtype,
                                  block_r=block_r, block_k=block_k,
                                  block_d=block_d, interpret=interpret)
    return out.reshape(-1, out.shape[-1])[:n_rows]


def spmm_bin_bin_full(ell: B2SREll, xw: jax.Array, out_dtype=jnp.float32,
                      block_r: int = 8, block_k: int = 4, block_d: int = 128,
                      interpret: Optional[bool] = None) -> jax.Array:
    """BitGNN aggregation: packed adjacency × BitMatrix words → dense counts.

    ``xw``: ``uint32[n_tile_cols, d]`` (one word column per feature); both
    operands stay packed end-to-end — the kernel is AND + popcount
    accumulation, never an unpack-and-matmul.
    """
    interpret = common.interpret_default() if interpret is None else interpret
    d = xw.shape[1]
    block_d = min(block_d, d)
    xw_pad = common.pad_to(xw, 1, block_d)
    col = common.pad_to(common.pad_to(ell.tile_col_idx, 0, block_r, fill=-1),
                        1, block_k, fill=-1)
    tiles = common.pad_to(common.pad_to(ell.bit_tiles, 0, block_r), 1, block_k)
    out = _spmm_bbf(col, tiles, xw_pad, ell.n_rows, jnp.dtype(out_dtype),
                    block_r, block_k, block_d, interpret)
    return out[:, :d]


def spmm_bin_bin_full_bucketed(b: B2SRBucketedEll, xw: jax.Array,
                               out_dtype=jnp.float32, block_r: int = 8,
                               block_k: int = 4, block_d: int = 128,
                               interpret: Optional[bool] = None) -> jax.Array:
    """Bucketed BitGNN aggregation: per-bucket k_b grids, scatter-merged."""
    d = xw.shape[1]
    out = jnp.zeros((b.n_tile_rows, b.tile_dim, d), out_dtype)
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        y = spmm_bin_bin_full(e, xw, out_dtype, block_r, bk, block_d,
                              interpret)
        out = out.at[rows].set(y.reshape(-1, b.tile_dim, d))
    return out.reshape(-1, d)[: b.n_rows]


# ---------------------------------------------------------------------------
# Packed-RHS path: frontier matrices (bin·bin→bin with a wide RHS, engine/)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_tile_rows", "complement", "block_r",
                                   "block_k", "interpret"))
def _spmm_bbb(col, tiles, f3, mask, n_tile_rows, complement, block_r, block_k,
              interpret):
    t = tiles.shape[-1]
    mask_pad = None if mask is None else common.pad_to(mask, 0, block_r)
    out = kernels.spmm_bbb_pallas(col, tiles, f3, mask_pad, t=t,
                                  complement=complement, block_r=block_r,
                                  block_k=block_k, interpret=interpret)
    return out[:n_tile_rows]


def spmm_bin_bin_bin(ell: B2SREll, f_packed: jax.Array,
                     mask_packed: Optional[jax.Array] = None,
                     complement: bool = True, block_r: int = 8,
                     block_k: int = 4,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Multi-frontier boolean traversal: packed frontier matrix in/out.

    ``f_packed``: ``uint32[n_tile_cols, t, W]`` (``pack_frontier_matrix``);
    returns ``uint32[n_tile_rows, t, W]``. The §V mask (per-source visited
    sets, output layout) is ANDed in-kernel at the last K step; unmasked
    calls compile the maskless kernel variant (no mask load, no AND pass).
    """
    interpret = common.interpret_default() if interpret is None else interpret
    t = ell.tile_dim
    n_tr = ceil_div(ell.n_rows, t)
    col = common.pad_to(common.pad_to(ell.tile_col_idx, 0, block_r, fill=-1),
                        1, block_k, fill=-1)
    tiles = common.pad_to(common.pad_to(ell.bit_tiles, 0, block_r), 1, block_k)
    return _spmm_bbb(col, tiles, f_packed, mask_packed, n_tr, complement,
                     block_r, block_k, interpret)


def spmm_bin_bin_bin_bucketed(b: B2SRBucketedEll, f_packed: jax.Array,
                              mask_packed: Optional[jax.Array] = None,
                              complement: bool = True, block_r: int = 8,
                              block_k: int = 4,
                              interpret: Optional[bool] = None) -> jax.Array:
    """Bucketed multi-frontier traversal: one pallas_call per bucket slab,
    scatter-merged; the mask is ANDed after the merge (still pre-store, §V)."""
    out = jnp.zeros((b.n_tile_rows, b.tile_dim, f_packed.shape[2]),
                    jnp.uint32)
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        words = spmm_bin_bin_bin(e, f_packed, None, True, block_r, bk,
                                 interpret)
        out = out.at[rows].set(words)
    if mask_packed is not None:
        out = core_ops.apply_frontier_mask(out, mask_packed, complement)
    return out


# ---------------------------------------------------------------------------
# Dispatch-registry entries: the "b2sr_pallas" wide-RHS mxm rows
# (dense feature SpMM + packed frontier matrices, DESIGN.md §10)
# ---------------------------------------------------------------------------

from repro.core.dispatch import apply_output_mask, register  # noqa: E402


@register("mxm", "dense", "full", "b2sr_pallas", bucketed=False, masked=False)
def _mxm_dense(g, x, call):
    return spmm(g.ell, x)


@register("mxm", "dense", "full", "b2sr_pallas", bucketed=False, masked=True)
def _mxm_dense_masked(g, x, call):
    y = spmm(g.ell, x)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "dense", "full", "b2sr_pallas", bucketed=True, masked=False)
def _mxm_dense_bucketed(g, x, call):
    return spmm_bucketed(g.buckets(), x)


@register("mxm", "dense", "full", "b2sr_pallas", bucketed=True, masked=True)
def _mxm_dense_bucketed_masked(g, x, call):
    y = spmm_bucketed(g.buckets(), x)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


def _bitmat_dtype(call):
    return call.out_dtype if call.out_dtype is not None else jnp.float32


@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=False,
          masked=False)
def _mxm_bitmat(g, xw, call):
    return spmm_bin_bin_full(g.ell, xw, _bitmat_dtype(call))


@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=False, masked=True)
def _mxm_bitmat_masked(g, xw, call):
    y = spmm_bin_bin_full(g.ell, xw, _bitmat_dtype(call))
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=True, masked=False)
def _mxm_bitmat_bucketed(g, xw, call):
    return spmm_bin_bin_full_bucketed(g.buckets(), xw, _bitmat_dtype(call))


@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=True, masked=True)
def _mxm_bitmat_bucketed_masked(g, xw, call):
    y = spmm_bin_bin_full_bucketed(g.buckets(), xw, _bitmat_dtype(call))
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=False)
def _mxm_frontier(g, fw, call):
    return spmm_bin_bin_bin(g.ell, fw, call.mask, call.complement)


@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=True)
def _mxm_frontier_bucketed(g, fw, call):
    return spmm_bin_bin_bin_bucketed(g.buckets(), fw, call.mask,
                                     call.complement)


# The batched pull rows reuse the masked multi-frontier kernel: a per-row
# early exit over S stacked frontiers only fires when *all* sources'
# allowed lanes are saturated (word granularity across 32 sources), which
# on mixed-depth batches is rare enough that the fused masked sweep is the
# faster schedule — the decision record is DESIGN.md §12. Parity with the
# single-source pull row is inherited from the shared block math.

@register("mxm_pull", "frontier", "bin", "b2sr_pallas", bucketed=False,
          masked=True)
def _mxm_pull(g, fw, call):
    return spmm_bin_bin_bin(g.ell, fw, call.mask, call.complement)


@register("mxm_pull", "frontier", "bin", "b2sr_pallas", bucketed=True,
          masked=True)
def _mxm_pull_bucketed(g, fw, call):
    return spmm_bin_bin_bin_bucketed(g.buckets(), fw, call.mask,
                                     call.complement)
