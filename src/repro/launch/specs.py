"""Per-(arch × shape) dry-run cells: step fn + ShapeDtypeStruct inputs +
partition specs (the assignment's ``input_specs()`` contract).

Everything here is symbolic — no array is ever allocated; ``build_cell``
returns ShapeDtypeStructs and spec trees that ``dryrun.py`` lowers and
compiles against the production mesh.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import DINConfig, GNNConfig, TransformerConfig
from repro.core.b2sr import B2SREll, ceil_div
from repro.models import transformer as T
from repro.models.gnn import graphcast as graphcast_mod
from repro.models.gnn.common import GraphBatch
from repro.models.recsys.din import DINBatch
from repro.sharding import rules
from repro.training import optimizer as opt_mod
from repro.training import train_steps

SDS = jax.ShapeDtypeStruct


def _pad512(n: int) -> int:
    """Pad counts to a 512 multiple so inputs shard evenly on every mesh
    (the data loader pads with masked entries in the real pipeline)."""
    return -(-n // 512) * 512


@dataclasses.dataclass
class Cell:
    arch: str
    shape_id: str
    kind: str                       # train | prefill | decode | serve | retrieval
    step: Callable
    args: Tuple[Any, ...]           # ShapeDtypeStruct trees
    in_specs: Tuple[Any, ...]       # PartitionSpec trees (same structure)
    out_specs: Any                  # PartitionSpec trees or None (auto)
    donate: Tuple[int, ...]
    meta: Dict[str, Any]


def _cast_tree(shape_tree, dtype):
    return jax.tree_util.tree_map(
        lambda s: SDS(s.shape, dtype)
        if jnp.issubdtype(s.dtype, jnp.floating) else s, shape_tree)


def _replicated_like(tree):
    return jax.tree_util.tree_map(lambda _: P(), tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

LM_SHAPE_TABLE = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def _lm_opt_cfg(cfg: TransformerConfig) -> opt_mod.OptimizerConfig:
    # arctic-480b: bf16 params + SGD-momentum — the only state budget that
    # fits 480B on a 256-chip pod (DESIGN.md §7); others: AdamW fp32.
    if cfg.name.startswith("arctic"):
        return opt_mod.OptimizerConfig(name="sgd", moment_dtype="bfloat16")
    return opt_mod.OptimizerConfig(name="adamw")


def _lm_param_dtype(cfg: TransformerConfig, kind: str):
    if kind != "train":
        return jnp.bfloat16
    return jnp.bfloat16 if cfg.name.startswith("arctic") else jnp.float32


def build_lm_cell(arch: str, shape_id: str, mesh: Mesh,
                  cfg: Optional[TransformerConfig] = None,
                  overrides: Optional[Dict[str, Any]] = None) -> Cell:
    cfg = cfg if cfg is not None else get_config(arch)
    overrides = overrides or {}
    info = LM_SHAPE_TABLE[shape_id]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    ba = rules.batch_axes(mesh)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 0)
    cfg = dataclasses.replace(cfg, batch_axes=tuple(ba), tp_width=tp)

    params_shape = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    params_shape = _cast_tree(params_shape, _lm_param_dtype(cfg, kind))
    p_specs = rules.lm_param_specs(cfg, params_shape)

    tokens_per_step = B * S
    meta = dict(
        n_params=cfg.n_params(), n_active=cfg.n_active_params(),
        tokens=tokens_per_step,
    )

    if kind == "train":
        opt_cfg = _lm_opt_cfg(cfg)
        opt_shape = jax.eval_shape(partial(opt_mod.init, opt_cfg),
                                   params_shape)
        o_specs = rules.opt_state_specs(p_specs, opt_shape)
        # microbatching: HBM-fit audit (EXPERIMENTS.md §Dry-run) — archs
        # whose activation working set overflows 16 GiB at global batch 256
        # train with gradient accumulation (scan over microbatches)
        # (arctic measured worse WITH accumulation — its temp is batch-
        # independent; it needs more pods / 8-bit state, see EXPERIMENTS.md)
        default_accum = {"gemma-7b": 4, "minitron-4b": 2}.get(arch, 1)
        grad_accum = int(overrides.get("grad_accum", default_accum))
        step = train_steps.lm_train_step(cfg, opt_cfg, grad_accum=grad_accum)
        meta_accum = grad_accum
        tok = SDS((B, S), jnp.int32)
        args = (params_shape, opt_shape, tok, tok)
        in_specs = (p_specs, o_specs, P(ba, None), P(ba, None))
        out_specs = (p_specs, o_specs, None)
        meta["model_flops"] = 6 * meta["n_active"] * tokens_per_step \
            + 12 * cfg.n_layers * cfg.n_heads * cfg.head_dim * B * S * S // 2
        return Cell(arch, shape_id, kind, step, args, in_specs, out_specs,
                    donate=(0, 1), meta=meta)

    if kind == "prefill":
        step = train_steps.lm_prefill_step(cfg)
        tok = SDS((B, S), jnp.int32)
        args = (params_shape, tok)
        in_specs = (p_specs, P(ba, None))
        cache_spec = rules.lm_cache_specs(mesh, cfg)
        out_specs = (None, (cache_spec, cache_spec))
        meta["model_flops"] = 2 * meta["n_active"] * tokens_per_step \
            + 4 * cfg.n_layers * cfg.n_heads * cfg.head_dim * B * S * S // 2
        return Cell(arch, shape_id, kind, step, args, in_specs, out_specs,
                    donate=(), meta=meta)

    # decode: one token against a full cache of length S
    step = train_steps.lm_decode_step(cfg)
    tok = SDS((B, 1), jnp.int32)
    cache = SDS((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim),
                jnp.bfloat16)
    cache_spec = rules.lm_cache_specs(mesh, cfg)
    args = (params_shape, tok, cache, cache, SDS((), jnp.int32))
    in_specs = (p_specs, P(ba, None), cache_spec, cache_spec, P())
    out_specs = (None, cache_spec, cache_spec)
    meta["tokens"] = B
    meta["model_flops"] = 2 * meta["n_active"] * B \
        + 4 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * B * S
    return Cell(arch, shape_id, kind, step, args, in_specs, out_specs,
                donate=(2, 3), meta=meta)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

GNN_SHAPE_TABLE = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          kind="train", b2sr_k=16),
    "minibatch_lg": dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=None, kind="train"),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                         kind="train", b2sr_k=64),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=None,
                     kind="train"),
}


def _gnn_batch_shapes(cfg: GNNConfig, shape_id: str,
                      b2sr_k: Optional[int] = None) -> GraphBatch:
    info = dict(GNN_SHAPE_TABLE[shape_id])
    if b2sr_k is not None and "b2sr_k" in info:
        info["b2sr_k"] = b2sr_k
    d_in = info["d_feat"] or cfg.d_in
    if cfg.family == "graphcast":
        d_in = cfg.d_in                       # arch-pinned (n_vars)
    needs_coords = cfg.family == "egnn"
    if shape_id == "minibatch_lg":
        from repro.data.neighbor_sampler import sampled_sizes
        N, E = sampled_sizes(info["batch_nodes"], info["fanout"])
        n_graphs = 1
    elif shape_id == "molecule":
        N = info["batch"] * info["n_nodes"]
        E = info["batch"] * info["n_edges"]
        n_graphs = info["batch"]
    else:
        N, E = info["n_nodes"], info["n_edges"]
        n_graphs = 1
    N, E = _pad512(N), _pad512(E)
    labels = (SDS((n_graphs,), jnp.int32) if n_graphs > 1
              else SDS((N,), jnp.int32))
    ell = None
    if cfg.family == "gcn" and cfg.use_b2sr and "b2sr_k" in info:
        t = cfg.tile_dim
        R = ceil_div(N, t)
        K = info["b2sr_k"]
        ell = B2SREll(
            tile_col_idx=SDS((R, K), jnp.int32),
            bit_tiles=SDS((R, K, t), jnp.uint32),
            row_n_tiles=SDS((R,), jnp.int32),
            tile_dim=t, n_rows=N, n_cols=N,
        )
    return GraphBatch(
        node_feat=SDS((N, d_in), jnp.float32),
        senders=SDS((E,), jnp.int32),
        receivers=SDS((E,), jnp.int32),
        node_mask=SDS((N,), jnp.bool_),
        edge_mask=SDS((E,), jnp.bool_),
        labels=labels,
        train_mask=SDS((N,), jnp.bool_),
        graph_ids=SDS((N,), jnp.int32),
        coords=SDS((N, 3), jnp.float32) if needs_coords else None,
        edge_feat=None,
        ell=ell,
        degrees=SDS((N,), jnp.float32) if cfg.family == "gcn" else None,
        n_graphs=n_graphs,
    )


def build_gnn_cell(arch: str, shape_id: str, mesh: Mesh,
                   cfg: Optional[GNNConfig] = None,
                   overrides: Optional[Dict[str, Any]] = None) -> Cell:
    cfg = cfg if cfg is not None else get_config(arch)
    overrides = overrides or {}
    info = GNN_SHAPE_TABLE[shape_id]
    d_in = info["d_feat"] or cfg.d_in
    opt_cfg = opt_mod.OptimizerConfig(name="adamw")

    if cfg.family == "graphcast":
        N = (info["batch"] * info["n_nodes"] if shape_id == "molecule"
             else (info["n_nodes"] if shape_id != "minibatch_lg" else 232965))
        N = _pad512(N)
        n_mesh, n_medges = graphcast_mod.mesh_sizes(cfg.mesh_refinement)
        n_medges = _pad512(n_medges)
        mesh_spec = graphcast_mod.MeshSpec(
            g2m_senders=SDS((N,), jnp.int32),
            g2m_receivers=SDS((N,), jnp.int32),
            mesh_senders=SDS((n_medges,), jnp.int32),
            mesh_receivers=SDS((n_medges,), jnp.int32),
            m2g_senders=SDS((3 * N,), jnp.int32),
            m2g_receivers=SDS((3 * N,), jnp.int32),
            n_mesh=n_mesh,
        )
        params_shape = jax.eval_shape(
            lambda: graphcast_mod.init_params(cfg, jax.random.PRNGKey(0)))
        opt_shape = jax.eval_shape(partial(opt_mod.init, opt_cfg),
                                   params_shape)
        feat = SDS((N, cfg.d_in), jnp.float32)
        target = SDS((N, cfg.n_classes), jnp.float32)

        def step(params, opt_state, feat, target, mesh_arrays):
            s = train_steps.graphcast_train_step(cfg, opt_cfg, mesh_arrays)
            return s(params, opt_state, feat, target)

        node_axes = rules.best_dim0_axes(mesh, N) or ()
        medge_axes = rules.best_dim0_axes(mesh, n_medges) or ()
        m2g_axes = rules.best_dim0_axes(mesh, 3 * N) or ()
        mesh_specs = graphcast_mod.MeshSpec(
            g2m_senders=P(node_axes), g2m_receivers=P(node_axes),
            mesh_senders=P(medge_axes), mesh_receivers=P(medge_axes),
            m2g_senders=P(m2g_axes), m2g_receivers=P(m2g_axes),
            n_mesh=n_mesh,
        )
        p_specs = _replicated_like(params_shape)
        o_specs = rules.opt_state_specs(p_specs, opt_shape)
        args = (params_shape, opt_shape, feat, target, mesh_spec)
        in_specs = (p_specs, o_specs, P(node_axes, None), P(node_axes, None),
                    mesh_specs)
        meta = dict(
            n_params=sum(int(jnp.prod(jnp.asarray(x.shape)))
                         for x in jax.tree_util.tree_leaves(params_shape)),
            tokens=N,
            model_flops=6 * (2 * N * cfg.d_in * cfg.d_hidden
                             + cfg.n_layers * n_medges * 3 * cfg.d_hidden ** 2
                             + cfg.n_layers * n_mesh * 2 * cfg.d_hidden ** 2
                             + 3 * N * 2 * cfg.d_hidden ** 2),
        )
        return Cell(arch, shape_id, "train", step, args, in_specs,
                    (p_specs, o_specs, None), donate=(0, 1), meta=meta)

    if (cfg.family == "gcn" and cfg.use_b2sr and shape_id == "ogb_products"
            and "tile_dim" not in overrides):
        # B2SR-8 profiled optimal for the ogb-scale community graph
        # (Algorithm-1 study, EXPERIMENTS.md §Perf iteration G3)
        cfg = dataclasses.replace(cfg, tile_dim=8)
    batch_shape = _gnn_batch_shapes(cfg, shape_id,
                                    b2sr_k=overrides.get("b2sr_k"))
    cfg_cell = dataclasses.replace(cfg, d_in=int(batch_shape.node_feat.shape[1]))
    if (cfg.family in ("gcn", "gatedgcn")
            and overrides.get("shardmap_agg", True)):
        # receiver-partitioned shard_map aggregation (§Perf, default ON):
        # node and edge arrays shard over the same best_dim0_axes, so the
        # contract (edge shard i targets node block i) is expressible.
        # --set shardmap_agg=false reproduces the GSPMD-gather baseline.
        ax = rules.best_dim0_axes(mesh, int(batch_shape.node_feat.shape[0]))
        cfg_cell = dataclasses.replace(cfg_cell, shardmap_agg_axes=tuple(ax or ()))
    params_shape = jax.eval_shape(
        lambda: _gnn_init(cfg_cell, jax.random.PRNGKey(0)))
    opt_shape = jax.eval_shape(partial(opt_mod.init, opt_cfg), params_shape)
    step = train_steps.gnn_train_step(cfg_cell, opt_cfg)
    b_specs = rules.gnn_batch_specs(mesh, batch_shape)
    p_specs = _replicated_like(params_shape)
    o_specs = rules.opt_state_specs(p_specs, opt_shape)
    N = batch_shape.node_feat.shape[0]
    E = batch_shape.senders.shape[0]
    d = cfg_cell.d_hidden
    flops_per_layer = 2 * E * d + 2 * N * d * d
    if cfg.family == "gatedgcn":
        flops_per_layer = 2 * E * 3 * d * d + 2 * N * 2 * d * d
    if cfg.family == "egnn":
        flops_per_layer = 2 * E * (2 * d + 1) * d * 4
    meta = dict(
        n_params=sum(int(jnp.prod(jnp.asarray(x.shape)))
                     for x in jax.tree_util.tree_leaves(params_shape)),
        tokens=N,
        model_flops=3 * cfg.n_layers * flops_per_layer,  # fwd+bwd ≈ 3× fwd
    )
    return Cell(arch, shape_id, "train", step,
                (params_shape, opt_shape, batch_shape),
                (p_specs, o_specs, b_specs),
                (p_specs, o_specs, None), donate=(0, 1), meta=meta)


def _gnn_init(cfg: GNNConfig, key):
    from repro.models.gnn import egnn, gatedgcn, gcn
    mod = {"gcn": gcn, "gatedgcn": gatedgcn, "egnn": egnn}[cfg.family]
    return mod.init_params(cfg, key)


# ---------------------------------------------------------------------------
# DIN cells
# ---------------------------------------------------------------------------

DIN_SHAPE_TABLE = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="retrieval"),
}


def _din_batch_shapes(cfg: DINConfig, batch: int) -> DINBatch:
    L = cfg.seq_len
    return DINBatch(
        hist_items=SDS((batch, L), jnp.int32),
        hist_cates=SDS((batch, L), jnp.int32),
        hist_mask=SDS((batch, L), jnp.bool_),
        target_item=SDS((batch,), jnp.int32),
        target_cate=SDS((batch,), jnp.int32),
        user_feats=SDS((batch, cfg.n_user_feats), jnp.int32),
        labels=SDS((batch,), jnp.float32),
    )


def build_din_cell(arch: str, shape_id: str, mesh: Mesh,
                   cfg: Optional[DINConfig] = None,
                   overrides: Optional[Dict[str, Any]] = None) -> Cell:
    cfg = cfg if cfg is not None else get_config(arch)
    overrides = overrides or {}
    info = DIN_SHAPE_TABLE[shape_id]
    B = info["batch"]
    kind = info["kind"]
    params_shape = jax.eval_shape(
        lambda: _din_init(cfg, jax.random.PRNGKey(0)))
    p_specs = rules.din_param_specs(cfg, params_shape)
    batch_shape = _din_batch_shapes(cfg, B)
    b_specs = (rules.din_batch_specs(mesh, batch_shape) if B > 1
               else _replicated_like(batch_shape))
    n_params = sum(int(jnp.prod(jnp.asarray(x.shape)))
                   for x in jax.tree_util.tree_leaves(params_shape))
    d = cfg.embed_dim
    attn_flops_per = 2 * cfg.seq_len * (8 * d * cfg.attn_mlp[0]
                                        + cfg.attn_mlp[0] * cfg.attn_mlp[1])
    mlp_in = cfg.n_user_feats * d + 4 * d
    mlp_flops_per = 2 * (mlp_in * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1])
    fwd = attn_flops_per + mlp_flops_per

    if kind == "train":
        opt_cfg = opt_mod.OptimizerConfig(name="adamw")
        opt_shape = jax.eval_shape(partial(opt_mod.init, opt_cfg),
                                   params_shape)
        o_specs = rules.opt_state_specs(p_specs, opt_shape)
        step = train_steps.din_train_step(cfg, opt_cfg)
        meta = dict(n_params=n_params, tokens=B, model_flops=3 * B * fwd)
        return Cell(arch, shape_id, kind, step,
                    (params_shape, opt_shape, batch_shape),
                    (p_specs, o_specs, b_specs),
                    (p_specs, o_specs, None), donate=(0, 1), meta=meta)

    if kind == "serve":
        step = train_steps.din_serve_step(cfg)
        meta = dict(n_params=n_params, tokens=B, model_flops=B * fwd)
        return Cell(arch, shape_id, kind, step, (params_shape, batch_shape),
                    (p_specs, b_specs), None, donate=(), meta=meta)

    # retrieval: 1 user × 1M candidates; candidates shard over all axes
    N = _pad512(info["n_candidates"])
    step = train_steps.din_retrieval_step(cfg)
    cands = SDS((N,), jnp.int32)
    cand_spec = P(rules.best_dim0_axes(mesh, N) or ("model",))
    meta = dict(n_params=n_params, tokens=N, model_flops=N * fwd)
    return Cell(arch, shape_id, kind, step,
                (params_shape, batch_shape, cands, cands),
                (p_specs, _replicated_like(batch_shape), cand_spec, cand_spec),
                None, donate=(), meta=meta)


def _din_init(cfg: DINConfig, key):
    from repro.models.recsys import din
    return din.init_params(cfg, key)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_id: str, mesh: Mesh,
               overrides: Optional[Dict[str, Any]] = None) -> Cell:
    cfg = get_config(arch)
    if overrides:
        cfg_over = {k: v for k, v in overrides.items()
                    if k in {f.name for f in dataclasses.fields(cfg)}}
        if cfg_over:
            cfg = dataclasses.replace(cfg, **cfg_over)
    if isinstance(cfg, TransformerConfig):
        return build_lm_cell(arch, shape_id, mesh, cfg, overrides or {})
    if isinstance(cfg, GNNConfig):
        return build_gnn_cell(arch, shape_id, mesh, cfg, overrides or {})
    return build_din_cell(arch, shape_id, mesh, cfg, overrides or {})


def input_specs(arch: str, shape_id: str, mesh: Mesh):
    """Assignment API: ShapeDtypeStruct stand-ins for every model input."""
    return build_cell(arch, shape_id, mesh).args
