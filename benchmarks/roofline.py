"""§Roofline: three-term roofline per (arch × shape) from the dry-run JSONs.

  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = wire_bytes(per-device) / (n_links × link_bw)

All terms are per-chip seconds (cost_analysis reports per-device numbers for
the SPMD module). Dominant term = bottleneck. MODEL_FLOPS/HLO_FLOPs ratios
use the 6·N·D (dense) / 6·N_active·D (MoE) convention recorded in the cell
meta at dry-run time. Outputs results/roofline.json and a markdown table.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
N_ICI_LINKS = 4  # v5e: 4 usable ICI links per chip in a 2-D torus


def analyse(rec: Dict) -> Dict:
    n_chips = rec["n_chips"]
    flops = rec["hlo_flops_per_device"]
    byts = rec["hlo_bytes_per_device"]
    wire = rec["collectives"]["per_device_wire_bytes"]
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = wire / (N_ICI_LINKS * ICI_LINK_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = rec.get("meta", {}).get("model_flops", 0)
    model_per_dev = model_flops / n_chips if n_chips else 0.0
    useful = model_per_dev / flops if flops else 0.0
    # roofline fraction: useful-compute time / achievable step time
    # (perfect overlap assumption: step time = max of the three terms)
    frac = (model_per_dev / PEAK_FLOPS_BF16) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "useful_flops_ratio": useful, "roofline_fraction": frac,
        "model_flops_per_dev": model_per_dev,
    }


def fix_hint(row: Dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_flops_ratio"] < 0.5:
            return ("compute-bound but <50% of HLO FLOPs are model FLOPs — "
                    "cut remat/recompute or fuse redundant ops")
        return "compute-bound near useful peak — increase arithmetic intensity only via algorithmic change"
    if d == "memory":
        return ("memory-bound — raise arithmetic intensity: fuse elementwise "
                "chains, bf16/fp8 activations, or larger per-chip tiles")
    return ("collective-bound — reshard to cut wire bytes (e.g. different "
            "batch/model split), overlap collectives with compute, or "
            "compress gradients")


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| dominant | useful/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                 f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
                 f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
                 f"| {r['useful_flops_ratio']:.2f} "
                 f"| {r['roofline_fraction']:.2f} |\n")
    return hdr + body


def run(mesh_filter: str = "16x16") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        row = analyse(rec)
        row["fix"] = fix_hint(row)
        rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = os.path.join(os.path.dirname(DRYRUN_DIR), "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    rows = run()
    print(markdown_table(rows))
    for r in rows:
        print(f"{r['arch']} × {r['shape']}: {r['fix']}")
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        coll = max(rows, key=lambda r: r["t_collective_s"] /
                   max(r["t_compute_s"], 1e-30))
        print(f"\nworst roofline fraction: {worst['arch']} × {worst['shape']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll['arch']} × {coll['shape']}")


if __name__ == "__main__":
    main()
