"""Partition rules: model-family-aware PartitionSpec assignment."""

from repro.sharding.rules import (  # noqa: F401
    batch_axes, lm_param_specs, gnn_batch_specs, din_param_specs,
    din_batch_specs, tree_shardings, opt_state_specs,
)
