"""Multi-device shard_map twins of the Table II/III rows (DESIGN.md §11, §16).

Every row here runs the *same per-shard math* as its single-device twin —
the jnp ``_*_block`` helpers from ``repro.core.ops`` or the real Pallas
wrappers from ``repro.kernels`` (selected by ``g.backend``), so
bit-exactness is by construction — wrapped in one ``jax.shard_map`` over
the stacked per-shard slabs of a
:class:`~repro.core.partition.PartitionedB2SR`. Two combine layouts exist,
selected at ``GraphMatrix.shard(combine=...)`` time and isolated per plan
(the mesh fingerprint in ``PlanKey`` carries the comm mode):

``combine="gather"`` (the PR 5 layout, default)
  - slab arrays shard their leading (shard) axis; the right-hand operand
    is replicated (``P()``),
  - each device computes its own contiguous row block locally,
  - one ``jax.lax.all_gather(..., tiled=True)`` concatenates the padded
    blocks on every device. Blocks are **ragged** since the nnz-balanced
    v2 partitioner, so the stacked layout is a permutation-with-holes of
    the global one; the partition's static ``gather_idx`` map undoes it
    with one local gather on the replicated result — no extra collective.

``combine="exchange"`` (communication-avoiding, DESIGN.md §16)
  - the operand arrives **device-sharded** in equal contiguous blocks of
    ``c_eq`` tile-columns — nothing is replicated, ever;
  - each device assembles only the column words its slab actually touches:
    its own block plus one statically-scheduled ``ppermute`` per nonempty
    ring offset (send/recv index sets precomputed host-side from the
    partition's column-word bitmap, padding lanes aimed at garbage slots);
  - after the local block compute, the ragged output rows are
    redistributed to their equal-block owners the same way (self-copy +
    per-offset ``ppermute``), so the op returns a **global but
    device-sharded** array in the single-device layout — iterative
    algorithms feed it straight back in with zero per-iteration
    replication. All P-1 hops of a phase are issued before any consumer,
    so XLA's latency-hiding scheduler runs the ring transfers
    concurrently with the scatter/compute between them.
  Exchange is bit-exact against gather by construction: both run the same
  block math over the same slab; only who holds which words differs.

Masks are applied *after* the combine through the same shared §V helpers
(``apply_frontier_mask`` / ``apply_grid_mask`` / ``apply_output_mask``) the
non-fused single-device paths use: mask-at-store semantics, one code path.

The rows register for both b2sr backends; since v2 the ``b2sr_pallas``
rows dispatch the real ``kernels/`` entry points *inside* the shard_map
body (interpret mode on CPU), building per-shard ELL views from the raw
slab arrays — the jnp word schemes remain the ``b2sr`` bodies. The graph
SpGEMM rows (B replicated, streamed tile-row-wise) and the fused
``mxm_sum`` reduction stay on the jnp blocks and the gather/psum combine:
their B-side slabs are three ragged arrays with no column-word layout to
exchange (decision record in DESIGN.md §16). The CSR baseline registers no
sharded rows — ``GraphMatrix.shard`` rejects it up front.

``row_chunk`` is rejected on every sharded row: the shards themselves are
the memory bound. The generic layer raises before any operand staging
(``dispatch.reject_sharded_row_chunk``); the checks here are backstops.

Comm accounting: every sharded call increments
``gather_words_total`` / ``exchange_words_total{op,backend,shards}`` with
the statically-known element counts its collectives move, and annotates
the ambient launch trace span. The increments run at trace time — once
per compiled plan, per call in eager execution — so eager benchmark
sweeps read exact per-call volumes while jitted serving loops see one
increment per (re)trace.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

import functools
import inspect

from repro.core import ops as core_ops
from repro.core.b2sr import (B2SREll, ceil_div, ell_to_packed_grid,
                             unpack_tiles)
from repro.core.dispatch import BOTH, apply_output_mask, register
from repro.core.operands import pad_leading
from repro.core.ops import (_bff_setup, _bmv_bbb_block, _bmv_bbf_block,
                            _bmv_bff_block, _mxm_bbb_block, _mxm_bbf_block,
                            _spmm_bbb_block, _spmm_bbf_block, _spmm_block,
                            apply_frontier_mask, apply_grid_mask,
                            shard_map_compat)
from repro.core.partition import (ExchangePlan, PartitionedB2SR, shard_count)


@functools.lru_cache(maxsize=1)
def _shard_map_kwargs() -> dict:
    """Disable the replication/varying check where the kwarg exists.

    The bodies here are collective-closed (gather/psum/exchange before
    return), but the older checker rejects scan carries inside them; probe
    the actual shard_map signature once instead of try/except-ing every
    call (which would re-trace the body and misattribute unrelated
    TypeErrors).
    """
    fn = jax.shard_map if hasattr(jax, "shard_map") else None
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    for kw in ("check_rep", "check_vma"):
        if kw in params:
            return {kw: False}
    return {}


class _LocalShard:
    """One device's view of the partition inside a shard_map body."""

    __slots__ = ("col", "tiles", "cnt", "bcol", "btiles", "brows", "part")

    def __init__(self, col, tiles, cnt, bcol, btiles, brows,
                 part: PartitionedB2SR):
        self.col = col          # int32[R, K]
        self.tiles = tiles      # uint32[R, K, t]
        self.cnt = cnt          # int32[R]
        self.bcol = bcol        # tuple of int32[rb, kb]
        self.btiles = btiles    # tuple of uint32[rb, kb, t]
        self.brows = brows      # tuple of int32[rb]; pad rows -> R (garbage)
        self.part = part

    @property
    def rows(self) -> int:
        return self.part.rows_per_shard

    def ell(self, n_cols: int) -> B2SREll:
        """This shard's slab as a B2SREll — the Pallas wrappers' operand."""
        return B2SREll(tile_col_idx=self.col, bit_tiles=self.tiles,
                       row_n_tiles=self.cnt, tile_dim=self.part.tile_dim,
                       n_rows=self.rows * self.part.tile_dim, n_cols=n_cols)

    def scatter_buckets(self, out, block_fn):
        """Per-bucket compute + scatter through the local row permutation.

        ``out`` must have ``rows_per_shard + 1`` leading rows — padding
        slab rows target the final garbage row, which is dropped here.
        """
        for cb, tb, rb in zip(self.bcol, self.btiles, self.brows):
            out = out.at[rb].set(block_fn(cb, tb))
        return out[: self.rows]


def _bucket_ell(cb, tb, tile_dim: int, n_cols: int) -> B2SREll:
    """One bucket slab as a B2SREll (per-bucket Pallas operand)."""
    return B2SREll(tile_col_idx=cb, bit_tiles=tb,
                   row_n_tiles=jnp.sum(cb >= 0, axis=1).astype(jnp.int32),
                   tile_dim=tile_dim, n_rows=cb.shape[0] * tile_dim,
                   n_cols=n_cols)


def _pallas(g) -> bool:
    return g.backend == "b2sr_pallas"


def _no_row_chunk(call):
    if call.row_chunk is not None:
        raise ValueError(
            "row_chunk is not supported on the sharded path — the row "
            "partition already bounds per-device memory (unshard() first "
            "if chunked evaluation is required)")


def _combine_for(g, part: Optional[PartitionedB2SR] = None) -> str:
    """Per-call combine mode: exchange only on the graph's own forward
    partition (the transposed view carries its own plan), gather for any
    side partition (tri_count's L) and for single-shard meshes, where
    gather is already collective-free."""
    if (getattr(g, "comm", "gather") == "exchange"
            and (part is None or part is g.partitioned)
            and getattr(g, "xplan", None) is not None):
        return "exchange"
    return "gather"


_COMM_LABELS = ("op", "backend", "shards")


def _record_comm(g, part: PartitionedB2SR, combine: str, op: str,
                 n: int) -> None:
    """Static comm-volume accounting for one sharded call (see module doc).

    ``n`` counts *elements* moved by the call's collectives — literal
    uint32 words on the packed rows, values on the dense ones. Gather
    charges the operand replication plus the ring all-gather of the
    padded blocks; exchange charges exactly its scheduled lanes.
    """
    if part.n_shards <= 1:
        return
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    if not obs_metrics.enabled():
        return
    reg = obs_metrics.get_registry()
    labels = {"op": op, "backend": g.backend, "shards": part.n_shards}
    if combine == "exchange":
        reg.counter("exchange_words_total",
                    "elements moved by exchange-mode collectives",
                    _COMM_LABELS).inc(n, **labels)
        obs_trace.annotate(comm="exchange", exchanged_words=n)
    else:
        reg.counter("gather_words_total",
                    "elements moved by gather/psum-mode collectives",
                    _COMM_LABELS).inc(n, **labels)
        obs_trace.annotate(comm=combine, gathered_words=n)


def _sharded_call(g, local_fn, rhs_arrays: Tuple, combine: str = "gather",
                  part: PartitionedB2SR = None, op: str = "mxv",
                  out_ndim: int = 1):
    """Run ``local_fn(view, *rhs)`` under shard_map over ``g``'s mesh.

    ``local_fn`` returns this device's output block (leading axis = the
    partition's padded local rows).

    ``combine="gather"``: rhs replicated, padded blocks all-gathered, the
    static ``gather_idx`` permutation restores the global row order; the
    result is replicated — drop-in for every caller. ``combine="psum"``
    sum-reduces scalars/partials. ``combine="exchange"`` takes exactly one
    rhs array whose leading axis is the tile-column/word axis, runs the
    statically-scheduled ppermute exchange from ``g.xplan``, and returns
    the global result **device-sharded** in equal row blocks (still the
    single-device layout — callers slice and mask it unchanged).
    ``out_ndim`` is the rank of ``local_fn``'s output (exchange needs it
    for the output partition spec).
    """
    from jax.sharding import PartitionSpec as P

    part = g.partitioned if part is None else part
    mesh, axes = g.mesh, g.shard_axes
    nb = part.n_buckets
    slabs = (part.tile_col_idx, part.bit_tiles, part.row_n_tiles,
             *part.bucket_col_idx, *part.bucket_bit_tiles,
             *part.bucket_rows)
    slab_specs = tuple(P(axes, *([None] * (a.ndim - 1))) for a in slabs)
    n_slab = len(slabs)

    def view_of(s):
        return _LocalShard(
            s[0][0], s[1][0], s[2][0],
            tuple(x[0] for x in s[3: 3 + nb]),
            tuple(x[0] for x in s[3 + nb: 3 + 2 * nb]),
            tuple(x[0] for x in s[3 + 2 * nb: 3 + 3 * nb]),
            part)

    if combine in ("gather", "psum"):
        in_specs = slab_specs + tuple(P() for _ in rhs_arrays)

        def body(*args):
            view = view_of(args[:n_slab])
            y = local_fn(view, *args[n_slab:])
            if combine == "psum":
                return jax.lax.psum(y, axes)
            return jax.lax.all_gather(y, axes, axis=0, tiled=True)

        y = shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                             out_specs=P(),
                             **_shard_map_kwargs())(*slabs, *rhs_arrays)
        rhs_words = sum(int(jnp.size(a)) for a in rhs_arrays)
        P_n = part.n_shards
        if combine == "gather":
            # ragged blocks: the stacked concatenation is a permutation
            # (with padding holes) of the global layout — one local gather
            # on the replicated result restores it
            lane = 1
            for s in y.shape[1:]:
                lane *= int(s)
            moved = (P_n - 1) * (rhs_words
                                 + P_n * part.rows_per_shard * lane)
            y = jnp.take(y, part.gather_idx, axis=0)
        else:
            moved = (P_n - 1) * (rhs_words + int(jnp.size(y)))
        _record_comm(g, part, combine, op, moved)
        return y

    if combine != "exchange":
        raise ValueError(f"unknown combine mode {combine!r}")
    xp: ExchangePlan = g.xplan
    if xp is None or len(rhs_arrays) != 1:
        raise ValueError("combine='exchange' needs a built ExchangePlan "
                         "and exactly one column-word operand")
    if len(axes) != 1:
        raise ValueError("combine='exchange' runs a single-axis ppermute "
                         "ring; shard over one mesh axis (got "
                         f"{axes})")
    axis = axes[0]
    Pn = xp.n_shards
    nr, no = len(xp.rhs_offsets), len(xp.out_offsets)
    idx = (*xp.rhs_send_idx, *xp.rhs_recv_pos, *xp.out_send_idx,
           *xp.out_recv_pos, xp.self_src, xp.self_dst)
    rhs = pad_leading(rhs_arrays[0], xp.n_tc_pad)
    in_specs = slab_specs
    in_specs += tuple(P(axes, None) for _ in idx)
    in_specs += (P(axes, *([None] * (rhs.ndim - 1))),)

    def ring(payload, offset):
        return jax.lax.ppermute(
            payload, axis, perm=[(i, (i + offset) % Pn) for i in range(Pn)])

    def body(*args):
        view = view_of(args[:n_slab])
        ix = [a[0] for a in args[n_slab: n_slab + len(idx)]]
        x_blk = args[n_slab + len(idx)]
        r_send, r_recv = ix[:nr], ix[nr: 2 * nr]
        o_send, o_recv = ix[2 * nr: 2 * nr + no], ix[2 * nr + no:
                                                     2 * nr + 2 * no]
        self_src, self_dst = ix[-2], ix[-1]

        # --- inbound word exchange: all P-1 ring hops issued up front, so
        # the transfers overlap each other and the own-block scatter
        tail = jnp.zeros((1,) + x_blk.shape[1:], x_blk.dtype)
        x_g = jnp.concatenate([x_blk, tail], axis=0)   # garbage src @ c_eq
        recvs = [ring(x_g[si], o)
                 for o, si in zip(xp.rhs_offsets, r_send)]
        buf = jnp.zeros((xp.n_tc_pad + 1,) + x_blk.shape[1:], x_blk.dtype)
        q = jax.lax.axis_index(axis)
        buf = jax.lax.dynamic_update_slice(
            buf, x_blk, (q * xp.c_eq,) + (0,) * (x_blk.ndim - 1))
        for rp, rv in zip(r_recv, recvs):
            buf = buf.at[rp].set(rv)   # pad lanes land on the drop row

        y = local_fn(view, buf[:-1])

        # --- outbound redistribution: ragged compute blocks -> the equal
        # owner blocks (self-copy + one ppermute per nonempty offset)
        y_g = jnp.concatenate(
            [y, jnp.zeros((1,) + y.shape[1:], y.dtype)], axis=0)
        o_recvs = [ring(y_g[si], o)
                   for o, si in zip(xp.out_offsets, o_send)]
        out = jnp.zeros((xp.r_eq + 1,) + y.shape[1:], y.dtype)
        out = out.at[self_dst].set(y_g[self_src])
        for rp, rv in zip(o_recv, o_recvs):
            out = out.at[rp].set(rv)
        return out[:-1]

    out_specs = P(axes, *([None] * (out_ndim - 1)))
    y = shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs,
                         **_shard_map_kwargs())(*slabs, *idx, rhs)
    rhs_lane = 1
    for s in rhs.shape[1:]:
        rhs_lane *= int(s)
    out_lane = 1
    for s in y.shape[1:]:
        out_lane *= int(s)
    _record_comm(g, part, "exchange", op,
                 xp.rhs_lanes * rhs_lane + xp.out_lanes * out_lane)
    return y


def _b2sr_ell(col, tiles, cnt, tile_dim: int, n_rows: int,
              n_cols: int) -> B2SREll:
    """Wrap raw replicated ELL arrays back into the view the blocks take."""
    return B2SREll(tile_col_idx=col, bit_tiles=tiles, row_n_tiles=cnt,
                   tile_dim=tile_dim, n_rows=n_rows, n_cols=n_cols)


# ---------------------------------------------------------------------------
# mxv rows (Table II)
# ---------------------------------------------------------------------------

def _mxv_bin_words(g, xw, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim

    # a partition without bucket slabs (built while use_buckets was off, or
    # an empty graph) runs the ELL slab — identical results, no SELL split
    bucketed = bucketed and part.n_buckets
    if _pallas(g):
        from repro.kernels import common as kcommon
        from repro.kernels.bmv import ops as bmv_ops
        if bucketed:
            def local(view, x):
                out = jnp.zeros((view.rows + 1,), jnp.uint32)
                return view.scatter_buckets(
                    out, lambda cb, tb: bmv_ops.bmv_bin_bin_bin(
                        _bucket_ell(cb, tb, t, part.n_cols), x,
                        block_k=kcommon.bucket_block_k(cb.shape[1], 8)))
        else:
            def local(view, x):
                return bmv_ops.bmv_bin_bin_bin(view.ell(part.n_cols), x)
    elif bucketed:
        def local(view, x):
            out = jnp.zeros((view.rows + 1,), jnp.uint32)
            return view.scatter_buckets(
                out, lambda cb, tb: _bmv_bbb_block(cb, tb, x, t))
    else:
        def local(view, x):
            return _bmv_bbb_block(view.col, view.tiles, x, t)

    y = _sharded_call(g, local, (xw,), combine=_combine_for(g), op="mxv",
                      out_ndim=1)
    return y[: ceil_div(part.n_rows, t)]


@register("mxv", "bitvec", "bin", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxv_bitvec_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_bin_words(g, xw, bucketed=False)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxv_bitvec_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_bin_words(g, xw, bucketed=True)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_bitvec_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=False)
    return y & (~call.mask if call.complement else call.mask)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_bitvec_bucketed_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=True)
    return y & (~call.mask if call.complement else call.mask)


# Sharded pull rows (DESIGN.md §12): the pull *schedule* is a per-shard
# kernel concern, but under shard_map every shard runs the same block math
# over its row slab, so the sharded pull twin is the masked sharded sweep.
# What direction-optimization changes on a mesh is the *decision*: the
# traversal loops popcount the frontier/visited words, so every shard
# derives the same global density and switches in lockstep — no collective
# needed for the heuristic itself.

@register("mxv_pull", "bitvec", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv_pull", "bitvec", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_pull_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=False)
    return y & (~call.mask if call.complement else call.mask)


@register("mxv_pull", "bitvec", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv_pull", "bitvec", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_pull_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=True)
    return y & (~call.mask if call.complement else call.mask)


def _mxv_count_vals(g, xw, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    dt = call.out_dtype

    bucketed = bucketed and part.n_buckets
    if _pallas(g):
        from repro.kernels import common as kcommon
        from repro.kernels.bmv import ops as bmv_ops
        if bucketed:
            def local(view, x):
                out = jnp.zeros((view.rows + 1, t), dt)
                return view.scatter_buckets(
                    out, lambda cb, tb: bmv_ops.bmv_bin_bin_full(
                        _bucket_ell(cb, tb, t, part.n_cols), x, dt,
                        block_k=kcommon.bucket_block_k(cb.shape[1], 8)
                    ).reshape(-1, t))
        else:
            def local(view, x):
                return bmv_ops.bmv_bin_bin_full(
                    view.ell(part.n_cols), x, dt).reshape(-1, t)
    elif bucketed:
        def local(view, x):
            out = jnp.zeros((view.rows + 1, t), dt)
            return view.scatter_buckets(
                out, lambda cb, tb: _bmv_bbf_block(cb, tb, x, dt))
    else:
        def local(view, x):
            return _bmv_bbf_block(view.col, view.tiles, x, dt)

    y = _sharded_call(g, local, (xw,), combine=_combine_for(g), op="mxv",
                      out_ndim=2)
    return y.reshape(-1)[: part.n_rows]


@register("mxv", "bitvec", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxv_count_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_count_vals(g, xw, call, bucketed=False)


@register("mxv", "bitvec", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxv_count_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_count_vals(g, xw, call, bucketed=True)


@register("mxv", "bitvec", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_count_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_count_vals(g, xw, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))


@register("mxv", "bitvec", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_count_bucketed_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_count_vals(g, xw, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))


def _mxv_dense_vals(g, x, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    sr = call.semiring
    av = call.a_value
    x3, ident, _ = _bff_setup(part.n_tile_cols, t, x, sr, call.a_value)

    bucketed = bucketed and part.n_buckets
    if _pallas(g):
        from repro.kernels import common as kcommon
        from repro.kernels.bmv import ops as bmv_ops
        # the wrapper pads/stages the flat vector itself, so the local body
        # recovers it from the (possibly exchange-widened) tile-word layout
        if bucketed:
            def local(view, xr):
                xf = xr.reshape(-1)[: part.n_cols]
                out = jnp.full((view.rows + 1, t), ident, dtype=xr.dtype)
                return view.scatter_buckets(
                    out, lambda cb, tb: bmv_ops.bmv_bin_full_full(
                        _bucket_ell(cb, tb, t, part.n_cols), xf, sr, av,
                        block_k=kcommon.bucket_block_k(cb.shape[1], 8)
                    ).reshape(-1, t))
        else:
            def local(view, xr):
                xf = xr.reshape(-1)[: part.n_cols]
                return bmv_ops.bmv_bin_full_full(
                    view.ell(part.n_cols), xf, sr, av).reshape(-1, t)
    elif bucketed:
        def local(view, xr):
            out = jnp.full((view.rows + 1, t), ident, dtype=xr.dtype)
            return view.scatter_buckets(
                out,
                lambda cb, tb: _bmv_bff_block(cb, tb, xr, sr, av, ident, t))
    else:
        def local(view, xr):
            return _bmv_bff_block(view.col, view.tiles, xr, sr, av, ident, t)

    y = _sharded_call(g, local, (x3,), combine=_combine_for(g), op="mxv",
                      out_ndim=2)
    return y.reshape(-1)[: part.n_rows]


@register("mxv", "dense", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxv_dense_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxv_dense_vals(g, x, call, bucketed=False)


@register("mxv", "dense", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxv_dense_bucketed_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxv_dense_vals(g, x, call, bucketed=True)


@register("mxv", "dense", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_dense_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxv_dense_vals(g, x, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxv", "dense", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_dense_bucketed_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxv_dense_vals(g, x, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


# ---------------------------------------------------------------------------
# mxm rows: dense features (SpMM) / frontier batches / graph SpGEMM
# ---------------------------------------------------------------------------

def _mxm_dense_vals(g, x, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    n_tc = part.n_tile_cols
    d = x.shape[1]
    dt = call.out_dtype or x.dtype
    x_pad = jnp.pad(x, ((0, n_tc * t - x.shape[0]), (0, 0)))
    x3 = x_pad.reshape(n_tc, t, d)

    bucketed = bucketed and part.n_buckets
    if _pallas(g):
        from repro.kernels import common as kcommon
        from repro.kernels.spmm import ops as spmm_ops
        if bucketed:
            def local(view, xr):
                x2 = xr.reshape(-1, d)[: part.n_cols]
                out = jnp.zeros((view.rows + 1, t, d), dtype=x.dtype)
                return view.scatter_buckets(
                    out, lambda cb, tb: spmm_ops.spmm(
                        _bucket_ell(cb, tb, t, part.n_cols), x2,
                        block_k=kcommon.bucket_block_k(cb.shape[1], 4)
                    ).reshape(-1, t, d))
        else:
            def local(view, xr):
                x2 = xr.reshape(-1, d)[: part.n_cols]
                return spmm_ops.spmm(view.ell(part.n_cols),
                                     x2).reshape(-1, t, d)
    elif bucketed:
        def local(view, xr):
            out = jnp.zeros((view.rows + 1, t, d), dtype=dt)
            return view.scatter_buckets(
                out, lambda cb, tb: _spmm_block(cb, tb, xr, t, dt))
    else:
        def local(view, xr):
            return _spmm_block(view.col, view.tiles, xr, t, dt)

    y = _sharded_call(g, local, (x3,), combine=_combine_for(g), op="mxm",
                      out_ndim=3)
    return y.reshape(-1, d)[: part.n_rows]


@register("mxm", "dense", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxm_dense_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxm_dense_vals(g, x, call, bucketed=False)


@register("mxm", "dense", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxm_dense_bucketed_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxm_dense_vals(g, x, call, bucketed=True)


@register("mxm", "dense", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_dense_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxm_dense_vals(g, x, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "dense", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_dense_bucketed_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxm_dense_vals(g, x, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


def _mxm_bitmat_vals(g, xw, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    d = xw.shape[1]
    dt = call.out_dtype if call.out_dtype is not None else jnp.float32

    bucketed = bucketed and part.n_buckets
    if _pallas(g):
        from repro.kernels import common as kcommon
        from repro.kernels.spmm import ops as spmm_ops
        if bucketed:
            def local(view, xr):
                out = jnp.zeros((view.rows + 1, t, d), dtype=dt)
                return view.scatter_buckets(
                    out, lambda cb, tb: spmm_ops.spmm_bin_bin_full(
                        _bucket_ell(cb, tb, t, part.n_cols), xr, dt,
                        block_k=kcommon.bucket_block_k(cb.shape[1], 4)
                    ).reshape(-1, t, d))
        else:
            def local(view, xr):
                return spmm_ops.spmm_bin_bin_full(
                    view.ell(part.n_cols), xr, dt).reshape(-1, t, d)
    elif bucketed:
        def local(view, xr):
            out = jnp.zeros((view.rows + 1, t, d), dtype=dt)
            return view.scatter_buckets(
                out, lambda cb, tb: _spmm_bbf_block(cb, tb, xr, dt))
    else:
        def local(view, xr):
            return _spmm_bbf_block(view.col, view.tiles, xr, dt)

    y = _sharded_call(g, local, (xw,), combine=_combine_for(g), op="mxm",
                      out_ndim=3)
    return y.reshape(-1, d)[: part.n_rows]


@register("mxm", "bitmat", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxm_bitmat_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxm_bitmat_vals(g, xw, call, bucketed=False)


@register("mxm", "bitmat", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxm_bitmat_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxm_bitmat_vals(g, xw, call, bucketed=True)


@register("mxm", "bitmat", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_bitmat_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxm_bitmat_vals(g, xw, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "bitmat", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_bitmat_bucketed_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxm_bitmat_vals(g, xw, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


def _mxm_frontier_words(g, fw, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    W = fw.shape[2]

    bucketed = bucketed and part.n_buckets
    if _pallas(g):
        from repro.kernels import common as kcommon
        from repro.kernels.spmm import ops as spmm_ops
        if bucketed:
            def local(view, f3):
                out = jnp.zeros((view.rows + 1, t, W), jnp.uint32)
                return view.scatter_buckets(
                    out, lambda cb, tb: spmm_ops.spmm_bin_bin_bin(
                        _bucket_ell(cb, tb, t, part.n_cols), f3,
                        block_k=kcommon.bucket_block_k(cb.shape[1], 4)))
        else:
            def local(view, f3):
                return spmm_ops.spmm_bin_bin_bin(view.ell(part.n_cols), f3)
    elif bucketed:
        def local(view, f3):
            out = jnp.zeros((view.rows + 1, t, W), jnp.uint32)
            return view.scatter_buckets(
                out, lambda cb, tb: _spmm_bbb_block(cb, tb, f3, t))
    else:
        def local(view, f3):
            return _spmm_bbb_block(view.col, view.tiles, f3, t)

    y = _sharded_call(g, local, (fw,), combine=_combine_for(g), op="mxm",
                      out_ndim=3)
    return y[: ceil_div(part.n_rows, t)]


@register("mxm", "frontier", "bin", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxm_frontier_sharded(g, fw, call):
    _no_row_chunk(call)
    return _mxm_frontier_words(g, fw, bucketed=False)


@register("mxm", "frontier", "bin", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxm_frontier_bucketed_sharded(g, fw, call):
    _no_row_chunk(call)
    return _mxm_frontier_words(g, fw, bucketed=True)


@register("mxm", "frontier", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_frontier_masked_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=False)
    return apply_frontier_mask(y, call.mask, call.complement)


@register("mxm", "frontier", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_frontier_bucketed_masked_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=True)
    return apply_frontier_mask(y, call.mask, call.complement)


@register("mxm_pull", "frontier", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm_pull", "frontier", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_pull_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=False)
    return apply_frontier_mask(y, call.mask, call.complement)


@register("mxm_pull", "frontier", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm_pull", "frontier", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_pull_bucketed_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=True)
    return apply_frontier_mask(y, call.mask, call.complement)


def _mxm_graph_grid(g, other_ell: B2SREll) -> jax.Array:
    """A (sharded) ∨.∧ B (replicated): per-shard SpGEMM row blocks.

    B streams tile-row-wise against every shard's A tiles — one pass of
    B's slabs per iteration for the whole mesh; the output grid blocks
    reassemble into the single-device ``mxm_bin_bin_bin`` grid through the
    gather_idx permutation. The slab (not the SELL buckets) carries A
    here, matching the single-device SpGEMM whose B side is always one
    ELL; B's three ragged slab arrays have no column-word layout, so the
    graph rows stay on the gather combine (DESIGN.md §16).
    """
    part = g.partitioned
    t = part.tile_dim
    if t != other_ell.tile_dim:
        raise ValueError(f"tile_dim mismatch: {t} vs {other_ell.tile_dim}")
    if part.n_cols != other_ell.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {part.n_rows}x"
                         f"{part.n_cols}, B is {other_ell.n_rows}x"
                         f"{other_ell.n_cols}")

    def local(view, b_col, b_tiles, b_cnt):
        b = _b2sr_ell(b_col, b_tiles, b_cnt, t, other_ell.n_rows,
                      other_ell.n_cols)
        return _mxm_bbb_block(view.col, view.tiles, b, t)

    grid = _sharded_call(g, local, (other_ell.tile_col_idx,
                                    other_ell.bit_tiles,
                                    other_ell.row_n_tiles), op="mxm")
    return grid[: part.n_tile_rows]


@register("mxm", "graph", "bin", "b2sr", bucketed=BOTH, sharded=True)
@register("mxm", "graph", "bin", "b2sr_pallas", bucketed=BOTH, sharded=True)
def _mxm_graph_sharded(g, other, call):
    _no_row_chunk(call)
    grid = _mxm_graph_grid(g, other.ell)
    m_ell = call.mask.ell if call.mask is not None else None
    return apply_grid_mask(grid, m_ell, call.complement)


def _mxm_graph_counts(g, other_ell: B2SREll, out_dtype) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    if t != other_ell.tile_dim:
        raise ValueError(f"tile_dim mismatch: {t} vs {other_ell.tile_dim}")
    if part.n_cols != other_ell.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {part.n_rows}x"
                         f"{part.n_cols}, B is {other_ell.n_rows}x"
                         f"{other_ell.n_cols}")

    def local(view, b_col, b_tiles, b_cnt):
        b = _b2sr_ell(b_col, b_tiles, b_cnt, t, other_ell.n_rows,
                      other_ell.n_cols)
        return _mxm_bbf_block(view.col, view.tiles, b, t)

    grid = _sharded_call(g, local, (other_ell.tile_col_idx,
                                    other_ell.bit_tiles,
                                    other_ell.row_n_tiles), op="mxm")
    grid = grid[: part.n_tile_rows]
    dense = grid.transpose(0, 2, 1, 3).reshape(
        part.n_tile_rows * t, other_ell.n_tile_cols * t)
    return dense[: part.n_rows, : other_ell.n_cols].astype(out_dtype)


@register("mxm", "graph", "full", "b2sr", bucketed=BOTH, masked=False,
          sharded=True)
@register("mxm", "graph", "full", "b2sr_pallas", bucketed=BOTH,
          masked=False, sharded=True)
def _mxm_graph_count_sharded(g, other, call):
    _no_row_chunk(call)
    return _mxm_graph_counts(g, other.ell, jnp.int32)


@register("mxm", "graph", "full", "b2sr", bucketed=BOTH, masked=True,
          sharded=True)
@register("mxm", "graph", "full", "b2sr_pallas", bucketed=BOTH,
          masked=True, sharded=True)
def _mxm_graph_count_masked_sharded(g, other, call):
    _no_row_chunk(call)
    counts = _mxm_graph_counts(g, other.ell, jnp.int32)
    return core_ops._apply_dense_mask(counts, call.mask.ell,
                                      call.complement, jnp.int32)


# ---------------------------------------------------------------------------
# mxm_sum: the fused Σ L ⊙ (L·Lᵀ) reduction (tri_count)
# ---------------------------------------------------------------------------

@register("mxm_sum", "tri", "full", "b2sr", bucketed=BOTH, masked=True,
          sharded=True)
@register("mxm_sum", "tri", "full", "b2sr_pallas", bucketed=BOTH,
          masked=True, sharded=True)
def _tri_sum_sharded(g, tri, call):
    """Per-shard masked count SpGEMM partials + one psum.

    L is row-partitioned with the graph's shard count (memoized on the
    :class:`LowerTriangle` operand); Lᵀ is replicated; the mask tile for an
    output block is the shard's own L slab, so each device's partial is
    Σ over its row block and the psum is exact (integer adds).
    """
    _no_row_chunk(call)
    part = tri.partitioned(shard_count(g.mesh, g.shard_axes))
    ell_t = tri.ell_t
    t = part.tile_dim

    def local(view, b_col, b_tiles, b_cnt):
        b = _b2sr_ell(b_col, b_tiles, b_cnt, t, ell_t.n_rows, ell_t.n_cols)
        counts = _mxm_bbf_block(view.col, view.tiles, b, t)  # [R, C, t, t]
        # the mask tiles for this output block are the shard's own L slab
        mg = ell_to_packed_grid(
            _b2sr_ell(view.col, view.tiles, view.cnt, t,
                      view.rows * t, part.n_cols))           # [R, C, t]
        m_bits = unpack_tiles(mg, t, jnp.int32)              # [R, C, t, t]
        return jnp.sum(counts * m_bits)

    total = _sharded_call(g, local, (ell_t.tile_col_idx, ell_t.bit_tiles,
                                     ell_t.row_n_tiles),
                          combine="psum", part=part, op="mxm_sum")
    return total.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mesh-context shardmap SpMM (the pre-registry scale-out entry point)
# ---------------------------------------------------------------------------

def spmm_b2sr_shardmap(ell: B2SREll, x, axes, row_chunk=None):
    """Tile-row-partitioned B2SR SpMM (§Perf, EXPERIMENTS.md).

    The ambient-mesh twin of the registered sharded rows above: instead of
    a pre-partitioned graph it shards a single ELL view over the *current*
    mesh context at call time (each device owns a block of tile-rows, the
    feature matrix is all-gathered once — reduce-scatter in the backward).
    Kept for callers that manage their own mesh scope
    (``tests/test_shardmap_agg.py`` pins it); model code routes through
    ``repro.gnn_bit.layers.aggregate`` and the registry instead.
    Requires ell.n_rows == n_tile_rows × tile_dim (padded) and both the
    tile-row dim and x's node dim to shard evenly over ``axes``.
    """
    from jax._src.mesh import thread_resources
    from jax.sharding import PartitionSpec as P

    mesh = thread_resources.env.physical_mesh
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes or mesh.empty:
        return core_ops.spmm_b2sr(ell, x, row_chunk=row_chunk)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    p_total = 1
    for a in axes:
        p_total *= sizes[a]
    R = int(ell.tile_col_idx.shape[0])
    if (R % p_total != 0 or x.shape[0] % p_total != 0
            or ell.n_rows != R * ell.tile_dim):
        # small graphs (fewer tile-rows than shards) fall back to the
        # GSPMD path — the shard_map contract needs even blocks
        return core_ops.spmm_b2sr(ell, x, row_chunk=row_chunk)
    t = ell.tile_dim

    def block(col_blk, tiles_blk, cnt_blk, x_blk):
        x_full = jax.lax.all_gather(x_blk, axes, axis=0, tiled=True)
        ell_blk = B2SREll(
            tile_col_idx=col_blk, bit_tiles=tiles_blk, row_n_tiles=cnt_blk,
            tile_dim=t, n_rows=col_blk.shape[0] * t, n_cols=ell.n_cols)
        return core_ops.spmm_b2sr(ell_blk, x_full, row_chunk=row_chunk,
                                  vma_axes=axes)

    return shard_map_compat(
        block, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None, None), P(axes), P(axes, None)),
        out_specs=P(axes, None),
    )(ell.tile_col_idx, ell.bit_tiles, ell.row_n_tiles, x)
