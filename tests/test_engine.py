"""Batched multi-source query engine: parity, planner, batcher (DESIGN.md §9).

The engine is a pure batching transform — every multi-source result must
equal a loop of the single-source algorithm: bit-exact for BFS/k-hop/SSSP
(boolean/integer ops), allclose for PPR (the multi-vector spmm sums in a
different float order than the scanned bmv). Pinned across tile dims
4/8/16/32, all three backends, bucketed on/off, and ragged batch sizes
(1, word-width, non-pow2, > 32 sources). Plus: the packed frontier-matrix
scheme itself, the plan cache (hit/miss/eviction), the request batcher,
and the GraphMatrix memoization satellites.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.algorithms import bfs, khop_frontier, pagerank, ppr, sssp
from repro.core import (
    TILE_DIMS, GraphMatrix, coo_to_b2sr, pack_bitvector, pack_frontier_matrix,
    to_bucketed, to_ell, unpack_bitvector, unpack_frontier_matrix,
)
from repro.core import ops
from repro.engine import (
    BatchFlushError, PlanCache, QueryBatcher, QueryGroupError, batched_ppr,
    ms_sssp, msbfs, mskhop, plan_key,
)

BACKENDS = ("b2sr", "b2sr_pallas", "csr")


def skewed_coo(n, seed, hub_deg=25, base_deg=3):
    rng = np.random.default_rng(seed)
    rows = np.concatenate([
        np.repeat(np.arange(n, dtype=np.int64), base_deg),
        np.repeat(rng.choice(n, 2, replace=False).astype(np.int64), hub_deg),
    ])
    cols = rng.integers(0, n, rows.size)
    return rows, cols


def build(n=96, t=8, backend="b2sr", seed=0, use_buckets=True):
    rows, cols = skewed_coo(n, seed)
    g = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=t, backend=backend)
    return g.with_buckets(use_buckets)


# ---------------------------------------------------------------------------
# frontier-matrix packing + the spmm_bin_bin_bin scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("s", (1, 5, 33))
def test_frontier_matrix_roundtrip(t, s):
    n = 70
    rng = np.random.default_rng(t + s)
    f = rng.random((n, s)) > 0.5
    fp = pack_frontier_matrix(jnp.asarray(f), t, n)
    assert fp.shape == (-(-n // t), t, -(-s // 32))
    assert np.array_equal(np.asarray(unpack_frontier_matrix(fp, n, s,
                                                            jnp.bool_)), f)


@pytest.mark.parametrize("t", TILE_DIMS)
def test_spmm_bbb_equals_per_source_bmv(t):
    n = 80
    rows, cols = skewed_coo(n, seed=t)
    ell = to_ell(coo_to_b2sr(rows, cols, n, n, t))
    bk = to_bucketed(ell)
    rng = np.random.default_rng(t)
    s = 37                                   # 2 source words, ragged
    f = rng.random((n, s)) > 0.6
    fp = pack_frontier_matrix(jnp.asarray(f), t, n)
    y = ops.spmm_bin_bin_bin(ell, fp)
    # bucketed twin is bit-identical
    assert np.array_equal(np.asarray(y),
                          np.asarray(ops.spmm_bin_bin_bin_bucketed(bk, fp)))
    # column s == the single-frontier bmv scheme
    yd = unpack_frontier_matrix(y, n, s, jnp.bool_)
    for col in (0, 17, 36):
        xp = pack_bitvector(jnp.asarray(f[:, col]), t, n)
        want = unpack_bitvector(ops.bmv_bin_bin_bin(ell, xp), t, n, jnp.bool_)
        assert np.array_equal(np.asarray(yd[:, col]), np.asarray(want)), col
    # §V mask-at-store, plain and complemented, both paths
    m = rng.random((n, s)) > 0.5
    mp = pack_frontier_matrix(jnp.asarray(m), t, n)
    for comp in (True, False):
        want = np.asarray(y & (~mp if comp else mp))
        assert np.array_equal(
            np.asarray(ops.spmm_bin_bin_bin_masked(ell, fp, mp, comp)), want)
        assert np.array_equal(
            np.asarray(ops.spmm_bin_bin_bin_bucketed_masked(bk, fp, mp,
                                                            comp)), want)


@pytest.mark.parametrize("t", (4, 8, 32))
def test_pallas_spmm_bbb_matches_jnp_and_ref(t):
    from repro.kernels.spmm import ops as kops, ref as kref
    n = 64
    rows, cols = skewed_coo(n, seed=t, hub_deg=15, base_deg=2)
    ell = to_ell(coo_to_b2sr(rows, cols, n, n, t))
    bk = to_bucketed(ell)
    rng = np.random.default_rng(t)
    s = 34
    f = rng.random((n, s)) > 0.5
    m = rng.random((n, s)) > 0.4
    fp = pack_frontier_matrix(jnp.asarray(f), t, n)
    mp = pack_frontier_matrix(jnp.asarray(m), t, n)
    want = np.asarray(ops.spmm_bin_bin_bin(ell, fp))
    assert np.array_equal(np.asarray(kops.spmm_bin_bin_bin(ell, fp)), want)
    assert np.array_equal(np.asarray(kref.spmm_bbb(ell, fp)), want)
    want_m = want & ~np.asarray(mp)
    assert np.array_equal(
        np.asarray(kops.spmm_bin_bin_bin(ell, fp, mp, True)), want_m)
    assert np.array_equal(
        np.asarray(kops.spmm_bin_bin_bin_bucketed(bk, fp, mp, True)), want_m)


# ---------------------------------------------------------------------------
# multi-source parity vs looped single-source runs
# ---------------------------------------------------------------------------

def assert_msbfs_matches(g, sources):
    res = msbfs(g, sources)
    assert res.levels.shape == (g.n_rows, len(sources))
    for i, s in enumerate(sources):
        want = bfs(g, int(s)).levels
        assert np.array_equal(np.asarray(res.levels[:, i]),
                              np.asarray(want)), f"source {s}"


@pytest.mark.parametrize("t", TILE_DIMS)
def test_msbfs_parity_tile_dims(t):
    g = build(n=96, t=t, seed=t)
    assert_msbfs_matches(g, [0, 9, 31, 64])


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("use_buckets", (True, False))
def test_msbfs_parity_backends(backend, use_buckets):
    g = build(n=80, t=8, backend=backend, seed=5, use_buckets=use_buckets)
    assert_msbfs_matches(g, [0, 3, 41])


@pytest.mark.parametrize("s_batch", (1, 8, 33, 70))
def test_msbfs_ragged_batch_sizes(s_batch):
    g = build(n=72, t=8, seed=2)
    rng = np.random.default_rng(s_batch)
    sources = rng.integers(0, g.n_rows, s_batch)   # duplicates allowed
    assert_msbfs_matches(g, list(sources))


@pytest.mark.parametrize("backend", BACKENDS)
def test_mskhop_parity(backend):
    g = build(n=80, t=8, backend=backend, seed=7)
    sources = [0, 11, 42, 42]
    for k in (1, 3):
        got = mskhop(g, sources, k)
        for i, s in enumerate(sources):
            want = khop_frontier(g, int(s), k)
            assert np.array_equal(np.asarray(got[:, i]),
                                  np.asarray(want)), (k, s)


@pytest.mark.parametrize("t", (4, 32))
def test_mskhop_parity_tile_dims(t):
    g = build(n=64, t=t, seed=t + 1)
    got = mskhop(g, [1, 30], 2)
    for i, s in enumerate((1, 30)):
        assert np.array_equal(np.asarray(got[:, i]),
                              np.asarray(khop_frontier(g, s, 2)))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("edge_weight", (1.0, 0.5))
def test_ms_sssp_parity(backend, edge_weight):
    g = build(n=80, t=16, backend=backend, seed=3)
    sources = [2, 19, 55]
    res = ms_sssp(g, sources, edge_weight=edge_weight)
    for i, s in enumerate(sources):
        want = sssp(g, int(s), edge_weight=edge_weight).distances
        assert np.array_equal(np.asarray(res.distances[:, i]),
                              np.asarray(want)), s


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_ppr_allclose(backend):
    g = build(n=64, t=8, backend=backend, seed=9)
    seeds = [0, 7, 33]
    res = batched_ppr(g, seeds, alpha=0.85, max_iters=8, eps=0.0)
    assert res.n_iterations == 8
    for i, s in enumerate(seeds):
        want = ppr(g, int(s), alpha=0.85, max_iters=8, eps=0.0).ranks
        assert np.allclose(np.asarray(res.ranks[:, i]), np.asarray(want),
                           atol=1e-5), s


def test_batched_ppr_restart_matrix():
    g = build(n=64, t=8, seed=4)
    n = g.n_rows
    r = np.zeros((n, 2), np.float32)
    r[10, 0] = 1.0
    r[[4, 5], 1] = 0.5                       # a 2-node restart distribution
    res = batched_ppr(g, r, max_iters=6, eps=0.0)
    want0 = ppr(g, 10, max_iters=6, eps=0.0).ranks
    want1 = ppr(g, r[:, 1], max_iters=6, eps=0.0).ranks
    assert np.allclose(np.asarray(res.ranks[:, 0]), np.asarray(want0),
                       atol=1e-5)
    assert np.allclose(np.asarray(res.ranks[:, 1]), np.asarray(want1),
                       atol=1e-5)
    # ranks concentrate around the seed's neighbourhood, sanity: positive
    assert float(res.ranks[10, 0]) > 0


def test_ppr_uniform_restart_equals_pagerank():
    g = build(n=64, t=8, seed=6)
    n = g.n_rows
    uniform = np.full(n, 1.0 / n, np.float32)
    a = ppr(g, uniform, max_iters=10, eps=0.0).ranks
    b = pagerank(g, max_iters=10, eps=0.0).ranks
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_bfs_sssp_array_source_wrappers():
    g = build(n=72, t=8, seed=8)
    res = bfs(g, np.array([0, 5]))
    assert res.levels.shape == (72, 2)
    assert np.array_equal(np.asarray(res.levels[:, 1]),
                          np.asarray(bfs(g, 5).levels))
    d = sssp(g, [0, 5])
    assert d.distances.shape == (72, 2)
    assert np.array_equal(np.asarray(d.distances[:, 0]),
                          np.asarray(sssp(g, 0).distances))


def test_graphmatrix_entry_points():
    g = build(n=64, t=8, seed=10)
    res = g.msbfs([1, 2, 3])
    assert np.array_equal(np.asarray(res.levels[:, 2]),
                          np.asarray(bfs(g, 3).levels))
    pr = g.ppr([4, 6], max_iters=5, eps=0.0)
    assert np.allclose(np.asarray(pr.ranks[:, 0]),
                       np.asarray(ppr(g, 4, max_iters=5, eps=0.0).ranks),
                       atol=1e-5)


def test_msbfs_source_validation():
    g = build(n=32, t=8)
    with pytest.raises(ValueError):
        msbfs(g, [])
    with pytest.raises(ValueError):
        msbfs(g, [32])


# ---------------------------------------------------------------------------
# planner: cache hits, width quantisation, eviction, key sensitivity
# ---------------------------------------------------------------------------

def test_planner_cache_hit_and_eviction():
    pc = PlanCache(capacity=2)
    g = build(n=64, t=8, seed=11)
    msbfs(g, [0, 1], planner=pc)
    s = pc.stats()
    assert (s["hits"], s["misses"]) == (0, 1)
    # the historical attributes remain as read-only views of the snapshot
    assert (pc.hits, pc.misses, pc.evictions) == (0, 1, 0)
    msbfs(g, [2, 3, 4], planner=pc)          # same padded width -> hit
    s = pc.stats()
    assert (s["hits"], s["misses"]) == (1, 1)
    msbfs(g, np.arange(40), planner=pc)      # wider batch -> new plan
    s = pc.stats()
    assert (s["hits"], s["misses"]) == (1, 2)
    assert s["size"] == 2 and s["evictions"] == 0
    mskhop(g, [0], 2, planner=pc)            # third key -> LRU eviction
    s = pc.stats()
    assert s["evictions"] == 1 and s["size"] == 2 == len(pc)
    # the evicted (oldest) entry was the first msbfs plan: re-miss
    msbfs(g, [5], planner=pc)
    assert pc.stats()["misses"] == 4
    pc.reset_stats()
    assert pc.stats() == {"hits": 0, "misses": 0, "evictions": 0,
                          "size": 2, "capacity": 2}


def test_plan_key_distinguishes_layout_and_backend():
    g = build(n=64, t=8, seed=12)
    k1 = plan_key(g, "msbfs", 32)
    assert plan_key(g, "msbfs", 32) == k1             # deterministic
    assert plan_key(g, "msbfs", 64) != k1             # width
    assert plan_key(g, "mskhop", 32) != k1            # kernel
    assert plan_key(g.with_backend("csr"), "msbfs", 32) != k1
    assert plan_key(g.with_buckets(False), "msbfs", 32) != k1
    # same structure in a fresh wrapper -> same fingerprint, same key
    g2 = build(n=64, t=8, seed=12)
    assert plan_key(g2, "msbfs", 32) == k1
    # different structure -> different fingerprint
    g3 = build(n=64, t=8, seed=13)
    assert plan_key(g3, "msbfs", 32) != k1


def test_planner_shared_across_query_kinds():
    pc = PlanCache()
    g = build(n=64, t=8, seed=14)
    batched_ppr(g, [0, 1], max_iters=3, planner=pc)
    batched_ppr(g, [2], max_iters=3, planner=pc)
    assert pc.stats()["hits"] == 1 and pc.stats()["misses"] == 1
    plan = pc.get(plan_key(g, "ppr", 32), lambda: None)
    assert plan.n_calls == 2


# ---------------------------------------------------------------------------
# batcher: coalescing, pow2 padding, scatter-back
# ---------------------------------------------------------------------------

def test_batcher_coalesces_and_scatters():
    pc = PlanCache()
    qb = QueryBatcher(planner=pc)
    g = build(n=72, t=8, seed=15)
    handles = [qb.bfs(g, s) for s in (0, 9, 33, 40, 40)]
    hk = qb.khop(g, 7, k=2)
    hp = qb.ppr(g, 3, max_iters=5, eps=0.0)
    assert qb.pending() == 7
    # result() on any handle flushes everything, one launch per group
    lv = handles[0].result()
    assert qb.pending() == 0
    assert qb.n_launches == 3 and qb.n_queries == 7
    for h, s in zip(handles, (0, 9, 33, 40, 40)):
        assert np.array_equal(np.asarray(h.result()),
                              np.asarray(bfs(g, s).levels)), s
    assert np.array_equal(np.asarray(lv), np.asarray(bfs(g, 0).levels))
    assert np.array_equal(np.asarray(hk.result()),
                          np.asarray(khop_frontier(g, 7, 2)))
    assert np.allclose(np.asarray(hp.result()),
                       np.asarray(ppr(g, 3, max_iters=5, eps=0.0).ranks),
                       atol=1e-5)


def test_batcher_pow2_padding_reuses_plans():
    pc = PlanCache()
    qb = QueryBatcher(planner=pc)
    g = build(n=64, t=8, seed=16)
    for s in (0, 1, 2):                       # batch of 3 -> padded to 4
        qb.bfs(g, s)
    qb.flush()
    for s in (3, 4):                          # batch of 2 -> padded... to 2
        qb.bfs(g, s)
    qb.flush()
    # both land on the same word-padded plan width (32): 1 miss, 1 hit
    assert pc.stats()["misses"] == 1 and pc.stats()["hits"] == 1
    # different params split the group
    qb.bfs(g, 0)
    qb.bfs(g, 1, max_iters=2)
    qb.flush()
    assert qb.n_launches == 4


def test_batcher_groups_by_graph():
    qb = QueryBatcher(planner=PlanCache())
    g1 = build(n=64, t=8, seed=17)
    g2 = build(n=64, t=8, seed=18)
    h1 = qb.bfs(g1, 0)
    h2 = qb.bfs(g2, 0)
    qb.flush()
    assert qb.n_launches == 2
    assert np.array_equal(np.asarray(h1.result()),
                          np.asarray(bfs(g1, 0).levels))
    assert np.array_equal(np.asarray(h2.result()),
                          np.asarray(bfs(g2, 0).levels))


def test_batcher_sssp_kind():
    qb = QueryBatcher(planner=PlanCache())
    g = build(n=64, t=8, seed=19)
    h = qb.sssp(g, 5, edge_weight=2.0)
    assert np.array_equal(np.asarray(h.result()),
                          np.asarray(sssp(g, 5, edge_weight=2.0).distances))


def test_batcher_rejects_unknown_kind():
    qb = QueryBatcher()
    g = build(n=32, t=8)
    with pytest.raises(ValueError):
        qb.submit(g, "tarjan", 0)


def test_batcher_validates_source_at_submit():
    qb = QueryBatcher()
    g = build(n=32, t=8)
    with pytest.raises(ValueError):
        qb.bfs(g, 32)
    with pytest.raises(ValueError):
        qb.bfs(g, -1)
    assert qb.pending() == 0                  # nothing half-enqueued


def test_batcher_group_failure_isolated():
    qb = QueryBatcher(planner=PlanCache())
    g = build(n=64, t=8, seed=24)
    ok = qb.bfs(g, 3)
    bad = qb.ppr(g, 5, max_iters="nope")      # fails inside its group
    # a healthy handle's result() flushes quietly: the sibling group's
    # failure stays on the sibling's handles, not this call
    assert np.array_equal(np.asarray(ok.result()),
                          np.asarray(bfs(g, 3).levels))
    assert ok.done() and bad.done()
    with pytest.raises(QueryGroupError):
        bad.result()
    # an explicit flush is loud about its own groups' failures
    qb.ppr(g, 5, max_iters="nope")
    with pytest.raises(BatchFlushError):
        qb.flush()


def test_batcher_multi_group_failures_keep_context():
    # regression (ISSUE 5): with several failing groups in one flush, each
    # handle's error must identify *its own* group (kind + params) and
    # chain the original traceback; the aggregate lists every group in
    # submission order instead of reporting only the first
    qb = QueryBatcher(planner=PlanCache())
    g = build(n=64, t=8, seed=26)
    h_ppr = qb.ppr(g, 5, max_iters="nope")        # TypeError inside jit
    h_ok = qb.bfs(g, 3)
    h_khop = qb.khop(g, 4, k=0)                   # ValueError: k >= 1
    qb.flush(raise_errors=False)                  # quiet sweep, all groups run
    assert h_ok.done() and h_ppr.done() and h_khop.done()
    assert np.array_equal(np.asarray(h_ok.result()),
                          np.asarray(bfs(g, 3).levels))
    with pytest.raises(QueryGroupError, match="'ppr'") as ei:
        h_ppr.result()
    assert ei.value.kind == "ppr"
    assert ("max_iters", "nope") in ei.value.params
    assert ei.value.__cause__ is not None          # original traceback kept
    with pytest.raises(QueryGroupError, match="'khop'") as ei:
        h_khop.result()
    assert ei.value.kind == "khop"
    assert isinstance(ei.value.__cause__, ValueError)
    # loud flush: one aggregate naming every dead group, submission order
    a = qb.ppr(g, 5, max_iters="nope")
    b = qb.khop(g, 4, k=0)
    with pytest.raises(BatchFlushError) as ei:
        qb.flush()
    kinds = [e.kind for e in ei.value.errors]
    assert kinds == ["ppr", "khop"]
    assert a.done() and b.done()


def test_single_source_scalars_keep_single_api():
    g = build(n=64, t=8, seed=25)
    # 0-d arrays / numpy scalars are single queries, not batches
    res = bfs(g, np.array(3))
    assert res.levels.shape == (64,)
    assert np.array_equal(np.asarray(res.levels),
                          np.asarray(bfs(g, 3).levels))
    d = sssp(g, np.int64(3))
    assert d.distances.shape == (64,)
    # batched sources reject row_chunk instead of silently dropping it
    with pytest.raises(ValueError):
        bfs(g, np.array([0, 1]), row_chunk=8)
    with pytest.raises(ValueError):
        sssp(g, [0, 1], row_chunk=8)


def test_ppr_seed_validation():
    g = build(n=32, t=8)
    with pytest.raises(ValueError):
        ppr(g, 32)
    with pytest.raises(ValueError):
        ppr(g, -1)


# ---------------------------------------------------------------------------
# memoization satellites: degrees, transposed, fingerprint invalidation
# ---------------------------------------------------------------------------

def test_degrees_memoized_and_correct():
    g = build(n=64, t=8, seed=20)
    d1 = g.degrees()
    assert g.degrees() is d1
    ptr = np.asarray(g.csr.row_ptr)
    assert np.array_equal(np.asarray(d1), np.diff(ptr).astype(np.float32))
    # the transpose gets its *own* cache (in-degrees, not a stale copy)
    gt = g.transposed()
    tptr = np.asarray(gt.csr.row_ptr)
    assert np.array_equal(np.asarray(gt.degrees()),
                          np.diff(tptr).astype(np.float32))


def test_transposed_memoized_involution():
    g = build(n=64, t=8, seed=21)
    gt = g.transposed()
    assert g.transposed() is gt               # cached
    assert gt.transposed() is g               # back-reference
    # backend/bucket toggles drop the stale cached transpose
    gc = g.with_backend("csr")
    assert gc.transposed_cache is None
    assert gc.transposed().backend == "csr"
    gu = g.with_buckets(False)
    assert gu.transposed_cache is None
    assert not gu.transposed().use_buckets


def test_fingerprint_memoized_and_structure_only():
    g = build(n=64, t=8, seed=22)
    fp = g.fingerprint()
    assert g.fingerprint() is g.fingerprint_cache
    assert g.with_backend("csr").fingerprint() == fp      # backend-agnostic
    assert build(n=64, t=8, seed=22).fingerprint() == fp  # content hash
    assert build(n=64, t=8, seed=23).fingerprint() != fp
    assert g.transposed().fingerprint() != fp             # Aᵀ != A here
