"""gcn-cora [arXiv:1609.02907]: 2L d=16, sym-norm mean aggregation.

B2SR integration: the GCN aggregation Â·X is refactored to a *binary* SpMM
D^{-1/2}(A·(D^{-1/2}X)) so the paper's technique is the hot path (use_b2sr).
"""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora",
    family="gcn",
    n_layers=2,
    d_hidden=16,
    aggregator="mean",
    norm="sym",
    d_in=1433,
    n_classes=7,
    use_b2sr=True,
    tile_dim=32,
)


def reduced() -> GNNConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, name="gcn-smoke", d_in=32, n_classes=4)
