"""Shared benchmark utilities: timing, the matrix corpus, CSV emission.

The corpus mirrors the paper's Table V pattern taxonomy (dot / diagonal /
block / stripe / road / hybrid) at CPU-friendly sizes. Wall-clock numbers on
this container measure the *jitted CPU* execution of both paths — they
validate the relative behaviour (B2SR vs float-CSR) and the format
accounting; the TPU projection lives in the roofline analysis.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.data import graphs as G

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


@dataclasses.dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            **kw) -> float:
    """Median wall-time (seconds) of ``fn(*args)`` with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


# --------------------------------------------------------------------------
# Matrix corpus (paper Table V patterns, sized for CPU)
# --------------------------------------------------------------------------

def corpus(n: int = 2048, seed: int = 7) -> Dict[str, Tuple[np.ndarray, np.ndarray, int]]:
    """pattern name -> (rows, cols, n). Binary square adjacency matrices."""
    out = {}
    for name, gen in G.PATTERNS.items():
        r, c = gen(n, seed=seed)
        side = int(np.sqrt(n)) ** 2 if name == "road" else n
        out[name] = (r, c, side)
    return out


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path
