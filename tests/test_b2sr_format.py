"""B2SR format: roundtrip, transpose, ELL view, storage accounting."""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import (
    TILE_DIMS, b2sr_to_dense, bit_transpose_words, compression_ratio,
    coo_to_b2sr, csr_storage_bytes, dense_to_b2sr, occupancy, pack_bitvector,
    pack_dense_tiles, to_ell, transpose, unpack_bitvector, unpack_tiles,
)
from repro.kernels.bmv.ref import dense_from_ell


def random_dense(n, m, density, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n, m)) < density).astype(np.uint8)


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("n,m,density", [(7, 7, 0.3), (64, 64, 0.05),
                                         (100, 37, 0.1), (33, 129, 0.02)])
def test_roundtrip(t, n, m, density):
    d = random_dense(n, m, density, seed=n * m + t)
    mat = dense_to_b2sr(d, t)
    assert np.array_equal(b2sr_to_dense(mat), d)
    assert mat.nnz == int(d.sum())


@pytest.mark.parametrize("t", TILE_DIMS)
def test_transpose(t):
    d = random_dense(50, 70, 0.1, seed=t)
    mat = dense_to_b2sr(d, t)
    assert np.array_equal(b2sr_to_dense(transpose(mat)), d.T)


@pytest.mark.parametrize("t", TILE_DIMS)
def test_ell_view_matches_dense(t):
    d = random_dense(60, 60, 0.08, seed=2 * t)
    ell = to_ell(dense_to_b2sr(d, t))
    back = np.asarray(dense_from_ell(ell))
    assert np.array_equal(back, d.astype(np.float32))


def test_empty_matrix():
    mat = coo_to_b2sr(np.array([]), np.array([]), 16, 16, 8)
    assert mat.n_tiles == 0
    assert np.array_equal(b2sr_to_dense(mat), np.zeros((16, 16), np.uint8))


def test_storage_accounting_table1():
    """Paper Table I: per-tile packed bytes vs 4-byte-float dense tile."""
    per_tile_bytes = {4: 4, 8: 8, 16: 32, 32: 128}
    savings = {4: 16, 8: 32, 16: 32, 32: 32}
    for t in TILE_DIMS:
        d = np.ones((t, t), np.uint8)  # one full tile
        mat = dense_to_b2sr(d, t)
        tile_bytes = mat.storage_bytes() - 4 * (mat.n_tile_rows + 1) - 4 * mat.n_tiles
        assert tile_bytes == per_tile_bytes[t]
        dense_tile_bytes = t * t * 4
        assert dense_tile_bytes // tile_bytes == savings[t]


def test_compression_beats_csr_on_diagonal():
    n = 512
    rows = np.arange(n - 1)
    cols = rows + 1
    rows = np.concatenate([rows, cols])
    cols = np.concatenate([cols, rows[: n - 1]])
    mat = coo_to_b2sr(rows, cols, n, n, 8)
    assert compression_ratio(mat) < 1.0


def test_occupancy_monotone_tile_effects():
    """Paper Fig. 3b: occupancy within non-empty tiles falls as t grows."""
    d = random_dense(256, 256, 0.02, seed=9)
    occ = [occupancy(dense_to_b2sr(d, t)) for t in TILE_DIMS]
    assert occ[0] >= occ[-1]


@given(st.integers(1, 80), st.integers(1, 80),
       st.sampled_from(TILE_DIMS), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_roundtrip_property(n, m, t, seed):
    d = random_dense(n, m, 0.15, seed)
    mat = dense_to_b2sr(d, t)
    assert np.array_equal(b2sr_to_dense(mat), d)


@given(st.sampled_from(TILE_DIMS), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_bit_transpose_involution(t, seed):
    rng = np.random.default_rng(seed)
    words = jnp.asarray(
        rng.integers(0, 2 ** t, size=(5, t), dtype=np.uint64).astype(np.uint32))
    tt = bit_transpose_words(bit_transpose_words(words, t), t)
    assert np.array_equal(np.asarray(tt), np.asarray(words))


@given(st.sampled_from(TILE_DIMS), st.integers(1, 200), st.integers(0, 999))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_vector(t, n, seed):
    rng = np.random.default_rng(seed)
    x = (rng.random(n) < 0.4)
    words = pack_bitvector(jnp.asarray(x), t, n)
    back = unpack_bitvector(words, t, n, jnp.int32)
    assert np.array_equal(np.asarray(back), x.astype(np.int32))


def test_pack_dense_tiles_matches_converter():
    d = random_dense(40, 56, 0.2, seed=3)
    for t in TILE_DIMS:
        words = np.asarray(pack_dense_tiles(jnp.asarray(d), t))
        mat = dense_to_b2sr(d, t)
        ell = to_ell(mat)
        # every non-empty tile's words must match the dense packing
        col = np.asarray(ell.tile_col_idx)
        tiles = np.asarray(ell.bit_tiles)
        for i in range(ell.n_tile_rows):
            for k in range(ell.max_tiles_per_row):
                if col[i, k] >= 0:
                    assert np.array_equal(tiles[i, k], words[i, col[i, k]])
