"""Paper Fig. 6a-c / 7a-c: BMV scheme performance vs the float-CSR baseline.

Per corpus matrix × tile size × scheme, measures jitted wall-time of:
  bmv_bin_bin_bin   (packed frontier in/out)       vs csr boolean mxv
  bmv_bin_bin_full  (packed in, counts out)        vs csr arithmetic mxv
  bmv_bin_full_full (full vector, any semiring)    vs csr arithmetic mxv
Speedup = csr_time / b2sr_time (CPU; relative behaviour only — the TPU
projection is §Roofline). Also reports the byte-traffic model ratio
(B2SR bytes moved / CSR bytes moved), the quantity the paper's GPU speedups
track most closely.
"""

from __future__ import annotations

from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, corpus, save_json, time_fn
from repro.core import csr as csr_mod
from repro.core import ops
from repro.core.b2sr import coo_to_b2sr, csr_storage_bytes, to_ell, pack_bitvector
from repro.core.semiring import ARITHMETIC

TILE_SWEEP = (4, 8, 16, 32)


def _traffic_ratio(m_b2sr, n: int, nnz: int) -> float:
    """Bytes the kernel must stream: B2SR tiles+index vs CSR vals+cols."""
    return m_b2sr.storage_bytes() / max(csr_storage_bytes(n, nnz), 1)


def run(n: int = 2048) -> List[BenchRow]:
    rows: List[BenchRow] = []
    detail = {}
    for name, (r, c, nn) in corpus(n).items():
        csr = csr_mod.from_coo(r, c, nn, nn)
        x = jnp.asarray(np.random.default_rng(0).random(nn), jnp.float32)
        xb = (x > 0.5).astype(jnp.float32)

        csr_mxv = jax.jit(partial(csr_mod.mxv, semiring=ARITHMETIC))
        t_csr = time_fn(csr_mxv, csr, x)
        t_csr_bool = time_fn(csr_mxv, csr, xb)

        entry = {"csr_mxv_us": t_csr * 1e6}
        for t in TILE_SWEEP:
            m = coo_to_b2sr(r, c, nn, nn, t)
            ell = to_ell(m)
            xp = pack_bitvector(xb, t, nn)

            f_bbb = jax.jit(ops.bmv_bin_bin_bin)
            f_bbf = jax.jit(ops.bmv_bin_bin_full)
            f_bff = jax.jit(partial(ops.bmv_bin_full_full, semiring=ARITHMETIC))
            t_bbb = time_fn(f_bbb, ell, xp)
            t_bbf = time_fn(f_bbf, ell, xp)
            t_bff = time_fn(f_bff, ell, x)

            entry[f"t{t}"] = {
                "bin_bin_bin_us": t_bbb * 1e6,
                "bin_bin_full_us": t_bbf * 1e6,
                "bin_full_full_us": t_bff * 1e6,
                "speedup_bbb": t_csr_bool / t_bbb,
                "speedup_bbf": t_csr / t_bbf,
                "speedup_bff": t_csr / t_bff,
                "traffic_ratio": _traffic_ratio(m, nn, m.nnz),
            }
            rows.append(BenchRow(
                f"fig6/bmv/{name}/B2SR-{t}", t_bff * 1e6,
                f"speedup_bff={t_csr / t_bff:.2f}x "
                f"speedup_bbb={t_csr_bool / t_bbb:.2f}x "
                f"traffic={_traffic_ratio(m, nn, m.nnz):.3f}"))
        detail[name] = entry
    save_json("kernels_bmv.json", detail)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
