"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; writes per-table JSON into
results/. Roofline rows (from dry-run artifacts, if present) are appended.

  python -m benchmarks.run                 # everything
  python -m benchmarks.run --only fig6     # substring filter
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on table name")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-pass sizes (CI); suites that support it only")
    args = ap.parse_args()

    from benchmarks import (compression, engine_batch, graph_algorithms,
                            kernels_bmm, kernels_bmv, kernels_bucketed,
                            kernels_spgemm, sampling_profile, scaling_shards,
                            serving_slo, traversal_direction,
                            triangle_counting)
    suites = [
        ("tableI+fig5 compression", compression.run),
        ("fig6a-c bmv", kernels_bmv.run),
        ("fig6d bmm", kernels_bmm.run),
        ("fig8 spgemm", kernels_spgemm.run),
        ("loadbalance bucketed", lambda: kernels_bucketed.run(tiny=args.tiny)),
        ("engine batched queries", lambda: engine_batch.run(tiny=args.tiny)),
        ("serving slo", lambda: serving_slo.run(tiny=args.tiny)),
        ("scaling sharded", lambda: scaling_shards.run(tiny=args.tiny)),
        ("direction traversal",
         lambda: traversal_direction.run(tiny=args.tiny)),
        ("tableVII/VIII algorithms", graph_algorithms.run),
        ("tableIX tc", triangle_counting.run),
        ("alg1 sampling", sampling_profile.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            for row in fn():
                print(row.csv())
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()

    # roofline rows (non-fatal if dry-run artifacts are absent)
    if not args.only or "roofline" in args.only:
        try:
            from benchmarks import roofline
            for r in roofline.run():
                print(f"roofline/{r['arch']}/{r['shape']},0.0,"
                      f"dominant={r['dominant']} frac={r['roofline_fraction']:.3f}")
        except Exception as e:
            print(f"roofline skipped: {e!r}", file=sys.stderr)

    if failures:
        for name, err in failures:
            print(f"FAILED suite {name}: {err}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
