"""Shard-scaling sweep: the sharded dispatch path across mesh widths.

The v2 distribution layer's claim (DESIGN.md §16): an nnz-balanced row
partition (balance → 1.0 instead of the v1 equal blocks' 2+) plus the
``combine="exchange"`` ppermute layout (move only touched column words and
owned output words, never replicate the operand) turns the sharded path
from a dispatch-overhead demo into a communication-avoiding one. This
sweep measures the batched engine (msBFS) and the single-shot kernel rows
(packed mxv, SpMM) across **shard count × skew × batch width × combine
mode**, against the unsharded twin on the same graph, and records each
partition's balance / edge-cut stats and the comm-volume counters
(``gather_words_total`` / ``exchange_words_total``) next to the timings.

Wall-clock caveat, stated in the JSON: with forced-host *virtual* devices
sharing fewer physical cores than shards, the per-shard compute is
serialized, so sharded wall-clock shows collective overhead but cannot
show parallel speedup. The sweep therefore gates on what the machine can
actually witness: partition balance and exchanged-vs-gathered word volume
always; the 8-shard-beats-1-shard latency check only when
``os.cpu_count()`` covers the shard count (real multi-core / multi-chip
runs). ``--assert-scaling`` turns the gates into hard failures (the CI
regression gate).

``results/scaling_shards.json`` records the full detail.
"""

from __future__ import annotations

import argparse
import os
from typing import List, Sequence

import jax
import numpy as np

from benchmarks.common import BenchRow, save_json, time_fn
from repro.core import GraphMatrix
from repro.data import graphs as G
from repro.engine import PlanCache, queries

BALANCE_GATE = 1.1
COMBINES = ("gather", "exchange")


def _mesh(n_devices: int):
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:n_devices]).reshape(n_devices)
    return Mesh(devs, ("data",))


def _graph(n: int, skew: int, tile_dim: int, seed: int) -> GraphMatrix:
    rows, cols = G.rmat_graph(n, avg_degree=4 + 2 * skew, seed=seed)
    return GraphMatrix.from_dense(
        _densify(rows, cols, n), tile_dim=tile_dim)


def _densify(rows, cols, n):
    d = np.zeros((n, n), np.uint8)
    d[rows % n, cols % n] = 1
    return d


def _comm_totals() -> dict:
    """Snapshot the comm-volume counters (summed over all label sets)."""
    from repro.obs import metrics
    reg = metrics.get_registry()
    out = {}
    for name in ("gather_words_total", "exchange_words_total"):
        c = reg.get(name)
        out[name] = sum(float(v) for v in c._series.values()) if c else 0.0
    return out


def run(tiny: bool = False, combines: Sequence[str] = COMBINES,
        assert_scaling: bool = False) -> List[BenchRow]:
    n_dev = len(jax.devices())
    shard_counts = [p for p in (1, 2, 4, 8) if p <= n_dev]
    n = 512 if tiny else 2048
    skews = (1, 8) if tiny else (1, 4, 16)
    widths = (32,) if tiny else (32, 256)
    t = 8
    cores = os.cpu_count() or 1
    max_p = max(shard_counts)
    # the latency gate needs one real core per shard — forced-host virtual
    # devices on fewer cores serialize the per-shard compute
    can_time_scaling = n_dev >= 2 and cores >= max_p

    rows_out: List[BenchRow] = []
    detail = {"n": n, "n_devices": n_dev, "cpu_cores": cores,
              "shard_counts": shard_counts, "combines": list(combines),
              "balance_gate": BALANCE_GATE,
              "strong_scaling_timed": can_time_scaling,
              "strong_scaling_skip_reason": None if can_time_scaling else
              (f"{n_dev} virtual device(s) on {cores} core(s): per-shard "
               f"compute is serialized, wall-clock cannot show speedup"),
              "cases": []}
    from repro.core import BitVector
    for skew in skews:
        g = _graph(n, skew, t, seed=skew)
        rng = np.random.default_rng(skew)
        x_bv = BitVector.pack(
            jax.numpy.asarray(rng.random(n) > 0.5), t)
        X = jax.numpy.asarray(rng.random((n, 16)).astype(np.float32))
        for p in shard_counts:
            for combine in (combines if p > 1 else combines[:1]):
                gg = (g if p == 1 and n_dev == 1
                      else g.shard(_mesh(p), combine=combine))
                part = gg.partitioned
                case = {
                    "skew": skew, "shards": p, "combine": combine,
                    "balance": part.balance() if part else 1.0,
                    "edge_cut": part.edge_cut() if part else 0.0,
                }
                # kernel rows: packed mxv + feature SpMM (jit to strip the
                # python dispatch layer from the measurement); the comm
                # counters increment at trace time, so the snapshot delta
                # around the timed (compiling) closures is per-trace volume
                before = _comm_totals()
                mxv = jax.jit(lambda v: gg.mxv(v).words)
                spmm = jax.jit(lambda m: gg.mxm(m))
                case["mxv_us"] = time_fn(mxv, x_bv) * 1e6
                case["spmm_us"] = time_fn(spmm, X) * 1e6
                after = _comm_totals()
                case["gather_words"] = (after["gather_words_total"]
                                        - before["gather_words_total"])
                case["exchange_words"] = (after["exchange_words_total"]
                                          - before["exchange_words_total"])
                # the engine path: one mesh serves the whole batch
                for s in widths:
                    pc = PlanCache()
                    srcs = np.arange(s) % n
                    queries.msbfs(gg, srcs, planner=pc)      # compile plan
                    sec = time_fn(
                        lambda: queries.msbfs(gg, srcs, planner=pc))
                    case[f"msbfs{s}_us_per_query"] = sec * 1e6 / s
                    rows_out.append(BenchRow(
                        f"scaling/skew{skew}/p{p}/{combine}/msbfs{s}",
                        sec * 1e6 / s,
                        f"balance={case['balance']:.2f} "
                        f"cut={case['edge_cut']:.2f}"))
                rows_out.append(BenchRow(
                    f"scaling/skew{skew}/p{p}/{combine}/mxv",
                    case["mxv_us"], f"spmm_us={case['spmm_us']:.1f}"))
                detail["cases"].append(case)

    detail["gates"] = _gates(detail)
    path = save_json("scaling_shards.json", detail)
    rows_out.append(BenchRow("scaling/json", 0.0, path))
    if assert_scaling:
        failed = [k for k, v in detail["gates"].items()
                  if v.get("ok") is False]
        if failed:
            raise AssertionError(
                f"scaling regression gate(s) failed: {failed} — see {path}")
    return rows_out


def _gates(detail: dict) -> dict:
    """The CI regression gates, evaluated from the recorded cases.

    - ``balance``: every multi-shard partition at the largest skew stays
      under :data:`BALANCE_GATE` (the v2 nnz split's contract).
    - ``exchange_volume``: at the largest skewed multi-shard config the
      exchange layout moved strictly fewer words than gather.
    - ``strong_scaling``: max-shard mxv and spmm beat the 1-shard
      baseline at the largest skew — evaluated only when the machine has
      a core per shard (``strong_scaling_timed``), else recorded as
      skipped with the reason.
    """
    cases = detail["cases"]
    max_skew = max(c["skew"] for c in cases)
    max_p = max(c["shards"] for c in cases)
    top = [c for c in cases if c["skew"] == max_skew]
    gates: dict = {}

    multi = [c for c in top if c["shards"] > 1]
    gates["balance"] = {
        "ok": all(c["balance"] <= BALANCE_GATE for c in multi)
        if multi else None,
        "worst": max((c["balance"] for c in multi), default=None),
        "gate": BALANCE_GATE,
    }

    pairs = {}
    for c in top:
        if c["shards"] > 1:
            pairs.setdefault(c["shards"], {})[c["combine"]] = c
    both = [v for v in pairs.values()
            if "gather" in v and "exchange" in v]
    gates["exchange_volume"] = {
        "ok": all(v["exchange"]["exchange_words"]
                  < v["gather"]["gather_words"] for v in both)
        if both else None,
        "detail": [{"shards": v["gather"]["shards"],
                    "gather_words": v["gather"]["gather_words"],
                    "exchange_words": v["exchange"]["exchange_words"]}
                   for v in both],
    }

    base = [c for c in top if c["shards"] == 1]
    wide = [c for c in top if c["shards"] == max_p]
    if not detail["strong_scaling_timed"]:
        gates["strong_scaling"] = {
            "ok": None, "skipped": detail["strong_scaling_skip_reason"]}
    elif base and wide:
        b = base[0]
        best = {k: min(c[k] for c in wide) for k in ("mxv_us", "spmm_us")}
        gates["strong_scaling"] = {
            "ok": best["mxv_us"] < b["mxv_us"]
            and best["spmm_us"] < b["spmm_us"],
            "baseline": {k: b[k] for k in ("mxv_us", "spmm_us")},
            "best_sharded": best, "shards": max_p,
        }
    else:
        gates["strong_scaling"] = {"ok": None,
                                   "skipped": "single shard count only"}
    return gates


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-pass sizes (CI)")
    ap.add_argument("--combine", nargs="+", choices=COMBINES,
                    default=list(COMBINES),
                    help="which collective layouts to sweep")
    ap.add_argument("--assert-scaling", action="store_true",
                    help="fail on a regression-gate violation (CI)")
    args = ap.parse_args()
    for row in run(tiny=args.tiny, combines=tuple(args.combine),
                   assert_scaling=args.assert_scaling):
        print(row.csv())


if __name__ == "__main__":
    main()
