"""Shared utilities for the Pallas kernel layer."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.b2sr import B2SRBucketedEll, B2SREll
from repro.core.b2sr import or_reduce_words as or_reduce  # noqa: F401 — kernel-body alias


def interpret_default() -> bool:
    """Pallas kernels run in interpret mode unless a real TPU is attached.

    CPU containers validate the kernel bodies in Python; on TPU the same
    pallas_call lowers through Mosaic.
    """
    if os.environ.get("REPRO_PALLAS_INTERPRET") is not None:
        return os.environ["REPRO_PALLAS_INTERPRET"] not in ("0", "false")
    return jax.default_backend() != "tpu"


def pad_to(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    n = x.shape[axis]
    target = -(-n // multiple) * multiple
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads, constant_values=fill)


def unpack_words(words: jax.Array, t: int, dtype=jnp.float32) -> jax.Array:
    """uint32[..., t] -> 0/1 [..., t, t] (row, col). Kernel-body safe."""
    shifts = jnp.arange(t, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.astype(dtype)


def bucket_ell(b: B2SRBucketedEll, i: int) -> B2SREll:
    """Bucket ``i``'s slab as a standalone ELL view for the kernel wrappers.

    The slab's rows are a permuted subset of the original tile-rows, so
    ``n_rows`` is the slab's own row extent (rows_b × t); callers scatter
    the result back through ``b.rows[i]``.
    """
    col = b.col_idx[i]
    return B2SREll(
        tile_col_idx=col,
        bit_tiles=b.bit_tiles[i],
        row_n_tiles=jnp.sum((col >= 0).astype(jnp.int32), axis=1),
        tile_dim=b.tile_dim,
        n_rows=int(col.shape[0]) * b.tile_dim,
        n_cols=b.n_cols,
    )


def bucket_block_k(k_b: int, block_k: int) -> int:
    """K-axis block for a bucket: its pow2-rounded width, capped at block_k.

    Small buckets get grids sized by their own k_b instead of inheriting
    the global block and re-padding hub-width work onto short rows.
    """
    if k_b >= block_k:
        return block_k
    return 1 << (k_b - 1).bit_length()
