"""BFS on the boolean semiring with bit-packed frontiers (paper §V).

Each iteration performs one-degree edge traversal ``vxm`` with the visited
mask applied right before the output store (``bmv_bin_bin_bin_masked``), the
paper's masking strategy (no early exit — mask AND at the end, which on TPU
also avoids divergence-like predication costs).

The frontier, visited set, and mask are bit-packed uint32 words end-to-end on
the b2sr backends; levels are materialised incrementally in an int32 vector.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.operands import BitVector


@dataclasses.dataclass
class BFSResult:
    levels: jax.Array      # int32[n]; -1 = unreachable
    n_iterations: int


def bfs(g: GraphMatrix, source, max_iters: Optional[int] = None,
        row_chunk: Optional[int] = None):
    """Hop levels from ``source`` following out-edges (push direction).

    ``source`` may also be an *array* of sources: the batch routes through
    the multi-source engine (one wide frontier-matrix traversal, plan-
    cached) and returns its ``MSBFSResult`` with ``levels[n, S]`` — column
    ``s`` bit-exact against the single-source run on ``source[s]``.
    """
    if np.ndim(source) > 0:
        if row_chunk is not None:
            raise ValueError("row_chunk is not supported for batched "
                             "sources (the engine plans its own loop)")
        from repro.engine.queries import msbfs
        return msbfs(g, source, max_iters=max_iters)
    source = int(source)
    n = g.n_rows
    max_iters = n if max_iters is None else max_iters
    t = g.tile_dim
    # push traversal: next = Aᵀ · frontier — use the transposed operand
    gt = g.transposed()

    src = jnp.zeros(n, jnp.float32).at[source].set(1.0)
    frontier = BitVector.pack(src, t, n)
    visited = frontier
    levels = jnp.full(n, -1, jnp.int32).at[source].set(0)

    def cond(state):
        # NOT jnp.sum(frontier.astype(uint64)): without x64 that silently
        # downcasts to uint32 and the word sum can wrap to exactly zero,
        # terminating BFS with a live frontier. any() is also cheaper.
        frontier, _, _, it = state
        return frontier.any() & (it < max_iters)

    def body(state):
        frontier, visited, levels, it = state
        # boolean-semiring mxv with the visited complement-mask (§V):
        # the BitVector operand selects the bin·bin→bin Table II row
        nxt = gt.mxv(frontier, desc=Descriptor(mask=visited, complement=True,
                                               row_chunk=row_chunk))
        new_visited = visited | nxt
        new_bits = nxt.unpack(jnp.int32)
        levels_new = jnp.where((new_bits > 0) & (levels < 0), it + 1, levels)
        return nxt, new_visited, levels_new, it + 1

    frontier, visited, levels, it = jax.lax.while_loop(
        cond, body, (frontier, visited, levels, jnp.int32(0)))
    return BFSResult(levels=levels, n_iterations=int(it))
