"""shard_map MoE dispatch vs the dense gather/scatter path.

With capacity_factor high enough that no token drops, the two dispatch
strategies must agree exactly (the only semantic difference is local vs
global overflow accounting). Runs in a subprocess with 8 forced devices.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.configs.base import MoEConfig, TransformerConfig
    from repro.models import moe as moe_mod

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=64, vocab_size=64,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                      capacity_factor=8.0),   # no drops
        batch_axes=("data",), dtype="float32")

    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe_params(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))

    with mesh:
        y_dense, aux_dense = jax.jit(
            lambda p, x: moe_mod._moe_ffn_dense(p, x, cfg))(p, x)
        y_smap, aux_smap = jax.jit(
            lambda p, x: moe_mod._moe_ffn_shardmap(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_smap),
                               rtol=2e-5, atol=2e-5)
    # aux: the sharded path averages per-shard load-balance losses (mean of
    # products) instead of the global product of means — a standard EP
    # estimator difference, ~0.3% here
    np.testing.assert_allclose(float(aux_dense), float(aux_smap), rtol=2e-2)
    print("MOE_OK")

    # gradients flow through the shard_map path
    def loss(p):
        y, aux = moe_mod._moe_ffn_shardmap(p, x, cfg)
        return jnp.sum(y ** 2) + aux
    with mesh:
        g = jax.jit(jax.grad(loss))(p)
    assert all(np.isfinite(np.asarray(v)).all()
               for v in jax.tree_util.tree_leaves(g))
    print("GRAD_OK")
""")


@pytest.fixture(scope="module")
def subprocess_run():
    return subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=420, env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.parametrize("marker", ["MOE_OK", "GRAD_OK"])
def test_moe_shardmap_matches_dense(subprocess_run, marker):
    assert subprocess_run.returncode == 0, subprocess_run.stderr[-3000:]
    assert marker in subprocess_run.stdout
