"""Shared utilities for the Pallas kernel layer."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def interpret_default() -> bool:
    """Pallas kernels run in interpret mode unless a real TPU is attached.

    CPU containers validate the kernel bodies in Python; on TPU the same
    pallas_call lowers through Mosaic.
    """
    if os.environ.get("REPRO_PALLAS_INTERPRET") is not None:
        return os.environ["REPRO_PALLAS_INTERPRET"] not in ("0", "false")
    return jax.default_backend() != "tpu"


def pad_to(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    n = x.shape[axis]
    target = -(-n // multiple) * multiple
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads, constant_values=fill)


def unpack_words(words: jax.Array, t: int, dtype=jnp.float32) -> jax.Array:
    """uint32[..., t] -> 0/1 [..., t, t] (row, col). Kernel-body safe."""
    shifts = jnp.arange(t, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.astype(dtype)
