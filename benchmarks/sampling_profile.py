"""Paper Algorithm 1 / §III.C: sampling profiler accuracy.

For every corpus matrix: run the row-sampling estimator at several sample
counts, compare estimated compression per tile size against the exact value,
and check the recommended tile size against the exact optimum.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, corpus, save_json, time_fn
from repro.core import csr as csr_mod
from repro.core.b2sr import TILE_DIMS, best_tile_dim, coo_to_b2sr, compression_ratio
from repro.core.sampling import sample_profile


def run(n_samples: int = 128) -> List[BenchRow]:
    rows: List[BenchRow] = []
    detail = {}
    for name, (r, c, nn) in corpus().items():
        csr = csr_mod.from_coo(r, c, nn, nn)
        row_ptr = np.asarray(csr.row_ptr)
        col_idx = np.asarray(csr.col_idx)
        exact = {t: compression_ratio(coo_to_b2sr(r, c, nn, nn, t))
                 for t in TILE_DIMS}
        best_exact, _ = best_tile_dim(r, c, nn, nn)
        prof = sample_profile(row_ptr, col_idx, nn, nn, n_samples=n_samples)
        errs = {t: abs(prof.est_compression[t] - exact[t]) for t in TILE_DIMS}
        t_prof = time_fn(
            lambda: sample_profile(row_ptr, col_idx, nn, nn,
                                   n_samples=n_samples),
            warmup=0, iters=3)
        # "hit" = recommended within the top-2 exact tile sizes (sampling is a
        # rough estimator by design; the paper positions it as guidance)
        order = sorted(exact, key=exact.get)
        hit = prof.recommended_tile_dim in order[:2] or (
            prof.recommended_tile_dim is None and exact[order[0]] >= 1.0)
        detail[name] = {
            "exact": exact, "est": prof.est_compression,
            "recommended": prof.recommended_tile_dim,
            "best_exact": best_exact, "max_abs_err": max(errs.values()),
            "profile_us": t_prof * 1e6, "top2_hit": hit,
        }
        rows.append(BenchRow(
            f"alg1/sampling/{name}", t_prof * 1e6,
            f"rec=B2SR-{prof.recommended_tile_dim} exact_best=B2SR-{best_exact} "
            f"maxerr={max(errs.values()):.3f} top2hit={hit}"))
    save_json("sampling_profile.json", detail)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
