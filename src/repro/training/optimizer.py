"""Optimizers as pure functions over parameter pytrees.

AdamW (optionally with bf16 moments — required to fit arctic-480b's optimizer
state in HBM, DESIGN.md §7), SGD-momentum, and warmup-cosine LR schedules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"               # adamw | sgd
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9             # sgd
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # float32 | bfloat16 (arctic)
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any       # None for sgd


def _moment_like(params, dtype):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dtype), params)


def init(cfg: OptimizerConfig, params) -> OptState:
    dtype = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    m = _moment_like(params, dtype)
    v = _moment_like(params, dtype) if cfg.name == "adamw" else None
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-6))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def update(cfg: OptimizerConfig, grads, state: OptState,
           params) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    if cfg.name == "adamw":
        b1, b2 = cfg.beta1, cfg.beta2

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
            vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
            mhat = mf / (1 - b1 ** step)
            vhat = vf / (1 - b2 ** step)
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (delta + cfg.weight_decay * pf)
            return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

        out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
    if cfg.name == "sgd":
        def upd(p, g, m):
            gf = g.astype(jnp.float32)
            mf = m.astype(jnp.float32) * cfg.momentum + gf
            pf = p.astype(jnp.float32) - lr * (mf + cfg.weight_decay
                                               * p.astype(jnp.float32))
            return pf.astype(p.dtype), mf.astype(m.dtype)

        out = jax.tree_util.tree_map(upd, params, grads, state.m)
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, OptState(step, new_m, None), {"lr": lr, "grad_norm": gnorm}
    raise ValueError(cfg.name)
