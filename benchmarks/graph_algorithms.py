"""Paper Tables VII/VIII: SpMV-based graph algorithms, B2SR vs float-CSR.

BFS / SSSP / PR / CC end-to-end wall time per corpus matrix for backend
"b2sr" (word-level bit ops) vs "csr" (the GraphBLAST stand-in). Correctness
is cross-checked between backends on every run.
"""

from __future__ import annotations

from typing import List

import jax
import numpy as np

from benchmarks.common import BenchRow, corpus, save_json, time_fn
from repro.algorithms.bfs import bfs
from repro.algorithms.cc import connected_components
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.core.graphblas import GraphMatrix

ALGOS = ("bfs", "sssp", "pr", "cc")


def _run_algo(algo: str, g: GraphMatrix):
    if algo == "bfs":
        return bfs(g, source=0).levels
    if algo == "sssp":
        return sssp(g, source=0).distances
    if algo == "pr":
        return pagerank(g, max_iters=10).ranks
    return connected_components(g).labels


def run(n: int = 2048, tile_dim: int = 32) -> List[BenchRow]:
    rows: List[BenchRow] = []
    detail = {}
    for name, (r, c, nn) in corpus(n).items():
        g_bit = GraphMatrix.from_coo(r, c, nn, nn, tile_dim, backend="b2sr")
        g_csr = g_bit.with_backend("csr")
        entry = {}
        for algo in ALGOS:
            out_bit = np.asarray(_run_algo(algo, g_bit))
            out_csr = np.asarray(_run_algo(algo, g_csr))
            if algo == "pr":
                agree = bool(np.allclose(out_bit, out_csr, atol=1e-5))
            else:
                agree = bool(np.array_equal(out_bit, out_csr))
            t_bit = time_fn(_run_algo, algo, g_bit, warmup=1, iters=3)
            t_csr = time_fn(_run_algo, algo, g_csr, warmup=1, iters=3)
            entry[algo] = {
                "b2sr_ms": t_bit * 1e3, "csr_ms": t_csr * 1e3,
                "speedup": t_csr / t_bit, "agree": agree,
            }
            rows.append(BenchRow(
                f"tableVII/{algo}/{name}", t_bit * 1e6,
                f"speedup={t_csr / t_bit:.2f}x agree={agree}"))
            assert agree, f"{algo} on {name}: backend mismatch"
        detail[name] = entry
    save_json("graph_algorithms.json", detail)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
