"""Row-partitioned B2SR: per-device shards for multi-device execution.

The scale-out layer (DESIGN.md §11): a graph's tile-row axis is split into
``n_shards`` equal contiguous blocks — shard ``p`` owns tile rows
``[p*R, (p+1)*R)`` of the (padded) global tile-row axis — and every shard's
ELL slab is padded to one **common slab width**, so the per-shard arrays
stack into single leading-axis-``P`` arrays that ``jax.shard_map`` splits
across a mesh with one ``in_specs`` entry. The column space is shared: a
row-partitioned ``A·x`` is a per-shard *local* mxv against the replicated
operand plus one tiled all-gather of the output block (the semiring
formulation makes this exact for every ⊕-monoid — blocks are disjoint).

Equal row blocks (not tile-balanced boundaries) are a deliberate choice:
the concatenation of shard outputs IS the global packed layout, so no
scatter/permutation ever touches the bit-packed words, and ``unpartition``
is a reshape. Load skew *inside* a shard is what the SELL-style buckets
already handle — the partition carries stacked per-bucket slabs with a
bucket structure harmonised across shards (same bucket count, same per-
bucket width everywhere) so the bucketed path also runs under one
``shard_map``. Imbalance *across* shards is reported, not rebalanced
(``balance()``, ``edge_cut()``): row reordering is an ingest-time decision
that would change the node numbering every consumer sees.

Host-side construction mirrors ``to_ell``/``to_bucketed``; nothing here
touches a mesh — placement happens at execution time in
``repro.core.ops_sharded``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.b2sr import (B2SR, B2SREll, TILE_DIMS, _pytree, ceil_div,
                             static_field, to_ell)


@_pytree
@dataclasses.dataclass(frozen=True)
class PartitionedB2SR:
    """Stacked per-shard ELL (+ bucketed) slabs over equal tile-row blocks.

    Shard ``p`` owns global tile rows ``[p*rows_per_shard,
    (p+1)*rows_per_shard)``; trailing padding rows (beyond the real
    ``n_tile_rows``) have ``row_n_tiles == 0`` and all-``-1`` columns, so
    every scheme's ⊕-identity fills them and a final slice drops them.

    Bucketed slabs (built when ``with_buckets``) share one global bucket
    structure: bucket ``b`` has the same slab width ``k_b`` on every shard
    and every shard's slab is padded to the same row count; padding slab
    rows scatter to the **garbage row** ``rows_per_shard`` (consumers
    allocate ``rows_per_shard + 1`` output rows and drop the last).
    """

    tile_col_idx: jax.Array    # int32[P, R, K]; -1 = padding
    bit_tiles: jax.Array       # uint32[P, R, K, t]
    row_n_tiles: jax.Array     # int32[P, R]
    # harmonised bucket slabs (parallel tuples, empty when buckets off)
    bucket_col_idx: Tuple[jax.Array, ...]    # int32[P, rb, kb]
    bucket_bit_tiles: Tuple[jax.Array, ...]  # uint32[P, rb, kb, t]
    bucket_rows: Tuple[jax.Array, ...]       # int32[P, rb]; pad rows -> R
    tile_dim: int = static_field()
    n_rows: int = static_field()
    n_cols: int = static_field()
    n_tile_rows: int = static_field()        # real (unpadded) global count
    shard_tiles: Tuple[int, ...] = static_field()  # real tiles per shard
    cut_tiles: int = static_field()          # tiles outside own row block

    @property
    def n_shards(self) -> int:
        return int(self.tile_col_idx.shape[0])

    @property
    def rows_per_shard(self) -> int:
        return int(self.tile_col_idx.shape[1])

    @property
    def slab_width(self) -> int:
        return int(self.tile_col_idx.shape[2])

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_col_idx)

    @property
    def n_tile_cols(self) -> int:
        return ceil_div(self.n_cols, self.tile_dim)

    def n_tiles(self) -> int:
        return sum(self.shard_tiles)

    def balance(self) -> float:
        """max/mean tiles per shard; 1.0 == perfectly even load."""
        total = self.n_tiles()
        if total == 0:
            return 1.0
        return max(self.shard_tiles) / (total / self.n_shards)

    def edge_cut(self) -> float:
        """Fraction of tiles whose tile-column lies outside the owning
        shard's own row block — the traffic a 2D (row×col) tiling would
        localise and the row partition pays via the operand broadcast."""
        total = self.n_tiles()
        return 0.0 if total == 0 else self.cut_tiles / total


def partition_rows(mat: Union[B2SR, B2SREll], n_shards: int,
                   with_buckets: bool = True,
                   max_buckets: int = 8) -> PartitionedB2SR:
    """Split a B2SR (or its ELL view) into ``n_shards`` row-block shards.

    Tile rows are padded to a multiple of ``n_shards`` and split into equal
    contiguous blocks; every shard's ELL slab shares the global max slab
    width. Works for any ``n_shards >= 1`` including counts that do not
    divide the tile-row axis (the last shard is ragged and padded).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    ell = mat if isinstance(mat, B2SREll) else to_ell(mat)
    t = ell.tile_dim
    if t not in TILE_DIMS:
        raise ValueError(f"tile_dim must be one of {TILE_DIMS}, got {t}")
    n_tr = ell.n_tile_rows
    rows_per_shard = max(ceil_div(n_tr, n_shards), 1)
    n_tr_pad = rows_per_shard * n_shards

    col = np.full((n_tr_pad, ell.max_tiles_per_row), -1, np.int32)
    tiles = np.zeros((n_tr_pad, ell.max_tiles_per_row, t), np.uint32)
    counts = np.zeros(n_tr_pad, np.int32)
    col[:n_tr] = np.asarray(ell.tile_col_idx)
    tiles[:n_tr] = np.asarray(ell.bit_tiles)
    counts[:n_tr] = np.asarray(ell.row_n_tiles)

    # per-shard stats: real tile counts + would-be-remote tiles (edge cut)
    shard_tiles = []
    cut = 0
    for p in range(n_shards):
        blk = slice(p * rows_per_shard, (p + 1) * rows_per_shard)
        c = col[blk]
        valid = c >= 0
        shard_tiles.append(int(valid.sum()))
        # a tile is "local" to shard p if its tile-col falls inside the
        # shard's own row block (square-matrix notion; rectangular graphs
        # count every tile as cut beyond the row range)
        local = (c >= blk.start) & (c < blk.stop)
        cut += int((valid & ~local).sum())

    buckets = _harmonised_buckets(col, tiles, counts, n_shards,
                                  rows_per_shard, t, max_buckets) \
        if with_buckets else ((), (), ())

    return PartitionedB2SR(
        tile_col_idx=jnp.asarray(
            col.reshape(n_shards, rows_per_shard, -1)),
        bit_tiles=jnp.asarray(
            tiles.reshape(n_shards, rows_per_shard, -1, t)),
        row_n_tiles=jnp.asarray(counts.reshape(n_shards, rows_per_shard)),
        bucket_col_idx=buckets[0],
        bucket_bit_tiles=buckets[1],
        bucket_rows=buckets[2],
        tile_dim=t,
        n_rows=ell.n_rows,
        n_cols=ell.n_cols,
        n_tile_rows=n_tr,
        shard_tiles=tuple(shard_tiles),
        cut_tiles=cut,
    )


def _harmonised_buckets(col: np.ndarray, tiles: np.ndarray,
                        counts: np.ndarray, n_shards: int,
                        rows_per_shard: int, t: int, max_buckets: int):
    """Per-shard SELL buckets with one global bucket structure.

    Bucket boundaries (power-of-two count ranges, merged to ``max_buckets``)
    and slab widths come from the *global* count histogram, so bucket ``b``
    means the same range and width on every shard; each bucket's slab is
    padded to the max per-shard row count, padding rows pointing at the
    garbage row ``rows_per_shard``.
    """
    nonempty = counts > 0
    if not nonempty.any():
        return (), (), ()
    bidx = np.full(counts.shape, -1, np.int64)
    bidx[nonempty] = np.ceil(np.log2(counts[nonempty])).astype(np.int64)
    uniq = np.sort(np.unique(bidx[nonempty]))
    if uniq.size > max_buckets:
        keep = uniq[: max_buckets - 1]
        hub = uniq[max_buckets - 1]
        sel = nonempty & ~np.isin(bidx, keep)
        bidx[sel] = hub
        uniq = np.sort(np.unique(bidx[nonempty]))

    cols_out, tiles_out, rows_out = [], [], []
    for b in uniq:
        per_shard = []
        k_b = 1
        for p in range(n_shards):
            lo = p * rows_per_shard
            local = np.flatnonzero(bidx[lo:lo + rows_per_shard] == b)
            per_shard.append(local)
            if local.size:
                k_b = max(k_b, int(counts[lo + local].max()))
        rb = max(max(len(ix) for ix in per_shard), 1)
        c_slab = np.full((n_shards, rb, k_b), -1, np.int32)
        t_slab = np.zeros((n_shards, rb, k_b, t), np.uint32)
        r_slab = np.full((n_shards, rb), rows_per_shard, np.int32)
        for p, local in enumerate(per_shard):
            if not local.size:
                continue
            g = p * rows_per_shard + local
            c_slab[p, : local.size] = col[g, :k_b]
            t_slab[p, : local.size] = tiles[g, :k_b]
            r_slab[p, : local.size] = local
        cols_out.append(jnp.asarray(c_slab))
        tiles_out.append(jnp.asarray(t_slab))
        rows_out.append(jnp.asarray(r_slab))
    return tuple(cols_out), tuple(tiles_out), tuple(rows_out)


def unpartition(part: PartitionedB2SR) -> B2SR:
    """Reassemble the global B2SR from the stacked shard slabs.

    The exact inverse of ``partition_rows`` for any shard count (the equal-
    block layout makes this a reshape + padding trim + CSR rebuild): tile
    order within each row is preserved, so the result is array-identical to
    the source B2SR.
    """
    t = part.tile_dim
    col = np.asarray(part.tile_col_idx).reshape(-1,
                                                part.slab_width)
    tiles = np.asarray(part.bit_tiles).reshape(-1, part.slab_width, t)
    col = col[: part.n_tile_rows]
    tiles = tiles[: part.n_tile_rows]

    valid = col >= 0
    counts = valid.sum(axis=1)
    ptr = np.zeros(part.n_tile_rows + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    tci = col[valid].astype(np.int32)
    bt = tiles[valid].astype(np.uint32)
    if bt.size == 0:
        nnz = 0
    elif hasattr(np, "bitwise_count"):
        nnz = int(np.bitwise_count(bt).sum())
    else:
        nnz = int(np.unpackbits(bt.view(np.uint8)).sum())
    return B2SR(
        tile_row_ptr=jnp.asarray(ptr.astype(np.int32)),
        tile_col_idx=jnp.asarray(tci),
        bit_tiles=jnp.asarray(bt.reshape(-1, t)),
        tile_dim=t,
        n_rows=part.n_rows,
        n_cols=part.n_cols,
        nnz=nnz,
    )


def mesh_fingerprint(mesh, axes: Tuple[str, ...]) -> Tuple:
    """Hashable identity of (mesh, shard axes) for plan-cache keys.

    Two meshes that differ in axis names, shape, or member devices must
    never share a compiled plan — the shard_map trace bakes all three in.
    """
    return (
        tuple(mesh.axis_names),
        tuple(int(s) for s in mesh.devices.shape),
        tuple(axes),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def shard_count(mesh, axes: Tuple[str, ...]) -> int:
    """Product of the mesh-axis sizes the partition shards over."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    missing = [a for a in axes if a not in sizes]
    if missing:
        raise ValueError(f"mesh has no axis {missing}; axes are "
                         f"{tuple(mesh.axis_names)}")
    p = 1
    for a in axes:
        p *= int(sizes[a])
    return p
