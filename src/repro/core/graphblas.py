"""High-level GraphBLAS matrix object: one generic operation API.

``GraphMatrix`` is what algorithms and models consume. It bundles:
  - the B2SR representation (+ optional transposed B2SR for vxm),
  - the float CSR baseline representation (the GraphBLAST stand-in),
  - padded ELL views for the static-shape TPU kernel path.

The operation surface is two generic ops (DESIGN.md §10):

  ``mxv(x, semiring, desc)``   x: dense vector | BitVector
  ``mxm(B, semiring, desc)``   B: GraphMatrix | dense matrix | FrontierBatch

The paper's Table II/III row is resolved from the operand *types* and the
semiring — a packed ``BitVector`` on the boolean semiring is the BFS
kernel, a dense vector on min-plus is SSSP, a ``FrontierBatch`` is the
multi-source engine row — and the implementation is looked up in the
central dispatch registry (``repro.core.dispatch``) keyed by
``(op, rhs, out, backend, bucketed, masked)``. Masks, complement,
input-transpose, replace semantics, and row chunking travel in one
:class:`~repro.core.descriptor.Descriptor`.

``backend`` selects the compute path:
  "b2sr"      jnp word-level bit ops (repro.core.ops)
  "b2sr_pallas"  Pallas kernels (repro.kernels, interpret on CPU)
  "csr"       float CSR baseline (repro.core.csr)

Load balancing: both b2sr backends transparently run the row-bucketed
(SELL-style) path when ``use_buckets`` is on (the default) — ``ell_buckets``
is built lazily from the ELL view on first use (DESIGN.md §2).
``row_chunk`` callers keep the single-ELL path (chunking needs one uniform
row axis).

The pre-registry per-row method names (``mxv_bool``, ``mxv_count``,
``spmm``, ``spmm_bool``, ``mxm_count``) survive as deprecation shims:
external callers get a warning and the old behavior; ``repro``-internal
call sites raise, so algorithms/ and engine/ can never quietly regress
onto them.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import b2sr as b2sr_mod
from repro.core import csr as csr_mod
from repro.core import descriptor as descriptor_mod
from repro.core import dispatch
from repro.core import partition as partition_mod
from repro.core.b2sr import (B2SR, B2SRBucketedEll, B2SREll,
                             ell_to_packed_grid, pack_bitvector)
from repro.core.descriptor import _UNSET, Descriptor
from repro.core.dispatch import OpCall, warn_deprecated
from repro.core.operands import (BitVector, FrontierBatch, check_operand,
                                 operand_kind)
from repro.core.semiring import Semiring, ARITHMETIC, BOOLEAN

BACKENDS = ("b2sr", "b2sr_pallas", "csr")


class LowerTriangle:
    """Memoized strict-lower-triangle operands (tri_count's L / Lᵀ pair).

    The COO split is done eagerly (cheap numpy); the B2SR/ELL builds and
    the bucketed view are lazy, so the CSR backend never pays for packing
    it never reads. Cached on the owning ``GraphMatrix`` (the
    ``degrees_cache`` pattern) — repeated ``tri_count`` calls stop
    rebuilding L host-side on every call.
    """

    def __init__(self, csr: csr_mod.CSRMatrix, tile_dim: int, n: int):
        rows = np.asarray(csr.row_idx)
        cols = np.asarray(csr.col_idx)
        keep = rows > cols
        self.rows, self.cols = rows[keep], cols[keep]
        self._tile_dim = tile_dim
        self._n = n
        self._ell: Optional[B2SREll] = None
        self._ell_t: Optional[B2SREll] = None
        self._buckets: Optional[B2SRBucketedEll] = None
        self._parts: dict = {}          # n_shards -> PartitionedB2SR of L

    @property
    def ell(self) -> B2SREll:
        if self._ell is None:
            m = b2sr_mod.coo_to_b2sr(self.rows, self.cols, self._n, self._n,
                                     self._tile_dim)
            self._ell = b2sr_mod.to_ell(m)
            self._ell_t = b2sr_mod.to_ell(b2sr_mod.transpose(m))
        return self._ell

    @property
    def ell_t(self) -> B2SREll:
        self.ell
        return self._ell_t

    def buckets(self) -> B2SRBucketedEll:
        if self._buckets is None:
            self._buckets = b2sr_mod.to_bucketed(self.ell)
        return self._buckets

    def partitioned(self, n_shards: int) -> "partition_mod.PartitionedB2SR":
        """L row-partitioned for the sharded mxm_sum row (memoized per
        shard count, like the ELL pair)."""
        if n_shards not in self._parts:
            self._parts[n_shards] = partition_mod.partition_rows(
                self.ell, n_shards, with_buckets=False)
        return self._parts[n_shards]


@dataclasses.dataclass
class GraphMatrix:
    """An immutable homogeneous-graph adjacency matrix, multi-format."""

    n_rows: int
    n_cols: int
    nnz: int
    tile_dim: int
    ell: B2SREll
    ell_t: Optional[B2SREll]          # transpose, for vxm / pull traversal
    csr: csr_mod.CSRMatrix
    csr_t: Optional[csr_mod.CSRMatrix]
    backend: str = "b2sr"
    # row-bucketed (SELL-style) views, built lazily from ell/ell_t; the
    # default compute path on the b2sr backends when ``use_buckets`` is on
    ell_buckets: Optional[B2SRBucketedEll] = None
    ell_buckets_t: Optional[B2SRBucketedEll] = None
    use_buckets: bool = True
    # lazy caches (same pattern as ell_buckets): the out-degree vector, the
    # transposed view, the structure fingerprint used by engine/planner,
    # and tri_count's strict-lower-triangle operand pair
    degrees_cache: Optional[jax.Array] = None
    transposed_cache: Optional["GraphMatrix"] = None
    fingerprint_cache: Optional[str] = None
    tri_cache: Optional[LowerTriangle] = None
    # scale-out state (``shard(mesh)``, DESIGN.md §11): the mesh + axes the
    # graph is row-partitioned over and the stacked per-shard slabs for the
    # forward / transposed orientation; every op dispatches to the
    # shard_map rows while these are set
    mesh: Optional[object] = None
    shard_axes: Optional[tuple] = None
    partitioned: Optional["partition_mod.PartitionedB2SR"] = None
    partitioned_t: Optional["partition_mod.PartitionedB2SR"] = None
    # comm layout for the sharded rows (DESIGN.md §16): "gather" replicates
    # operands + all-gathers outputs; "exchange" moves only the column
    # words each shard's slab touches over a static ppermute ring. The
    # ExchangePlans hold device arrays, so they live here (mutable holder)
    # rather than on the frozen partition pytree.
    comm: str = "gather"
    xplan: Optional["partition_mod.ExchangePlan"] = None
    xplan_t: Optional["partition_mod.ExchangePlan"] = None

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_coo(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int,
                 tile_dim: int = 32, with_transpose: bool = True,
                 backend: str = "b2sr",
                 max_tiles_per_row: Optional[int] = None) -> "GraphMatrix":
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        mat = b2sr_mod.coo_to_b2sr(rows, cols, n_rows, n_cols, tile_dim)
        ell = b2sr_mod.to_ell(mat, max_tiles_per_row)
        ell_t = None
        csr_t = None
        if with_transpose:
            mt = b2sr_mod.transpose(mat)
            ell_t = b2sr_mod.to_ell(mt, max_tiles_per_row)
            csr_t = csr_mod.from_coo(cols, rows, n_cols, n_rows)
        return GraphMatrix(
            n_rows=n_rows, n_cols=n_cols, nnz=mat.nnz, tile_dim=tile_dim,
            ell=ell, ell_t=ell_t,
            csr=csr_mod.from_coo(rows, cols, n_rows, n_cols), csr_t=csr_t,
            backend=backend,
        )

    @staticmethod
    def from_dense(mat: np.ndarray, tile_dim: int = 32, **kw) -> "GraphMatrix":
        rows, cols = np.nonzero(np.asarray(mat))
        return GraphMatrix.from_coo(rows, cols, mat.shape[0], mat.shape[1],
                                    tile_dim, **kw)

    @staticmethod
    def from_b2sr(mat: B2SR, with_transpose: bool = True,
                  backend: str = "b2sr",
                  max_tiles_per_row: Optional[int] = None) -> "GraphMatrix":
        """Wrap an already-built B2SR (e.g. an mxm output) without re-packing.

        The CSR twin is derived from the same tiles (one unpack), not by a
        second COO -> B2SR conversion.
        """
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        rows, cols = b2sr_mod.b2sr_to_coo(mat)
        ell = b2sr_mod.to_ell(mat, max_tiles_per_row)
        ell_t = None
        csr_t = None
        if with_transpose:
            mt = b2sr_mod.transpose(mat)
            ell_t = b2sr_mod.to_ell(mt, max_tiles_per_row)
            csr_t = csr_mod.from_coo(cols, rows, mat.n_cols, mat.n_rows)
        return GraphMatrix(
            n_rows=mat.n_rows, n_cols=mat.n_cols, nnz=mat.nnz,
            tile_dim=mat.tile_dim, ell=ell, ell_t=ell_t,
            csr=csr_mod.from_coo(rows, cols, mat.n_rows, mat.n_cols),
            csr_t=csr_t, backend=backend,
        )

    def with_backend(self, backend: str) -> "GraphMatrix":
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        if backend == "csr" and self.sharded:
            raise ValueError("the csr baseline has no sharded rows; call "
                             "unshard() before with_backend('csr')")
        # the cached transpose carries the old backend; drop it (degrees,
        # the structure fingerprint, and the lower-triangle operands are
        # backend-independent and survive)
        return dataclasses.replace(self, backend=backend,
                                   transposed_cache=None)

    def with_buckets(self, use_buckets: bool) -> "GraphMatrix":
        """Toggle the bucketed (SELL-style) compute path on the b2sr backends."""
        return dataclasses.replace(self, use_buckets=use_buckets,
                                   transposed_cache=None)

    def transposed(self) -> "GraphMatrix":
        """Aᵀ as a view: swap the stored forward/transposed representations.

        Memoized (like ``ell_buckets``): repeated PageRank/PPR/vxm calls on
        the same graph reuse one transposed view instead of rebuilding it —
        and the view's back-reference makes ``transposed()`` an involution.
        """
        if self.transposed_cache is not None:
            return self.transposed_cache
        if self.ell_t is None:
            raise ValueError("GraphMatrix built without transpose "
                             "(with_transpose=True)")
        # build (and cache on *self*) the transpose's bucketed view before
        # swapping, so the cached view shares it with this instance
        if (self.use_buckets and self.backend != "csr"
                and self.ell_buckets_t is None):
            self.ell_buckets_t = b2sr_mod.to_bucketed(self.ell_t)
        gt = dataclasses.replace(
            self, ell=self.ell_t, ell_t=self.ell, csr=self.csr_t,
            csr_t=self.csr, ell_buckets=self.ell_buckets_t,
            ell_buckets_t=self.ell_buckets, n_rows=self.n_cols,
            n_cols=self.n_rows, degrees_cache=None, transposed_cache=self,
            fingerprint_cache=None, tri_cache=None,
            partitioned=self.partitioned_t, partitioned_t=self.partitioned,
            xplan=self.xplan_t, xplan_t=self.xplan)
        self.transposed_cache = gt
        return gt

    def buckets(self) -> B2SRBucketedEll:
        """The bucketed view of ``ell``, built lazily and cached."""
        if self.ell_buckets is None:
            self.ell_buckets = b2sr_mod.to_bucketed(self.ell)
        return self.ell_buckets

    def _bucketed(self, row_chunk: Optional[int] = None) -> bool:
        """Whether this op dispatches to the bucketed path."""
        return self.use_buckets and row_chunk is None

    # -- scale-out: row-partitioned multi-device execution (DESIGN.md §11) --
    @property
    def sharded(self) -> bool:
        return self.partitioned is not None

    def shard(self, mesh, axes: Optional[Sequence[str]] = None,
              max_buckets: int = 8, combine: str = "gather",
              balanced: bool = True) -> "GraphMatrix":
        """Row-partition this graph across ``mesh`` (scale-out entry point).

        Returns a new ``GraphMatrix`` whose every operation — and hence
        every algorithm and engine query built on it — executes under
        ``jax.shard_map``: shard ``p`` owns a contiguous block of tile
        rows, split nnz-balanced over the per-tile-row word counts
        (``balanced=False`` restores the v1 equal blocks). Results are
        bit-exact against the unsharded twin; no call site changes.

        ``combine`` picks the collective layout (DESIGN.md §16):
        ``"gather"`` replicates operands and all-gathers the padded row
        blocks every op; ``"exchange"`` precomputes which column words
        each shard's slab touches and moves only those (plus the owned
        output words) over a static ``ppermute`` ring — the
        communication-avoiding mode for iterative mxv/spmm. Exchange
        needs a single shard axis (``ppermute`` rings are 1-D); rows
        without an exchange layout (graph SpGEMM, tri_count) stay on
        gather/psum transparently.

        ``axes`` selects the mesh axes to shard over (default: all of
        them); their size product is the shard count. Both orientations
        are partitioned so ``transposed()`` (BFS/PR pull direction) stays
        sharded too.
        """
        if self.backend == "csr":
            raise ValueError("the csr baseline has no sharded rows; shard "
                             "the b2sr or b2sr_pallas backend")
        if combine not in ("gather", "exchange"):
            raise ValueError(f"combine must be 'gather' or 'exchange', "
                             f"got {combine!r}")
        axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        if combine == "exchange" and len(axes) != 1:
            raise ValueError("combine='exchange' runs a single-axis "
                             "ppermute ring; shard over exactly one mesh "
                             f"axis (got {axes})")
        n_shards = partition_mod.shard_count(mesh, axes)
        # bucket slabs only when the bucketed path is on: the sharded rows
        # fall back to the ELL slab if a later with_buckets(True) finds a
        # partition without them (correct, just not SELL-balanced — reshard
        # to get harmonised buckets back)
        part = partition_mod.partition_rows(self.ell, n_shards,
                                            with_buckets=self.use_buckets,
                                            max_buckets=max_buckets,
                                            balanced=balanced)
        part_t = None
        if self.ell_t is not None:
            part_t = partition_mod.partition_rows(
                self.ell_t, n_shards, with_buckets=self.use_buckets,
                max_buckets=max_buckets, balanced=balanced)
        xplan = xplan_t = None
        if combine == "exchange":
            xplan = partition_mod.build_exchange_plan(part)
            if part_t is not None:
                xplan_t = partition_mod.build_exchange_plan(part_t)
        self._publish_partition_quality(part, part_t, n_shards)
        return dataclasses.replace(
            self, mesh=mesh, shard_axes=axes, partitioned=part,
            partitioned_t=part_t, comm=combine, xplan=xplan,
            xplan_t=xplan_t, transposed_cache=None)

    @staticmethod
    def _publish_partition_quality(part, part_t, n_shards: int) -> None:
        """Partition-quality gauges (ISSUE 10 satellite): one point per
        ``shard()`` call, labelled by orientation and shard count."""
        from repro.obs import metrics as obs_metrics
        if not obs_metrics.enabled():
            return
        reg = obs_metrics.get_registry()
        labels = ("orientation", "shards")
        bal = reg.gauge("partition_balance",
                        "max/mean per-shard tile load of the row partition",
                        labels)
        cut = reg.gauge("partition_edge_cut",
                        "fraction of tiles whose column block lives on "
                        "another shard", labels)
        for orient, p in (("forward", part), ("transpose", part_t)):
            if p is None:
                continue
            bal.set(p.balance(), orientation=orient, shards=n_shards)
            cut.set(p.edge_cut(), orientation=orient, shards=n_shards)

    def unshard(self) -> "GraphMatrix":
        """Back to single-device execution (drops the partition, keeps all
        single-device representations — they were never removed)."""
        return dataclasses.replace(
            self, mesh=None, shard_axes=None, partitioned=None,
            partitioned_t=None, comm="gather", xplan=None, xplan_t=None,
            transposed_cache=None)

    # -- packed-vector helpers ---------------------------------------------
    def pack(self, x: jax.Array) -> jax.Array:
        """Binarize + bit-pack a column-space vector (paper §IV, Listing 1).

        Returns raw uint32 words; ``BitVector.pack`` wraps the same layout
        in the typed operand the generic API consumes.
        """
        return pack_bitvector(x, self.tile_dim, self.n_cols)

    def pack_rows(self, x: jax.Array) -> jax.Array:
        """Binarize + bit-pack a row-space vector (output/frontier side)."""
        return pack_bitvector(x, self.tile_dim, self.n_rows)

    # -- the generic operations (DESIGN.md §10) -----------------------------
    def mxv(self, x, semiring: Optional[Semiring] = None,
            desc: Optional[Descriptor] = None, *, a_value: float = 1.0,
            out_dtype=None, out=None, mask=_UNSET, complement=_UNSET,
            row_chunk=_UNSET):
        """y = A ⊕.⊗ x — the generic matrix-vector product (paper Table II).

        The table row is resolved from the operand type and the semiring:

          dense ``x``           bin·full→full (any Table IV semiring;
                                SSSP / PageRank / CC)
          ``BitVector`` x,      bin·bin→bin — packed frontier traversal
          boolean semiring      (the BFS kernel); returns a ``BitVector``
          ``BitVector`` x,      bin·bin→full — neighbour counts
          other semiring        y_i = |N(i) ∩ frontier|

        ``semiring`` defaults to boolean for packed operands and arithmetic
        for dense ones. ``desc`` carries mask / complement / transpose /
        replace / row_chunk (``mask=``/``complement=``/``row_chunk=`` are
        accepted as one-off sugar); with ``desc.replace=False`` the
        masked-out output entries are taken from ``out``.
        """
        desc = descriptor_mod.merge_sugar(desc, mask, complement, row_chunk)
        if self.sharded:
            dispatch.reject_sharded_row_chunk("mxv", desc.row_chunk)
        if desc.transpose_a:
            return self.transposed().mxv(
                x, semiring, desc.replace_with(transpose_a=False),
                a_value=a_value, out_dtype=out_dtype, out=out)
        kind = operand_kind(x)
        if kind not in ("dense", "bitvec"):
            raise TypeError(f"mxv right-hand side must be a dense vector or "
                            f"BitVector, got {type(x).__name__}; use mxm "
                            f"for FrontierBatch/GraphMatrix operands")
        if kind == "bitvec":
            check_operand(x, self.tile_dim, self.n_cols, "x")
        semiring = semiring if semiring is not None else (
            BOOLEAN if kind == "bitvec" else ARITHMETIC)
        dispatch.check_semiring("mxv", kind, semiring)
        out_kind = dispatch.out_kind_for(semiring, kind)
        call = OpCall(
            semiring=semiring,
            mask=self._norm_mask(desc.mask, kind, out_kind),
            complement=desc.complement, row_chunk=desc.row_chunk,
            a_value=a_value,
            out_dtype=out_dtype if out_dtype is not None else jnp.float32)
        op = self._direction_op("mxv", desc, kind, "bitvec", out_kind,
                                call.mask is not None)
        impl = dispatch.resolve(op, kind, out_kind, self.backend,
                                self._bucketed(desc.row_chunk),
                                call.mask is not None, self.sharded)
        y = impl(self, x.words if kind == "bitvec" else x, call)
        if out_kind == "bin":
            y = BitVector.from_words(y, self.n_rows, self.tile_dim)
        return self._merge_unreplaced(y, desc, out, out_kind, call)

    def vxm(self, x, semiring: Optional[Semiring] = None,
            desc: Optional[Descriptor] = None, *, mask=_UNSET,
            complement=_UNSET, row_chunk=_UNSET, **kw):
        """xᵀ·A, pull direction (Table II via Aᵀ): ``mxv`` with the
        descriptor's input transpose — uses the stored transpose. Accepts
        the same ``mask=``/``complement=``/``row_chunk=`` sugar as mxv."""
        desc = descriptor_mod.merge_sugar(desc, mask, complement, row_chunk)
        return self.mxv(x, semiring, desc.replace_with(transpose_a=True),
                        **kw)

    def mxm(self, other=None, semiring: Optional[Semiring] = None,
            desc: Optional[Descriptor] = None, *, out=None,
            with_transpose: bool = True, out_dtype=None, mask=_UNSET,
            complement=_UNSET, row_chunk=_UNSET):
        """C⟨M⟩ = A ⊕.⊗ B — the generic matrix product (paper Table III).

        The table row is resolved from the operand type and the semiring:

          ``GraphMatrix`` B,    bin·bin→bin boolean SpGEMM; the packed
          boolean semiring      output grid is recompressed host-side into
                                a full ``GraphMatrix`` (``other`` defaults
                                to ``self``: A², 2-hop reachability)
          ``GraphMatrix`` B,    bin·bin→full count SpGEMM: dense
          other semiring        common-neighbour counts (TC / k-truss)
          dense matrix B        bin·full→full widened: Y = A @ X over
                                features (the GNN hot path)
          ``FrontierBatch`` B   bin·bin→bin widened: one traversal for S
                                packed frontiers (the engine/ hot path);
                                returns a ``FrontierBatch``
          ``BitMatrix`` B       bin·bin→full: popcount-accumulated dense
                                counts over packed binarized activations
                                (the fully-binarized BitGNN layer;
                                DESIGN.md §15) — arithmetic semiring only

        ``semiring`` defaults to boolean for packed/graph operands and
        arithmetic for dense ones. Masks are structural and applied right
        before the store (paper §V); ``desc.replace=False`` merges
        masked-out entries from ``out``.
        """
        desc = descriptor_mod.merge_sugar(desc, mask, complement, row_chunk)
        if self.sharded:
            dispatch.reject_sharded_row_chunk("mxm", desc.row_chunk)
        if desc.transpose_a:
            return self.transposed().mxm(
                other, semiring, desc.replace_with(transpose_a=False),
                out=out, with_transpose=with_transpose, out_dtype=out_dtype)
        other = self if other is None else other
        kind = operand_kind(other)
        if kind == "bitvec":
            raise TypeError("mxm right-hand side is a BitVector; use mxv "
                            "for packed vector operands")
        semiring = semiring if semiring is not None else (
            ARITHMETIC if kind in ("dense", "bitmat") else BOOLEAN)
        dispatch.check_semiring("mxm", kind, semiring)
        out_kind = dispatch.out_kind_for(semiring, kind)
        if kind == "graph":
            if self.n_cols != other.n_rows:
                raise ValueError(f"inner-dim mismatch: {self.n_cols} vs "
                                 f"{other.n_rows}")
            if self.backend != "csr" and self.tile_dim != other.tile_dim:
                raise ValueError(f"tile_dim mismatch: {self.tile_dim} vs "
                                 f"{other.tile_dim}")
        elif kind in ("frontier", "bitmat"):
            check_operand(other, self.tile_dim, self.n_cols, "B")
        norm_mask = self._norm_mask(desc.mask, kind, out_kind, other=other)
        if (kind in ("dense", "bitmat") and norm_mask is not None
                and norm_mask.ndim == 1):
            # a vector mask over the [n_rows, d] feature output masks rows
            norm_mask = norm_mask[:, None]
        call = OpCall(
            semiring=semiring, mask=norm_mask,
            complement=desc.complement, row_chunk=desc.row_chunk,
            out_dtype=out_dtype)
        op = self._direction_op("mxm", desc, kind, "frontier", out_kind,
                                call.mask is not None)
        impl = dispatch.resolve(op, kind, out_kind, self.backend,
                                self._bucketed(desc.row_chunk),
                                call.mask is not None, self.sharded)
        y = impl(self, other.words if kind in ("frontier", "bitmat")
                 else other, call)
        if kind == "graph" and out_kind == "bin":
            return self._grid_to_graph(y, other, desc, out, with_transpose)
        if kind == "frontier":
            y = FrontierBatch.from_words(y, self.n_rows, other.n_sources,
                                         self.tile_dim)
        return self._merge_unreplaced(y, desc, out, out_kind, call)

    def tri_count(self, row_chunk: Optional[int] = None) -> jax.Array:
        """Σ (L·Lᵀ ⊙ L) where L = strict lower triangle of this matrix.

        The fused masked reduction (paper §V, Listing 2 — Azad-Buluç as in
        GraphBLAST), dispatched as the ``mxm_sum`` registry op: the b2sr
        backend runs the masked count SpGEMM + sum, the Pallas backend the
        fully-fused BMM kernel, the CSR baseline a dense masked matmul.
        The L / Lᵀ operand pair is built once and memoized
        (:class:`LowerTriangle`, the ``degrees_cache`` pattern).
        """
        if self.sharded:
            dispatch.reject_sharded_row_chunk("mxm_sum", row_chunk)
        if self.tri_cache is None:
            self.tri_cache = LowerTriangle(self.csr, self.tile_dim,
                                           self.n_rows)
        call = OpCall(semiring=ARITHMETIC, row_chunk=row_chunk)
        impl = dispatch.resolve("mxm_sum", "tri", "full", self.backend,
                                self._bucketed(row_chunk), True,
                                self.sharded)
        return impl(self, self.tri_cache, call)

    # -- generic-layer helpers ---------------------------------------------
    @staticmethod
    def _direction_op(base: str, desc: Descriptor, kind: str,
                      pull_kind: str, out_kind: str, masked: bool) -> str:
        """Resolve ``desc.direction`` to the registry op name.

        ``direction="pull"`` selects the fused pull row (DESIGN.md §12),
        which exists only for the masked packed traversal — the
        bin·bin→bin ``pull_kind`` operand with a §V visited mask. Any
        other shape has no pull semantics and is rejected here so a typo
        never silently runs push.
        """
        if desc.direction is None:
            return base
        if desc.direction != "pull":
            raise ValueError(f"unknown descriptor direction "
                             f"{desc.direction!r}; expected None or 'pull'")
        if kind != pull_kind or out_kind != "bin" or not masked:
            raise ValueError(
                f"direction='pull' applies only to the masked packed "
                f"traversal row ({base} over a {pull_kind} operand on the "
                f"boolean semiring with a visited mask); got rhs={kind} "
                f"out={out_kind} masked={masked}")
        return base + "_pull"

    def _norm_mask(self, mask, rhs_kind: str, out_kind: str,
                   other: Optional["GraphMatrix"] = None):
        """Validate the descriptor mask and strip it to the row's raw form.

        Packed outputs take packed masks (words), SpGEMM takes a structural
        ``GraphMatrix`` mask, dense outputs take dense masks (a
        ``BitVector`` is unpacked as a convenience).
        """
        if mask is None:
            return None
        if rhs_kind == "graph":
            if operand_kind(mask) != "graph":
                raise TypeError("mxm over GraphMatrix operands takes a "
                                "structural GraphMatrix mask")
            if (mask.n_rows != self.n_rows
                    or mask.n_cols != other.n_cols):
                raise ValueError("mask shape must match the output")
            if (out_kind == "bin" and self.backend != "csr"
                    and mask.tile_dim != self.tile_dim):
                raise ValueError(f"mask tile_dim mismatch: {mask.tile_dim} "
                                 f"vs {self.tile_dim}")
            return mask
        if out_kind == "bin":
            if rhs_kind == "bitvec":
                if not isinstance(mask, BitVector):
                    raise TypeError("packed mxv takes a BitVector mask")
                check_operand(mask, self.tile_dim, self.n_rows, "mask")
            else:  # frontier
                if not isinstance(mask, FrontierBatch):
                    raise TypeError("frontier mxm takes a FrontierBatch mask")
                check_operand(mask, self.tile_dim, self.n_rows, "mask")
            return mask.words
        if isinstance(mask, BitVector):
            check_operand(mask, self.tile_dim, self.n_rows, "mask")
            return mask.unpack(jnp.bool_)
        return mask

    def _merge_unreplaced(self, y, desc: Descriptor, out, out_kind: str,
                          call: OpCall):
        """Apply ``desc.replace=False``: masked-out entries come from ``out``.

        With ``replace=True`` (the default, the paper's mask-at-store) the
        registered impl already stored the ⊕-identity there and ``y`` is
        returned as-is.
        """
        if desc.replace or desc.mask is None:
            return y
        if out is None:
            raise ValueError("desc.replace=False needs the previous output "
                             "(out=) to merge masked-out entries from")
        if out_kind == "bin":
            m = call.mask if not desc.complement else ~call.mask
            merged = (y.words & m) | (out.words & ~m)
            return y._like(merged)
        keep = ((call.mask == 0) if desc.complement else (call.mask != 0))
        return jnp.where(keep, y, out)

    def _grid_to_graph(self, grid, other: "GraphMatrix", desc: Descriptor,
                       out, with_transpose: bool) -> "GraphMatrix":
        """Recompress a packed SpGEMM output grid into a ``GraphMatrix``."""
        if not desc.replace and desc.mask is not None:
            if out is None:
                raise ValueError("desc.replace=False needs the previous "
                                 "output (out=) to merge masked-out entries "
                                 "from")
            mg = ell_to_packed_grid(desc.mask.ell)
            m = ~mg if desc.complement else mg
            grid = (jnp.asarray(grid) & m) | (ell_to_packed_grid(out.ell) & ~m)
        mat = b2sr_mod.packed_grid_to_b2sr(np.asarray(grid), self.n_rows,
                                           other.n_cols)
        return GraphMatrix.from_b2sr(mat, with_transpose=with_transpose,
                                     backend=self.backend)

    # -- legacy per-row method names (deprecation shims) --------------------
    def mxv_bool(self, x_packed: jax.Array,
                 mask_packed: Optional[jax.Array] = None,
                 complement: bool = True,
                 row_chunk: Optional[int] = None) -> jax.Array:
        """Deprecated: ``mxv`` with a ``BitVector`` operand (boolean row)."""
        warn_deprecated("mxv_bool", "mxv(BitVector, desc=Descriptor(...))")
        m = (None if mask_packed is None else
             BitVector.from_words(mask_packed, self.n_rows, self.tile_dim))
        y = self.mxv(BitVector.from_words(x_packed, self.n_cols,
                                          self.tile_dim),
                     BOOLEAN, Descriptor(mask=m, complement=complement,
                                         row_chunk=row_chunk))
        return y.words

    def mxv_count(self, x_packed: jax.Array, out_dtype=jnp.float32,
                  row_chunk: Optional[int] = None) -> jax.Array:
        """Deprecated: ``mxv`` with a ``BitVector`` operand on arithmetic."""
        warn_deprecated("mxv_count",
                        "mxv(BitVector, ARITHMETIC, out_dtype=...)")
        return self.mxv(BitVector.from_words(x_packed, self.n_cols,
                                             self.tile_dim),
                        ARITHMETIC, Descriptor(row_chunk=row_chunk),
                        out_dtype=out_dtype)

    def spmm(self, x: jax.Array,
             row_chunk: Optional[int] = None) -> jax.Array:
        """Deprecated: ``mxm`` with a dense feature-matrix operand."""
        warn_deprecated("spmm", "mxm(X)")
        return self.mxm(x, ARITHMETIC, Descriptor(row_chunk=row_chunk))

    def spmm_bool(self, f_packed: jax.Array,
                  mask_packed: Optional[jax.Array] = None,
                  complement: bool = True,
                  row_chunk: Optional[int] = None) -> jax.Array:
        """Deprecated: ``mxm`` with a ``FrontierBatch`` operand."""
        warn_deprecated("spmm_bool",
                        "mxm(FrontierBatch, desc=Descriptor(...))")
        s_pad = int(f_packed.shape[2]) * b2sr_mod.SOURCE_WORD_BITS
        m = (None if mask_packed is None else
             FrontierBatch.from_words(mask_packed, self.n_rows, s_pad,
                                      self.tile_dim))
        y = self.mxm(FrontierBatch.from_words(f_packed, self.n_cols, s_pad,
                                              self.tile_dim),
                     BOOLEAN, Descriptor(mask=m, complement=complement,
                                         row_chunk=row_chunk))
        return y.words

    def mxm_count(self, other: Optional["GraphMatrix"] = None,
                  mask: Optional["GraphMatrix"] = None,
                  complement: bool = False,
                  row_chunk: Optional[int] = None) -> jax.Array:
        """Deprecated: ``mxm`` with a GraphMatrix operand on arithmetic."""
        warn_deprecated("mxm_count", "mxm(B, ARITHMETIC, desc=...)")
        return self.mxm(other, ARITHMETIC,
                        Descriptor(mask=mask, complement=complement,
                                   row_chunk=row_chunk))

    # -- batched query entry points (dispatch through engine/) ---------------
    def msbfs(self, sources: Sequence[int], max_iters: Optional[int] = None,
              direction=None):
        """Multi-source BFS: per-source hop levels ``int32[n, S]``.

        One wide frontier-matrix traversal for the whole batch (engine/
        queries, plan-cached) — column ``s`` is bit-exact against
        ``algorithms.bfs(g, sources[s])`` for every ``direction`` mode
        (``"push"``/``"pull"``/``"auto"``; default auto).
        """
        from repro.engine import queries
        return queries.msbfs(self, sources, max_iters=max_iters,
                             direction=direction)

    def ppr(self, seeds: Sequence[int], alpha: float = 0.85,
            max_iters: int = 10, eps: float = 1e-9):
        """Batched personalized PageRank: per-seed ranks ``f32[n, S]``."""
        from repro.engine import queries
        return queries.batched_ppr(self, seeds, alpha=alpha,
                                   max_iters=max_iters, eps=eps)

    # -- storage -------------------------------------------------------------
    def degrees(self) -> jax.Array:
        """Out-degree vector from the CSR twin (row_ptr diff); memoized."""
        if self.degrees_cache is None:
            ptr = self.csr.row_ptr
            self.degrees_cache = (ptr[1:] - ptr[:-1]).astype(jnp.float32)
        return self.degrees_cache

    def fingerprint(self) -> str:
        """Content hash of the graph structure (the plan-cache key component).

        Hashes the ELL tile layout + bit tiles once per instance (memoized;
        backend/bucket toggles keep it — they are separate plan-key fields).
        """
        if self.fingerprint_cache is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(f"{self.n_rows}:{self.n_cols}:{self.nnz}:"
                     f"{self.tile_dim}".encode())
            h.update(np.asarray(self.ell.tile_col_idx).tobytes())
            h.update(np.asarray(self.ell.bit_tiles).tobytes())
            self.fingerprint_cache = h.hexdigest()
        return self.fingerprint_cache
