"""GNN zoo: gcn (B2SR-integrated), gatedgcn, egnn, graphcast."""

from repro.models.gnn.common import GraphBatch  # noqa: F401
