"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; writes per-table JSON into
results/. Roofline rows (from dry-run artifacts, if present) are appended.

  python -m benchmarks.run                 # everything
  python -m benchmarks.run --only fig6     # substring filter
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
import traceback


def _run_manifest() -> dict:
    """Provenance for one harness invocation: code identity + environment.

    Written next to the per-table results JSONs so a results directory is
    self-describing — which commit produced it, on what device set, with
    which env toggles, and how long each suite took.
    """
    m: dict = {"started_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
               "argv": sys.argv[1:]}
    try:
        m["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except Exception:                        # noqa: BLE001 — provenance only
        m["git_sha"] = None
    m["env"] = {k: os.environ.get(k) for k in
                ("JAX_PLATFORMS", "REPRO_PALLAS_INTERPRET", "PYTHONPATH")}
    try:
        import jax

        from repro.core import dispatch
        m["jax_devices"] = [str(d) for d in jax.devices()]
        backends = {}
        for b in ("csr", "b2sr", "b2sr_pallas"):
            try:
                dispatch._ensure_backend(b)
                backends[b] = True
            except Exception as e:           # noqa: BLE001 — availability probe
                backends[b] = f"unavailable: {e!r}"
        m["backends"] = backends
    except Exception as e:                   # noqa: BLE001 — provenance only
        m["jax_devices"] = f"unavailable: {e!r}"
    return m


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on table name")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-pass sizes (CI); suites that support it only")
    ap.add_argument("--combine", nargs="+", choices=("gather", "exchange"),
                    default=("gather", "exchange"),
                    help="collective layouts for the scaling suite")
    ap.add_argument("--assert-scaling", action="store_true",
                    help="scaling suite: fail on regression-gate violation")
    args = ap.parse_args()

    from benchmarks import (compression, engine_batch, gnn_bit,
                            graph_algorithms, kernels_bmm, kernels_bmv,
                            kernels_bucketed, kernels_spgemm,
                            sampling_profile, scaling_shards, serving_slo,
                            traversal_direction, triangle_counting)
    suites = [
        ("tableI+fig5 compression", compression.run),
        ("fig6a-c bmv", kernels_bmv.run),
        ("fig6d bmm", kernels_bmm.run),
        ("fig8 spgemm", kernels_spgemm.run),
        ("loadbalance bucketed", lambda: kernels_bucketed.run(tiny=args.tiny)),
        ("engine batched queries", lambda: engine_batch.run(tiny=args.tiny)),
        ("serving slo", lambda: serving_slo.run(tiny=args.tiny)),
        ("scaling sharded", lambda: scaling_shards.run(
            tiny=args.tiny, combines=tuple(args.combine),
            assert_scaling=args.assert_scaling)),
        ("direction traversal",
         lambda: traversal_direction.run(tiny=args.tiny)),
        ("gnn bit aggregation", lambda: gnn_bit.run(tiny=args.tiny)),
        ("tableVII/VIII algorithms", graph_algorithms.run),
        ("tableIX tc", triangle_counting.run),
        ("alg1 sampling", sampling_profile.run),
    ]
    manifest = _run_manifest()
    manifest["suites"] = {}
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            for row in fn():
                print(row.csv())
            status = "ok"
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
            status = repr(e)
        manifest["suites"][name] = {
            "wall_s": time.perf_counter() - t0, "status": status}

    # roofline rows (non-fatal if dry-run artifacts are absent)
    if not args.only or "roofline" in args.only:
        try:
            from benchmarks import roofline
            for r in roofline.run():
                print(f"roofline/{r['arch']}/{r['shape']},0.0,"
                      f"dominant={r['dominant']} frac={r['roofline_fraction']:.3f}")
        except Exception as e:
            print(f"roofline skipped: {e!r}", file=sys.stderr)

    manifest["total_wall_s"] = sum(s["wall_s"]
                                   for s in manifest["suites"].values())
    from benchmarks.common import save_json
    print(f"manifest: {save_json('run_manifest.json', manifest)}",
          file=sys.stderr)

    if failures:
        for name, err in failures:
            print(f"FAILED suite {name}: {err}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
