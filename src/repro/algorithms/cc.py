"""Connected components, FastSV-style linear-algebra formulation (paper §V).

min-plus label propagation with pointer jumping (the FastSV "stochastic
hooking + shortcutting" collapsed to its min-label core, as in the
GraphBLAST implementation the paper follows): every vertex repeatedly takes
the minimum label among {itself, its neighbors' labels}, then shortcuts
through its parent. Converges in O(log n) iterations on typical graphs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.semiring import MIN_PLUS


@dataclasses.dataclass
class CCResult:
    labels: jax.Array       # int32[n]: representative (min vertex id) per component
    n_iterations: int


def connected_components(g: GraphMatrix, max_iters: Optional[int] = None,
                         row_chunk: Optional[int] = None) -> CCResult:
    n = g.n_rows
    max_iters = n if max_iters is None else max_iters
    f0 = jnp.arange(n, dtype=jnp.float32)

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        f, _, it = state
        # hook: min over neighbors' labels (a_value=0 ⇒ pure min of f_j)
        neigh = g.mxv(f, MIN_PLUS, Descriptor(row_chunk=row_chunk),
                      a_value=0.0)
        f_new = jnp.minimum(f, neigh)
        # shortcut: pointer jumping f[i] <- f[f[i]]
        f_new = f_new[f_new.astype(jnp.int32)]
        return f_new, jnp.any(f_new != f), it + 1

    f, _, it = jax.lax.while_loop(cond, body, (f0, jnp.bool_(True),
                                               jnp.int32(0)))
    return CCResult(labels=f.astype(jnp.int32), n_iterations=int(it))
