"""Sharded B2SR tests (ISSUE 5, DESIGN.md §11).

Host-side partition/unpartition round-trips and stats run in-process (they
never touch a mesh). The execution-parity half — every sharded Table row
bit-exact against its single-device twin, descriptors, plan-cache mesh
isolation, and whole algorithms through ``GraphMatrix.shard`` with zero
call-site changes — needs >1 device, so it runs in a subprocess with 8
forced host devices (the dry-run-only rule for device forcing), using
``launch.mesh.make_debug_mesh`` as the mesh factory.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import partition as pm
from repro.core.b2sr import b2sr_to_dense, coo_to_b2sr, to_ell

TILE_DIMS = (4, 8, 16, 32)
SHARD_COUNTS = (1, 2, 3, 8)        # 3 and 8 leave a ragged last shard


def rand_coo(n, seed=0, density=0.08, skew_hubs=2, hub_deg=None):
    rng = np.random.default_rng(seed)
    m = int(n * n * density)
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    if skew_hubs:
        hd = hub_deg or 4 * max(int(n * density), 1)
        hubs = rng.choice(n, skew_hubs, replace=False)
        rows = np.concatenate([rows, np.repeat(hubs, hd)])
        cols = np.concatenate([cols, rng.integers(0, n, skew_hubs * hd)])
    # dedupe: B2SR ORs duplicates away, so round-trip nnz is bit population
    key = np.unique(rows * n + cols)
    return key // n, key % n


# ---------------------------------------------------------------------------
# partition/unpartition round-trip (host-side, meshless)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile_dim", TILE_DIMS)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_partition_roundtrip(tile_dim, n_shards):
    n = 70                                  # not a multiple of any tile_dim
    rows, cols = rand_coo(n, seed=tile_dim + n_shards)
    mat = coo_to_b2sr(rows, cols, n, n, tile_dim)
    part = pm.partition_rows(mat, n_shards)
    assert part.n_shards == n_shards
    assert part.n_shards * part.rows_per_shard >= mat.n_tile_rows
    back = pm.unpartition(part)
    # array-identical reconstruction, not just equal structure
    assert np.array_equal(np.asarray(back.tile_row_ptr),
                          np.asarray(mat.tile_row_ptr))
    assert np.array_equal(np.asarray(back.tile_col_idx),
                          np.asarray(mat.tile_col_idx))
    assert np.array_equal(np.asarray(back.bit_tiles),
                          np.asarray(mat.bit_tiles))
    assert back.nnz == mat.nnz
    assert np.array_equal(b2sr_to_dense(back), b2sr_to_dense(mat))


def test_partition_accepts_ell_view():
    rows, cols = rand_coo(50, seed=3)
    mat = coo_to_b2sr(rows, cols, 50, 50, 8)
    a = pm.partition_rows(mat, 3)
    b = pm.partition_rows(to_ell(mat), 3)
    assert np.array_equal(np.asarray(a.tile_col_idx),
                          np.asarray(b.tile_col_idx))
    assert a.shard_tiles == b.shard_tiles


def test_partition_empty_and_tiny_graphs():
    empty = coo_to_b2sr(np.array([]), np.array([]), 16, 16, 8)
    part = pm.partition_rows(empty, 4)
    assert part.balance() == 1.0 and part.edge_cut() == 0.0
    assert pm.unpartition(part).nnz == 0
    # more shards than tile rows: trailing shards are pure padding
    tiny = coo_to_b2sr(np.array([0]), np.array([1]), 4, 4, 4)
    part = pm.partition_rows(tiny, 8)
    assert part.rows_per_shard == 1
    assert pm.unpartition(part).nnz == 1


def test_partition_stats():
    rows, cols = rand_coo(96, seed=5, skew_hubs=3)
    mat = coo_to_b2sr(rows, cols, 96, 96, 8)
    part = pm.partition_rows(mat, 4)
    assert sum(part.shard_tiles) == mat.n_tiles
    assert part.balance() >= 1.0
    assert 0.0 <= part.edge_cut() <= 1.0
    # single shard: everything local, perfectly balanced
    solo = pm.partition_rows(mat, 1)
    assert solo.balance() == 1.0 and solo.edge_cut() == 0.0


def test_harmonised_buckets_share_structure():
    rows, cols = rand_coo(128, seed=7, density=0.02, skew_hubs=2,
                          hub_deg=100)
    mat = coo_to_b2sr(rows, cols, 128, 128, 4)
    part = pm.partition_rows(mat, 4)
    assert part.n_buckets >= 2               # skew spreads the histogram
    R = part.rows_per_shard
    for c, t, r in zip(part.bucket_col_idx, part.bucket_bit_tiles,
                       part.bucket_rows):
        # one slab per bucket, stacked across all shards with one width
        assert c.shape[0] == part.n_shards and t.shape[:3] == c.shape[:3]
        ra = np.asarray(r)
        assert ra.shape[0] == part.n_shards
        assert ra.min() >= 0 and ra.max() <= R   # R == the garbage row
    # every real (non-empty) tile row appears in exactly one bucket
    counts = np.asarray(part.row_n_tiles)
    for p in range(part.n_shards):
        seen = np.concatenate([np.asarray(r)[p] for r in part.bucket_rows])
        seen = seen[seen < R]
        expect = np.flatnonzero(counts[p] > 0)
        assert np.array_equal(np.sort(seen), expect)


def test_partition_rejects_bad_args():
    mat = coo_to_b2sr(np.array([0]), np.array([1]), 8, 8, 4)
    with pytest.raises(ValueError, match="n_shards"):
        pm.partition_rows(mat, 0)


def test_mesh_helpers_validate():
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    assert pm.shard_count(mesh, ("data",)) == 1
    with pytest.raises(ValueError, match="no axis"):
        pm.shard_count(mesh, ("model",))
    fp = pm.mesh_fingerprint(mesh, ("data",))
    assert fp[0] == ("data",) and fp[2] == ("data",)


def test_shard_respects_use_buckets_and_falls_back():
    # shard() only builds harmonised bucket slabs when the bucketed path is
    # on; toggling buckets on afterwards must stay *correct* via the ELL
    # slab fallback (just without the SELL split) — a single-device mesh
    # exercises the real shard_map rows in-process
    import jax
    import jax.numpy as jnp
    from repro.core import BitVector, GraphMatrix
    rng = np.random.RandomState(8)
    d = (rng.random((48, 48)) < 0.15).astype(np.uint8)
    g = GraphMatrix.from_dense(d, tile_dim=8)
    mesh = jax.make_mesh((1,), ("data",))
    gs_nb = g.with_buckets(False).shard(mesh)
    assert gs_nb.partitioned.n_buckets == 0        # nothing built
    assert g.shard(mesh).partitioned.n_buckets >= 1
    bv = BitVector.pack(jnp.asarray(rng.rand(48) > 0.5), 8)
    want = np.asarray(g.mxv(bv).words)
    assert np.array_equal(np.asarray(gs_nb.mxv(bv).words), want)
    # bucketed dispatch on a bucketless partition: ELL fallback, same bits
    assert np.array_equal(
        np.asarray(gs_nb.with_buckets(True).mxv(bv).words), want)


def test_make_debug_mesh_rejects_non_divisible():
    # the satellite fix: no more silent device dropping
    import jax
    from repro.launch.mesh import make_debug_mesh
    with pytest.raises(ValueError, match="not divisible"):
        make_debug_mesh(n_devices=1, model=2)
    with pytest.raises(ValueError, match="out of range"):
        make_debug_mesh(n_devices=len(jax.devices()) + 1)
    mesh = make_debug_mesh(n_devices=1, model=1)
    assert mesh.devices.shape == (1, 1)


# ---------------------------------------------------------------------------
# sharded execution parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.algorithms.bfs import bfs
    from repro.algorithms.cc import connected_components
    from repro.algorithms.pagerank import pagerank
    from repro.algorithms.tc import triangle_count
    from repro.core.descriptor import Descriptor
    from repro.core.graphblas import GraphMatrix
    from repro.core.operands import BitVector, FrontierBatch
    from repro.core.semiring import ARITHMETIC, MIN_PLUS
    from repro.engine.planner import PlanCache, plan_key
    from repro.engine.queries import batched_ppr, msbfs
    from repro.launch.mesh import make_debug_mesh

    assert len(jax.devices()) == 8
    mesh = make_debug_mesh(8, model=2)            # (data=4, model=2)

    def build(n, t, seed, density=0.08):
        rng = np.random.RandomState(seed)
        d = (rng.random((n, n)) < density).astype(np.uint8)
        # two hub rows so the bucket histogram has >1 bucket
        d[seed % n] |= (rng.random(n) < 0.6)
        return GraphMatrix.from_dense(d, tile_dim=t), d

    # --- every kernel row, all tile dims, buckets on/off ------------------
    for t in (4, 8, 16, 32):
        g, d = build(96, t, seed=t)
        gs = g.shard(mesh)
        rng = np.random.RandomState(100 + t)
        x = jnp.asarray(rng.rand(96).astype(np.float32))
        bv = BitVector.pack(jnp.asarray(rng.rand(96) > 0.5), t)
        fb = FrontierBatch.pack(jnp.asarray(rng.rand(96, 5) > 0.5), t)
        X = jnp.asarray(rng.rand(96, 6).astype(np.float32))
        for ub in (True, False):
            a, b = g.with_buckets(ub), gs.with_buckets(ub)
            assert np.array_equal(np.asarray(b.mxv(bv).words),
                                  np.asarray(a.mxv(bv).words))
            assert np.array_equal(
                np.asarray(b.mxv(bv, ARITHMETIC, out_dtype=jnp.int32)),
                np.asarray(a.mxv(bv, ARITHMETIC, out_dtype=jnp.int32)))
            # float ⊕ rows: same per-row reduction order, but allow for
            # shape-dependent XLA lowering; bit-level rows stay bit-exact
            assert np.allclose(np.asarray(b.mxv(x)), np.asarray(a.mxv(x)),
                               atol=1e-6)
            assert np.array_equal(np.asarray(b.mxv(x, MIN_PLUS)),
                                  np.asarray(a.mxv(x, MIN_PLUS)))
            assert np.allclose(np.asarray(b.mxm(X)), np.asarray(a.mxm(X)),
                               atol=1e-5)
            assert np.array_equal(np.asarray(b.mxm(fb).words),
                                  np.asarray(a.mxm(fb).words))
        # SpGEMM rows (bin + count) and the fused tri reduction
        pa, pb = g.mxm(g), gs.mxm(g)
        assert pa.nnz == pb.nnz
        assert np.array_equal(np.asarray(pa.csr.col_idx),
                              np.asarray(pb.csr.col_idx))
        assert np.array_equal(np.asarray(gs.mxm(g, ARITHMETIC)),
                              np.asarray(g.mxm(g, ARITHMETIC)))
    print("ROWS_OK")

    # --- masked + transposed descriptors ----------------------------------
    t = 8
    g, d = build(96, t, seed=41)
    gs = g.shard(mesh)
    rng = np.random.RandomState(5)
    bv = BitVector.pack(jnp.asarray(rng.rand(96) > 0.5), t)
    mask = BitVector.pack(jnp.asarray(rng.rand(96) > 0.5), t)
    fb = FrontierBatch.pack(jnp.asarray(rng.rand(96, 3) > 0.5), t)
    fmask = FrontierBatch.pack(jnp.asarray(rng.rand(96, 3) > 0.5), t)
    x = jnp.asarray(rng.rand(96).astype(np.float32))
    dmask = jnp.asarray((rng.rand(96) > 0.5).astype(np.float32))
    for tr in (False, True):
        dsc = Descriptor(mask=mask, complement=True, transpose_a=tr)
        assert np.array_equal(np.asarray(gs.mxv(bv, desc=dsc).words),
                              np.asarray(g.mxv(bv, desc=dsc).words))
        dsc = Descriptor(mask=dmask, complement=tr, transpose_a=tr)
        assert np.allclose(np.asarray(gs.mxv(x, ARITHMETIC, dsc)),
                           np.asarray(g.mxv(x, ARITHMETIC, dsc)), atol=1e-6)
        dsc = Descriptor(mask=fmask, complement=True, transpose_a=tr)
        assert np.array_equal(np.asarray(gs.mxm(fb, desc=dsc).words),
                              np.asarray(g.mxm(fb, desc=dsc).words))
    ma, mb = g.mxm(g, mask=g, complement=True), gs.mxm(g, mask=g,
                                                       complement=True)
    assert ma.nnz == mb.nnz
    assert np.array_equal(
        np.asarray(gs.mxm(g, ARITHMETIC, mask=g, complement=True)),
        np.asarray(g.mxm(g, ARITHMETIC, mask=g, complement=True)))
    print("DESC_OK")

    # --- whole algorithms through shard(mesh), zero call-site changes -----
    sym = ((d | d.T) & ~np.eye(96, dtype=bool)).astype(np.uint8)
    h = GraphMatrix.from_dense(sym, tile_dim=8)
    hs = h.shard(mesh)
    assert np.array_equal(np.asarray(bfs(gs, 3).levels),
                          np.asarray(bfs(g, 3).levels))
    assert np.allclose(np.asarray(pagerank(gs).ranks),
                       np.asarray(pagerank(g).ranks), atol=1e-7)
    assert np.array_equal(np.asarray(connected_components(gs).labels),
                          np.asarray(connected_components(g).labels))
    assert triangle_count(hs) == triangle_count(h)
    print("ALGOS_OK")

    # --- engine: one mesh serves a whole batch; plan-cache mesh isolation --
    pc = PlanCache()
    mesh_b = make_debug_mesh(4, model=2)          # (2, 2): different shape
    gs_b = g.shard(mesh_b)
    srcs = [1, 9, 17, 33]
    ref = msbfs(g, srcs, planner=pc)
    for gg in (gs, gs_b):
        got = msbfs(gg, srcs, planner=pc)
        assert np.array_equal(np.asarray(got.levels), np.asarray(ref.levels))
    assert pc.misses == 3 and pc.hits == 0        # three distinct plans
    keys = pc.keys()
    assert len({k.mesh for k in keys}) == 3       # None + two mesh shapes
    msbfs(gs, srcs, planner=pc)                   # same mesh: cache hit
    assert pc.hits == 1 and pc.misses == 3
    pr_ref = batched_ppr(g, [2, 7], planner=pc)
    pr_got = batched_ppr(gs, [2, 7], planner=pc)
    assert np.allclose(np.asarray(pr_got.ranks), np.asarray(pr_ref.ranks),
                       atol=1e-6)
    # sharding over a subset of mesh axes is its own plan too
    gs_data = g.shard(mesh, axes=("data",))
    assert gs_data.partitioned.n_shards == 4
    assert np.array_equal(np.asarray(msbfs(gs_data, srcs).levels),
                          np.asarray(ref.levels))
    print("ENGINE_OK")

    # --- sharded pallas-backend graph + error contracts -------------------
    gp = g.with_backend("b2sr_pallas").shard(mesh)
    assert np.array_equal(np.asarray(bfs(gp, 3).levels),
                          np.asarray(bfs(g, 3).levels))
    try:
        g.with_backend("csr").shard(mesh)
        raise SystemExit("csr shard must raise")
    except ValueError:
        pass
    try:
        gs.mxv(x, ARITHMETIC, Descriptor(row_chunk=16))
        raise SystemExit("sharded row_chunk must raise")
    except ValueError:
        pass
    assert gs.unshard().sharded is False
    assert np.array_equal(np.asarray(gs.unshard().mxv(bv).words),
                          np.asarray(g.mxv(bv).words))
    print("GUARDS_OK")
""")

MARKERS = ["ROWS_OK", "DESC_OK", "ALGOS_OK", "ENGINE_OK", "GUARDS_OK"]


@pytest.fixture(scope="module")
def sharded_parity_run():
    return subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=900, env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.parametrize("marker", MARKERS)
def test_sharded_parity(sharded_parity_run, marker):
    assert sharded_parity_run.returncode == 0, \
        sharded_parity_run.stderr[-4000:]
    assert marker in sharded_parity_run.stdout
