"""Launch-plan cache for the batched query engine (DESIGN.md §9).

A *plan* is a jit-compiled batched query loop specialised to one
(graph, kernel, batch width) combination: the closure captures the graph's
device arrays, so XLA constant-folds the operand layout, and the while-loop
is traced exactly once per plan. Serving traffic re-traces nothing — the
planner looks plans up by a :class:`PlanKey` built from

  - the graph's **structure fingerprint** (content hash of the ELL layout —
    two `GraphMatrix` wrappers around the same adjacency share plans),
  - the **kernel** name (msbfs / mskhop / ppr),
  - **backend**, **tile_dim**, and the **bucket layout** (per-bucket
    (rows, width) pairs — the bucketed dispatch bakes slab shapes into the
    trace, so a different bucketing is a different program),
  - the **padded batch width** (frontier columns after word padding; the
    batcher additionally quantises to powers of two so widths collapse to
    a handful of plan entries).

Eviction is LRU with a fixed capacity: serving fleets hold plans for the
hot graphs and let cold graph/width combinations fall out.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Optional, Tuple

from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.obs import cost as obs_cost
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

#: Labels the planner stamps on its registry series (DESIGN.md §14).
_CACHE_LABELS = ("kind", "backend")
_LAUNCH_LABELS = ("op", "backend", "tile_dim", "bucketed", "sharded")


@dataclasses.dataclass(frozen=True)
class PlanKey:
    graph_fp: str
    kernel: str
    backend: str
    tile_dim: int
    bucket_layout: Optional[Tuple[Tuple[int, int], ...]]
    batch_width: int            # padded number of frontier columns (S_pad)
    # descriptor fields the traced loop bakes in (``descriptor_key``);
    # None for plans whose loop shape is fully named by ``kernel``
    desc: Optional[Tuple] = None
    # the mesh fingerprint for sharded graphs (``partition.mesh_fingerprint``:
    # axis names, shape, shard axes, member device ids) — a sharded plan's
    # shard_map trace bakes all of these in, so plans must never leak
    # across mesh shapes (or between sharded and unsharded execution, where
    # this field is None)
    mesh: Optional[Tuple] = None


def descriptor_key(desc: Descriptor,
                   masked: Optional[bool] = None) -> Tuple:
    """Hashable summary of the :class:`Descriptor` fields a plan bakes in.

    A traced query loop specialises on mask presence, complement,
    input-transpose, replace semantics, and row chunking — two loops
    differing in any of these are different XLA programs. ``masked``
    overrides mask presence for plans whose mask is loop-carried (built
    inside the loop, so not present on the descriptor at key time).
    """
    m = (desc.mask is not None) if masked is None else masked
    return (m, desc.complement, desc.transpose_a, desc.replace,
            desc.row_chunk, desc.direction)


@dataclasses.dataclass
class Plan:
    """A cached, jit-compiled batched query loop.

    ``cost`` is the plan's HLO cost-model estimate (FLOPs / HBM bytes /
    wire bytes per launch) — populated on the first call when
    :func:`repro.obs.cost.set_cost_accounting` is on, None otherwise.
    Every call lands one observation in the ``launch_latency_s``
    histogram, labeled by the plan-key coordinates, so the registry can
    report achieved vs roofline rates per (op, backend, tile_dim).
    """

    key: PlanKey
    fn: Callable
    n_calls: int = 0
    cost: Optional[dict] = None

    def _labels(self) -> dict:
        return {"op": self.key.kernel, "backend": self.key.backend,
                "tile_dim": self.key.tile_dim,
                "bucketed": self.key.bucket_layout is not None,
                "sharded": self.key.mesh is not None}

    def __call__(self, *args, **kw):
        first = self.n_calls == 0
        self.n_calls += 1
        if not obs_metrics.enabled():
            return self.fn(*args, **kw)
        if first and self.cost is None and obs_cost.cost_accounting_enabled():
            self.cost = obs_cost.analyze_plan(self.fn, args, kw)
            if self.cost is not None:
                obs_cost.record_plan_cost(self.cost, self.key.kernel,
                                          self.key.backend,
                                          self.key.tile_dim)
        # tag the enclosing launch span: a first call pays trace+compile
        # inside this launch, which is the "slow query" smoking gun
        obs_trace.annotate(first_call=first, op=self.key.kernel)
        t0 = time.perf_counter()
        out = self.fn(*args, **kw)
        # dispatch-to-ready on sync backends (CPU); a dispatch-time lower
        # bound on async ones — callers needing exact device time should
        # block before reading the histogram
        obs_metrics.get_registry().histogram(
            "launch_latency_s", "plan launch wall time",
            _LAUNCH_LABELS).observe(time.perf_counter() - t0,
                                    **self._labels())
        return out


def plan_key(g: GraphMatrix, kernel: str, batch_width: int,
             desc: Optional[Tuple] = None) -> PlanKey:
    """Build the cache key for ``kernel`` on ``g`` at ``batch_width``.

    ``desc`` is a :func:`descriptor_key` tuple for loops parameterised by
    a Descriptor (mask presence / complement / replace / chunking).
    Sharded graphs contribute their mesh fingerprint, so one serving
    process can hold plans for several meshes (and for the unsharded twin)
    without cross-talk.
    """
    bucket_layout = None
    if g.backend != "csr" and g.use_buckets:
        b = g.buckets()
        bucket_layout = tuple(zip(b.bucket_sizes, b.bucket_widths))
    mesh_fp = None
    if g.sharded:
        from repro.core.partition import mesh_fingerprint
        # the comm layout changes the traced collectives (gather vs
        # ppermute exchange), so it is part of the layout identity: plans
        # for a regathered/resharded twin never collide
        mesh_fp = mesh_fingerprint(g.mesh, g.shard_axes) + (g.comm,)
    return PlanKey(
        graph_fp=g.fingerprint(), kernel=kernel, backend=g.backend,
        tile_dim=g.tile_dim, bucket_layout=bucket_layout,
        batch_width=batch_width, desc=desc, mesh=mesh_fp)


class PlanCache:
    """LRU cache of :class:`Plan` objects with a stats snapshot.

    Counters live in one dict (:meth:`stats` / :meth:`reset_stats`) and
    are mirrored into the metrics registry as
    ``plan_cache_{hits,misses,evictions}_total{kind,backend}``; the
    historical ``hits`` / ``misses`` / ``evictions`` attributes remain as
    thin read-only properties over the snapshot.
    """

    def __init__(self, capacity: int = 32,
                 registry: Optional["obs_metrics.MetricsRegistry"] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._plans: "OrderedDict[PlanKey, Plan]" = OrderedDict()
        self._registry = registry            # None -> default at emit time
        self._stats = {"hits": 0, "misses": 0, "evictions": 0}

    # -- stats ---------------------------------------------------------------
    @property
    def hits(self) -> int:
        return self._stats["hits"]

    @property
    def misses(self) -> int:
        return self._stats["misses"]

    @property
    def evictions(self) -> int:
        return self._stats["evictions"]

    def stats(self) -> dict:
        """Counter snapshot plus occupancy, as one plain dict."""
        return {**self._stats, "size": len(self._plans),
                "capacity": self.capacity}

    def reset_stats(self) -> None:
        for k in self._stats:
            self._stats[k] = 0

    def _count(self, what: str, key: PlanKey) -> None:
        self._stats[what] += 1
        if obs_metrics.enabled():
            reg = self._registry or obs_metrics.get_registry()
            reg.counter(f"plan_cache_{what}_total",
                        f"plan cache {what}", _CACHE_LABELS).inc(
                kind=key.kernel, backend=key.backend)

    # -- lookup --------------------------------------------------------------
    def get(self, key: PlanKey, builder: Callable[[], Callable]) -> Plan:
        """The plan for ``key``, building (and possibly evicting) on miss."""
        plan = self._plans.get(key)
        with obs_trace.current_span("plan_resolve", cache_hit=plan is not None,
                                    op=key.kernel, backend=key.backend):
            if plan is not None:
                self._plans.move_to_end(key)
                self._count("hits", key)
                return plan
            self._count("misses", key)
            plan = Plan(key=key, fn=builder())
            self._plans[key] = plan
            while len(self._plans) > self.capacity:
                old_key, _ = self._plans.popitem(last=False)
                self._count("evictions", old_key)
        return plan

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._plans

    def keys(self):
        return list(self._plans.keys())

    def clear(self) -> None:
        self._plans.clear()
        self.reset_stats()


# The module-level cache that GraphMatrix entry points and the batcher use;
# pass an explicit PlanCache to engine.queries for isolated lifetimes.
DEFAULT_PLANNER = PlanCache()
