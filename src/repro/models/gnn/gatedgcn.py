"""GatedGCN [Bresson & Laurent; Dwivedi benchmark 2003.00982].

h_i' = h_i + ReLU(Norm(U h_i + Σ_j η_ij ⊙ V h_j)),
η_ij = σ(ê_ij) / (Σ_j' σ(ê_ij') + ε),
ê_ij  = e_ij + ReLU(Norm(A h_i + B h_j + C e_ij)).

Edge gates are per-edge floats → the aggregation is inherently valued; B2SR
applies only to structure queries (DESIGN.md §Arch-applicability). Norm is
LayerNorm (stateless stand-in for the benchmark's BatchNorm — noted).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.ops import shard_map_compat
from repro.configs.base import GNNConfig
from repro.models.gnn.common import GraphBatch, node_ce_loss

Params = Dict[str, Any]


def init_layer(key, d: int) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "U": nn.dense_params(ks[0], d, d),
        "V": nn.dense_params(ks[1], d, d),
        "A": nn.dense_params(ks[2], d, d),
        "B": nn.dense_params(ks[3], d, d),
        "C": nn.dense_params(ks[4], d, d),
        "norm_h": nn.layer_norm_params(d),
        "norm_e": nn.layer_norm_params(d),
    }


def init_params(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 3)
    return {
        "embed_h": nn.dense_params(ks[0], cfg.d_in, cfg.d_hidden),
        "embed_e": nn.dense_params(ks[1], 1, cfg.d_hidden),
        "layers": [init_layer(ks[2 + i], cfg.d_hidden)
                   for i in range(cfg.n_layers)],
        "head": nn.dense_params(ks[-1], cfg.d_hidden, cfg.n_classes),
    }


def _layer_agg_dense(lp, h, e, batch, n):
    """Reference gather/scatter aggregation (GSPMD decides the comms)."""
    hs = h[batch.senders]
    hr = h[batch.receivers]
    e_hat = e + jax.nn.relu(nn.layer_norm(
        lp["norm_e"],
        nn.dense(lp["A"], hr) + nn.dense(lp["B"], hs) + nn.dense(lp["C"], e)))
    sig = jax.nn.sigmoid(e_hat) * batch.edge_mask[:, None]
    denom = jax.ops.segment_sum(sig, batch.receivers, num_segments=n)
    msgs = sig * nn.dense(lp["V"], hs)
    agg = jax.ops.segment_sum(msgs, batch.receivers, num_segments=n)
    return e_hat, agg, denom


def _layer_agg_shardmap(lp, h, e, batch, cfg, n):
    """Receiver-partitioned aggregation (§Perf, EXPERIMENTS.md).

    Contract (data pipeline): edge arrays are receiver-sorted and padded so
    shard i's receivers fall in node block i. Each device all-gathers the
    (small-d) node features once, computes its edges locally, and
    scatter-adds into its own node block — no cross-device scatter, and the
    backward of the all-gather is a reduce-scatter.
    """
    from jax._src.mesh import thread_resources
    from jax.sharding import PartitionSpec as P

    mesh = thread_resources.env.physical_mesh
    axes = tuple(a for a in cfg.shardmap_agg_axes if a in mesh.axis_names)
    if not axes or mesh.empty:
        return _layer_agg_dense(lp, h, e, batch, n)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    p_total = 1
    for a in axes:
        p_total *= sizes[a]
    if n % p_total != 0:
        return _layer_agg_dense(lp, h, e, batch, n)
    n_local = n // p_total
    msg_dtype = (jnp.bfloat16 if cfg.message_dtype == "bfloat16"
                 else h.dtype)

    def block(lp_, h_blk, e_blk, snd, rcv, msk):
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * sizes[a] + jax.lax.axis_index(a)
        h_full = jax.lax.all_gather(h_blk.astype(msg_dtype), axes,
                                    axis=0, tiled=True)
        hs = h_full[snd]
        hr = h_full[rcv]
        e_hat = e_blk + jax.nn.relu(nn.layer_norm(
            lp_["norm_e"],
            (nn.dense(lp_["A"], hr) + nn.dense(lp_["B"], hs)).astype(e_blk.dtype)
            + nn.dense(lp_["C"], e_blk)))
        sig = jax.nn.sigmoid(e_hat) * msk[:, None]
        r_local = jnp.clip(rcv - idx * n_local, 0, n_local - 1)
        sig32 = sig.astype(jnp.float32)
        denom = jax.ops.segment_sum(sig32, r_local, num_segments=n_local)
        msgs = sig32 * nn.dense(lp_["V"], hs).astype(jnp.float32)
        agg = jax.ops.segment_sum(msgs, r_local, num_segments=n_local)
        return e_hat, agg.astype(h_blk.dtype), denom.astype(h_blk.dtype)

    nspec = P(axes, None)
    espec = P(axes, None)
    mspec = P(axes)
    lp_specs = jax.tree_util.tree_map(lambda _: P(), lp)
    return shard_map_compat(
        block, mesh=mesh,
        in_specs=(lp_specs, nspec, espec, mspec, mspec, mspec),
        out_specs=(espec, nspec, nspec),
    )(lp, h, e, batch.senders, batch.receivers, batch.edge_mask)


def forward(params: Params, batch: GraphBatch, cfg: GNNConfig,
            pooled: bool = False) -> jax.Array:
    n = batch.node_feat.shape[0]
    h = nn.dense(params["embed_h"], batch.node_feat)
    if batch.edge_feat is not None:
        e = nn.dense(params["embed_e"], batch.edge_feat)
    else:
        e = jnp.zeros((batch.senders.shape[0], cfg.d_hidden), h.dtype)

    def layer_fn(lp, h, e):
        if cfg.shardmap_agg_axes:
            e_hat, agg, denom = _layer_agg_shardmap(lp, h, e, batch, cfg, n)
        else:
            e_hat, agg, denom = _layer_agg_dense(lp, h, e, batch, n)
        eta_agg = agg / jnp.maximum(denom, 1e-6)
        h = h + jax.nn.relu(nn.layer_norm(
            lp["norm_h"], nn.dense(lp["U"], h) + eta_agg))
        return h, e_hat

    if cfg.remat:
        layer_fn = jax.checkpoint(layer_fn)
    for lp in params["layers"]:
        h, e = layer_fn(lp, h, e)
    if pooled:
        from repro.models.gnn.common import graph_pool
        h = graph_pool(h, batch.graph_ids, batch.n_graphs, batch.node_mask)
    return nn.dense(params["head"], h)


def loss_fn(params: Params, batch: GraphBatch, cfg: GNNConfig):
    if batch.n_graphs > 1:  # graph-level task (molecule shape)
        logits = forward(params, batch, cfg, pooled=True)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch.labels[:, None], -1)[:, 0]
        loss = jnp.mean(logz - gold)
    else:
        logits = forward(params, batch, cfg)
        loss = node_ce_loss(logits, batch.labels, batch.train_mask)
    return loss, {"ce": loss}
