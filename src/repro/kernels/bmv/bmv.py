"""Pallas TPU kernels for BMV over B2SR-ELL (paper Listing 1, TPU-native).

Layout (per DESIGN.md §2): the packed vector / packed x-tile table lives in
VMEM for the whole kernel (it is tiny: n/8 bytes); bit tiles stream through
VMEM in (row-block × k-block) grid steps; AND+popcount on uint32 VREG lanes
replaces ``__popc``; accumulation is private per grid program (no atomics).

Grid: (tile_row_blocks, k_blocks). k is the innermost ("arbitrary") axis and
accumulates into the output block, initialised at k == 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import unpack_words


# ---------------------------------------------------------------------------
# bmv_bin_bin_full : counts  y[i] = Σ_j A[i,j] & x[j]
# ---------------------------------------------------------------------------

def _bin_bin_full_kernel(col_ref, tiles_ref, x_ref, out_ref, *, t: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = col_ref[...]                                  # [BR, BK] int32
    xw_all = x_ref[...]                                 # [C] uint32
    safe = jnp.clip(idx, 0, xw_all.shape[0] - 1)
    xw = jnp.take(xw_all, safe.reshape(-1), axis=0).reshape(idx.shape)
    xw = jnp.where(idx >= 0, xw, jnp.uint32(0))
    counts = jax.lax.population_count(tiles_ref[...] & xw[:, :, None])  # [BR,BK,t]
    out_ref[...] += jnp.sum(counts, axis=1, dtype=jnp.int32)


def bmv_bin_bin_full_pallas(col_idx, tiles, x_words, *, t: int,
                            block_r: int = 8, block_k: int = 8,
                            interpret: bool = True):
    R, K = col_idx.shape
    C = x_words.shape[0]
    assert R % block_r == 0 and K % block_k == 0
    grid = (R // block_r, K // block_k)
    out = pl.pallas_call(
        functools.partial(_bin_bin_full_kernel, t=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_r, block_k, t), lambda i, k: (i, k, 0)),
            pl.BlockSpec((C,), lambda i, k: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, t), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, t), jnp.int32),
        interpret=interpret,
    )(col_idx, tiles, x_words)
    return out


# ---------------------------------------------------------------------------
# bmv_bin_bin_bin (+ masked) : packed frontier -> packed frontier
# ---------------------------------------------------------------------------

def _bin_bin_bin_kernel(col_ref, tiles_ref, x_ref, mask_ref, out_ref, *,
                        t: int, complement: bool):
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = col_ref[...]
    xw_all = x_ref[...]
    safe = jnp.clip(idx, 0, xw_all.shape[0] - 1)
    xw = jnp.take(xw_all, safe.reshape(-1), axis=0).reshape(idx.shape)
    xw = jnp.where(idx >= 0, xw, jnp.uint32(0))
    hit = jnp.any((tiles_ref[...] & xw[:, :, None]) != 0, axis=1)     # [BR, t]
    shifts = jnp.arange(t, dtype=jnp.uint32)
    word = jnp.sum(hit.astype(jnp.uint32) << shifts[None, :], axis=1,
                   dtype=jnp.uint32)
    out_ref[...] |= word

    @pl.when(k == nk - 1)
    def _apply_mask():
        m = mask_ref[...]
        m = ~m if complement else m
        out_ref[...] &= m


def bmv_bin_bin_bin_pallas(col_idx, tiles, x_words, mask_words, *, t: int,
                           complement: bool = True, block_r: int = 8,
                           block_k: int = 8, interpret: bool = True):
    R, K = col_idx.shape
    C = x_words.shape[0]
    assert R % block_r == 0 and K % block_k == 0
    grid = (R // block_r, K // block_k)
    return pl.pallas_call(
        functools.partial(_bin_bin_bin_kernel, t=t, complement=complement),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_r, block_k, t), lambda i, k: (i, k, 0)),
            pl.BlockSpec((C,), lambda i, k: (0,)),
            pl.BlockSpec((block_r,), lambda i, k: (i,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.uint32),
        interpret=interpret,
    )(col_idx, tiles, x_words, mask_words)


# ---------------------------------------------------------------------------
# bmv_bin_bin_bin_pull : direction-optimized pull traversal with early exit
# ---------------------------------------------------------------------------

def _bin_bin_bin_pull_kernel(col_ref, tiles_ref, x_ref, mask_ref, out_ref, *,
                             t: int, complement: bool, block_k: int):
    """Pull row block: consume the k-axis until every allowed lane is set.

    The grid is 1-D over row blocks — the whole k extent of the block's
    ELL slab sits in VMEM and an internal ``while_loop`` walks it
    ``block_k`` tiles at a time. The §V mask is applied *up front*
    (``allowed`` = the unvisited lanes) and the loop exits as soon as
    ``out == allowed``: a pulled row stops scanning in-edges on the first
    frontier parent, the DESIGN.md §12 asymmetry that makes pull win on
    dense frontiers. Early exit is bit-exact by construction — the
    accumulator only ever ORs ``word & allowed``, so skipped k-tiles
    could only have contributed bits that are already set.
    """
    idx_all = col_ref[...]                               # [BR, K] int32
    tiles_all = tiles_ref[...]                           # [BR, K, t]
    xw_all = x_ref[...]                                  # [C] uint32
    m = mask_ref[...]                                    # [BR] uint32
    lanes = (jnp.uint32(0xFFFFFFFF) if t == 32
             else jnp.uint32((1 << t) - 1))
    allowed = (~m if complement else m) & lanes
    n_kb = idx_all.shape[1] // block_k
    shifts = jnp.arange(t, dtype=jnp.uint32)

    def cond(state):
        kb, out = state
        return (kb < n_kb) & jnp.any((out & allowed) != allowed)

    def body(state):
        kb, out = state
        k0 = kb * block_k
        idx = jax.lax.dynamic_slice(idx_all, (0, k0),
                                    (idx_all.shape[0], block_k))
        tls = jax.lax.dynamic_slice(
            tiles_all, (0, k0, 0), (tiles_all.shape[0], block_k, t))
        safe = jnp.clip(idx, 0, xw_all.shape[0] - 1)
        xw = jnp.take(xw_all, safe.reshape(-1), axis=0).reshape(idx.shape)
        xw = jnp.where(idx >= 0, xw, jnp.uint32(0))
        hit = jnp.any((tls & xw[:, :, None]) != 0, axis=1)       # [BR, t]
        word = jnp.sum(hit.astype(jnp.uint32) << shifts[None, :], axis=1,
                       dtype=jnp.uint32)
        return kb + 1, out | (word & allowed)

    _, out = jax.lax.while_loop(cond, body,
                                (jnp.int32(0), jnp.zeros_like(allowed)))
    out_ref[...] = out


def bmv_bin_bin_bin_pull_pallas(col_idx, tiles, x_words, mask_words, *,
                                t: int, complement: bool = True,
                                block_r: int = 8, block_k: int = 8,
                                interpret: bool = True):
    R, K = col_idx.shape
    C = x_words.shape[0]
    assert R % block_r == 0 and K % block_k == 0
    grid = (R // block_r,)
    return pl.pallas_call(
        functools.partial(_bin_bin_bin_pull_kernel, t=t,
                          complement=complement, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, K), lambda i: (i, 0)),
            pl.BlockSpec((block_r, K, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((C,), lambda i: (0,)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.uint32),
        interpret=interpret,
    )(col_idx, tiles, x_words, mask_words)


# ---------------------------------------------------------------------------
# bmv_bin_full_full : general semiring with a full-precision vector
# ---------------------------------------------------------------------------

def _bin_full_full_kernel(col_ref, tiles_ref, x_ref, out_ref, *, t: int,
                          mode: str, a_value: float, ident: float):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    idx = col_ref[...]                                   # [BR, BK]
    x3 = x_ref[...]                                      # [C, t]
    safe = jnp.clip(idx, 0, x3.shape[0] - 1)
    xk = jnp.take(x3, safe.reshape(-1), axis=0).reshape(idx.shape + (t,))
    dtype = out_ref.dtype
    identv = jnp.asarray(ident, dtype)
    xk = jnp.where((idx >= 0)[:, :, None], xk, identv)   # [BR, BK, t]
    av = jnp.asarray(a_value, dtype)
    if mode == "sum":
        # MXU path: unpacked 0/1 tiles contract against the gathered x tiles
        # (sum_k sum_c bits[r,k,a,c] * x[r,k,c]) — the mxm_count trick from
        # core/ops.py; invalid lanes already carry x == 0. Contract: x must
        # be finite (0 * inf = NaN would leak through absent edges; inf
        # vectors belong on min_plus, which keeps the select form below).
        bits_f = unpack_words(tiles_ref[...], t, dtype)   # [BR, BK, t, t]
        out_ref[...] += av * jnp.einsum("rkac,rkc->ra", bits_f, xk,
                                        preferred_element_type=dtype)
        return
    bits = unpack_words(tiles_ref[...], t, jnp.bool_)    # [BR, BK, t, t]
    if mode == "min_plus":
        contrib = jnp.where(bits, av + xk[:, :, None, :], identv)
        out_ref[...] = jnp.minimum(out_ref[...], jnp.min(contrib, axis=(1, 3)))
    elif mode == "max_times":
        contrib = jnp.where(bits, av * xk[:, :, None, :], identv)
        out_ref[...] = jnp.maximum(out_ref[...], jnp.max(contrib, axis=(1, 3)))
    else:
        raise ValueError(mode)


def bmv_bin_full_full_pallas(col_idx, tiles, x3, *, t: int, mode: str = "sum",
                             a_value: float = 1.0, ident: float = 0.0,
                             block_r: int = 8, block_k: int = 8,
                             interpret: bool = True):
    R, K = col_idx.shape
    C = x3.shape[0]
    assert R % block_r == 0 and K % block_k == 0
    grid = (R // block_r, K // block_k)
    return pl.pallas_call(
        functools.partial(_bin_full_full_kernel, t=t, mode=mode,
                          a_value=a_value, ident=ident),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_r, block_k, t), lambda i, k: (i, k, 0)),
            pl.BlockSpec((C, t), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, t), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, t), x3.dtype),
        interpret=interpret,
    )(col_idx, tiles, x3)
