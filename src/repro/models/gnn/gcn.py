"""GCN [Kipf & Welling]: h' = σ(Â h W), Â = D^-1/2 (A + I) D^-1/2.

B2SR integration (the paper's technique as the GNN hot path): the
normalisation is refactored as  Â·h = D^-1/2 · (A+I)·(D^-1/2 h)  so the
inner SpMM is over the *binary* adjacency and dispatches through the
registry's ``spmm_bin_full_full`` row via ``repro.gnn_bit.layers`` (bit
tiles → MXU; DESIGN.md §15) — including the ``cfg.shardmap_agg_axes``
scale-out path, which routes through the registry's ``sharded`` axis
(prepare the graph once with ``gnn_bit.layers.prepare_sharded``; unshared
single-device runs need no preparation). The segment-sum path is the
float baseline (cfg.use_b2sr=False or batches without a B2SR view).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import GNNConfig
from repro.gnn_bit import layers as bit_layers
from repro.models.gnn.common import GraphBatch, node_ce_loss, segment_agg

Params = Dict[str, Any]


def init_params(cfg: GNNConfig, key) -> Params:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, cfg.n_layers)
    return {f"layer_{i}": {"w": nn.dense_init(keys[i], dims[i], dims[i + 1]),
                           "b": jnp.zeros((dims[i + 1],))}
            for i in range(cfg.n_layers)}


def _aggregate(batch: GraphBatch, h: jax.Array, cfg: GNNConfig) -> jax.Array:
    """Â·h with symmetric normalisation (or plain mean aggregation)."""
    deg = batch.degrees
    if deg is None:
        ones = batch.edge_mask.astype(h.dtype)
        deg = jax.ops.segment_sum(ones, batch.receivers,
                                  num_segments=h.shape[0]) + 1.0  # + self loop
    if cfg.norm == "sym":
        inv_sqrt = jax.lax.rsqrt(jnp.maximum(deg, 1.0))[:, None]
        hs = h * inv_sqrt
        if cfg.use_b2sr and batch.ell is not None:
            agg = bit_layers.aggregate(
                batch.ell, hs, axes=tuple(cfg.shardmap_agg_axes)) + hs
        else:
            msgs = hs[batch.senders]
            agg = segment_agg(msgs, batch.receivers, h.shape[0],
                              batch.edge_mask, "sum") + hs
        return agg * inv_sqrt
    # mean aggregation (cora config's aggregator=mean at the node level)
    if cfg.use_b2sr and batch.ell is not None:
        agg = bit_layers.aggregate(
            batch.ell, h, axes=tuple(cfg.shardmap_agg_axes)) + h
    else:
        msgs = h[batch.senders]
        agg = segment_agg(msgs, batch.receivers, h.shape[0],
                          batch.edge_mask, "sum") + h
    return agg / jnp.maximum(deg, 1.0)[:, None]


def forward(params: Params, batch: GraphBatch, cfg: GNNConfig) -> jax.Array:
    h = batch.node_feat
    for i in range(cfg.n_layers):
        h = _aggregate(batch, h, cfg)
        h = h @ params[f"layer_{i}"]["w"] + params[f"layer_{i}"]["b"]
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h


def loss_fn(params: Params, batch: GraphBatch, cfg: GNNConfig):
    logits = forward(params, batch, cfg)
    if batch.n_graphs > 1:  # graph-level task (molecule shape)
        from repro.models.gnn.common import graph_pool
        pooled = graph_pool(logits, batch.graph_ids, batch.n_graphs,
                            batch.node_mask)
        logz = jax.nn.logsumexp(pooled, axis=-1)
        gold = jnp.take_along_axis(pooled, batch.labels[:, None], -1)[:, 0]
        loss = jnp.mean(logz - gold)
    else:
        loss = node_ce_loss(logits, batch.labels, batch.train_mask)
    return loss, {"ce": loss}
