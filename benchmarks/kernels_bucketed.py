"""Bucketed-ELL load balancing sweep: single-max ELL vs SELL-style buckets.

The paper's GPU speedups rest on bit tiles *plus* load balancing; our TPU
port's single ``max_tiles_per_row`` ELL view makes every tile-row pay
hub-row cost on power-law graphs (DESIGN.md §2). This sweep measures the
row-bucketed path (``core.b2sr.to_bucketed``) against the single-ELL path
for bmv and spmm across skew × tile_dim × bucket count, on both controlled
hub graphs (exact skew knob) and R-MAT graphs (the paper's benchmark
shape). Each row reports the padded-vs-real-words fill ratio alongside
latency so the win is attributable: the speedup tracks the padded work
removed, and outputs are asserted identical before timing.

Skew is the tile-level imbalance ``max(tiles_per_row) / mean`` over
non-empty tile-rows. Wall-clock on this container is jitted-CPU; the
compute saved (masked-out slots skipped) transfers to TPU unchanged.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, save_json, time_fn
from repro.core import ops
from repro.core.b2sr import (coo_to_b2sr, ell_fill_ratio, pack_bitvector,
                             to_bucketed, to_ell)
from repro.data import graphs as G


def _hub_coo(n: int, skew: int, base_deg: int = 2, hub_frac: float = 1 / 64,
             tile_dim: int = 8, seed: int = 0):
    """Directed COO with a controlled tile-level skew knob.

    Every row gets ``base_deg`` random out-edges (≈ base_deg × tile_dim
    tiles per tile-row); one row per ``1/hub_frac`` tile-rows is a hub with
    enough edges to land ≈ ``skew`` × the mean tile count (oversampled 1.5x
    to beat distinct-tile saturation).
    """
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n, dtype=np.int64), base_deg)
    cols = rng.integers(0, n, rows.size)
    n_tile_rows = -(-n // tile_dim)
    hub_tile_rows = rng.choice(n_tile_rows, max(int(n_tile_rows * hub_frac), 1),
                               replace=False)
    hub_deg = int(1.5 * skew * base_deg * tile_dim)
    for tr in hub_tile_rows:
        hr = np.full(hub_deg, tr * tile_dim, np.int64)
        rows = np.concatenate([rows, hr])
        cols = np.concatenate([cols, rng.integers(0, n, hub_deg)])
    return rows, cols


def _measured_skew(ell) -> float:
    counts = np.asarray(ell.row_n_tiles)
    counts = counts[counts > 0]
    if counts.size == 0:
        return 1.0
    return float(counts.max() / counts.mean())


def _bench_pair(name: str, ell, bucketed, x_packed, x_dense,
                rows_out: List[BenchRow], detail: dict) -> None:
    """Time bmv + spmm on both paths; assert identical outputs first."""
    f_bmv_ell = jax.jit(lambda e, x: ops.bmv_bin_bin_full(e, x, jnp.int32))
    f_bmv_bkt = jax.jit(
        lambda b, x: ops.bmv_bin_bin_full_bucketed(b, x, jnp.int32))
    f_spmm_ell = jax.jit(ops.spmm_b2sr)
    f_spmm_bkt = jax.jit(ops.spmm_b2sr_bucketed)

    y_ell = np.asarray(f_bmv_ell(ell, x_packed))
    y_bkt = np.asarray(f_bmv_bkt(bucketed, x_packed))
    s_ell = np.asarray(f_spmm_ell(ell, x_dense))
    s_bkt = np.asarray(f_spmm_bkt(bucketed, x_dense))
    match = bool(np.array_equal(y_ell, y_bkt) and np.array_equal(s_ell, s_bkt))
    if not match:
        raise AssertionError(
            f"{name}: bucketed outputs diverge from the single-ELL path "
            "(load balancing must be bit-exact)")

    t_bmv_ell = time_fn(f_bmv_ell, ell, x_packed)
    t_bmv_bkt = time_fn(f_bmv_bkt, bucketed, x_packed)
    t_spmm_ell = time_fn(f_spmm_ell, ell, x_dense)
    t_spmm_bkt = time_fn(f_spmm_bkt, bucketed, x_dense)

    skew = _measured_skew(ell)
    entry = {
        "skew": round(skew, 2),
        "fill_ratio_ell": round(ell_fill_ratio(ell), 4),
        "fill_ratio_bucketed": round(bucketed.fill_ratio(), 4),
        "padded_words_ell": int(ell.tile_col_idx.shape[0]
                                * ell.tile_col_idx.shape[1]),
        "padded_words_bucketed": bucketed.padded_words(),
        "real_words": bucketed.real_words(),
        "n_buckets": bucketed.n_buckets,
        "bucket_widths": list(bucketed.bucket_widths),
        "bmv_ell_us": t_bmv_ell * 1e6,
        "bmv_bucketed_us": t_bmv_bkt * 1e6,
        "bmv_speedup": t_bmv_ell / t_bmv_bkt,
        "spmm_ell_us": t_spmm_ell * 1e6,
        "spmm_bucketed_us": t_spmm_bkt * 1e6,
        "spmm_speedup": t_spmm_ell / t_spmm_bkt,
        "outputs_match": match,
    }
    detail[name] = entry
    rows_out.append(BenchRow(
        f"bucketed/{name}/bmv", t_bmv_bkt * 1e6,
        f"speedup={entry['bmv_speedup']:.2f}x skew={skew:.1f} "
        f"fill={entry['fill_ratio_bucketed']:.2f}v{entry['fill_ratio_ell']:.2f} "
        f"match={match}"))
    rows_out.append(BenchRow(
        f"bucketed/{name}/spmm", t_spmm_bkt * 1e6,
        f"speedup={entry['spmm_speedup']:.2f}x skew={skew:.1f} "
        f"match={match}"))


def run(tiny: bool = False) -> List[BenchRow]:
    rows_out: List[BenchRow] = []
    detail: dict = {"mode": "tiny" if tiny else "full"}

    n = 512 if tiny else 8192
    d = 16 if tiny else 32
    skews = (16,) if tiny else (4, 16, 64)
    tile_dims = (8,) if tiny else (8, 16)
    base_deg = 2 if tiny else 1
    rng = np.random.default_rng(99)

    # -- controlled-skew hub graphs: skew × tile_dim --------------------------
    for t in tile_dims:
        for skew in skews:
            r, c = _hub_coo(n, skew, base_deg=base_deg, tile_dim=t, seed=skew)
            ell = to_ell(coo_to_b2sr(r, c, n, n, t))
            bucketed = to_bucketed(ell)
            x_packed = pack_bitvector(
                jnp.asarray(rng.random(n) > 0.5), t, n)
            x_dense = jnp.asarray(rng.random((n, d)).astype(np.float32))
            _bench_pair(f"hub/skew{skew}/t{t}", ell, bucketed, x_packed,
                        x_dense, rows_out, detail)

    # -- R-MAT (the paper's power-law benchmark shape) ------------------------
    for t in tile_dims:
        r, c = G.rmat_graph(n, avg_degree=8, seed=3, symmetric=False)
        ell = to_ell(coo_to_b2sr(r, c, n, n, t))
        bucketed = to_bucketed(ell)
        x_packed = pack_bitvector(jnp.asarray(rng.random(n) > 0.5), t, n)
        x_dense = jnp.asarray(rng.random((n, d)).astype(np.float32))
        _bench_pair(f"rmat/t{t}", ell, bucketed, x_packed, x_dense,
                    rows_out, detail)

    # -- bucket-count trade-off on the long-tailed R-MAT histogram ------------
    t = tile_dims[0]
    r, c = G.rmat_graph(n, avg_degree=8, seed=3, symmetric=False)
    ell = to_ell(coo_to_b2sr(r, c, n, n, t))
    x_packed = pack_bitvector(jnp.asarray(rng.random(n) > 0.5), t, n)
    f_bkt = jax.jit(lambda b, x: ops.bmv_bin_bin_full_bucketed(b, x, jnp.int32))
    sweep = {}
    for max_buckets in (1, 2, 4, 8, 16):
        bucketed = to_bucketed(ell, max_buckets=max_buckets)
        tb = time_fn(f_bkt, bucketed, x_packed)
        sweep[f"max_buckets={max_buckets}"] = {
            "fill_ratio": round(bucketed.fill_ratio(), 4),
            "n_buckets": bucketed.n_buckets,
            "bmv_us": tb * 1e6,
        }
        rows_out.append(BenchRow(
            f"bucketed/sweep/t{t}/K{max_buckets}", tb * 1e6,
            f"fill={bucketed.fill_ratio():.3f} buckets={bucketed.n_buckets}"))
    detail[f"buckets_sweep/t{t}"] = sweep

    save_json("kernels_bucketed.json", detail)
    return rows_out


if __name__ == "__main__":
    import sys
    for row in run(tiny="--tiny" in sys.argv):
        print(row.csv())
