"""Training launcher: --arch <id> against whatever devices are attached.

On a TPU slice this builds the production mesh and full config; on CPU (CI,
this container) it uses the reduced config and a debug mesh so the same
entry point exercises the identical code path end-to-end:

  PYTHONPATH=src python -m repro.launch.train --arch gcn-cora --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --steps 30 --batch 8 --seq 128

Fault tolerance: pass --ckpt-dir to checkpoint every --ckpt-every steps and
restart-from-latest on relaunch (see training/trainer.py for the exact
semantics: atomic manifests, data-stream resumption, straggler logging).
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import logging

import jax

from repro.configs import get_config, get_reduced_config
from repro.configs.base import DINConfig, GNNConfig, TransformerConfig
from repro.data import synthetic
from repro.training import optimizer as opt_mod
from repro.training import train_steps
from repro.training.trainer import TrainerConfig, TrainState, run


def build(arch: str, reduced: bool, batch: int, seq: int, nodes: int):
    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    key = jax.random.PRNGKey(0)
    opt_cfg = opt_mod.OptimizerConfig(name="adamw", lr=1e-3)

    if isinstance(cfg, TransformerConfig):
        from repro.models import transformer as T
        params = T.init_params(cfg, key)
        step = train_steps.lm_train_step(cfg, opt_cfg)
        data = synthetic.TokenStream(cfg, batch, seq, seed=0)
        return cfg, params, opt_cfg, step, data

    if isinstance(cfg, GNNConfig):
        if cfg.family == "graphcast":
            raise SystemExit("use examples/ for graphcast (needs mesh spec)")
        from repro.launch.specs import _gnn_init
        cfg = dataclasses.replace(cfg, d_in=min(cfg.d_in, 64))
        params = _gnn_init(cfg, key)
        step = train_steps.gnn_train_step(cfg, opt_cfg)
        b = synthetic.full_graph_batch(cfg, nodes, pattern="block", seed=1,
                                       coords=cfg.family == "egnn")
        return cfg, params, opt_cfg, step, itertools.repeat((b,))

    assert isinstance(cfg, DINConfig)
    from repro.models.recsys import din
    params = din.init_params(cfg, key)
    step = train_steps.din_train_step(cfg, opt_cfg)

    def din_stream():
        i = 0
        while True:
            yield (synthetic.din_batch(cfg, batch, seed=i),)
            i += 1

    return cfg, params, opt_cfg, step, din_stream()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--nodes", type=int, default=512)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (paper-scale) config — TPU slices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--deadline-s", type=float, default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    cfg, params, opt_cfg, step, data = build(
        args.arch, not args.full_config, args.batch, args.seq, args.nodes)
    opt_state = opt_mod.init(opt_cfg, params)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={args.arch} params={n_params/1e6:.2f}M "
          f"devices={len(jax.devices())}")

    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, log_every=10,
                         step_deadline_s=args.deadline_s)
    out = run(tcfg, jax.jit(step), TrainState(params, opt_state), data)
    print(f"done: step {out['final_step']} "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
