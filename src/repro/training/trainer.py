"""Fault-tolerant training driver.

Production posture (DESIGN.md §7):
  - restart-from-latest: on (re)start the trainer restores the newest intact
    checkpoint (atomic manifests make torn writes invisible) and the data
    stream position, so a node failure costs at most ``ckpt_every`` steps;
  - step deadline (straggler mitigation): each step gets a wall-clock budget;
    a breach is logged and counted — the fleet-scale reaction (re-slice the
    job, evict the straggler) is delegated to the launcher, the trainer just
    surfaces the signal;
  - elastic rescale: checkpoints are mesh-agnostic (full arrays), so a
    restart may pass a different mesh/shardings and the restore re-shards;
  - failure injection for tests (``fail_at_step``) exercises the recovery
    path deterministically.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.training import checkpoint as ckpt_mod
from repro.training.optimizer import OptState

log = logging.getLogger("repro.trainer")


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    step_deadline_s: Optional[float] = None   # straggler budget
    log_every: int = 10
    fail_at_step: Optional[int] = None        # failure injection (tests)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: OptState
    step: int = 0


def run(tcfg: TrainerConfig, step_fn: Callable, state: TrainState,
        data: Iterator, shardings: Any = None,
        data_state_hooks=None) -> Dict[str, Any]:
    """Run the loop; returns summary metrics. ``step_fn(params, opt, *batch)``.

    ``data`` may expose .state()/.restore() for exact stream resumption.
    """
    history = []
    stragglers = 0

    # --- restart-from-latest ---
    if tcfg.ckpt_dir:
        latest = ckpt_mod.latest_step(tcfg.ckpt_dir)
        if latest is not None and latest > state.step:
            tree = {"params": state.params, "opt": state.opt_state}
            restored, extra = ckpt_mod.restore(
                tcfg.ckpt_dir, latest, tree, shardings)
            state = TrainState(params=restored["params"],
                               opt_state=restored["opt"], step=latest)
            if hasattr(data, "restore") and "data" in extra:
                data.restore(extra["data"])
            log.info("restored checkpoint at step %d", latest)

    while state.step < tcfg.total_steps:
        batch = next(data)
        if not isinstance(batch, tuple):
            batch = (batch,)
        t0 = time.monotonic()
        if tcfg.fail_at_step is not None and state.step == tcfg.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {state.step}")
        params, opt_state, metrics = step_fn(state.params, state.opt_state,
                                             *batch)
        loss = float(metrics["loss"])
        if not np.isfinite(loss):
            raise FloatingPointError(f"non-finite loss at step {state.step}")
        dt = time.monotonic() - t0
        if tcfg.step_deadline_s and dt > tcfg.step_deadline_s:
            stragglers += 1
            log.warning("straggler: step %d took %.2fs (budget %.2fs)",
                        state.step, dt, tcfg.step_deadline_s)
        state = TrainState(params=params, opt_state=opt_state,
                           step=state.step + 1)
        history.append(loss)
        if tcfg.log_every and state.step % tcfg.log_every == 0:
            log.info("step %d loss %.4f (%.0f ms)", state.step, loss, dt * 1e3)
        if tcfg.ckpt_dir and state.step % tcfg.ckpt_every == 0:
            extra = {"data": data.state()} if hasattr(data, "state") else {}
            ckpt_mod.save(tcfg.ckpt_dir, state.step,
                          {"params": state.params, "opt": state.opt_state},
                          extra=extra, keep=tcfg.keep_ckpts)

    return {
        "final_step": state.step,
        "losses": history,
        "stragglers": stragglers,
        "state": state,
    }
