"""Pure-jnp oracles for the SpMM kernels: densify, then dense matmul."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.b2sr import (B2SREll, pack_frontier_matrix,
                             unpack_frontier_matrix)
from repro.kernels.bmv.ref import dense_from_ell


def spmm(ell: B2SREll, x: jnp.ndarray) -> jnp.ndarray:
    a = dense_from_ell(ell, x.dtype)
    return a @ x


def spmm_bbb(ell: B2SREll, f_packed: jnp.ndarray) -> jnp.ndarray:
    """Packed-RHS oracle: unpack, float matmul, re-pack the >0 bits."""
    a = dense_from_ell(ell, jnp.float32)
    s_pad = f_packed.shape[2] * 32
    f = unpack_frontier_matrix(f_packed, ell.n_cols, s_pad, jnp.float32)
    return pack_frontier_matrix((a @ f) > 0, ell.tile_dim, ell.n_rows)
