"""Central kernel-dispatch registry for the unified GraphBLAS API.

Every compute path in the system — jnp word schemes (``repro.core.ops``),
their multi-device shard_map twins (``repro.core.ops_sharded``), Pallas
kernels (``repro.kernels.*.ops``), and the float-CSR baseline
(``repro.core.csr_backend``) — registers its implementations here at
import time, keyed by the full Table II/III coordinate:

    (op, rhs, out, backend, bucketed, masked, sharded)

  op        "mxv" | "mxm" | "mxm_sum" (the fused Σ mask ⊙ (A·B) reduction)
            | "mxv_pull" | "mxm_pull" (the direction-optimized pull
            traversal rows — masked-only, selected by
            ``Descriptor(direction="pull")``; DESIGN.md §12)
  rhs       operand kind of the right-hand side: "dense" | "bitvec" |
            "frontier" | "graph" | "tri" (the memoized lower-triangle pair)
            | "bitmat" (packed binarized activation matrix — the BitGNN
            bin·bin→full aggregation rows; DESIGN.md §15)
  out       "bin" (packed words) | "full" (dense values) — derived from
            the semiring: boolean ⊕.⊗ produces packed bits
  backend   "b2sr" | "b2sr_pallas" | "csr"
  bucketed  whether the SELL-style row-bucketed path is active
  masked    whether a §V output mask is applied
  sharded   whether the matrix is row-partitioned across a device mesh
            (``GraphMatrix.shard``): the row runs under ``jax.shard_map``
            over the stacked per-shard slabs (DESIGN.md §11)

``GraphMatrix`` resolves one entry per call instead of walking per-method
if/elif ladders; adding a backend or a Table row is a registration, not an
edit in seven methods (DESIGN.md §10).

Implementations have the uniform signature ``fn(g, rhs, call)`` where
``g`` is the GraphMatrix, ``rhs`` the raw right-hand operand (packed words
/ dense array / GraphMatrix / lower-triangle pair), and ``call`` an
:class:`OpCall` with the semiring and the normalized descriptor fields.
They return the *raw* result (words, grids, dense arrays); the generic
layer wraps it back into typed operands / GraphMatrix.

Backend modules are imported lazily on the first lookup for that backend,
so importing ``repro.core.graphblas`` does not pull in the Pallas stack.
"""

from __future__ import annotations

import dataclasses
import importlib
import sys
import time
import warnings
from typing import Any, Callable, Dict, Iterable, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.semiring import Semiring

Key = Tuple[str, str, str, str, bool, bool, bool]

#: op -> human-readable paper row, for docs and error messages
#: (DESIGN.md §10 carries the full Table II/III -> key mapping).
OPS = ("mxv", "mxm", "mxm_sum", "mxv_pull", "mxm_pull")

#: Ops whose rows exist only with a mask: pull *is* "scan my in-edges for
#: an unvisited-row parent" — without the visited mask it degenerates to
#: push, and mxm_sum is the fused masked reduction by definition. The
#: registry-completeness test exempts these from the full flag square.
MASKED_ONLY_OPS = ("mxm_sum", "mxv_pull", "mxm_pull")
RHS_KINDS = ("dense", "bitvec", "frontier", "graph", "tri", "bitmat")
OUT_KINDS = ("bin", "full")

_REGISTRY: Dict[Key, Callable] = {}

# Modules that register implementations for each backend, imported on the
# first resolve() against that backend (registration-at-import-time without
# eagerly importing the Pallas stack).
_BACKEND_MODULES: Dict[str, Tuple[str, ...]] = {
    "b2sr": ("repro.core.ops", "repro.core.ops_sharded"),
    "b2sr_pallas": (
        "repro.kernels.bmv.ops",
        "repro.kernels.spmm.ops",
        "repro.kernels.spgemm.ops",
        "repro.kernels.bmm.ops",
        "repro.core.ops_sharded",
    ),
    "csr": ("repro.core.csr_backend",),
}
_LOADED: set = set()

#: Dispatch counters: tests assert every public op resolves through here.
stats = {"resolves": 0}
last_key: Optional[Key] = None


class InjectedFault(RuntimeError):
    """A deterministic fault raised through the resolve hook.

    Stands in for a real kernel/backend failure (OOM, miscompiled Pallas
    kernel, device loss) so the serving layer's fallback and
    circuit-breaker behavior is testable without real GPU faults. Raised
    by the engine's :class:`~repro.engine.faults.FaultInjector` when it is
    installed via :func:`set_resolve_hook`.
    """


_RESOLVE_HOOK: Optional[Callable[[Key], None]] = None


def set_resolve_hook(hook: Optional[Callable[[Key], None]]
                     ) -> Optional[Callable[[Key], None]]:
    """Install (or clear, with ``None``) the resolve-time hook.

    The hook is called with the fully-specified key on every successful
    :func:`resolve` — i.e. at trace time for every kernel a plan bakes in
    — and may raise (typically :class:`InjectedFault`) to make that
    resolution fail exactly where a broken kernel would. Returns the
    previously installed hook so callers can restore it.
    """
    global _RESOLVE_HOOK
    prev = _RESOLVE_HOOK
    _RESOLVE_HOOK = hook
    return prev


_OBSERVE_HOOK: Optional[Callable[[Key, float, Optional[BaseException]],
                                 None]] = None


def set_observe_hook(hook: Optional[Callable[[Key, float,
                                              Optional[BaseException]],
                                             None]]
                     ) -> Optional[Callable]:
    """Install (or clear) the read-only observe hook.

    The observability sibling of :func:`set_resolve_hook`: called as
    ``hook(key, duration_s, err)`` on **every** :func:`resolve` — whether
    it succeeded (``err is None``), the resolve hook aborted it (``err``
    is the raised exception, typically :class:`InjectedFault`), or the row
    was missing (``err`` is the :class:`NotImplementedError`).
    ``duration_s`` is the resolve wall time, lazy backend import included.

    Unlike the resolve hook it must never raise a control-flow exception:
    any exception it raises is swallowed — observation cannot change what
    executes. ``repro.obs`` installs a registry-counting default on
    import; returns the previously installed hook.
    """
    global _OBSERVE_HOOK
    prev = _OBSERVE_HOOK
    _OBSERVE_HOOK = hook
    return prev


def _observe(key: Key, t0: float, err: Optional[BaseException]) -> None:
    if _OBSERVE_HOOK is None:
        return
    try:
        _OBSERVE_HOOK(key, time.perf_counter() - t0, err)
    except Exception:                        # noqa: BLE001 — read-only hook
        pass


@dataclasses.dataclass
class OpCall:
    """The normalized per-call context handed to registered impls.

    ``mask`` is already in the row's raw form (packed words for packed
    outputs, a GraphMatrix for SpGEMM, a dense array for dense outputs) —
    the generic layer normalizes typed wrappers before dispatch.
    """

    semiring: Semiring
    mask: Any = None
    complement: bool = False
    row_chunk: Optional[int] = None
    a_value: float = 1.0
    out_dtype: Any = None


def _iter_flags(v: Union[bool, Iterable[bool]]) -> Tuple[bool, ...]:
    return (v,) if isinstance(v, bool) else tuple(v)


BOTH = (False, True)


def register(op: str, rhs: str, out: str, backend: str,
             bucketed: Union[bool, Iterable[bool]] = BOTH,
             masked: Union[bool, Iterable[bool]] = BOTH,
             sharded: Union[bool, Iterable[bool]] = False):
    """Decorator: register ``fn`` for every (bucketed, masked, sharded) combo.

    The flag params accept a bool or an iterable of bools; backends whose
    kernels take the mask as an argument register one function for both
    masked flags, backends with separate ``*_masked`` schemes register each
    flag separately. ``sharded`` defaults to False — single-device rows
    never see the flag; the shard_map twins in ``repro.core.ops_sharded``
    register with ``sharded=True``.
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
    if rhs not in RHS_KINDS:
        raise ValueError(f"unknown rhs kind {rhs!r}")
    if out not in OUT_KINDS:
        raise ValueError(f"unknown out kind {out!r}")

    def deco(fn: Callable) -> Callable:
        for b in _iter_flags(bucketed):
            for m in _iter_flags(masked):
                for s in _iter_flags(sharded):
                    key: Key = (op, rhs, out, backend, b, m, s)
                    if key in _REGISTRY:
                        raise ValueError(f"duplicate registration for {key}")
                    _REGISTRY[key] = fn
        return fn

    return deco


def _ensure_backend(backend: str) -> None:
    if backend in _LOADED:
        return
    for mod in _BACKEND_MODULES.get(backend, ()):
        importlib.import_module(mod)
    _LOADED.add(backend)


def resolve(op: str, rhs: str, out: str, backend: str, bucketed: bool,
            masked: bool, sharded: bool = False) -> Callable:
    """Look up the implementation for one fully-specified Table row."""
    global last_key
    t0 = time.perf_counter()
    key: Key = (op, rhs, out, backend, bucketed, masked, sharded)
    _ensure_backend(backend)
    fn = _REGISTRY.get(key)
    if fn is None:
        hint = (" (sharded rows exist only for the b2sr backends — "
                "call GraphMatrix.unshard() for this op)" if sharded else "")
        err = NotImplementedError(
            f"no kernel registered for op={op} rhs={rhs} out={out} "
            f"backend={backend} bucketed={bucketed} masked={masked} "
            f"sharded={sharded}{hint}; "
            f"registered rows: {sorted(k for k in _REGISTRY if k[0] == op)}")
        _observe(key, t0, err)
        raise err
    if _RESOLVE_HOOK is not None:
        try:
            _RESOLVE_HOOK(key)
        except BaseException as e:
            # the observe hook still sees the aborted resolution: injected
            # faults must land in the telemetry exactly like real ones
            _observe(key, t0, e)
            raise
    _observe(key, t0, None)
    stats["resolves"] += 1
    last_key = key
    return fn


def registered_keys(load_all: bool = False) -> Tuple[Key, ...]:
    """All registered keys (optionally forcing every backend module in)."""
    if load_all:
        for backend in _BACKEND_MODULES:
            _ensure_backend(backend)
    return tuple(sorted(_REGISTRY))


def out_kind_for(semiring: Semiring, rhs: str) -> str:
    """Derive the Table-row output column from (semiring, operand kind).

    Boolean ⊕.⊗ over packed operands stays packed (bin·bin→bin); any other
    semiring — or a dense operand — produces full-precision output.
    """
    if semiring.name == "boolean" and rhs in ("bitvec", "frontier", "graph"):
        return "bin"
    return "full"


#: Semirings each (op, rhs) pair can honor. The "full" rows over packed
#: operands hard-code the plus-count / plus-times reduction, so any other
#: semiring must be rejected up front — never silently reinterpreted as
#: counts (dense-rhs mxv is the general-semiring row and accepts all).
SEMIRING_ROWS = {
    ("mxv", "bitvec"): ("boolean", "arithmetic"),
    ("mxm", "dense"): ("arithmetic",),
    ("mxm", "frontier"): ("boolean",),
    # bin·bin→full (BitGNN aggregation over binarized activations): the
    # popcount accumulation *is* the plus-and reduction — arithmetic only
    ("mxm", "bitmat"): ("arithmetic",),
    ("mxm", "graph"): ("boolean", "arithmetic"),
    # the pull rows are the boolean traversal only: early exit is "first
    # set bit wins", which no counting/min-plus reduction can honor
    ("mxv_pull", "bitvec"): ("boolean",),
    ("mxm_pull", "frontier"): ("boolean",),
}


def check_semiring(op: str, rhs: str, semiring: Semiring) -> None:
    """Reject semirings the resolved Table row cannot honor."""
    allowed = SEMIRING_ROWS.get((op, rhs))
    if allowed is not None and semiring.name not in allowed:
        raise NotImplementedError(
            f"{op} over a {rhs} operand supports only the {allowed} "
            f"semiring(s), got {semiring.name!r}")


def reject_sharded_row_chunk(op: str, row_chunk) -> None:
    """Raise on ``row_chunk`` + sharded *before* any operand staging.

    The sharded rows cannot honor chunked row evaluation — the row
    partition already bounds per-device memory — and their own backstop
    checks only fire inside the adapter, after the generic layer has
    staged operands for tracing. ``GraphMatrix.mxv``/``mxm``/``tri_count``
    call this first so the error is immediate and names the op.
    """
    if row_chunk is not None:
        raise ValueError(
            f"{op}: row_chunk is not supported on the sharded path — the "
            "row partition already bounds per-device memory (unshard() "
            "first if chunked evaluation is required)")


def apply_output_mask(y, mask, complement: bool, identity):
    """§V mask-at-store for dense outputs: masked-out entries → identity.

    The one shared post-mask used by every adapter whose scheme has no
    fused masked variant (jnp-bucketed, Pallas, CSR counts), so the mask
    semantics live in exactly one place.
    """
    keep = (mask == 0) if complement else (mask != 0)
    return jnp.where(keep, y, identity)


# ---------------------------------------------------------------------------
# Deprecation machinery for the legacy per-row method names
# ---------------------------------------------------------------------------

class GraphBLASDeprecationWarning(DeprecationWarning):
    """Raised (as a warning) by the legacy ``GraphMatrix`` method shims."""


def warn_deprecated(old: str, new: str) -> None:
    """Warn that a legacy method shim was called; *raise* for internal code.

    External callers get a :class:`GraphBLASDeprecationWarning` and the old
    behavior. Call sites inside ``repro.*`` raise instead — ``algorithms/``
    and ``engine/`` can never quietly regress onto the shims (the CI
    contract; see ISSUE 4 / DESIGN.md §10).
    """
    caller = sys._getframe(2).f_globals.get("__name__", "")
    msg = (f"GraphMatrix.{old} is deprecated; use {new} "
           f"(see DESIGN.md §10)")
    if caller.split(".", 1)[0] == "repro":
        raise RuntimeError(
            f"{msg} — repro-internal call sites must use the unified API "
            f"(called from {caller})")
    warnings.warn(msg, GraphBLASDeprecationWarning, stacklevel=3)
