"""Fault-tolerant graph query serving on top of the batching engine.

``GraphQueryServer`` wraps :class:`~repro.engine.batcher.QueryBatcher`'s
coalescing core with the behaviors that survive contact with real traffic
(DESIGN.md §13):

  **Deadline-aware admission.** Every submit carries a latency budget;
  ``poll()`` fires a flush when the *oldest* pending query's deadline
  comes within ``flush_margin_s`` — latency-bound traffic no longer waits
  for a batch to fill. Fill still flushes too (``max_batch``), so the
  pow2-padded plan reuse from the batcher is unchanged. Admission is a
  bounded queue: overflow is **rejected** (:class:`QueryRejected`,
  synchronously, so the caller can retry elsewhere), never silently
  dropped — a submitted query always resolves.

  **Graceful degradation.** Every Table II/III row is registered on three
  bit-exact backends, so a failing Pallas kernel is not an error — it is
  a *downgrade*. Each group runs behind a per-(kind, backend) circuit
  breaker: a failure is retried once with exponential backoff, then the
  group falls through the ``b2sr_pallas → b2sr → csr`` chain (csr
  unshards first — the baseline has no sharded rows). After
  ``fail_threshold`` consecutive failures the breaker opens and traffic
  skips the backend outright; after ``cooldown_s`` it half-opens and one
  probe group tests recovery (success closes it, failure re-opens). The
  downgrade is recorded on the result handle (``handle.degraded``,
  ``handle.backend_used``).

  **Restart-safe warmup.** Every successful launch records a *plan
  recipe* — (graph fingerprint, kind, params, padded width, backend,
  layout flags), the serialisable identity of a
  :class:`~repro.engine.planner.PlanKey`. ``save_warmup(path)`` persists
  the set; ``warmup(path)`` on a restarted server replays each recipe
  against its registered graphs, pre-compiling the hot plans instead of
  paying first-query compile storms (we persist keys, not compiled
  artifacts — see DESIGN.md §13).

  **Deterministic fault injection.** Pass a
  :class:`~repro.engine.faults.FaultInjector` and the server consults it
  per launch attempt (and, when installed, the dispatch layer consults it
  per kernel resolution), so every behavior above is testable without
  real GPU faults.

The server is a synchronous event loop citizen: ``submit`` / ``poll`` /
``flush`` from one thread, with an injectable clock for deterministic
tests. ``handle.result()`` force-flushes, so a bare client can never hang
on an un-flushed queue.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.dispatch import InjectedFault  # noqa: F401  (re-export)
from repro.core.graphblas import GraphMatrix
from repro.engine import warmup as warmup_mod
from repro.engine.batcher import (QueryGroupError, QueryHandle, _Pending,
                                  launch_group, validate_query)
from repro.engine.faults import FaultInjector
from repro.engine.planner import PlanCache
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

#: Backend downgrade order: most-optimized first, the always-available
#: float-CSR baseline last. A graph's chain starts at its own backend.
FALLBACK_CHAIN = ("b2sr_pallas", "b2sr", "csr")


class QueryRejected(RuntimeError):
    """Admission-control rejection: the bounded queue is full.

    Raised synchronously from ``submit`` (the caller knows immediately and
    can back off / retry elsewhere) — overflow is never enqueued-and-
    dropped, so an accepted query always resolves.
    """

    def __init__(self, depth: int, max_queue: int):
        self.depth = depth
        self.max_queue = max_queue
        super().__init__(
            f"queue full ({depth}/{max_queue} pending); retry later")


# -- circuit breaker ---------------------------------------------------------

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    """Per-(kind, backend) failure gate with open → half-open recovery.

    ``fail_threshold`` *consecutive* failures open the breaker: traffic
    skips the backend without paying its failure latency. After
    ``cooldown_s`` the next ``allow()`` half-opens it — one probe group
    runs; success closes the breaker, failure re-opens it (and restarts
    the cooldown). Clock is injectable so tests pin transitions exactly.

    Every state change is recorded: ``transitions`` is the timestamped
    ``(ts, from, to)`` log and ``state_counts`` counts entries into each
    state (``closed`` starts at 1 — the breaker is born closed).
    ``on_transition(old, new, ts)``, when given, lets an owner mirror
    transitions into the metrics registry (the server does; see
    DESIGN.md §14).
    """

    def __init__(self, fail_threshold: int = 3, cooldown_s: float = 0.5,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Optional[Callable[[str, str, float],
                                                  None]] = None):
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        self.fail_threshold = fail_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self.on_transition = on_transition
        self.state = CLOSED
        self.failures = 0           # consecutive, while closed
        self.opened_at: Optional[float] = None
        self.n_opens = 0
        self.transitions: List[Tuple[float, str, str]] = []
        self.state_counts: Dict[str, int] = {CLOSED: 1, OPEN: 0,
                                             HALF_OPEN: 0}

    def _set_state(self, new: str) -> None:
        if new == self.state:
            return
        ts = self._clock()
        old = self.state
        self.state = new
        self.transitions.append((ts, old, new))
        self.state_counts[new] += 1
        if self.on_transition is not None:
            self.on_transition(old, new, ts)

    def allow(self) -> bool:
        if self.state == CLOSED:
            return True
        if (self.state == OPEN
                and self._clock() - self.opened_at >= self.cooldown_s):
            self._set_state(HALF_OPEN)
            return True
        return self.state == HALF_OPEN

    def record_success(self) -> None:
        self._set_state(CLOSED)
        self.failures = 0
        self.opened_at = None

    def record_failure(self) -> None:
        if self.state == HALF_OPEN:
            self._open()                     # failed probe: back to open
        else:
            self.failures += 1
            if self.failures >= self.fail_threshold:
                self._open()

    def _open(self) -> None:
        self._set_state(OPEN)
        self.opened_at = self._clock()
        self.failures = 0
        self.n_opens += 1

    def stats(self) -> dict:
        """One-dict snapshot: state, counters, and the transition log."""
        return {"state": self.state, "failures": self.failures,
                "n_opens": self.n_opens,
                "state_counts": dict(self.state_counts),
                "transitions": list(self.transitions)}


class ServerStats(dict):
    """The server's counter dict that is *also* callable.

    ``server.stats["completed"]`` keeps the historical counter access;
    ``server.stats()`` returns the aggregated one-dict snapshot — counters,
    queue depth, per-(kind, backend) breaker states with transition logs,
    plan-cache stats, and registered graph/recipe counts.
    """

    def __init__(self, server: "GraphQueryServer", **counters):
        super().__init__(**counters)
        self._server = server

    def __call__(self) -> dict:
        return self._server._stats_snapshot()


# -- server ------------------------------------------------------------------

@dataclasses.dataclass
class ServerConfig:
    """Knobs for admission, flushing, retry/fallback, and breakers."""

    max_queue: int = 1024            # bounded admission queue (reject over)
    max_batch: int = 256             # fill-flush threshold / group chunking
    default_budget_s: float = 0.100  # per-query latency budget if unset
    flush_margin_s: float = 0.005    # flush when a deadline is this close
    max_retries: int = 1             # same-backend retries before falling
    backoff_base_s: float = 0.0      # exp backoff: base * 2**attempt
    fail_threshold: int = 3          # consecutive failures to open a breaker
    cooldown_s: float = 0.5          # open -> half-open probe delay


@dataclasses.dataclass
class LaunchRecord:
    """Audit-log row: what one group launch actually executed.

    ``sources`` is the exact padded source tuple handed to the engine, so
    a degraded answer can be re-derived (and checked bit-exact) on the
    healthy backend by replaying the identical launch.
    """

    kind: str
    params: Tuple[Tuple[str, Any], ...]
    sources: Tuple[int, ...]
    graph_fp: str
    backend: str
    degraded: bool
    attempts: int


@dataclasses.dataclass
class _ServerPending(_Pending):
    deadline: float = 0.0


class GraphQueryServer:
    """Deadline-aware, fault-tolerant front end for batched graph queries."""

    def __init__(self, planner: Optional[PlanCache] = None,
                 config: Optional[ServerConfig] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 registry: Optional[obs_metrics.MetricsRegistry] = None,
                 max_traces: int = 1024):
        self.planner = planner if planner is not None else PlanCache()
        self.config = config if config is not None else ServerConfig()
        self.injector = fault_injector
        self._clock = clock
        self._sleep = sleep
        self._registry = registry            # None -> default at emit time
        self._pending: List[_ServerPending] = []
        self._graphs: Dict[str, GraphMatrix] = {}
        self._backend_views: Dict[Tuple[int, str], GraphMatrix] = {}
        self._breakers: Dict[Tuple[str, str], CircuitBreaker] = {}
        self._recipes: Dict[tuple, dict] = {}
        self.launch_log: List[LaunchRecord] = []
        #: completed-query traces, newest last (bounded; see dump_traces)
        self.trace_log: deque = deque(maxlen=max_traces)
        self.stats = ServerStats(
            self,
            submitted=0, completed=0, rejected=0, deduped=0,
            failed_queries=0, flushes=0, deadline_flushes=0,
            fill_flushes=0, launches=0, degraded_launches=0,
            retries=0, breaker_skips=0, warmup_replayed=0,
            warmup_skipped=0, warmup_failed=0,
        )

    # -- observability -------------------------------------------------------
    def _reg(self) -> obs_metrics.MetricsRegistry:
        return self._registry or obs_metrics.get_registry()

    def _count(self, name: str, help: str, n: float = 1, **labels) -> None:
        if obs_metrics.enabled():
            self._reg().counter("server_" + name, help,
                                tuple(sorted(labels))).inc(n, **labels)

    def _queue_gauge(self) -> None:
        if obs_metrics.enabled():
            self._reg().gauge("server_queue_depth",
                              "pending (admitted, unflushed) queries").set(
                len(self._pending))

    def _on_breaker_transition(self, kind: str, backend: str, old: str,
                               new: str, ts: float) -> None:
        if not obs_metrics.enabled():
            return
        reg = self._reg()
        reg.counter("server_breaker_transitions_total",
                    "circuit breaker state changes",
                    ("kind", "backend", "to")).inc(kind=kind,
                                                   backend=backend, to=new)
        reg.gauge("server_breaker_state", "0=closed 1=half_open 2=open",
                  ("kind", "backend")).set(
            {CLOSED: 0, HALF_OPEN: 1, OPEN: 2}[new], kind=kind,
            backend=backend)
        reg.event("breaker_transition", kind=kind, backend=backend,
                  from_state=old, to_state=new, at=ts)

    def _stats_snapshot(self) -> dict:
        """Everything operational about this server in one plain dict."""
        return {
            "counters": {k: v for k, v in self.stats.items()},
            "queue_depth": len(self._pending),
            "breakers": {f"{kind}/{backend}": br.stats()
                         for (kind, backend), br in
                         sorted(self._breakers.items())},
            "plan_cache": self.planner.stats(),
            "graphs": len(self._graphs),
            "recipes": len(self._recipes),
            "traces_held": len(self.trace_log),
        }

    # -- graph registry ------------------------------------------------------
    def register(self, graph: GraphMatrix) -> str:
        """Register a graph for serving and warmup replay; returns its
        structure fingerprint (idempotent — same fingerprint re-registers)."""
        fp = graph.fingerprint()
        self._graphs[fp] = graph
        return fp

    # -- admission -----------------------------------------------------------
    def submit(self, graph: GraphMatrix, kind: str, source: int,
               budget_s: Optional[float] = None, **params) -> QueryHandle:
        """Admit one query; returns a handle resolving within its budget.

        Raises :class:`QueryRejected` when the bounded queue is full and
        ``ValueError`` for an unknown kind or an out-of-range source —
        both synchronously, before any state changes.
        """
        t0 = time.monotonic()
        src = validate_query(graph, kind, source)
        if len(self._pending) >= self.config.max_queue:
            self.stats["rejected"] += 1
            self._count("queries_rejected_total",
                        "admission-control rejections", kind=kind)
            raise QueryRejected(len(self._pending), self.config.max_queue)
        self.register(graph)
        budget = (self.config.default_budget_s if budget_s is None
                  else float(budget_s))
        handle = QueryHandle(self)
        deadline = self._clock() + budget
        handle.deadline = deadline
        if handle.trace is not None:
            handle.trace.attrs.update(kind=kind, source=src,
                                      budget_s=budget)
            handle.trace.add_span("submit", t0, time.monotonic())
        self._pending.append(_ServerPending(
            graph=graph, kind=kind, source=src,
            params=tuple(sorted(params.items())), handle=handle,
            submitted_at=time.monotonic(), deadline=deadline))
        self.stats["submitted"] += 1
        self._count("queries_submitted_total", "admitted queries",
                    kind=kind)
        self._queue_gauge()
        if len(self._pending) >= self.config.max_batch:
            self._flush("fill")
        return handle

    def bfs(self, graph, source, budget_s=None, max_iters=None):
        return self.submit(graph, "bfs", source, budget_s=budget_s,
                           max_iters=max_iters)

    def khop(self, graph, source, k, budget_s=None):
        return self.submit(graph, "khop", source, budget_s=budget_s, k=k)

    def sssp(self, graph, source, budget_s=None, edge_weight=1.0):
        return self.submit(graph, "sssp", source, budget_s=budget_s,
                           edge_weight=edge_weight)

    def ppr(self, graph, seed, budget_s=None, alpha=0.85, max_iters=10,
            eps=1e-9):
        return self.submit(graph, "ppr", seed, budget_s=budget_s,
                           alpha=alpha, max_iters=max_iters, eps=eps)

    def gnn_infer(self, graph, node, model, budget_s=None):
        """Batched GNN inference for one node (BitGNN forward; DESIGN.md
        §15): class scores from the model registered under ``model`` via
        ``engine.queries.register_gnn_model``. Coalesces with every other
        pending query for the same (graph, model) into one full-graph
        forward, behind the same deadline/fallback/warmup machinery."""
        return self.submit(graph, "gnn_infer", node, budget_s=budget_s,
                           model=model)

    # -- flushing ------------------------------------------------------------
    def pending(self) -> int:
        return len(self._pending)

    def next_deadline(self) -> Optional[float]:
        if not self._pending:
            return None
        return min(q.deadline for q in self._pending)

    def due(self, now: Optional[float] = None) -> bool:
        """Whether the oldest pending deadline is within the flush margin."""
        dl = self.next_deadline()
        if dl is None:
            return False
        now = self._clock() if now is None else now
        return dl - now <= self.config.flush_margin_s

    def poll(self) -> int:
        """Deadline pump: flush everything once any deadline nears.

        Call from the serving loop (or a timer). Returns the number of
        queries flushed (0 when nothing is due).
        """
        if not self.due():
            return 0
        n = len(self._pending)
        self._flush("deadline")
        return n

    def flush(self, raise_errors: bool = False) -> None:
        """Force-run everything pending (``handle.result()`` calls this).

        Unlike ``QueryBatcher.flush`` this is quiet by default: failures
        are terminal per-handle verdicts (the fallback chain already ran),
        and the serving loop must not die with them.
        """
        del raise_errors                     # errors live on the handles
        if self._pending:
            self._flush("forced")

    def _flush(self, reason: str) -> None:
        groups: Dict[Tuple, List[_ServerPending]] = {}
        for q in self._pending:
            groups.setdefault((id(q.graph), q.kind, q.params), []).append(q)
        self._pending = []
        self.stats["flushes"] += 1
        self._count("flushes_total", "queue flushes by trigger",
                    reason=reason)
        self._queue_gauge()
        if reason == "deadline":
            self.stats["deadline_flushes"] += 1
        elif reason == "fill":
            self.stats["fill_flushes"] += 1
        for (_, kind, params), qs in groups.items():
            for start in range(0, len(qs), self.config.max_batch):
                self._run_group(kind, params, qs[start:start
                                                 + self.config.max_batch])

    # -- fallback execution --------------------------------------------------
    def _chain_for(self, g: GraphMatrix) -> Tuple[str, ...]:
        try:
            idx = FALLBACK_CHAIN.index(g.backend)
        except ValueError:                   # unknown backend: no fallback
            return (g.backend,)
        return FALLBACK_CHAIN[idx:]

    def _backend_view(self, g: GraphMatrix, backend: str) -> GraphMatrix:
        """``g`` on ``backend`` (memoized): csr unshards — no sharded rows."""
        if backend == g.backend:
            return g
        key = (id(g), backend)
        view = self._backend_views.get(key)
        if view is None:
            base = g.unshard() if (backend == "csr" and g.sharded) else g
            view = base.with_backend(backend)
            self._backend_views[key] = view
        return view

    def breaker(self, kind: str, backend: str) -> CircuitBreaker:
        key = (kind, backend)
        br = self._breakers.get(key)
        if br is None:
            br = CircuitBreaker(
                self.config.fail_threshold, self.config.cooldown_s,
                self._clock,
                on_transition=lambda old, new, ts, k=kind, b=backend:
                    self._on_breaker_transition(k, b, old, new, ts))
            self._breakers[key] = br
        return br

    def _run_group(self, kind: str, params: Tuple[Tuple[str, Any], ...],
                   qs: List[_ServerPending]) -> None:
        g = qs[0].graph
        chain = self._chain_for(g)
        last_err: Optional[BaseException] = None
        attempts = 0
        for backend in chain:
            br = self.breaker(kind, backend)
            if not br.allow():
                self.stats["breaker_skips"] += 1
                continue
            for attempt in range(self.config.max_retries + 1):
                attempts += 1
                try:
                    gv = self._backend_view(g, backend)
                    if self.injector is not None:
                        self.injector.check(kind, backend)
                    self.stats["launches"] += 1
                    n_dedup, padded = launch_group(gv, kind, dict(params),
                                                   qs, self.planner)
                except Exception as e:       # noqa: BLE001 — verdict per try
                    last_err = e
                    br.record_failure()
                    if (attempt < self.config.max_retries
                            and br.state == CLOSED):
                        self.stats["retries"] += 1
                        self._sleep(self.config.backoff_base_s
                                    * (2 ** attempt))
                        continue
                    break                    # breaker opened or retries spent
                br.record_success()
                self._finish_group(kind, params, qs, gv, g, padded,
                                   n_dedup, attempts)
                return
        err = QueryGroupError(kind, params, len(qs),
                              last_err if last_err is not None
                              else RuntimeError(
                                  f"all backends unavailable (breakers "
                                  f"open for {chain})"))
        self.stats["failed_queries"] += len(qs)
        self._count("queries_failed_total",
                    "queries whose whole fallback chain failed",
                    len(qs), kind=kind)
        if obs_metrics.enabled():
            self._reg().event("group_failed", kind=kind, n_queries=len(qs),
                              attempts=attempts, error=repr(err.__cause__))
        for q in qs:
            q.handle._fail(err)
            if q.handle.trace is not None:
                q.handle.trace.attrs.update(failed=True,
                                            error=repr(err.__cause__))
                self.trace_log.append(q.handle.trace)

    def _finish_group(self, kind, params, qs, gv: GraphMatrix,
                      g: GraphMatrix, padded: Tuple[int, ...],
                      n_dedup: int, attempts: int) -> None:
        degraded = gv.backend != g.backend
        now = self._clock()
        for q in qs:
            q.handle.backend_used = gv.backend
            q.handle.degraded = degraded
            q.handle.completed_at = now
            if q.handle.trace is not None:
                q.handle.trace.attrs.update(backend_used=gv.backend,
                                            degraded=degraded,
                                            attempts=attempts)
                self.trace_log.append(q.handle.trace)
        self.stats["completed"] += len(qs)
        self.stats["deduped"] += n_dedup
        self._count("queries_completed_total", "fulfilled queries",
                    len(qs), kind=kind, backend=gv.backend,
                    degraded=degraded)
        if degraded:
            self.stats["degraded_launches"] += 1
            self._count("degraded_launches_total",
                        "launches answered on a fallback backend",
                        kind=kind, backend=gv.backend)
        fp = g.fingerprint()
        self.launch_log.append(LaunchRecord(
            kind=kind, params=params, sources=padded, graph_fp=fp,
            backend=gv.backend, degraded=degraded, attempts=attempts))
        recipe = {
            "graph_fp": fp, "kind": kind, "params": dict(params),
            "width": len(padded), "backend": gv.backend,
            "use_buckets": bool(gv.use_buckets),
            "sharded": bool(g.sharded),
        }
        self._recipes[warmup_mod.recipe_key(recipe)] = recipe

    # -- trace export --------------------------------------------------------
    def dump_traces(self, path: str, append: bool = False,
                    clear: bool = True) -> int:
        """Write completed-query traces as JSONL; returns how many.

        ``clear`` (default) drains the bounded buffer so a periodic dump
        loop never re-writes old traces.
        """
        n = obs_trace.write_jsonl(path, list(self.trace_log), append=append)
        if clear:
            self.trace_log.clear()
        return n

    # -- restart-safe warmup -------------------------------------------------
    def save_warmup(self, path: str) -> int:
        """Persist the served plan-recipe set; returns how many were saved."""
        return warmup_mod.save(path, self._recipes.values())

    def warmup(self, path: str) -> int:
        """Replay a warmup file: pre-compile hot plans for registered graphs.

        Each recipe whose graph fingerprint is registered (and whose
        sharded flag matches) is replayed as one dummy launch of the
        recorded kind/width/backend — populating ``self.planner`` with
        exactly the plan the live query would need. Returns the number of
        recipes replayed; mismatched or failing recipes are counted in
        ``stats['warmup_skipped'] / ['warmup_failed']`` and never abort
        startup.
        """
        n = 0
        for r in warmup_mod.load(path):
            g = self._graphs.get(r["graph_fp"])
            if (g is None or bool(g.sharded) != r["sharded"]
                    or r["width"] > g.n_rows):
                self.stats["warmup_skipped"] += 1
                continue
            base = g if g.use_buckets == r["use_buckets"] else \
                g.with_buckets(r["use_buckets"])
            gv = self._backend_view(base, r["backend"])
            # distinct sources so in-flight dedup keeps the padded width
            qs = [_Pending(graph=gv, kind=r["kind"], source=i,
                           params=tuple(sorted(r["params"].items())),
                           handle=QueryHandle(None))
                  for i in range(r["width"])]
            try:
                launch_group(gv, r["kind"], dict(r["params"]), qs,
                             self.planner)
                n += 1
            except Exception:                # noqa: BLE001 — never abort boot
                self.stats["warmup_failed"] += 1
        self.stats["warmup_replayed"] += n
        return n
