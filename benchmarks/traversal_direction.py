"""Direction-optimizing traversal sweep: push vs pull vs auto BFS.

The direction layer's claim (DESIGN.md §12): on skewed graphs with a wide
mid-traversal frontier, complement-masked pull iterations with per-row
early exit beat push, and the auto policy captures most of that win
without tuning. This sweep runs bfs (and msbfs at a couple of batch
widths) with direction forced to ``push``, forced to ``pull``, and
``auto`` across **rmat skew × erdős background density** — the knobs
that control how wide the frontier hump gets — and records the auto
policy's per-iteration direction trace next to each timing, so the JSON
shows not just *that* a schedule won but *which* schedule auto chose.

The schedule only differs on ``b2sr_pallas``: its pull row is the
early-exit kernel, whose k-axis ``while_loop`` genuinely stops once every
allowed output lane is set (even in interpret mode the loop runs fewer
steps). On ``b2sr`` the pull row delegates to the same masked push block
math — bit-exactness anchor, identical cost — so the full sweep times it
as the control and the tiny (CI) sweep skips it. Every mode is bit-exact
against forced push (tests/test_direction.py), so the timings compare
schedules, not answers.

Reading the two families: the ``bfs_*`` rows retrace the traversal every
call (``bfs`` is not plan-cached), and the auto loop traces *both*
branches of its ``lax.cond``, so forced-push vs forced-pull is the clean
schedule comparison there; the ``msbfs*`` rows run through the engine's
plan cache (compile once, execute many), which is where the auto
policy's runtime win shows undiluted. ``results/traversal_direction.json``
records the full detail.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, save_json, time_fn
from repro.algorithms.bfs import bfs
from repro.core import GraphMatrix
from repro.data import graphs as G
from repro.engine import PlanCache, queries

MODES = ("push", "pull", "auto")


def _graph(n: int, skew: int, density: float, backend: str,
           seed: int) -> GraphMatrix:
    r1, c1 = G.rmat_graph(n, avg_degree=2 + 2 * skew, seed=seed)
    r2, c2 = G.dot_graph(n, density=density, seed=seed + 1)
    key = np.unique(np.concatenate([r1, r2]).astype(np.int64) * n
                    + np.concatenate([c1, c2]))
    return GraphMatrix.from_coo(key // n, key % n, n_rows=n, n_cols=n,
                                tile_dim=8, backend=backend)


def run(tiny: bool = False) -> List[BenchRow]:
    n = 256 if tiny else 1024
    skews = (1, 6) if tiny else (1, 4, 8)
    densities = (0.02,) if tiny else (0.002, 0.02)
    widths = (8,) if tiny else (8, 32)
    # csr rides the sweep as the schedule-fair float baseline: its pull
    # row (PR 6) is the masked push row on the float CSR twin, so the
    # push/pull/auto spread on csr brackets what direction choice is worth
    # when there is no bit-level early exit at all
    backends = (("b2sr_pallas", "csr") if tiny
                else ("b2sr", "b2sr_pallas", "csr"))

    rows_out: List[BenchRow] = []
    detail = {"n": n, "modes": list(MODES), "cases": []}
    for backend in backends:
        for skew in skews:
            for density in densities:
                g = _graph(n, skew, density, backend, seed=skew)
                case = {"backend": backend, "skew": skew, "density": density,
                        "avg_degree": g.nnz / n}
                for mode in MODES:
                    bfs(g, 0, direction=mode)             # compile
                    sec = time_fn(
                        lambda m=mode: bfs(g, 0, direction=m).levels)
                    case[f"bfs_{mode}_us"] = sec * 1e6
                res = bfs(g, 0, direction="auto")
                case["auto_trace"] = list(res.directions)
                case["n_iterations"] = res.n_iterations
                for s in widths:
                    srcs = np.arange(s) % n
                    for mode in MODES:
                        pc = PlanCache()
                        queries.msbfs(g, srcs, planner=pc, direction=mode)
                        sec = time_fn(lambda m=mode, p=pc: queries.msbfs(
                            g, srcs, planner=p, direction=m).levels)
                        case[f"msbfs{s}_{mode}_us_per_query"] = sec * 1e6 / s
                detail["cases"].append(case)
                best = min(MODES, key=lambda m: case[f"bfs_{m}_us"])
                rows_out.append(BenchRow(
                    f"direction/{backend}/skew{skew}/d{density}/bfs",
                    case["bfs_auto_us"],
                    f"best={best} push_us={case['bfs_push_us']:.1f} "
                    f"pull_us={case['bfs_pull_us']:.1f} "
                    f"trace={'>'.join(case['auto_trace'])}"))
    path = save_json("traversal_direction.json", detail)
    rows_out.append(BenchRow("direction/json", 0.0, path))
    return rows_out
