"""PartitionSpec rules per model family (DESIGN.md §7).

Strategy (v5e-style 2D mesh (data=16, model=16), optional leading pod axis):

- dense LM: Megatron-TP over "model" (attn heads / ffn hidden / vocab)
  combined with FSDP-style weight sharding over "data" on the other matrix
  dim — no parameter replication inside a pod. Batch shards over
  ("pod", "data"). The pod axis is pure DP for parameters.
- MoE LM: experts over "model" (EP), expert matrices additionally sharded
  over "data" (d_model or d_ff dim); dense residual like dense LM.
- GNN: node/edge arrays sharded over ("data", "model") flattened; params
  replicated (they are small).
- DIN: embedding tables row-sharded over ("data", "model"); MLPs replicated;
  batch over ("pod", "data").

Optimizer moments inherit the param specs (states are never replicated more
than their parameters — ZeRO-1-equivalent storage given FSDP weight specs).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DINConfig, GNNConfig, TransformerConfig


def batch_axes(mesh: Mesh):
    """Mesh axes the batch dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: TransformerConfig, params_shape) -> Any:
    """Spec tree matching the param tree (layers stacked: leading L dim)."""

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if "embed" in name:                       # [V, d]
            return P("model", "data")
        if "lm_head" in name:                     # [d, V]
            return P("data", "model")
        if "moe" in name:
            if "router" in name:                  # [L, d, E]
                return P(None, "data", None)
            if "dense_gate" in name or "dense_up" in name:   # [L, d, ff]
                return P(None, "data", "model")
            if "dense_down" in name:              # [L, ff, d]
                return P(None, "model", "data")
            # expert FFN: E over "model" (EP), d_model over "data". The
            # Megatron column→row flip (ff over "data") was tried and
            # REFUTED — the dispatch buffers then carry full-d activations
            # and wire grows 26% (EXPERIMENTS.md §Perf, qwen3 iteration);
            # the real fix is shard_map all-to-all expert dispatch (future
            # work).
            if "w_down" in name:                  # [L, E, ff, d]
                return P(None, "model", None, "data")
            if nd == 4:                           # w_gate/w_up [L, E, d, ff]
                return P(None, "model", "data", None)
        if "wq" in name or "wk" in name or "wv" in name:     # [L, d, *]
            return P(None, "data", "model")
        if "wo" in name:                          # [L, qdim, d]
            return P(None, "model", "data")
        if "w_gate" in name or "w_up" in name:    # [L, d, ff]
            return P(None, "data", "model")
        if "w_down" in name:                      # [L, ff, d]
            return P(None, "model", "data")
        return P()                                # norms etc: replicated

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def lm_batch_specs(mesh: Mesh):
    ba = batch_axes(mesh)
    return (P(ba, None), P(ba, None))             # (tokens, labels)


def lm_cache_specs(mesh: Mesh, cfg: TransformerConfig):
    """KV cache [L, B, T, KV, hd]: batch over DP axes, *sequence* over model.

    GQA kv-head counts (4–16) don't divide a 16-wide TP axis, so the cache
    shards the time axis instead — flash-decoding-style split-KV: softmax
    statistics and the tiny [B,1,H,hd] output all-reduce across "model"
    (cheap), while cache reads stay fully local. The cache write
    (dynamic-update-slice at cache_len) touches one shard; GSPMD lowers it
    to a local masked update.
    """
    ba = batch_axes(mesh)
    return P(None, ba, "model", None, None)


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def _mesh_axis_sizes(mesh: Mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def best_dim0_axes(mesh: Mesh, n: int):
    """Widest mesh-axis combination that divides dim0 evenly (inputs must
    shard evenly; intermediates may be uneven — GSPMD pads those)."""
    sizes = _mesh_axis_sizes(mesh)
    candidates = [("pod", "data", "model"), ("data", "model"),
                  ("pod", "data"), ("data",), ("model",)]
    for axes in candidates:
        if not all(a in sizes for a in axes):
            continue
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if n % prod == 0:
            return axes
    return None


def gnn_batch_specs(mesh: Mesh, batch_shape) -> Any:
    """Shard node/edge-leading arrays over the widest dividing axes."""

    def rule(path, leaf):
        if leaf is None:
            return None
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        axes = best_dim0_axes(mesh, leaf.shape[0])
        if axes is None:
            return P()                         # small/odd arrays: replicate
        return P(axes, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(
        rule, batch_shape, is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# DIN
# ---------------------------------------------------------------------------

def din_param_specs(cfg: DINConfig, params_shape) -> Any:
    def rule(path, leaf):
        name = _path_str(path)
        if "table" in name:                       # [rows, d] row-sharded
            axes = best_dim0_axes_static(leaf.shape[0])
            return P(axes, None) if axes else P()
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def best_dim0_axes_static(n: int):
    """Mesh-independent variant for 16-wide model axis tables."""
    for axes, prod in ((("data", "model"), 256), (("model",), 16)):
        if n % prod == 0:
            return axes
    return None


def din_batch_specs(mesh: Mesh, batch_shape) -> Any:
    ba = batch_axes(mesh)

    def rule(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(ba, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


# ---------------------------------------------------------------------------
# generic helpers
# ---------------------------------------------------------------------------

def opt_state_specs(param_specs, opt_state_shape) -> Any:
    """Moments inherit the param spec; scalars replicated."""
    from repro.training.optimizer import OptState

    def like_params(tree_shape):
        return jax.tree_util.tree_map(
            lambda spec, leaf: spec, param_specs, tree_shape)

    m = like_params(opt_state_shape.m)
    v = like_params(opt_state_shape.v) if opt_state_shape.v is not None else None
    return OptState(step=P(), m=m, v=v)


def tree_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if s is not None else None,
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)
