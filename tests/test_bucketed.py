"""Bucketed (SELL-style) ELL vs the single-max ELL path: bit-exact parity.

The bucketed representation (DESIGN.md §2) is a pure load-balancing
transform — every scheme must produce *identical* outputs through it:
bmv (all three Table II schemes + masks), spmm, mxm (bin and full, +mask),
across all tile dims, on skewed random graphs, including the permutation
round-trip, empty-bucket edge cases, the Pallas bucketed entry points, and
backend-transparent GraphMatrix/algorithms dispatch.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import (
    TILE_DIMS, GraphMatrix, b2sr_to_dense, coo_to_b2sr, ell_fill_ratio,
    pack_bitvector, to_bucketed, to_ell,
)
from repro.core import ops
from repro.core.semiring import ARITHMETIC, MIN_PLUS, MAX_TIMES
from repro.data import graphs as graph_gen


def skewed_coo(n, seed, hub_deg=40, base_deg=3):
    """Directed COO with a few hub rows (power-law-ish row skew)."""
    rng = np.random.default_rng(seed)
    rows = np.concatenate([
        np.repeat(np.arange(n, dtype=np.int64), base_deg),
        np.repeat(rng.choice(n, 3, replace=False).astype(np.int64), hub_deg),
    ])
    cols = rng.integers(0, n, rows.size)
    return rows, cols


def build(n, t, seed=0, **kw):
    rows, cols = skewed_coo(n, seed, **kw)
    mat = coo_to_b2sr(rows, cols, n, n, t)
    ell = to_ell(mat)
    return ell, to_bucketed(ell)


# ---------------------------------------------------------------------------
# structure: permutation round-trip, bucket invariants, fill accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
def test_bucket_permutation_roundtrip(t):
    n = 100
    ell, bk = build(n, t, seed=t)
    counts = np.asarray(ell.row_n_tiles)
    # every non-empty tile-row appears in exactly one bucket
    all_rows = np.concatenate([np.asarray(r) for r in bk.rows])
    assert sorted(all_rows.tolist()) == np.flatnonzero(counts > 0).tolist()
    # slabs hold exactly the original row contents (left-justified ELL)
    ell_col = np.asarray(ell.tile_col_idx)
    ell_tiles = np.asarray(ell.bit_tiles)
    for col, tiles, rows in zip(bk.col_idx, bk.bit_tiles, bk.rows):
        k_b = col.shape[1]
        assert np.array_equal(np.asarray(col), ell_col[np.asarray(rows), :k_b])
        assert np.array_equal(np.asarray(tiles),
                              ell_tiles[np.asarray(rows), :k_b])
        # no real entry of a bucketed row lives beyond its slab width
        assert (counts[np.asarray(rows)] <= k_b).all()
    # bucketing never holds more padded slots than the single-max ELL
    assert bk.real_words() == int((ell_col >= 0).sum())
    assert bk.padded_words() <= ell_col.size
    assert bk.fill_ratio() >= ell_fill_ratio(ell)


def test_bucket_width_merge_cap():
    n = 256
    ell, _ = build(n, 4, seed=9, hub_deg=60, base_deg=1)
    for max_buckets in (1, 2, 4):
        bk = to_bucketed(ell, max_buckets=max_buckets)
        assert bk.n_buckets <= max_buckets
        # merging only widens slabs; contents stay complete
        counts = np.asarray(ell.row_n_tiles)
        got = np.concatenate([np.asarray(r) for r in bk.rows])
        assert sorted(got.tolist()) == np.flatnonzero(counts > 0).tolist()


@pytest.mark.parametrize("t", (4, 16))
def test_empty_matrix_has_no_buckets(t):
    empty = np.array([], dtype=np.int64)
    ell = to_ell(coo_to_b2sr(empty, empty, 20, 20, t))
    bk = to_bucketed(ell)
    assert bk.n_buckets == 0
    xp = pack_bitvector(jnp.ones(20), t, 20)
    assert np.array_equal(np.asarray(ops.bmv_bin_bin_full_bucketed(bk, xp)),
                          np.asarray(ops.bmv_bin_bin_full(ell, xp)))
    assert np.array_equal(np.asarray(ops.bmv_bin_bin_bin_bucketed(bk, xp)),
                          np.asarray(ops.bmv_bin_bin_bin(ell, xp)))
    y = ops.bmv_bin_full_full_bucketed(bk, jnp.ones(20), MIN_PLUS)
    assert np.all(np.isinf(np.asarray(y)))


def test_uniform_rows_single_bucket():
    # identity matrix: every tile-row exactly 1 tile -> one bucket
    n = 64
    rows = np.arange(n, dtype=np.int64)
    cols = np.arange(n, dtype=np.int64)
    ell = to_ell(coo_to_b2sr(rows, cols, n, n, 8))
    bk = to_bucketed(ell)
    assert bk.n_buckets == 1
    xp = pack_bitvector(jnp.arange(n) % 3 == 0, 8, n)
    assert np.array_equal(np.asarray(ops.bmv_bin_bin_full_bucketed(bk, xp)),
                          np.asarray(ops.bmv_bin_bin_full(ell, xp)))


# ---------------------------------------------------------------------------
# jnp scheme parity (bit-exact) across tile dims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", TILE_DIMS)
def test_bmv_schemes_match(t):
    n = 120
    ell, bk = build(n, t, seed=t + 1)
    rng = np.random.default_rng(t)
    x = jnp.asarray(rng.random(n).astype(np.float32))
    xp = pack_bitvector(x > 0.5, t, n)
    mp = pack_bitvector(x > 0.3, t, n)

    assert np.array_equal(np.asarray(ops.bmv_bin_bin_bin(ell, xp)),
                          np.asarray(ops.bmv_bin_bin_bin_bucketed(bk, xp)))
    assert np.array_equal(
        np.asarray(ops.bmv_bin_bin_bin_masked(ell, xp, mp, complement=True)),
        np.asarray(ops.bmv_bin_bin_bin_bucketed_masked(bk, xp, mp,
                                                       complement=True)))
    assert np.array_equal(
        np.asarray(ops.bmv_bin_bin_full(ell, xp, jnp.int32)),
        np.asarray(ops.bmv_bin_bin_full_bucketed(bk, xp, jnp.int32)))
    for sr in (ARITHMETIC, MIN_PLUS, MAX_TIMES):
        assert np.array_equal(
            np.asarray(ops.bmv_bin_full_full(ell, x, sr, a_value=1.0)),
            np.asarray(ops.bmv_bin_full_full_bucketed(bk, x, sr,
                                                      a_value=1.0))), sr.name


@pytest.mark.parametrize("t", TILE_DIMS)
def test_spmm_matches(t):
    n = 96
    ell, bk = build(n, t, seed=t + 2)
    rng = np.random.default_rng(t)
    x = jnp.asarray(rng.random((n, 9)).astype(np.float32))
    assert np.array_equal(np.asarray(ops.spmm_b2sr(ell, x)),
                          np.asarray(ops.spmm_b2sr_bucketed(bk, x)))


@pytest.mark.parametrize("t", TILE_DIMS)
def test_mxm_matches(t):
    n = 72
    ell, bk = build(n, t, seed=t + 3, hub_deg=25, base_deg=2)
    # boolean grid, plain + masked/complement
    assert np.array_equal(np.asarray(ops.mxm_bin_bin_bin(ell, ell)),
                          np.asarray(ops.mxm_bin_bin_bin_bucketed(bk, ell)))
    for comp in (False, True):
        assert np.array_equal(
            np.asarray(ops.mxm_bin_bin_bin(ell, ell, mask=ell,
                                           complement=comp)),
            np.asarray(ops.mxm_bin_bin_bin_bucketed(bk, ell, mask=ell,
                                                    complement=comp)))
    # count SpGEMM, plain + masked
    assert np.array_equal(np.asarray(ops.mxm_bin_bin_full(ell, ell)),
                          np.asarray(ops.mxm_bin_bin_full_bucketed(bk, ell)))
    assert np.array_equal(
        np.asarray(ops.mxm_bin_bin_full_masked(ell, ell, ell)),
        np.asarray(ops.mxm_bin_bin_full_masked_bucketed(bk, ell, ell)))


def test_rmat_graph_parity_and_skew():
    n = 256
    rows, cols = graph_gen.rmat_graph(n, avg_degree=8, seed=5,
                                      symmetric=False)
    assert rows.size > 0 and (rows != cols).all()
    ell = to_ell(coo_to_b2sr(rows, cols, n, n, 8))
    bk = to_bucketed(ell)
    counts = np.asarray(ell.row_n_tiles)
    nz = counts[counts > 0]
    assert nz.max() / nz.mean() > 2.0  # power-law rows are actually skewed
    xp = pack_bitvector(jnp.arange(n) % 2 == 0, 8, n)
    assert np.array_equal(np.asarray(ops.bmv_bin_bin_full(ell, xp)),
                          np.asarray(ops.bmv_bin_bin_full_bucketed(bk, xp)))


# ---------------------------------------------------------------------------
# Pallas bucketed entry points (interpret mode) vs the jnp bucketed path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", (4, 8, 32))
def test_pallas_bucketed_bmv(t):
    from repro.kernels.bmv import ops as kbmv
    n = 96
    ell, bk = build(n, t, seed=t + 4)
    rng = np.random.default_rng(t)
    x = jnp.asarray(rng.random(n).astype(np.float32))
    xp = pack_bitvector(x > 0.5, t, n)
    mp = pack_bitvector(x > 0.2, t, n)
    assert np.array_equal(
        np.asarray(kbmv.bmv_bin_bin_full_bucketed(bk, xp, jnp.int32)),
        np.asarray(ops.bmv_bin_bin_full_bucketed(bk, xp, jnp.int32)))
    assert np.array_equal(
        np.asarray(kbmv.bmv_bin_bin_bin_bucketed(bk, xp, mp, True)),
        np.asarray(ops.bmv_bin_bin_bin_bucketed_masked(bk, xp, mp, True)))
    for sr in (ARITHMETIC, MIN_PLUS):
        assert np.allclose(
            np.asarray(kbmv.bmv_bin_full_full_bucketed(bk, x, sr)),
            np.asarray(ops.bmv_bin_full_full_bucketed(bk, x, sr)),
            atol=1e-5), sr.name


@pytest.mark.parametrize("t", (8, 16))
def test_pallas_bucketed_spmm_mxm(t):
    from repro.kernels.spmm import ops as kspmm
    from repro.kernels.spgemm import ops as kspgemm
    n = 64
    ell, bk = build(n, t, seed=t + 5, hub_deg=20, base_deg=2)
    rng = np.random.default_rng(t)
    x = jnp.asarray(rng.random((n, 8)).astype(np.float32))
    assert np.allclose(np.asarray(kspmm.spmm_bucketed(bk, x)),
                       np.asarray(ops.spmm_b2sr_bucketed(bk, x)), atol=1e-5)
    assert np.array_equal(
        np.asarray(kspgemm.mxm_bucketed(bk, ell, mask=ell, complement=True)),
        np.asarray(ops.mxm_bin_bin_bin_bucketed(bk, ell, mask=ell,
                                                complement=True)))


# ---------------------------------------------------------------------------
# GraphMatrix dispatch: bucketed default == unbucketed, zero call-site change
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("b2sr", "b2sr_pallas"))
def test_graphmatrix_transparent(backend):
    from repro.algorithms import bfs, sssp, pagerank
    n = 80
    rows, cols = skewed_coo(n, seed=11, hub_deg=20, base_deg=2)
    g_b = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=8, backend=backend)
    g_u = g_b.with_buckets(False)
    assert g_b.use_buckets and not g_u.use_buckets

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(n).astype(np.float32))
    xp = g_b.pack(x > 0.5)
    assert np.array_equal(np.asarray(g_b.mxv_bool(xp)),
                          np.asarray(g_u.mxv_bool(xp)))
    assert np.array_equal(np.asarray(g_b.mxv_count(xp, jnp.int32)),
                          np.asarray(g_u.mxv_count(xp, jnp.int32)))
    assert np.allclose(np.asarray(g_b.mxv(x)), np.asarray(g_u.mxv(x)),
                       atol=1e-5)
    assert np.allclose(np.asarray(g_b.spmm(x[:, None])),
                       np.asarray(g_u.spmm(x[:, None])), atol=1e-5)
    # algorithms ride the bucketed path with zero call-site changes
    lv_b = bfs(g_b, source=0).levels
    lv_u = bfs(g_u, source=0).levels
    assert np.array_equal(np.asarray(lv_b), np.asarray(lv_u))
    if backend == "b2sr":
        d_b = sssp(g_b, source=0).distances
        d_u = sssp(g_u, source=0).distances
        assert np.array_equal(np.asarray(d_b), np.asarray(d_u))
        pr_b = pagerank(g_b, max_iters=5).ranks
        pr_u = pagerank(g_u, max_iters=5).ranks
        assert np.allclose(np.asarray(pr_b), np.asarray(pr_u), atol=1e-6)
        assert float(g_b.tri_count()) == float(g_u.tri_count())
        c_b = b2sr_to_dense_of(g_b.mxm(g_b))
        c_u = b2sr_to_dense_of(g_u.mxm(g_u))
        assert np.array_equal(c_b, c_u)
        assert np.array_equal(np.asarray(g_b.mxm_count(g_b)),
                              np.asarray(g_u.mxm_count(g_u)))


def b2sr_to_dense_of(g: GraphMatrix) -> np.ndarray:
    from repro.core import csr as csr_mod
    return np.asarray(csr_mod.to_dense(g.csr))


def test_transposed_swaps_and_caches_buckets():
    n = 60
    rows, cols = skewed_coo(n, seed=3)
    g = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=8)
    g.buckets()                       # force lazy build on the forward view
    gt = g.transposed()
    # transposed() builds the transpose's buckets eagerly and caches them on
    # g, so repeated transposed()/vxm calls don't re-run host bucketing
    assert g.ell_buckets_t is not None
    assert gt.ell_buckets is g.ell_buckets_t
    assert gt.ell_buckets_t is g.ell_buckets
    assert g.transposed().ell_buckets is gt.ell_buckets
    # vxm == mxv on the transpose, bucketed on both sides
    x = jnp.asarray(np.random.default_rng(1).random(n).astype(np.float32))
    assert np.allclose(np.asarray(g.vxm(x)), np.asarray(gt.mxv(x)), atol=1e-6)


def test_bfs_termination_word_sum_regression():
    """frontier word-sums that overflow uint32 must not stop BFS early.

    A star graph from node 0 makes iteration-1's frontier words dense;
    with the old uint64-astype (truncated to uint32 without x64) a
    carefully-sized frontier could sum to 0 mod 2^32. jnp.any is exact;
    here we just pin the behaviour: all nodes get level 1.
    """
    n = 128
    rows = np.zeros(n - 1, np.int64)
    cols = np.arange(1, n, dtype=np.int64)
    from repro.algorithms import bfs
    g = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=32)
    res = bfs(g, source=0)
    lv = np.asarray(res.levels)
    assert lv[0] == 0 and (lv[1:] == 1).all()


# ---------------------------------------------------------------------------
# property test: bucketing is invisible for any COO set (optional hypothesis)
# ---------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=25, deadline=None)
def test_bucketed_bmv_property(data):
    n = data.draw(st.integers(min_value=1, max_value=64), label="n")
    t = data.draw(st.sampled_from(TILE_DIMS), label="t")
    m = data.draw(st.integers(min_value=0, max_value=200), label="nnz")
    seed = data.draw(st.integers(min_value=0, max_value=2**31), label="seed")
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    ell = to_ell(coo_to_b2sr(rows, cols, n, n, t))
    bk = to_bucketed(ell)
    xp = pack_bitvector(jnp.asarray(rng.random(n) > 0.4), t, n)
    assert np.array_equal(np.asarray(ops.bmv_bin_bin_full(ell, xp)),
                          np.asarray(ops.bmv_bin_bin_full_bucketed(bk, xp)))
    assert np.array_equal(np.asarray(ops.bmv_bin_bin_bin(ell, xp)),
                          np.asarray(ops.bmv_bin_bin_bin_bucketed(bk, xp)))
