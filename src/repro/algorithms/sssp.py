"""SSSP on the tropical min-plus semiring (paper §V).

On B2SR the adjacency is binary, so edge weights are uniform (= ``a_value``):
distances are hop counts × weight, iterated Bellman-Ford style with
``bmv_bin_full_full`` — the paper's relaxation where matrix 0s act as +inf.
The CSR backend supports real per-edge weights (the GraphBLAST-style
baseline path).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.semiring import MIN_PLUS


@dataclasses.dataclass
class SSSPResult:
    distances: jax.Array   # float32[n]; +inf = unreachable
    n_iterations: int


def sssp(g: GraphMatrix, source, edge_weight: float = 1.0,
         max_iters: Optional[int] = None,
         row_chunk: Optional[int] = None):
    """Uniform-weight SSSP (Bellman-Ford on min-plus, paper §V).

    ``source`` may also be an *array* of sources: the batch routes through
    the multi-source engine and returns ``MSSSSPResult`` with
    ``distances[n, S]`` (exact vs looped runs for dyadic edge weights).
    """
    if np.ndim(source) > 0:
        if row_chunk is not None:
            raise ValueError("row_chunk is not supported for batched "
                             "sources (the engine plans its own loop)")
        from repro.engine.queries import ms_sssp
        return ms_sssp(g, source, edge_weight=edge_weight,
                       max_iters=max_iters)
    source = int(source)
    n = g.n_rows
    max_iters = n if max_iters is None else max_iters
    gt = g.transposed()

    dist = jnp.full(n, jnp.inf, jnp.float32).at[source].set(0.0)

    def cond(state):
        dist, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        dist, _, it = state
        relax = gt.mxv(dist, MIN_PLUS, Descriptor(row_chunk=row_chunk),
                       a_value=edge_weight)
        new = jnp.minimum(dist, relax)
        return new, jnp.any(new < dist), it + 1

    dist, _, it = jax.lax.while_loop(
        cond, body, (dist, jnp.bool_(True), jnp.int32(0)))
    return SSSPResult(distances=dist, n_iterations=int(it))

