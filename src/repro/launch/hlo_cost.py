"""Hierarchical HLO cost model with loop-trip-count multipliers.

``compiled.cost_analysis()`` counts a ``lax.scan``/``while`` body ONCE, not
×trip-count — for scan-over-layers models that undercounts FLOPs/bytes by the
layer count and silently drops in-loop collectives. This module re-derives the
three roofline inputs from ``compiled.as_text()`` directly:

  flops       2·M·N·K for every ``dot`` (shapes parsed from operand types,
              contracting dims from the op attrs) + 1/elem for elementwise
              arithmetic; fused computations contribute their inner flops.
  hbm_bytes   per-instruction operand+result byte traffic at fusion
              boundaries (inner fused instructions are NOT counted — the
              fusion op's own operands/results model the actual HBM traffic,
              the same model XLA's bytes-accessed uses).
  wire_bytes  per-collective result bytes × op ring factor (all-reduce 2×,
              reduce-scatter ×group, others 1×).

Every cost is multiplied by the product of enclosing ``while`` trip counts
(``backend_config known_trip_count``; unannotated loops default to 1 and are
reported so the caller can see the residual risk).

This is a *model*, not a simulator: fusion decisions come from the CPU
backend here, so treat hbm_bytes as an upper-ish bound on a TPU lowering.
FLOPs and wire bytes are backend-neutral (dots and collectives are decided
by the program + SPMD partitioner, not the target).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# opcodes that move no bytes / do no work
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier", "domain",
    "custom-call",  # CPU thunks (layout/alias helpers); none compute here
}

# elementwise-ish opcodes costed at 1 flop per result element
_ARITH = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "sine", "cosine",
    "atan2", "remainder", "compare", "select", "clamp", "and", "or", "xor",
    "not", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "popcnt", "count-leading-zeros", "convert", "is-finite", "erf",
}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
# instruction: "  %name = TYPE opcode(operands), attrs"  (TYPE may be a tuple;
# lines are comment-stripped first, so tuple types contain no parens/equals)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\]{},]+)\s+"
    r"([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(\(.*\))?\s*->.*\{\s*$")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\w+\[[\d,]*\](?:\{[\d,]*\})?))")
_TRIP_RE = re.compile(r'known_trip_count[":{]+n["\s:]+(\d+)')
_CALLEE_RES = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
}
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")


def _elem_count(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            total += _elem_count(dims) * _DTYPE_BYTES[dtype]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            total += _elem_count(dims)
    return total


def _first_shape(type_str: str) -> Optional[List[int]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    line: str          # comment-stripped full line
    args_start: int    # index of '(' right after the opcode


@dataclasses.dataclass
class _Computation:
    name: str
    is_entry: bool
    instrs: List[_Instr]
    types: Dict[str, str]           # value name -> type string
    params: List[str] = dataclasses.field(default_factory=list)  # in order


@dataclasses.dataclass
class CostReport:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    wire_by_op: Dict[str, float]
    unannotated_whiles: int

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes, "wire_by_op": self.wire_by_op,
            "unannotated_whiles": self.unannotated_whiles,
        }


def _parse_module(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                is_entry, name, params = m.group(1), m.group(2), m.group(3)
                cur = _Computation(name=name, is_entry=bool(is_entry),
                                   instrs=[], types={})
                for pname, ptype in _PARAM_RE.findall(params or ""):
                    cur.types[pname] = ptype
                    cur.params.append(pname)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, opcode = m.groups()
            cur.types[name] = type_str
            cur.instrs.append(_Instr(name, type_str, opcode, line,
                                     args_start=m.end() - 1))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _operand_names(instr: _Instr) -> List[str]:
    """Operand value names: the parenthesised group right after the opcode."""
    line = instr.line
    start = instr.args_start
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = line[start + 1:end]
    return re.findall(r"%([\w.\-]+)", inner)


def _sliced_param_bytes(callee: _Computation, pname: str,
                        comps: Optional[Dict[str, _Computation]] = None,
                        depth: int = 0) -> Optional[float]:
    """If ``pname`` is consumed ONLY by dynamic-slice/gather ops inside
    ``callee``, return the summed result-proportional bytes (the traffic
    actually addressed per call); else None (parameter is read in full).

    This is what makes loop byte accounting sane: a scan body receives the
    full stacked [L, ...] weight tensor (or a big gather source, e.g. a
    feature matrix) as a loop-invariant operand, but each iteration only
    touches one slice / the gathered rows.

    The slice may be wrapped in call/fusion levels (XLA versions differ in
    how deep the dynamic-slice lands: some emit while-body -> call ->
    fusion -> dynamic-slice), so a param consumed only by call/fusion ops
    recurses into the callee's corresponding parameter.
    """
    if depth > 4:
        return None
    total = 0.0
    seen = False
    token = "%" + pname
    for instr in callee.instrs:
        if token not in instr.line:
            continue
        ops = _operand_names(instr)
        if pname not in ops:
            continue
        if (instr.opcode in ("dynamic-slice", "gather")
                and ops and ops[0] == pname):
            total += _type_bytes(instr.type_str)
            seen = True
        elif instr.opcode in ("fusion", "call") and comps is not None:
            cm = (_CALLEE_RES["calls"].search(instr.line)
                  or _CALLEE_RES["to_apply"].search(instr.line))
            sub = comps.get(cm.group(1)) if cm else None
            if sub is None:
                return None
            # the param may be passed at several operand positions; every
            # one must be slice-only or the whole tensor is read
            idxs = [i for i, o in enumerate(ops) if o == pname]
            if not idxs or any(i >= len(sub.params) for i in idxs):
                return None
            for idx in idxs:
                inner = _sliced_param_bytes(sub, sub.params[idx], comps,
                                            depth + 1)
                if inner is None:
                    return None
                total += inner
            seen = True
        else:
            return None
    return total if seen else None


def _dot_flops(instr: _Instr, comp: _Computation) -> float:
    ops = _operand_names(instr)
    if not ops:
        return 0.0
    lhs_type = comp.types.get(ops[0])
    if lhs_type is None:
        return 0.0
    lhs_shape = _first_shape(lhs_type)
    if lhs_shape is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            i = int(d)
            if i < len(lhs_shape):
                contract *= lhs_shape[i]
    out_elems = _type_elems(instr.type_str)
    return 2.0 * out_elems * contract


def _collective_wire(instr: _Instr, base: str) -> float:
    rb = _type_bytes(instr.type_str)
    if base == "all-reduce":
        return 2.0 * rb
    if base == "reduce-scatter":
        g = re.search(r"replica_groups=\{?\{([\d,]+)\}", instr.line)
        group = len(g.group(1).split(",")) if g else 1
        return float(rb) * group
    return float(rb)


def _instr_cost(instr: _Instr, comp: _Computation, comps, memo,
                in_fusion: bool) -> Tuple[float, float, Dict[str, float], int]:
    """(flops, hbm_bytes, wire_by_op, unannotated) for one instruction,
    recursing into callees with multipliers."""
    op = instr.opcode
    if op in _FREE:
        return 0.0, 0.0, {}, 0

    base = op.replace("-start", "")
    if base.endswith("-done") or base.endswith("-update"):
        return 0.0, 0.0, {}, 0
    if base in _COLLECTIVES:
        wire = _collective_wire(instr, base)
        bytes_ = 0.0 if in_fusion else 2.0 * _type_bytes(instr.type_str)
        return 0.0, bytes_, {base: wire}, 0

    if op == "while":
        trip = 1
        un = 0
        m = _TRIP_RE.search(instr.line)
        if m:
            trip = int(m.group(1))
        else:
            un = 1
        f = b = 0.0
        w: Dict[str, float] = {}
        for key in ("body", "condition"):
            cm = _CALLEE_RES[key].search(instr.line)
            if cm and cm.group(1) in comps:
                cf, cb, cw, cu = _comp_cost(comps[cm.group(1)], comps, memo)
                mult = trip if key == "body" else trip + 1
                f += cf * mult
                b += cb * mult
                for k, v in cw.items():
                    w[k] = w.get(k, 0.0) + v * mult
                un += cu
        return f, b, w, un

    if op in ("fusion", "call", "async-start"):
        key = "calls" if op == "fusion" else "to_apply"
        cm = (_CALLEE_RES[key].search(instr.line)
              or _CALLEE_RES["calls"].search(instr.line)
              or _CALLEE_RES["to_apply"].search(instr.line))
        f = b = 0.0
        w: Dict[str, float] = {}
        un = 0
        if cm and cm.group(1) in comps:
            f, b_inner, w, un = _comp_cost(comps[cm.group(1)], comps, memo,
                                           fused=(op == "fusion"))
            b = b_inner
        if not in_fusion:
            # fusion boundary traffic: operands + result of the op itself;
            # operands only dynamic-sliced inside count their slice bytes
            io = _type_bytes(instr.type_str)
            callee = comps.get(cm.group(1)) if cm else None
            operands = _operand_names(instr)
            for idx, o in enumerate(operands):
                t = comp.types.get(o)
                if not t:
                    continue
                full = _type_bytes(t)
                if callee is not None and idx < len(callee.params):
                    sliced = _sliced_param_bytes(callee, callee.params[idx],
                                                 comps)
                    if sliced is not None:
                        io += min(sliced, full)
                        continue
                io += full
            b += io
        return f, b, w, un

    if op == "conditional":
        names = []
        bm = _BRANCH_RE.search(instr.line)
        if bm:
            names = re.findall(r"%?([\w.\-]+)", bm.group(1))
        names += _TF_RE.findall(instr.line)
        f = b = 0.0
        w: Dict[str, float] = {}
        un = 0
        costs = []
        for nm in names:
            if nm in comps:
                costs.append(_comp_cost(comps[nm], comps, memo))
        if costs:  # conservative: the most expensive branch
            cf, cb, cw, cu = max(costs, key=lambda c: c[0] + c[1])
            f, b, w, un = cf, cb, dict(cw), cu
        if not in_fusion:
            b += 2.0 * _type_bytes(instr.type_str)
        return f, b, w, un

    # --- plain instruction ---
    flops = 0.0
    if op == "dot":
        flops = _dot_flops(instr, comp)
    elif op == "convolution":
        # rare here; approximate as dot over the kernel volume
        flops = 2.0 * _type_elems(instr.type_str)
    elif op in ("reduce", "reduce-window", "scatter", "select-and-scatter"):
        ops_ = _operand_names(instr)
        in_elems = sum(_type_elems(comp.types.get(o, "")) for o in ops_[:1])
        flops = float(in_elems)
    elif op in _ARITH:
        flops = float(_type_elems(instr.type_str))

    bytes_ = 0.0
    if not in_fusion:
        ops_ = _operand_names(instr)
        if op == "dynamic-slice":
            # reads slice-sized window, writes result
            bytes_ = 2.0 * _type_bytes(instr.type_str)
        elif op == "gather":
            idx_t = comp.types.get(ops_[1]) if len(ops_) > 1 else None
            bytes_ = (2.0 * _type_bytes(instr.type_str)
                      + (_type_bytes(idx_t) if idx_t else 0.0))
        elif op == "dynamic-update-slice":
            upd_t = comp.types.get(ops_[1]) if len(ops_) > 1 else None
            bytes_ = 2.0 * (_type_bytes(upd_t) if upd_t else 0.0)
        else:
            bytes_ = float(_type_bytes(instr.type_str))
            for o in ops_:
                t = comp.types.get(o)
                if t:
                    bytes_ += _type_bytes(t)
    return flops, bytes_, {}, 0


def _comp_cost(comp: _Computation, comps, memo, fused: bool = False):
    key = (comp.name, fused)
    if key in memo:
        return memo[key]
    memo[key] = (0.0, 0.0, {}, 0)   # cycle guard
    f = b = 0.0
    w: Dict[str, float] = {}
    un = 0
    for instr in comp.instrs:
        cf, cb, cw, cu = _instr_cost(instr, comp, comps, memo, in_fusion=fused)
        f += cf
        b += cb
        un += cu
        for k, v in cw.items():
            w[k] = w.get(k, 0.0) + v
    memo[key] = (f, b, w, un)
    return memo[key]


def analyze_hlo(text: str) -> CostReport:
    """Hierarchical per-device cost of a post-SPMD HLO module."""
    comps = _parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: last computation is usually the entry
        entry = list(comps.values())[-1]
    memo: Dict = {}
    f, b, w, un = _comp_cost(entry, comps, memo)
    return CostReport(flops=f, hbm_bytes=b,
                      wire_bytes=float(sum(w.values())), wire_by_op=w,
                      unannotated_whiles=un)
