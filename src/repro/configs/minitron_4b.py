"""minitron-4b [arXiv:2407.14679; hf]: pruned Nemotron, squared-ReLU FFN."""

from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    activation="relu2",
)


def reduced() -> TransformerConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="minitron-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab_size=256,
        dtype="float32", max_seq_len=64)
