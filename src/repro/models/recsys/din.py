"""DIN [Zhou et al. 1706.06978]: target attention over user behaviour.

Per sample: user history (item, cate) id sequences (padded to seq_len),
a target (item, cate), and categorical user features. The attention MLP
(80-40) scores each history position against the target; the weighted-sum
pooled interest vector feeds the final MLP (200-80) → CTR logit.

``score_candidates`` scores one user against a large candidate set with a
single batched einsum (retrieval_cand shape: 10⁶ candidates, no loop).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import DINConfig
from repro.models.recsys.embedding import embedding_bag_padded, embedding_lookup

Params = Dict[str, Any]


class DINBatch(NamedTuple):
    hist_items: jax.Array      # [B, L] int32
    hist_cates: jax.Array      # [B, L] int32
    hist_mask: jax.Array       # [B, L] bool
    target_item: jax.Array     # [B] int32
    target_cate: jax.Array     # [B] int32
    user_feats: jax.Array      # [B, F] int32
    labels: jax.Array          # [B] float32 (click 0/1)


def init_params(cfg: DINConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.embed_dim
    # attention MLP input: [hist, target, hist-target, hist*target] of 2d each
    attn_dims = [8 * d, *cfg.attn_mlp, 1]
    # final MLP: user-feat sum + interest + target (each 2d or F*d)
    mlp_in = cfg.n_user_feats * d + 2 * d + 2 * d
    mlp_dims = [mlp_in, *cfg.mlp, 1]
    return {
        "item_table": nn.embed_init(ks[0], cfg.n_items, d),
        "cate_table": nn.embed_init(ks[1], cfg.n_cates, d),
        "user_table": nn.embed_init(ks[2], cfg.user_feat_vocab, d),
        "attn_mlp": nn.mlp_params(ks[3], attn_dims),
        "mlp": nn.mlp_params(ks[4], mlp_dims),
    }


def _hist_embed(params: Params, batch: DINBatch) -> jax.Array:
    ei = embedding_lookup(params["item_table"], batch.hist_items)
    ec = embedding_lookup(params["cate_table"], batch.hist_cates)
    return jnp.concatenate([ei, ec], axis=-1)            # [B, L, 2d]


def _target_embed(params: Params, item, cate) -> jax.Array:
    ei = embedding_lookup(params["item_table"], item)
    ec = embedding_lookup(params["cate_table"], cate)
    return jnp.concatenate([ei, ec], axis=-1)            # [..., 2d]


def attention_pool(params: Params, hist: jax.Array, mask: jax.Array,
                   target: jax.Array) -> jax.Array:
    """DIN local activation unit: weight history by target relevance."""
    L = hist.shape[1]
    tgt = jnp.broadcast_to(target[:, None, :], hist.shape)
    feats = jnp.concatenate([hist, tgt, hist - tgt, hist * tgt], axis=-1)
    scores = nn.mlp(params["attn_mlp"], feats, act=jax.nn.sigmoid)[..., 0]
    scores = jnp.where(mask, scores, 0.0)                # no softmax (paper §4)
    return jnp.sum(scores[..., None] * hist, axis=1)     # [B, 2d]


def forward(params: Params, batch: DINBatch, cfg: DINConfig) -> jax.Array:
    hist = _hist_embed(params, batch)
    target = _target_embed(params, batch.target_item, batch.target_cate)
    interest = attention_pool(params, hist, batch.hist_mask, target)
    uf = embedding_lookup(params["user_table"], batch.user_feats)  # [B, F, d]
    uf = uf.reshape(uf.shape[0], -1)
    x = jnp.concatenate([uf, interest, target], axis=-1)
    return nn.mlp(params["mlp"], x, act=jax.nn.relu)[..., 0]       # logits [B]


def loss_fn(params: Params, batch: DINBatch, cfg: DINConfig):
    logits = forward(params, batch, cfg)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * batch.labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))          # stable BCE
    return loss, {"bce": loss}


def score_candidates(params: Params, batch: DINBatch,
                     cand_items: jax.Array, cand_cates: jax.Array,
                     cfg: DINConfig, chunk: int = 4096) -> jax.Array:
    """Score n_candidates items for one (or few) users: [B, N] logits.

    DIN's interest vector is target-aware, so attention runs per
    (user, candidate). The candidate axis is processed in ``chunk``-sized
    blocks via lax.map (bounded memory: [B, chunk, L, 8d] per block, never
    the full [B, N, L, 8d]) — the retrieval_cand contract (batched op, no
    python loop).
    """
    B = batch.hist_items.shape[0]
    N = cand_items.shape[0]
    chunk = min(chunk, N)
    hist = _hist_embed(params, batch)                     # [B, L, 2d]
    uf = embedding_lookup(params["user_table"], batch.user_feats)
    uf = uf.reshape(B, -1)

    n_pad = -(-N // chunk) * chunk
    ci = jnp.pad(cand_items, (0, n_pad - N))
    cc = jnp.pad(cand_cates, (0, n_pad - N))
    ci = ci.reshape(-1, chunk)
    cc = cc.reshape(-1, chunk)

    def block(args):
        items, cates = args
        cands = _target_embed(params, items, cates)       # [chunk, 2d]
        h = hist[:, None, :, :]                           # [B,1,L,2d]
        t = cands[None, :, None, :]                       # [1,chunk,1,2d]
        bshape = (B, chunk) + hist.shape[1:]
        feats = jnp.concatenate(
            [jnp.broadcast_to(h, bshape),
             jnp.broadcast_to(t, (B, chunk, hist.shape[1], t.shape[-1])),
             h - t, h * t], axis=-1)
        scores = nn.mlp(params["attn_mlp"], feats, act=jax.nn.sigmoid)[..., 0]
        scores = jnp.where(batch.hist_mask[:, None, :], scores, 0.0)
        interest = jnp.einsum("bnl,bld->bnd", scores, hist)   # [B,chunk,2d]
        u = jnp.broadcast_to(uf[:, None, :], (B, chunk, uf.shape[-1]))
        tgt = jnp.broadcast_to(cands[None], (B, chunk, cands.shape[-1]))
        x = jnp.concatenate([u, interest, tgt], axis=-1)
        return nn.mlp(params["mlp"], x, act=jax.nn.relu)[..., 0]  # [B, chunk]

    out = jax.lax.map(block, (ci, cc))                    # [nb, B, chunk]
    return jnp.moveaxis(out, 0, 1).reshape(B, n_pad)[:, :N]
