"""EmbeddingBag built from jnp.take + jax.ops.segment_sum (assignment note:
JAX has no native EmbeddingBag — this IS part of the system).

Tables are row-sharded over the "model" mesh axis in the distributed setup;
lookups become all-to-all-ish gathers handled by GSPMD.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain gather: ids [...,] -> [..., d]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, offsets: jax.Array,
                  n_bags: int, mode: str = "sum",
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """torch.nn.EmbeddingBag semantics over a flat ragged id list.

    ids: [nnz] int32; offsets: [n_bags] int32 (bag start positions, sorted).
    """
    nnz = ids.shape[0]
    bag_ids = jnp.searchsorted(offsets, jnp.arange(nnz), side="right") - 1
    emb = jnp.take(table, ids, axis=0)
    if weights is not None:
        emb = emb * weights[:, None]
    s = jax.ops.segment_sum(emb, bag_ids, num_segments=n_bags)
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(bag_ids, emb.dtype), bag_ids,
                                  num_segments=n_bags)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(emb, bag_ids, num_segments=n_bags)
    raise ValueError(mode)


def embedding_bag_padded(table: jax.Array, ids: jax.Array, mask: jax.Array,
                         mode: str = "sum") -> jax.Array:
    """Padded-batch variant: ids [B, L] with mask [B, L] (static shapes)."""
    emb = jnp.take(table, ids, axis=0) * mask[..., None].astype(table.dtype)
    s = jnp.sum(emb, axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jnp.sum(mask, axis=1, keepdims=True).astype(table.dtype)
        return s / jnp.maximum(cnt, 1.0)
    raise ValueError(mode)
