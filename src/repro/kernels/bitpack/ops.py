"""Jitted wrappers for the bitpack kernels."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.bitpack import bitpack as kernels


@partial(jax.jit, static_argnames=("t", "col_major", "interpret"))
def _pack(x, t, col_major, interpret):
    return kernels.pack_dense_pallas(x, t=t, col_major=col_major,
                                     block_r=1, block_c=1,
                                     interpret=interpret)


def pack_dense(x: jax.Array, t: int, col_major: bool = False,
               interpret: Optional[bool] = None) -> jax.Array:
    """Dense 0/1 [n, m] -> uint32[ceil(n/t), ceil(m/t), t] packed tiles."""
    interpret = common.interpret_default() if interpret is None else interpret
    x = (x != 0).astype(jnp.uint32)
    x = common.pad_to(common.pad_to(x, 0, t), 1, t)
    return _pack(x, t, col_major, interpret)


@partial(jax.jit, static_argnames=("t", "interpret"))
def _pack_rows(x, t, interpret):
    return kernels.pack_rows_pallas(x, t=t, block_r=1,
                                    block_d=x.shape[1],
                                    interpret=interpret)


def pack_columns(x: jax.Array, t: int,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Dense [n, d] -> uint32[ceil(n/t), d] activation words (BitMatrix).

    Binarizes (``x != 0``) and packs the node axis LSB-first; feature
    columns stay one word each — the layout the bin·bin→full spmm rows
    consume. Traceable (interpret-mode Pallas), so serving plans can pack
    per-layer activations inside their jitted forward.
    """
    interpret = common.interpret_default() if interpret is None else interpret
    x = (x != 0).astype(jnp.uint32)
    x = common.pad_to(x, 0, t)
    return _pack_rows(x, t, interpret)


@partial(jax.jit, static_argnames=("t", "interpret"))
def _transpose(words, t, interpret):
    return kernels.bit_transpose_pallas(words, t=t, block=1,
                                        interpret=interpret)


def bit_transpose(words: jax.Array, t: int,
                  interpret: Optional[bool] = None) -> jax.Array:
    interpret = common.interpret_default() if interpret is None else interpret
    flat = words.reshape(-1, t)
    out = _transpose(flat, t, interpret)
    return out.reshape(words.shape)
