"""Pallas kernels (interpret mode) vs pure-jnp ref.py oracles.

Sweeps shapes, tile sizes, dtypes per the kernel-test contract: for each
kernel, assert_allclose against the ref.py oracle.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import (
    ARITHMETIC, MIN_PLUS, MAX_TIMES, TILE_DIMS, dense_to_b2sr, pack_bitvector,
    to_ell,
)
from repro.kernels.bmv import ops as bmv_ops, ref as bmv_ref
from repro.kernels.bmm import ops as bmm_ops, ref as bmm_ref
from repro.kernels.spmm import ops as spmm_ops, ref as spmm_ref
from repro.kernels.bitpack import ops as bp_ops, ref as bp_ref


def random_dense(n, m, density, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n, m)) < density).astype(np.uint8)


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("n,density", [(32, 0.3), (100, 0.08), (257, 0.02)])
def test_bmv_bin_bin_full_kernel(t, n, density):
    d = random_dense(n, n, density, seed=n + t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(0)
    xp = pack_bitvector(jnp.asarray(rng.random(n) < 0.4), t, n)
    got = bmv_ops.bmv_bin_bin_full(ell, xp)
    want = bmv_ref.bmv_bin_bin_full(ell, xp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_bmv_bin_bin_full_dtypes(t, out_dtype):
    n = 64
    d = random_dense(n, n, 0.2, seed=t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(1)
    xp = pack_bitvector(jnp.asarray(rng.random(n) < 0.4), t, n)
    got = bmv_ops.bmv_bin_bin_full(ell, xp, out_dtype=out_dtype)
    assert got.dtype == out_dtype
    want = bmv_ref.bmv_bin_bin_full(ell, xp, out_dtype=out_dtype)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64))


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("complement", [True, False])
def test_bmv_bin_bin_bin_kernel(t, complement):
    n = 120
    d = random_dense(n, n, 0.1, seed=t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(2)
    xp = pack_bitvector(jnp.asarray(rng.random(n) < 0.3), t, n)
    mp = pack_bitvector(jnp.asarray(rng.random(n) < 0.5), t, n)
    got = bmv_ops.bmv_bin_bin_bin(ell, xp, mp, complement=complement)
    want = bmv_ref.bmv_bin_bin_bin(ell, xp, mp, complement=complement)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("semiring,a_value", [
    (ARITHMETIC, 1.0), (MIN_PLUS, 1.0), (MAX_TIMES, 0.5),
])
def test_bmv_bin_full_full_kernel(t, semiring, a_value):
    n = 77
    d = random_dense(n, n, 0.12, seed=t)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.random(n).astype(np.float32) + 0.1)
    got = bmv_ops.bmv_bin_full_full(ell, x, semiring, a_value)
    want = bmv_ref.bmv_bin_full_full(ell, x, semiring, a_value)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("t", [4, 8, 16, 32])
@pytest.mark.parametrize("n,d_feat", [(40, 16), (96, 33), (130, 8)])
def test_spmm_kernel(t, n, d_feat):
    d = random_dense(n, n, 0.1, seed=t + n)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(4)
    X = jnp.asarray(rng.standard_normal((n, d_feat)).astype(np.float32))
    got = spmm_ops.spmm(ell, X, block_d=16)
    want = spmm_ref.spmm(ell, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("t", TILE_DIMS)
def test_bmm_kernel_triangle(t):
    n = 64
    d = random_dense(n, n, 0.15, seed=t)
    d = np.triu(d, 1); d = d + d.T
    L = np.tril(d, -1)
    eL = to_ell(dense_to_b2sr(L, t))
    eLT = to_ell(dense_to_b2sr(L.T, t))
    got = float(bmm_ops.bmm_bin_bin_sum_masked(eL, eLT, eL))
    want = float(bmm_ref.bmm_bin_bin_sum_masked(eL, eLT, eL))
    assert got == want


@pytest.mark.parametrize("t", TILE_DIMS)
@pytest.mark.parametrize("col_major", [False, True])
def test_bitpack_kernel(t, col_major):
    d = jnp.asarray(random_dense(70, 41, 0.3, seed=t))
    got = bp_ops.pack_dense(d, t, col_major=col_major)
    want = bp_ref.pack_dense(d, t, col_major=col_major)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.sampled_from(TILE_DIMS), st.integers(4, 120), st.integers(0, 400))
@settings(max_examples=10, deadline=None)
def test_property_kernel_vs_oracle(t, n, seed):
    d = random_dense(n, n, 0.2, seed)
    ell = to_ell(dense_to_b2sr(d, t))
    rng = np.random.default_rng(seed)
    xp = pack_bitvector(jnp.asarray(rng.random(n) < 0.5), t, n)
    got = bmv_ops.bmv_bin_bin_full(ell, xp)
    want = bmv_ref.bmv_bin_bin_full(ell, xp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
