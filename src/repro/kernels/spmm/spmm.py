"""Pallas TPU kernels: Y = A @ X with A in B2SR-ELL (dense X, GNN hot path),
the packed-RHS twin Y = A ∨.∧ F with F a bit-packed frontier matrix
(multi-source traversal, engine/ hot path — word select/OR, no unpacked RHS),
and the BitGNN twin Y = A +.∧ X with X a bit-packed activation matrix
(bin·bin→full: AND + popcount accumulation, both operands stay packed).

MXU formulation (DESIGN.md §2): each uint32 bit tile is unpacked in-register
(VPU shifts) into a t×t 0/1 matrix that feeds a batched t×t @ t×BD matmul on
the MXU. HBM traffic for A is 1 bit per element; X tiles are gathered from a
VMEM-resident [n_tile_cols, t, BD] panel.

Grid: (row_blocks, d_blocks, k_blocks); k innermost, accumulating.
VMEM budget note: the X panel is (n_cols × BD × 4) bytes — this kernel
targets minibatch/molecule-scale graphs (n ≲ 16k with BD=128); full-graph
aggregation runs on the XLA path (core.ops.spmm_b2sr) which panelises via
lax.scan, or on a multi-launch panel loop (hillclimb note in EXPERIMENTS.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import or_reduce, unpack_words


def _spmm_bbb_kernel(col_ref, tiles_ref, f_ref, *rest, t: int,
                     complement: bool, has_mask: bool):
    mask_ref, out_ref = rest if has_mask else (None, rest[0])
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = col_ref[...]                                    # [BR, BK]
    f3 = f_ref[...]                                       # [C, t, W]
    safe = jnp.clip(idx, 0, f3.shape[0] - 1)
    fk = jnp.take(f3, safe.reshape(-1), axis=0)
    fk = fk.reshape(idx.shape + f3.shape[1:])             # [BR, BK, t, W]
    fk = jnp.where((idx >= 0)[:, :, None, None], fk, jnp.uint32(0))
    a_bits = unpack_words(tiles_ref[...], t, jnp.uint32)  # [BR, BK, t, t]
    # AND/shift with a dense bit RHS: broadcast the frontier word panel of
    # tile column c where A bit (r, c) is set, OR over the K block and c
    contrib = jnp.where((a_bits != 0)[..., None],
                        fk[:, :, None, :, :], jnp.uint32(0))  # [BR,BK,t,t,W]
    out_ref[...] |= or_reduce(contrib, (1, 3))            # [BR, t, W]

    if has_mask:
        @pl.when(k == nk - 1)
        def _apply_mask():
            m = mask_ref[...]
            m = ~m if complement else m
            out_ref[...] &= m


def spmm_bbb_pallas(col_idx, tiles, f3, mask_words=None, *, t: int,
                    complement: bool = True, block_r: int = 8,
                    block_k: int = 4, interpret: bool = True):
    R, K = col_idx.shape
    C, _, W = f3.shape
    assert R % block_r == 0 and K % block_k == 0
    grid = (R // block_r, K // block_k)
    in_specs = [
        pl.BlockSpec((block_r, block_k), lambda i, k: (i, k)),
        pl.BlockSpec((block_r, block_k, t), lambda i, k: (i, k, 0)),
        pl.BlockSpec((C, t, W), lambda i, k: (0, 0, 0)),
    ]
    args = [col_idx, tiles, f3]
    if mask_words is not None:
        in_specs.append(pl.BlockSpec((block_r, t, W), lambda i, k: (i, 0, 0)))
        args.append(mask_words)
    return pl.pallas_call(
        functools.partial(_spmm_bbb_kernel, t=t, complement=complement,
                          has_mask=mask_words is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_r, t, W), lambda i, k: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, t, W), jnp.uint32),
        interpret=interpret,
    )(*args)


def _spmm_bbf_kernel(col_ref, tiles_ref, xw_ref, out_ref, *, t: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = col_ref[...]                                    # [BR, BK]
    xw = xw_ref[...]                                      # [C, BD] uint32
    safe = jnp.clip(idx, 0, xw.shape[0] - 1)
    xk = jnp.take(xw, safe.reshape(-1), axis=0)
    xk = xk.reshape(idx.shape + xw.shape[1:])             # [BR, BK, BD]
    xk = jnp.where((idx >= 0)[:, :, None], xk, jnp.uint32(0))
    # the paper's __popc(a & b) widened over the feature word columns:
    # tile word r of A against activation word column d, popcount-summed
    # over the K block (the (+, AND) semiring — no unpack, no matmul)
    counts = jax.lax.population_count(
        tiles_ref[...][:, :, :, None] & xk[:, :, None, :])  # [BR, BK, t, BD]
    out_ref[...] += jnp.sum(counts, axis=1).astype(out_ref.dtype)


def spmm_bbf_pallas(col_idx, tiles, xw, *, t: int, out_dtype=jnp.float32,
                    block_r: int = 8, block_k: int = 4, block_d: int = 128,
                    interpret: bool = True):
    R, K = col_idx.shape
    C, D = xw.shape
    assert R % block_r == 0 and K % block_k == 0 and D % block_d == 0
    grid = (R // block_r, D // block_d, K // block_k)
    return pl.pallas_call(
        functools.partial(_spmm_bbf_kernel, t=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, d, k: (i, k)),
            pl.BlockSpec((block_r, block_k, t), lambda i, d, k: (i, k, 0)),
            pl.BlockSpec((C, block_d), lambda i, d, k: (0, d)),
        ],
        out_specs=pl.BlockSpec((block_r, t, block_d),
                               lambda i, d, k: (i, 0, d)),
        out_shape=jax.ShapeDtypeStruct((R, t, D), out_dtype),
        interpret=interpret,
    )(col_idx, tiles, xw)


def _spmm_kernel(col_ref, tiles_ref, x_ref, out_ref, *, t: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = col_ref[...]                                    # [BR, BK]
    x3 = x_ref[...]                                       # [C, t, BD]
    safe = jnp.clip(idx, 0, x3.shape[0] - 1)
    xk = jnp.take(x3, safe.reshape(-1), axis=0)
    xk = xk.reshape(idx.shape + x3.shape[1:])             # [BR, BK, t, BD]
    xk = jnp.where((idx >= 0)[:, :, None, None], xk, 0)
    bits = unpack_words(tiles_ref[...], t, out_ref.dtype)  # [BR, BK, t, t]
    # batched (t×t) @ (t×BD) on the MXU, summed over the K block
    out_ref[...] += jnp.einsum("rkab,rkbd->rad", bits, xk,
                               preferred_element_type=out_ref.dtype)


def spmm_pallas(col_idx, tiles, x3, *, t: int, block_r: int = 8,
                block_k: int = 4, block_d: int = 128, interpret: bool = True):
    R, K = col_idx.shape
    C, _, D = x3.shape
    assert R % block_r == 0 and K % block_k == 0 and D % block_d == 0
    grid = (R // block_r, D // block_d, K // block_k)
    return pl.pallas_call(
        functools.partial(_spmm_kernel, t=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, d, k: (i, k)),
            pl.BlockSpec((block_r, block_k, t), lambda i, d, k: (i, k, 0)),
            pl.BlockSpec((C, t, block_d), lambda i, d, k: (0, 0, d)),
        ],
        out_specs=pl.BlockSpec((block_r, t, block_d), lambda i, d, k: (i, 0, d)),
        out_shape=jax.ShapeDtypeStruct((R, t, D), x3.dtype),
        interpret=interpret,
    )(col_idx, tiles, x3)
