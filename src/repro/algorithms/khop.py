"""k-hop reachability / neighborhood expansion via repeated masked mxm.

The multi-hop traversal workload the SpGEMM subsystem unlocks (paper §VI's
headline kernel, composed GraphBLAST-style): with A the boolean adjacency,

    R_1 = A,    F_1 = A
    F_{i+1} = (F_i ∨.∧ A)⟨¬R_i⟩        -- frontier: *newly* reached pairs
    R_{i+1} = R_i ∨ F_{i+1}            -- reached within i+1 hops

The complemented structural mask ⟨¬R_i⟩ is the matrix analogue of BFS's
visited-mask (applied right before the store, paper §V): it keeps every
frontier product sparse, which is what makes repeated B2SR×B2SR mxm cheap.
Iteration stops early when a frontier empties (graph diameter reached).

All-pairs state (R_i) is held as a packed tile grid — uint32 words, 1 bit
per pair — so even the dense-ish late iterations stay bit-compressed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import b2sr as b2sr_mod
from repro.core.b2sr import ell_to_packed_grid
from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.operands import BitVector


@dataclasses.dataclass
class KHopResult:
    reach: GraphMatrix       # R[i, j] = 1 iff j reachable from i in <= k hops
    n_iterations: int        # mxm steps actually run (early exit at diameter)


def _grid_to_graph(grid: np.ndarray, n_rows: int, n_cols: int,
                   backend: str, with_transpose: bool = True) -> GraphMatrix:
    mat = b2sr_mod.packed_grid_to_b2sr(np.asarray(grid), n_rows, n_cols)
    return GraphMatrix.from_b2sr(mat, with_transpose=with_transpose,
                                 backend=backend)


def khop_reachability(g: GraphMatrix, k: int,
                      row_chunk: Optional[int] = None) -> KHopResult:
    """All-pairs <=k-hop reachability matrix via repeated masked mxm."""
    if g.n_rows != g.n_cols:
        raise ValueError("khop needs a square adjacency matrix")
    if k < 1:
        raise ValueError("k must be >= 1")
    if g.backend == "csr":
        return _khop_csr(g, k, row_chunk)
    # bit backends stay at the packed-grid level between hops: the visited
    # mask IS the reach grid (word AND-NOT), and the frontier only needs a
    # fresh ELL view — no COO/CSR/transpose materialisation per hop.
    reach_grid = np.asarray(ell_to_packed_grid(g.ell))
    frontier_ell = g.ell
    it = 1
    for _ in range(k - 1):
        if g.backend == "b2sr_pallas":
            from repro.kernels.spgemm import ops as spgemm_kernel_ops
            prod = np.asarray(spgemm_kernel_ops.mxm(frontier_ell, g.ell))
        else:
            from repro.core import ops
            prod = np.asarray(ops.mxm_bin_bin_bin(frontier_ell, g.ell,
                                                  row_chunk=row_chunk))
        new_grid = prod & ~reach_grid          # ⟨¬R_i⟩ mask-at-store
        if not new_grid.any():
            break
        reach_grid = reach_grid | new_grid
        frontier_ell = b2sr_mod.to_ell(b2sr_mod.packed_grid_to_b2sr(
            new_grid, g.n_rows, g.n_cols))
        it += 1
    reach = _grid_to_graph(reach_grid, g.n_rows, g.n_cols, g.backend)
    return KHopResult(reach=reach, n_iterations=it)


def _khop_csr(g: GraphMatrix, k: int,
              row_chunk: Optional[int] = None) -> KHopResult:
    """Float-baseline k-hop: repeated masked GraphMatrix.mxm."""
    reach = g
    frontier = g
    it = 1
    for _ in range(k - 1):
        new = frontier.mxm(g, mask=reach, complement=True,
                           row_chunk=row_chunk, with_transpose=False)
        if new.nnz == 0:
            break
        reach_grid = (np.asarray(ell_to_packed_grid(reach.ell))
                      | np.asarray(ell_to_packed_grid(new.ell)))
        reach = _grid_to_graph(reach_grid, g.n_rows, g.n_cols, g.backend,
                               with_transpose=False)
        frontier = new
        it += 1
    final = _grid_to_graph(np.asarray(ell_to_packed_grid(reach.ell)),
                           g.n_rows, g.n_cols, g.backend)
    return KHopResult(reach=final, n_iterations=it)


def khop_frontier(g: GraphMatrix, source: int, k: int,
                  row_chunk: Optional[int] = None) -> jax.Array:
    """Single-source <=k-hop neighborhood as a bool[n] vector.

    The vector specialisation of ``khop_reachability``: repeated masked
    ``mxv_bool`` on packed frontiers — the same visited-complement masking,
    one word-AND per tile instead of a tile product. BFS seed semantics:
    the source seeds ``visited`` and is excluded from the result (so a
    cycle back to the source is not reported, unlike the matrix diagonal).
    """
    if g.ell_t is None:
        raise ValueError("khop_frontier needs the transpose "
                         "(with_transpose=True)")
    n = g.n_rows
    gt = g.transposed()
    src = jnp.zeros(n, jnp.float32).at[source].set(1.0)
    frontier = BitVector.pack(src, g.tile_dim, n)
    seed = frontier
    visited = frontier
    for _ in range(k):
        frontier = gt.mxv(frontier,
                          desc=Descriptor(mask=visited, complement=True,
                                          row_chunk=row_chunk))
        visited = visited | frontier
    reached = visited & ~seed                  # exclude the source itself
    return reached.unpack(jnp.bool_)
