"""Jitted wrapper for the Pallas SpMM kernel (pad + dispatch + unpad)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.b2sr import B2SRBucketedEll, B2SREll
from repro.kernels import common
from repro.kernels.spmm import spmm as kernels


@partial(jax.jit, static_argnames=("n_rows", "block_r", "block_k", "block_d",
                                   "interpret"))
def _spmm(col, tiles, x3, n_rows, block_r, block_k, block_d, interpret):
    t = tiles.shape[-1]
    out = kernels.spmm_pallas(col, tiles, x3, t=t, block_r=block_r,
                              block_k=block_k, block_d=block_d,
                              interpret=interpret)
    return out.reshape(-1, out.shape[-1])[:n_rows]


def spmm(ell: B2SREll, x: jax.Array, block_r: int = 8, block_k: int = 4,
         block_d: int = 128, interpret: Optional[bool] = None) -> jax.Array:
    """Y = A @ X for dense X [n_cols, d]."""
    interpret = common.interpret_default() if interpret is None else interpret
    t = ell.tile_dim
    n_tc = ell.n_tile_cols
    d = x.shape[1]
    block_d = min(block_d, -(-d // 1))
    x_pad = jnp.pad(x, ((0, n_tc * t - x.shape[0]), (0, 0)))
    x3 = common.pad_to(x_pad.reshape(n_tc, t, d), 2, block_d)
    col = common.pad_to(common.pad_to(ell.tile_col_idx, 0, block_r, fill=-1),
                        1, block_k, fill=-1)
    tiles = common.pad_to(common.pad_to(ell.bit_tiles, 0, block_r), 1, block_k)
    out = _spmm(col, tiles, x3, ell.n_rows, block_r, block_k, block_d,
                interpret)
    return out[:, :d]


def spmm_bucketed(b: B2SRBucketedEll, x: jax.Array, block_r: int = 8,
                  block_k: int = 4, block_d: int = 128,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Y = A @ X with bucketed A: one pallas_call per bucket (k_b-sized
    grids), feature rows scatter-merged through the row permutation."""
    d = x.shape[1]
    out = jnp.zeros((b.n_tile_rows, b.tile_dim, d), x.dtype)
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        y = spmm(e, x, block_r, bk, block_d, interpret)     # [rows_b*t, d]
        out = out.at[rows].set(y.reshape(-1, b.tile_dim, d))
    return out.reshape(-1, d)[: b.n_rows]
