"""Pallas TPU kernel: masked BMM scalar sum (paper Listing 2, the TC kernel).

Computes  Σ_{(r,c): mask_rc = 1} (A·B)_rc  where A, B, mask are binary
matrices; A and mask are in B2SR-ELL (row-major packed words), B is in
B2SR-ELL with *column-major packed* tiles (word c = bit-column c), the TPU
analogue of the paper's ``__shfl_sync`` lane broadcast: the popcount dot
product  P[r,c] = popc(a_word[r] & b_colword[c])  needs B's columns as words,
so the transposed packing is precomputed at conversion time (paper §III.A
stores both layouts for the same reason).

The double indirection of SpGEMM (walk B's tile-row selected by A's tile
column) is expressed with in-VMEM gathers over the full B arrays — B must fit
VMEM; TC benchmark graphs do. Accumulation is a per-program scalar; the final
cross-block sum happens outside the kernel (no atomics on TPU — grid-major
reduction instead, DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import unpack_words


def _bmm_masked_kernel(a_col_ref, a_tiles_ref, b_col_ref, b_tiles_ref,
                       m_col_ref, m_tiles_ref, out_ref, *, t: int):
    a_col = a_col_ref[...]          # [BR, Ka]
    a_tiles = a_tiles_ref[...]      # [BR, Ka, t]
    b_col = b_col_ref[...]          # [Rb, Kb]
    b_tiles = b_tiles_ref[...]      # [Rb, Kb, t]  (column-major packed)
    m_col = m_col_ref[...]          # [BR, Km]
    m_tiles = m_tiles_ref[...]      # [BR, Km, t]
    Ka = a_col.shape[1]
    Kb = b_col.shape[1]

    def body_ka(ka, total):
        ac = a_col[:, ka]                                     # [BR]
        aw = a_tiles[:, ka]                                   # [BR, t]
        valid_a = ac >= 0
        safe = jnp.clip(ac, 0, b_col.shape[0] - 1)
        bc_all = jnp.take(b_col, safe, axis=0)                # [BR, Kb]
        bt_all = jnp.take(b_tiles, safe, axis=0)              # [BR, Kb, t]

        def body_kb(kb, tot):
            bc = bc_all[:, kb]                                # [BR]
            bw = bt_all[:, kb]                                # [BR, t] col words
            # P[r, c] = popc(a_word[r] & b_colword[c])
            p = jax.lax.population_count(
                aw[:, :, None] & bw[:, None, :])              # [BR, t, t]
            # fetch mask tile (i, bc): match bc against mask's col list
            match = (m_col == bc[:, None]) & (m_col >= 0)     # [BR, Km]
            m_words = jnp.sum(
                jnp.where(match[:, :, None], m_tiles, jnp.uint32(0)),
                axis=1, dtype=jnp.uint32)                     # [BR, t]
            m_bits = unpack_words(m_words, t, jnp.int32)      # [BR, t, t]
            ok = valid_a & (bc >= 0)                          # [BR]
            contrib = jnp.sum(p * m_bits, axis=(1, 2))        # [BR]
            return tot + jnp.sum(jnp.where(ok, contrib, 0))

        return jax.lax.fori_loop(0, Kb, body_kb, total)

    total = jax.lax.fori_loop(0, Ka, body_ka, jnp.int32(0))
    out_ref[0] = total


def bmm_bin_bin_sum_masked_pallas(a_col, a_tiles, b_col, b_tiles_T, m_col,
                                  m_tiles, *, t: int, block_r: int = 8,
                                  interpret: bool = True):
    R, Ka = a_col.shape
    assert R % block_r == 0
    grid = (R // block_r,)
    Rb, Kb = b_col.shape
    Km = m_col.shape[1]
    partials = pl.pallas_call(
        functools.partial(_bmm_masked_kernel, t=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, Ka), lambda i: (i, 0)),
            pl.BlockSpec((block_r, Ka, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((Rb, Kb), lambda i: (0, 0)),
            pl.BlockSpec((Rb, Kb, t), lambda i: (0, 0, 0)),
            pl.BlockSpec((block_r, Km), lambda i: (i, 0)),
            pl.BlockSpec((block_r, Km, t), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R // block_r,), jnp.int32),
        interpret=interpret,
    )(a_col, a_tiles, b_col, b_tiles_T, m_col, m_tiles)
    return jnp.sum(partials)
