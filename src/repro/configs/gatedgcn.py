"""gatedgcn [arXiv:2003.00982]: 16L d=70 gated edge aggregation."""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gatedgcn",
    family="gatedgcn",
    n_layers=16,
    d_hidden=70,
    aggregator="gated",
    d_in=128,
    n_classes=16,
)


def reduced() -> GNNConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, name="gatedgcn-smoke", n_layers=2,
                               d_hidden=16, d_in=8, n_classes=4)
