"""Request coalescing: single queries -> padded power-of-two batches.

The serving front door: callers submit individual queries ("BFS from node
17", "PPR seeded at node 3") and get a handle back; ``flush()`` groups the
pending queries by (graph, kind, parameters), pads each group's source list
to the next power of two, runs **one** engine launch per group, and
scatters result columns back onto the handles.

Why pad to powers of two: the planner keys plans by padded batch width, so
quantised widths collapse arbitrary traffic (3 queries, then 9, then 6...)
onto a handful of cached plans instead of one plan per batch size. Padding
columns repeat the group's first source and are dropped at scatter-back —
boolean/PPR columns are independent, so duplicates cost only lanes that
word-packing had already reserved (any S <= 32 packs into one word).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.graphblas import GraphMatrix
from repro.engine import queries
from repro.engine.planner import PlanCache
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


#: Query kinds the coalescing layer (and the server on top of it) accepts.
KINDS = ("bfs", "khop", "sssp", "ppr", "gnn_infer")


def validate_query(graph: GraphMatrix, kind: str, source) -> int:
    """Check one query at the admission edge; returns the source as int.

    Rejections happen *here*, synchronously at submit time, with an error
    naming the graph's node count — not as an opaque out-of-bounds gather
    three layers down inside a jitted kernel.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown query kind {kind!r}; expected one "
                         f"of {KINDS}")
    s = int(source)
    if not 0 <= s < graph.n_rows:
        raise ValueError(
            f"source {source} out of range for a graph with "
            f"{graph.n_rows} nodes (valid ids are 0..{graph.n_rows - 1})")
    return s


class QueryGroupError(RuntimeError):
    """One coalesced group's failure, with the group identity attached.

    Raised (via ``__cause__``-chained wrapping) out of ``QueryHandle
    .result()`` and collected by ``flush``: callers see *which* group died
    — kind, parameters, and how many queries it carried — instead of a
    bare engine exception with no routing context. The original exception
    and its traceback ride on ``__cause__``.
    """

    def __init__(self, kind: str, params: Tuple[Tuple[str, Any], ...],
                 n_queries: int, cause: BaseException):
        self.kind = kind
        self.params = params
        self.n_queries = n_queries
        p = ", ".join(f"{k}={v!r}" for k, v in params)
        super().__init__(
            f"batched {kind!r} group ({p or 'no params'}; "
            f"{n_queries} queries) failed: {cause!r}")
        self.__cause__ = cause


class BatchFlushError(RuntimeError):
    """Aggregate raised by ``flush(raise_errors=True)`` when groups failed.

    ``errors`` lists every failing group's :class:`QueryGroupError` in
    submission order, so a fire-and-forget ``flush()`` reports all dead
    groups at once instead of only the first one seen.
    """

    def __init__(self, errors: List["QueryGroupError"]):
        self.errors = list(errors)
        lines = "\n  ".join(str(e) for e in self.errors)
        super().__init__(
            f"{len(self.errors)} query group(s) failed:\n  {lines}")
        self.__cause__ = self.errors[0]


class QueryHandle:
    """Future-style result slot; ``result()`` flushes the owning batcher.

    Serving metadata rides on the handle once it resolves: ``backend_used``
    names the backend that actually produced the answer and ``degraded``
    is True when the server answered on a fallback backend instead of the
    graph's preferred one (bit-exact either way — every Table row is
    registered on all three backends).

    ``result()`` is idempotent after failure: every call re-raises the
    *same* stored exception object — first outcome wins (``_fulfill`` /
    ``_fail`` ignore later calls), so repeated polling can never re-wrap
    the error or grow its ``__cause__`` chain.
    """

    def __init__(self, batcher: Optional["QueryBatcher"]):
        self._batcher = batcher
        self._result: Any = None
        self._error: Optional[BaseException] = None
        self._done = False
        self.backend_used: Optional[str] = None
        self.degraded: bool = False
        self.completed_at: Optional[float] = None
        # per-query trace spans (submit -> queue wait -> group spans);
        # None when observability is disabled (DESIGN.md §14)
        self.trace = obs_trace.new_trace()

    def done(self) -> bool:
        return self._done

    def result(self) -> Any:
        if not self._done and self._batcher is not None:
            # non-raising flush: a *sibling* group's failure is stored on
            # its own handles; this handle only raises its own error
            self._batcher.flush(raise_errors=False)
        if self._error is not None:
            raise self._error
        return self._result

    def _fulfill(self, value: Any) -> None:
        if self._done:
            return
        self._result = value
        self._done = True

    def _fail(self, err: BaseException) -> None:
        if self._done:
            return
        self._error = err
        self._done = True


@dataclasses.dataclass
class _Pending:
    graph: GraphMatrix
    kind: str
    source: int
    params: Tuple[Tuple[str, Any], ...]
    handle: QueryHandle
    # monotonic admission timestamp: start of the queue_wait span (always
    # real time, independent of any injectable deadline clock)
    submitted_at: float = 0.0


class QueryBatcher:
    """Coalesces single-source queries into batched engine launches.

    ``kind`` is one of ``"bfs"`` (-> levels ``int32[n]``), ``"khop"``
    (-> reached ``bool[n]``), ``"sssp"`` (-> distances ``f32[n]``), or
    ``"ppr"`` (-> ranks ``f32[n]``) — each handle resolves to exactly what
    the single-source algorithm would have returned for that query.
    """

    def __init__(self, planner: Optional[PlanCache] = None,
                 max_batch: int = 256):
        self.planner = planner
        self.max_batch = max_batch
        self._pending: List[_Pending] = []
        self.n_queries = 0
        self.n_launches = 0
        self.n_deduped = 0

    # -- submission ---------------------------------------------------------
    def submit(self, graph: GraphMatrix, kind: str, source: int,
               **params) -> QueryHandle:
        t0 = time.monotonic()
        src = validate_query(graph, kind, source)
        handle = QueryHandle(self)
        if handle.trace is not None:
            handle.trace.attrs.update(kind=kind, source=src)
            handle.trace.add_span("submit", t0, time.monotonic())
        self._pending.append(_Pending(
            graph=graph, kind=kind, source=src,
            params=tuple(sorted(params.items())), handle=handle,
            submitted_at=time.monotonic()))
        self.n_queries += 1
        return handle

    def bfs(self, graph: GraphMatrix, source: int,
            max_iters: Optional[int] = None) -> QueryHandle:
        return self.submit(graph, "bfs", source, max_iters=max_iters)

    def khop(self, graph: GraphMatrix, source: int, k: int) -> QueryHandle:
        return self.submit(graph, "khop", source, k=k)

    def sssp(self, graph: GraphMatrix, source: int,
             edge_weight: float = 1.0) -> QueryHandle:
        return self.submit(graph, "sssp", source, edge_weight=edge_weight)

    def ppr(self, graph: GraphMatrix, seed: int, alpha: float = 0.85,
            max_iters: int = 10, eps: float = 1e-9) -> QueryHandle:
        return self.submit(graph, "ppr", seed, alpha=alpha,
                           max_iters=max_iters, eps=eps)

    def gnn_infer(self, graph: GraphMatrix, node: int,
                  model: str) -> QueryHandle:
        """Class scores for ``node`` from a registered GNN model
        (``engine.queries.register_gnn_model``); resolves to
        ``float32[n_classes]``."""
        return self.submit(graph, "gnn_infer", node, model=model)

    # -- execution ----------------------------------------------------------
    def pending(self) -> int:
        return len(self._pending)

    def flush(self, raise_errors: bool = True) -> None:
        """Run every pending group as one padded batched launch each.

        A failing group fails only its own handles: each gets a
        :class:`QueryGroupError` naming the group (kind + params + size)
        with the original exception chained on ``__cause__``, so
        ``result()`` tracebacks say *which* group died even when several
        groups fail in one sweep. The remaining groups still run. With
        ``raise_errors`` (the default) a :class:`BatchFlushError` listing
        every failed group (in submission order) re-raises after the sweep
        so a fire-and-forget ``flush()`` is loud; ``result()`` flushes
        quietly and surfaces only its own handle's error.
        """
        groups: Dict[Tuple, List[_Pending]] = {}
        for q in self._pending:
            groups.setdefault((id(q.graph), q.kind, q.params), []).append(q)
        self._pending = []
        errors: List[QueryGroupError] = []
        for (_, kind, params), qs in groups.items():
            for start in range(0, len(qs), self.max_batch):
                chunk = qs[start:start + self.max_batch]
                try:
                    self._run_group(kind, dict(params), chunk)
                except Exception as e:         # noqa: BLE001 — stored per handle
                    err = QueryGroupError(kind, params, len(chunk), e)
                    for q in chunk:
                        q.handle._fail(err)
                    errors.append(err)
        if raise_errors and errors:
            raise BatchFlushError(errors)

    def _run_group(self, kind: str, params: dict,
                   qs: List[_Pending]) -> None:
        self.n_launches += 1
        n_dedup, _ = launch_group(qs[0].graph, kind, params, qs,
                                  self.planner)
        self.n_deduped += n_dedup


def launch_group(g: GraphMatrix, kind: str, params: dict,
                 qs: List[_Pending], planner: Optional[PlanCache]
                 ) -> Tuple[int, Tuple[int, ...]]:
    """Run one coalesced group as a single padded batched launch.

    The shared engine-launch core under both :class:`QueryBatcher` and the
    serving layer (``engine/server.py`` passes a fallback-backend view of
    the graph here). Identical in-flight queries are **deduplicated**:
    duplicate sources — retries from impatient callers — share one batch
    column, and every duplicate handle is fulfilled from it, so a retry
    storm never multiplies engine work. Padding columns repeat the first
    source and are dropped at scatter-back.

    Returns ``(n_deduped, padded_sources)``: how many queries shared a
    column, and the exact padded source tuple that was launched (what the
    server records for warmup recipes and degraded-answer audits).

    Observability (DESIGN.md §14): the group gets one shared set of trace
    spans — ``launch`` (frontier build + the batched engine run, with the
    ``plan_resolve`` span nesting inside via the ambient trace) and
    ``scatter_back`` — adopted into every member handle's trace alongside
    that handle's own ``queue_wait`` span, so per-query traces carry the
    true amortised accounting.
    """
    group_trace = obs_trace.new_trace("group", kind=kind,
                                      backend=g.backend)
    if group_trace is not None:
        t_start = time.monotonic()
        for q in qs:
            if q.handle.trace is not None and q.submitted_at:
                q.handle.trace.add_span("queue_wait", q.submitted_at,
                                        t_start)
    with obs_trace.use(group_trace):
        with obs_trace.current_span("launch", kind=kind,
                                    backend=g.backend, n_queries=len(qs)):
            sources = np.asarray([q.source for q in qs], np.int64)
            uniq, inv = np.unique(sources, return_inverse=True)
            s_pad = _next_pow2(uniq.size)
            # pad with the first source; duplicate columns dropped below
            padded = np.concatenate(
                [uniq, np.full(s_pad - uniq.size, uniq[0], np.int64)])
            if kind == "bfs":
                out = queries.msbfs(g, padded, planner=planner,
                                    **params).levels
            elif kind == "khop":
                out = queries.mskhop(g, padded, planner=planner, **params)
            elif kind == "sssp":
                out = queries.ms_sssp(g, padded, planner=planner,
                                      **params).distances
            elif kind == "gnn_infer":
                out = queries.gnn_infer(g, padded, planner=planner,
                                        **params).logits
            else:
                out = queries.batched_ppr(g, padded, planner=planner,
                                          **params).ranks
        with obs_trace.current_span("scatter_back", n_queries=len(qs)):
            for q, col in zip(qs, inv):
                q.handle._fulfill(out[:, col])
    if group_trace is not None:
        for q in qs:
            if q.handle.trace is not None:
                q.handle.trace.adopt(group_trace.spans)
    n_dedup = len(qs) - uniq.size
    if obs_metrics.enabled():
        reg = obs_metrics.get_registry()
        reg.counter("engine_launches_total", "coalesced group launches",
                    ("kind", "backend")).inc(kind=kind, backend=g.backend)
        reg.counter("engine_deduped_total",
                    "in-flight duplicate queries sharing a batch column",
                    ("kind",)).inc(n_dedup, kind=kind)
    return n_dedup, tuple(int(s) for s in padded)
