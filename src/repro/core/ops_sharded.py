"""Multi-device shard_map twins of the Table II/III rows (DESIGN.md §11).

Every row here runs the *same per-shard math* as its single-device twin in
``repro.core.ops`` — the ``_*_block`` helpers are shared, so bit-exactness
is by construction — wrapped in one ``jax.shard_map`` over the stacked
per-shard slabs of a :class:`~repro.core.partition.PartitionedB2SR`:

  - the slab arrays shard their leading (shard) axis over the graph's mesh
    axes; the right-hand operand is replicated (``P()``),
  - each device computes its own contiguous row block locally (gathers hit
    only the replicated operand — a row partition has no cross-device
    reads inside the kernel),
  - one ``jax.lax.all_gather(..., tiled=True)`` concatenates the blocks
    back into the full output on every device (``mxm_sum`` reduces with a
    ``psum`` instead). Because blocks are equal, contiguous and in mesh-
    axis order, the gathered array IS the single-device layout — packed
    words included — and a final slice drops the partition padding.

Masks are applied *after* the gather through the same shared §V helpers
(``apply_frontier_mask`` / ``apply_grid_mask`` / ``apply_output_mask``) the
non-fused single-device paths use: mask-at-store semantics, one code path.

The rows register for both b2sr backends: a ``b2sr_pallas`` graph that is
sharded runs the jnp word schemes per shard today (per-shard Pallas
dispatch is future work; distribution logic stays single-sourced here).
The CSR baseline registers no sharded rows — ``GraphMatrix.shard``
rejects it up front.

``row_chunk`` is rejected on every sharded row: the shards themselves are
the memory bound, and a chunked shard_map body would re-trace per chunk.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

import functools
import inspect

from repro.core import ops as core_ops
from repro.core.b2sr import (B2SREll, ceil_div, ell_to_packed_grid,
                             unpack_tiles)
from repro.core.dispatch import BOTH, apply_output_mask, register
from repro.core.ops import (_bff_setup, _bmv_bbb_block, _bmv_bbf_block,
                            _bmv_bff_block, _mxm_bbb_block, _mxm_bbf_block,
                            _spmm_bbb_block, _spmm_bbf_block, _spmm_block,
                            apply_frontier_mask, apply_grid_mask,
                            shard_map_compat)
from repro.core.partition import PartitionedB2SR, shard_count


@functools.lru_cache(maxsize=1)
def _shard_map_kwargs() -> dict:
    """Disable the replication/varying check where the kwarg exists.

    The bodies here are collective-closed (gather/psum before return), but
    the older checker rejects scan carries inside them; probe the actual
    shard_map signature once instead of try/except-ing every call (which
    would re-trace the body and misattribute unrelated TypeErrors).
    """
    fn = jax.shard_map if hasattr(jax, "shard_map") else None
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    for kw in ("check_rep", "check_vma"):
        if kw in params:
            return {kw: False}
    return {}


class _LocalShard:
    """One device's view of the partition inside a shard_map body."""

    __slots__ = ("col", "tiles", "cnt", "bcol", "btiles", "brows", "part")

    def __init__(self, col, tiles, cnt, bcol, btiles, brows,
                 part: PartitionedB2SR):
        self.col = col          # int32[R, K]
        self.tiles = tiles      # uint32[R, K, t]
        self.cnt = cnt          # int32[R]
        self.bcol = bcol        # tuple of int32[rb, kb]
        self.btiles = btiles    # tuple of uint32[rb, kb, t]
        self.brows = brows      # tuple of int32[rb]; pad rows -> R (garbage)
        self.part = part

    @property
    def rows(self) -> int:
        return self.part.rows_per_shard

    def scatter_buckets(self, out, block_fn):
        """Per-bucket compute + scatter through the local row permutation.

        ``out`` must have ``rows_per_shard + 1`` leading rows — padding
        slab rows target the final garbage row, which is dropped here.
        """
        for cb, tb, rb in zip(self.bcol, self.btiles, self.brows):
            out = out.at[rb].set(block_fn(cb, tb))
        return out[: self.rows]


def _no_row_chunk(call):
    if call.row_chunk is not None:
        raise ValueError(
            "row_chunk is not supported on the sharded path — the row "
            "partition already bounds per-device memory (unshard() first "
            "if chunked evaluation is required)")


def _sharded_call(g, local_fn, rhs_arrays: Tuple, combine: str = "gather",
                  part: PartitionedB2SR = None):
    """Run ``local_fn(view, *rhs)`` under shard_map over ``g``'s mesh.

    ``local_fn`` returns this device's output block (leading axis = local
    rows); ``combine="gather"`` tiles the blocks back together,
    ``combine="psum"`` sum-reduces scalars/partials. The result is
    replicated (out_specs ``P()``) — exactly what the iterative algorithms
    need, since the next iteration's operand must be full-length anyway.
    """
    from jax.sharding import PartitionSpec as P

    part = g.partitioned if part is None else part
    mesh, axes = g.mesh, g.shard_axes
    nb = part.n_buckets
    slabs = (part.tile_col_idx, part.bit_tiles, part.row_n_tiles,
             *part.bucket_col_idx, *part.bucket_bit_tiles,
             *part.bucket_rows)
    in_specs = tuple(P(axes, *([None] * (a.ndim - 1))) for a in slabs)
    in_specs += tuple(P() for _ in rhs_arrays)

    def body(*args):
        s, rhs = args[: 3 + 3 * nb], args[3 + 3 * nb:]
        view = _LocalShard(
            s[0][0], s[1][0], s[2][0],
            tuple(x[0] for x in s[3: 3 + nb]),
            tuple(x[0] for x in s[3 + nb: 3 + 2 * nb]),
            tuple(x[0] for x in s[3 + 2 * nb: 3 + 3 * nb]),
            part)
        y = local_fn(view, *rhs)
        if combine == "psum":
            return jax.lax.psum(y, axes)
        return jax.lax.all_gather(y, axes, axis=0, tiled=True)

    return shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                            out_specs=P(), **_shard_map_kwargs())(*slabs,
                                                                  *rhs_arrays)


def _b2sr_ell(col, tiles, cnt, tile_dim: int, n_rows: int,
              n_cols: int) -> B2SREll:
    """Wrap raw replicated ELL arrays back into the view the blocks take."""
    return B2SREll(tile_col_idx=col, bit_tiles=tiles, row_n_tiles=cnt,
                   tile_dim=tile_dim, n_rows=n_rows, n_cols=n_cols)


# ---------------------------------------------------------------------------
# mxv rows (Table II)
# ---------------------------------------------------------------------------

def _mxv_bin_words(g, xw, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim

    # a partition without bucket slabs (built while use_buckets was off, or
    # an empty graph) runs the ELL slab — identical results, no SELL split
    if bucketed and part.n_buckets:
        def local(view, x):
            out = jnp.zeros((view.rows + 1,), jnp.uint32)
            return view.scatter_buckets(
                out, lambda cb, tb: _bmv_bbb_block(cb, tb, x, t))
    else:
        def local(view, x):
            return _bmv_bbb_block(view.col, view.tiles, x, t)

    y = _sharded_call(g, local, (xw,))
    return y[: ceil_div(part.n_rows, t)]


@register("mxv", "bitvec", "bin", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxv_bitvec_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_bin_words(g, xw, bucketed=False)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxv_bitvec_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_bin_words(g, xw, bucketed=True)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_bitvec_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=False)
    return y & (~call.mask if call.complement else call.mask)


@register("mxv", "bitvec", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_bitvec_bucketed_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=True)
    return y & (~call.mask if call.complement else call.mask)


# Sharded pull rows (DESIGN.md §12): the pull *schedule* is a per-shard
# kernel concern, but under shard_map every shard runs the same jnp block
# math over its row slab, so the sharded pull twin is the masked sharded
# sweep. What direction-optimization changes on a mesh is the *decision*:
# the traversal loops popcount the replicated frontier/visited words, so
# every shard derives the same global density and switches in lockstep —
# no collective needed for the heuristic itself.

@register("mxv_pull", "bitvec", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv_pull", "bitvec", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_pull_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=False)
    return y & (~call.mask if call.complement else call.mask)


@register("mxv_pull", "bitvec", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv_pull", "bitvec", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_pull_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_bin_words(g, xw, bucketed=True)
    return y & (~call.mask if call.complement else call.mask)


def _mxv_count_vals(g, xw, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    dt = call.out_dtype

    if bucketed and part.n_buckets:
        def local(view, x):
            out = jnp.zeros((view.rows + 1, t), dt)
            return view.scatter_buckets(
                out, lambda cb, tb: _bmv_bbf_block(cb, tb, x, dt))
    else:
        def local(view, x):
            return _bmv_bbf_block(view.col, view.tiles, x, dt)

    y = _sharded_call(g, local, (xw,))
    return y.reshape(-1)[: part.n_rows]


@register("mxv", "bitvec", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxv_count_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_count_vals(g, xw, call, bucketed=False)


@register("mxv", "bitvec", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxv_count_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxv_count_vals(g, xw, call, bucketed=True)


@register("mxv", "bitvec", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_count_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_count_vals(g, xw, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))


@register("mxv", "bitvec", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_count_bucketed_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxv_count_vals(g, xw, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))


def _mxv_dense_vals(g, x, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    sr = call.semiring
    x3, ident, av = _bff_setup(part.n_tile_cols, t, x, sr, call.a_value)

    if bucketed and part.n_buckets:
        def local(view, xr):
            out = jnp.full((view.rows + 1, t), ident, dtype=xr.dtype)
            return view.scatter_buckets(
                out,
                lambda cb, tb: _bmv_bff_block(cb, tb, xr, sr, av, ident, t))
    else:
        def local(view, xr):
            return _bmv_bff_block(view.col, view.tiles, xr, sr, av, ident, t)

    y = _sharded_call(g, local, (x3,))
    return y.reshape(-1)[: part.n_rows]


@register("mxv", "dense", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxv_dense_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxv_dense_vals(g, x, call, bucketed=False)


@register("mxv", "dense", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxv_dense_bucketed_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxv_dense_vals(g, x, call, bucketed=True)


@register("mxv", "dense", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxv_dense_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxv_dense_vals(g, x, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxv", "dense", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxv", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxv_dense_bucketed_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxv_dense_vals(g, x, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


# ---------------------------------------------------------------------------
# mxm rows: dense features (SpMM) / frontier batches / graph SpGEMM
# ---------------------------------------------------------------------------

def _mxm_dense_vals(g, x, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    n_tc = part.n_tile_cols
    d = x.shape[1]
    dt = call.out_dtype or x.dtype
    x_pad = jnp.pad(x, ((0, n_tc * t - x.shape[0]), (0, 0)))
    x3 = x_pad.reshape(n_tc, t, d)

    if bucketed and part.n_buckets:
        def local(view, xr):
            out = jnp.zeros((view.rows + 1, t, d), dtype=dt)
            return view.scatter_buckets(
                out, lambda cb, tb: _spmm_block(cb, tb, xr, t, dt))
    else:
        def local(view, xr):
            return _spmm_block(view.col, view.tiles, xr, t, dt)

    y = _sharded_call(g, local, (x3,))
    return y.reshape(-1, d)[: part.n_rows]


@register("mxm", "dense", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxm_dense_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxm_dense_vals(g, x, call, bucketed=False)


@register("mxm", "dense", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxm_dense_bucketed_sharded(g, x, call):
    _no_row_chunk(call)
    return _mxm_dense_vals(g, x, call, bucketed=True)


@register("mxm", "dense", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_dense_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxm_dense_vals(g, x, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "dense", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm", "dense", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_dense_bucketed_masked_sharded(g, x, call):
    _no_row_chunk(call)
    y = _mxm_dense_vals(g, x, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


def _mxm_bitmat_vals(g, xw, call, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    d = xw.shape[1]
    dt = call.out_dtype if call.out_dtype is not None else jnp.float32

    if bucketed and part.n_buckets:
        def local(view, xr):
            out = jnp.zeros((view.rows + 1, t, d), dtype=dt)
            return view.scatter_buckets(
                out, lambda cb, tb: _spmm_bbf_block(cb, tb, xr, dt))
    else:
        def local(view, xr):
            return _spmm_bbf_block(view.col, view.tiles, xr, dt)

    y = _sharded_call(g, local, (xw,))
    return y.reshape(-1, d)[: part.n_rows]


@register("mxm", "bitmat", "full", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxm_bitmat_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxm_bitmat_vals(g, xw, call, bucketed=False)


@register("mxm", "bitmat", "full", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxm_bitmat_bucketed_sharded(g, xw, call):
    _no_row_chunk(call)
    return _mxm_bitmat_vals(g, xw, call, bucketed=True)


@register("mxm", "bitmat", "full", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_bitmat_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxm_bitmat_vals(g, xw, call, bucketed=False)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "bitmat", "full", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm", "bitmat", "full", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_bitmat_bucketed_masked_sharded(g, xw, call):
    _no_row_chunk(call)
    y = _mxm_bitmat_vals(g, xw, call, bucketed=True)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


def _mxm_frontier_words(g, fw, bucketed: bool) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    W = fw.shape[2]

    if bucketed and part.n_buckets:
        def local(view, f3):
            out = jnp.zeros((view.rows + 1, t, W), jnp.uint32)
            return view.scatter_buckets(
                out, lambda cb, tb: _spmm_bbb_block(cb, tb, f3, t))
    else:
        def local(view, f3):
            return _spmm_bbb_block(view.col, view.tiles, f3, t)

    y = _sharded_call(g, local, (fw,))
    return y[: ceil_div(part.n_rows, t)]


@register("mxm", "frontier", "bin", "b2sr", bucketed=False, masked=False,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=False,
          masked=False, sharded=True)
def _mxm_frontier_sharded(g, fw, call):
    _no_row_chunk(call)
    return _mxm_frontier_words(g, fw, bucketed=False)


@register("mxm", "frontier", "bin", "b2sr", bucketed=True, masked=False,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=True,
          masked=False, sharded=True)
def _mxm_frontier_bucketed_sharded(g, fw, call):
    _no_row_chunk(call)
    return _mxm_frontier_words(g, fw, bucketed=True)


@register("mxm", "frontier", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_frontier_masked_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=False)
    return apply_frontier_mask(y, call.mask, call.complement)


@register("mxm", "frontier", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm", "frontier", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_frontier_bucketed_masked_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=True)
    return apply_frontier_mask(y, call.mask, call.complement)


@register("mxm_pull", "frontier", "bin", "b2sr", bucketed=False, masked=True,
          sharded=True)
@register("mxm_pull", "frontier", "bin", "b2sr_pallas", bucketed=False,
          masked=True, sharded=True)
def _mxm_pull_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=False)
    return apply_frontier_mask(y, call.mask, call.complement)


@register("mxm_pull", "frontier", "bin", "b2sr", bucketed=True, masked=True,
          sharded=True)
@register("mxm_pull", "frontier", "bin", "b2sr_pallas", bucketed=True,
          masked=True, sharded=True)
def _mxm_pull_bucketed_sharded(g, fw, call):
    _no_row_chunk(call)
    y = _mxm_frontier_words(g, fw, bucketed=True)
    return apply_frontier_mask(y, call.mask, call.complement)


def _mxm_graph_grid(g, other_ell: B2SREll) -> jax.Array:
    """A (sharded) ∨.∧ B (replicated): per-shard SpGEMM row blocks.

    B streams tile-row-wise against every shard's A tiles — one pass of
    B's slabs per iteration for the whole mesh; the output grid blocks
    concatenate into the single-device ``mxm_bin_bin_bin`` grid. The slab
    (not the SELL buckets) carries A here, matching the single-device
    SpGEMM whose B side is always one ELL.
    """
    part = g.partitioned
    t = part.tile_dim
    if t != other_ell.tile_dim:
        raise ValueError(f"tile_dim mismatch: {t} vs {other_ell.tile_dim}")
    if part.n_cols != other_ell.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {part.n_rows}x"
                         f"{part.n_cols}, B is {other_ell.n_rows}x"
                         f"{other_ell.n_cols}")

    def local(view, b_col, b_tiles, b_cnt):
        b = _b2sr_ell(b_col, b_tiles, b_cnt, t, other_ell.n_rows,
                      other_ell.n_cols)
        return _mxm_bbb_block(view.col, view.tiles, b, t)

    grid = _sharded_call(g, local, (other_ell.tile_col_idx,
                                    other_ell.bit_tiles,
                                    other_ell.row_n_tiles))
    return grid[: part.n_tile_rows]


@register("mxm", "graph", "bin", "b2sr", bucketed=BOTH, sharded=True)
@register("mxm", "graph", "bin", "b2sr_pallas", bucketed=BOTH, sharded=True)
def _mxm_graph_sharded(g, other, call):
    _no_row_chunk(call)
    grid = _mxm_graph_grid(g, other.ell)
    m_ell = call.mask.ell if call.mask is not None else None
    return apply_grid_mask(grid, m_ell, call.complement)


def _mxm_graph_counts(g, other_ell: B2SREll, out_dtype) -> jax.Array:
    part = g.partitioned
    t = part.tile_dim
    if t != other_ell.tile_dim:
        raise ValueError(f"tile_dim mismatch: {t} vs {other_ell.tile_dim}")
    if part.n_cols != other_ell.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {part.n_rows}x"
                         f"{part.n_cols}, B is {other_ell.n_rows}x"
                         f"{other_ell.n_cols}")

    def local(view, b_col, b_tiles, b_cnt):
        b = _b2sr_ell(b_col, b_tiles, b_cnt, t, other_ell.n_rows,
                      other_ell.n_cols)
        return _mxm_bbf_block(view.col, view.tiles, b, t)

    grid = _sharded_call(g, local, (other_ell.tile_col_idx,
                                    other_ell.bit_tiles,
                                    other_ell.row_n_tiles))
    grid = grid[: part.n_tile_rows]
    dense = grid.transpose(0, 2, 1, 3).reshape(
        part.n_tile_rows * t, other_ell.n_tile_cols * t)
    return dense[: part.n_rows, : other_ell.n_cols].astype(out_dtype)


@register("mxm", "graph", "full", "b2sr", bucketed=BOTH, masked=False,
          sharded=True)
@register("mxm", "graph", "full", "b2sr_pallas", bucketed=BOTH,
          masked=False, sharded=True)
def _mxm_graph_count_sharded(g, other, call):
    _no_row_chunk(call)
    return _mxm_graph_counts(g, other.ell, jnp.int32)


@register("mxm", "graph", "full", "b2sr", bucketed=BOTH, masked=True,
          sharded=True)
@register("mxm", "graph", "full", "b2sr_pallas", bucketed=BOTH,
          masked=True, sharded=True)
def _mxm_graph_count_masked_sharded(g, other, call):
    _no_row_chunk(call)
    counts = _mxm_graph_counts(g, other.ell, jnp.int32)
    return core_ops._apply_dense_mask(counts, call.mask.ell,
                                      call.complement, jnp.int32)


# ---------------------------------------------------------------------------
# mxm_sum: the fused Σ L ⊙ (L·Lᵀ) reduction (tri_count)
# ---------------------------------------------------------------------------

@register("mxm_sum", "tri", "full", "b2sr", bucketed=BOTH, masked=True,
          sharded=True)
@register("mxm_sum", "tri", "full", "b2sr_pallas", bucketed=BOTH,
          masked=True, sharded=True)
def _tri_sum_sharded(g, tri, call):
    """Per-shard masked count SpGEMM partials + one psum.

    L is row-partitioned with the graph's shard count (memoized on the
    :class:`LowerTriangle` operand); Lᵀ is replicated; the mask tile for an
    output block is the shard's own L slab, so each device's partial is
    Σ over its row block and the psum is exact (integer adds).
    """
    _no_row_chunk(call)
    part = tri.partitioned(shard_count(g.mesh, g.shard_axes))
    ell_t = tri.ell_t
    t = part.tile_dim

    def local(view, b_col, b_tiles, b_cnt):
        b = _b2sr_ell(b_col, b_tiles, b_cnt, t, ell_t.n_rows, ell_t.n_cols)
        counts = _mxm_bbf_block(view.col, view.tiles, b, t)  # [R, C, t, t]
        # the mask tiles for this output block are the shard's own L slab
        mg = ell_to_packed_grid(
            _b2sr_ell(view.col, view.tiles, view.cnt, t,
                      view.rows * t, part.n_cols))           # [R, C, t]
        m_bits = unpack_tiles(mg, t, jnp.int32)              # [R, C, t, t]
        return jnp.sum(counts * m_bits)

    total = _sharded_call(g, local, (ell_t.tile_col_idx, ell_t.bit_tiles,
                                     ell_t.row_n_tiles),
                          combine="psum", part=part)
    return total.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mesh-context shardmap SpMM (the pre-registry scale-out entry point)
# ---------------------------------------------------------------------------

def spmm_b2sr_shardmap(ell: B2SREll, x, axes, row_chunk=None):
    """Tile-row-partitioned B2SR SpMM (§Perf, EXPERIMENTS.md).

    The ambient-mesh twin of the registered sharded rows above: instead of
    a pre-partitioned graph it shards a single ELL view over the *current*
    mesh context at call time (each device owns a block of tile-rows, the
    feature matrix is all-gathered once — reduce-scatter in the backward).
    Kept for callers that manage their own mesh scope
    (``tests/test_shardmap_agg.py`` pins it); model code routes through
    ``repro.gnn_bit.layers.aggregate`` and the registry instead.
    Requires ell.n_rows == n_tile_rows × tile_dim (padded) and both the
    tile-row dim and x's node dim to shard evenly over ``axes``.
    """
    from jax._src.mesh import thread_resources
    from jax.sharding import PartitionSpec as P

    mesh = thread_resources.env.physical_mesh
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes or mesh.empty:
        return core_ops.spmm_b2sr(ell, x, row_chunk=row_chunk)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    p_total = 1
    for a in axes:
        p_total *= sizes[a]
    R = int(ell.tile_col_idx.shape[0])
    if (R % p_total != 0 or x.shape[0] % p_total != 0
            or ell.n_rows != R * ell.tile_dim):
        # small graphs (fewer tile-rows than shards) fall back to the
        # GSPMD path — the shard_map contract needs even blocks
        return core_ops.spmm_b2sr(ell, x, row_chunk=row_chunk)
    t = ell.tile_dim

    def block(col_blk, tiles_blk, cnt_blk, x_blk):
        x_full = jax.lax.all_gather(x_blk, axes, axis=0, tiled=True)
        ell_blk = B2SREll(
            tile_col_idx=col_blk, bit_tiles=tiles_blk, row_n_tiles=cnt_blk,
            tile_dim=t, n_rows=col_blk.shape[0] * t, n_cols=ell.n_cols)
        return core_ops.spmm_b2sr(ell_blk, x_full, row_chunk=row_chunk,
                                  vma_axes=axes)

    return shard_map_compat(
        block, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None, None), P(axes), P(axes, None)),
        out_specs=P(axes, None),
    )(ell.tile_col_idx, ell.bit_tiles, ell.row_n_tiles, x)
