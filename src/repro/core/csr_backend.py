"""Dispatch-registry entries for the float-CSR baseline backend.

The GraphBLAST/cuSPARSE stand-in: every Table II/III row is computed on the
float CSR twin (unpack packed operands → segment-reduce → repack), exactly
the inline ``backend == "csr"`` branches the per-method ladders in
``GraphMatrix`` used to carry (DESIGN.md §10). Bucketing never applies to
CSR, so every entry registers for both ``bucketed`` flags.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import csr as csr_mod
from repro.core import ops as core_ops
from repro.core.b2sr import (dense_to_b2sr, ell_to_packed_grid,
                             pack_bitvector, pack_frontier_matrix, to_ell,
                             unpack_bitvector, unpack_frontier_matrix)
from repro.core.dispatch import BOTH, apply_output_mask, register
from repro.core.semiring import ARITHMETIC


# -- mxv: Table II ----------------------------------------------------------

@register("mxv", "dense", "full", "csr", bucketed=BOTH, masked=False)
def _mxv_dense(g, x, call):
    return csr_mod.mxv(g.csr, x, call.semiring, call.a_value)


@register("mxv", "dense", "full", "csr", bucketed=BOTH, masked=True)
def _mxv_dense_masked(g, x, call):
    return csr_mod.mxv_masked(g.csr, x, call.mask, call.semiring,
                              call.complement, call.a_value)


@register("mxv", "bitvec", "bin", "csr", bucketed=BOTH)
def _mxv_bitvec(g, xw, call):
    t = g.tile_dim
    x = unpack_bitvector(xw, t, g.n_cols, jnp.float32)
    y = csr_mod.mxv(g.csr, x, ARITHMETIC) > 0
    yp = pack_bitvector(y, t, g.n_rows)
    if call.mask is not None:
        yp = yp & (~call.mask if call.complement else call.mask)
    return yp


@register("mxv_pull", "bitvec", "bin", "csr", bucketed=BOTH, masked=True)
def _mxv_pull(g, xw, call):
    # the float baseline has no early-exit schedule to switch to — the
    # pull row is the masked push row, so direction="pull" stays bit-exact
    # (and benchmarkable) against the bit backends
    return _mxv_bitvec(g, xw, call)


@register("mxv", "bitvec", "full", "csr", bucketed=BOTH, masked=False)
def _mxv_count(g, xw, call):
    x = unpack_bitvector(xw, g.tile_dim, g.n_cols, jnp.float32)
    return csr_mod.mxv(g.csr, x, ARITHMETIC).astype(call.out_dtype)


@register("mxv", "bitvec", "full", "csr", bucketed=BOTH, masked=True)
def _mxv_count_masked(g, xw, call):
    y = _mxv_count(g, xw, call)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))


# -- mxm: Table III + widened-RHS rows --------------------------------------

@register("mxm", "dense", "full", "csr", bucketed=BOTH, masked=False)
def _mxm_dense(g, x, call):
    return csr_mod.spmm(g.csr, x)


@register("mxm", "dense", "full", "csr", bucketed=BOTH, masked=True)
def _mxm_dense_masked(g, x, call):
    y = csr_mod.spmm(g.csr, x)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


def _unpack_bitmat(xw, t: int, n: int, dtype):
    """BitMatrix words uint32[ceil(n/t), d] -> dense 0/1 [n, d]."""
    shifts = jnp.arange(t, dtype=jnp.uint32)[None, :, None]
    bits = (xw[:, None, :] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1, xw.shape[1])[:n].astype(dtype)


@register("mxm", "bitmat", "full", "csr", bucketed=BOTH, masked=False)
def _mxm_bitmat(g, xw, call):
    x = _unpack_bitmat(xw, g.tile_dim, g.n_cols, jnp.float32)
    dt = call.out_dtype if call.out_dtype is not None else jnp.float32
    return csr_mod.spmm(g.csr, x).astype(dt)


@register("mxm", "bitmat", "full", "csr", bucketed=BOTH, masked=True)
def _mxm_bitmat_masked(g, xw, call):
    y = _mxm_bitmat(g, xw, call)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxm", "frontier", "bin", "csr", bucketed=BOTH)
def _mxm_frontier(g, fw, call):
    s_pad = fw.shape[2] * 32
    x = unpack_frontier_matrix(fw, g.n_cols, s_pad, jnp.float32)
    y = csr_mod.spmm(g.csr, x) > 0
    yp = pack_frontier_matrix(y, g.tile_dim, g.n_rows)
    if call.mask is not None:
        yp = core_ops.apply_frontier_mask(yp, call.mask, call.complement)
    return yp


@register("mxm_pull", "frontier", "bin", "csr", bucketed=BOTH, masked=True)
def _mxm_pull(g, fw, call):
    return _mxm_frontier(g, fw, call)


@register("mxm", "graph", "bin", "csr", bucketed=BOTH)
def _mxm_graph(g, other, call):
    db = jnp.asarray(csr_mod.to_dense(other.csr))
    out = np.asarray(csr_mod.spmm(g.csr, db)) > 0
    if call.mask is not None:
        dm = csr_mod.to_dense(call.mask.csr) > 0
        out = out & (~dm if call.complement else dm)
    # same packed-grid contract as the b2sr backends: the generic layer
    # rebuilds the sparse top level host-side
    return ell_to_packed_grid(to_ell(dense_to_b2sr(out, g.tile_dim)))


@register("mxm", "graph", "full", "csr", bucketed=BOTH, masked=False)
def _mxm_graph_count(g, other, call):
    db = jnp.asarray(csr_mod.to_dense(other.csr))
    return csr_mod.spmm(g.csr, db)


@register("mxm", "graph", "full", "csr", bucketed=BOTH, masked=True)
def _mxm_graph_count_masked(g, other, call):
    counts = _mxm_graph_count(g, other, call)
    dm = jnp.asarray(csr_mod.to_dense(call.mask.csr)) > 0
    keep = ~dm if call.complement else dm
    return jnp.where(keep, counts, 0)


# -- mxm_sum: fused Σ mask ⊙ (A·B) (tri_count, paper Listing 2) -------------

@register("mxm_sum", "tri", "full", "csr", bucketed=BOTH, masked=True)
def _tri_sum(g, tri, call):
    n = g.n_rows
    L = np.zeros((n, n), np.float32)
    L[tri.rows, tri.cols] = 1.0
    Lj = jnp.asarray(L)
    return jnp.sum((Lj @ Lj.T) * Lj)
