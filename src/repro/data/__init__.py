"""Data substrate: graph generators, token streams, samplers, recsys batches."""
