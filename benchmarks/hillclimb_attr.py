"""Hillclimb profiler: compile one cell (with overrides) and attribute
HBM bytes / wire bytes / flops to (opcode, result-shape) groups, with loop
multipliers applied. This is the 'profile' of the §Perf loop.

  PYTHONPATH=src python -m benchmarks.hillclimb_attr --arch phi4-mini-3.8b \
      --shape train_4k --set attn_seq_shard=true --top 20
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
from collections import Counter

import jax
from jax.sharding import NamedSharding

from repro.launch import hlo_cost as H
from repro.launch import mesh as mesh_mod
from repro.launch.specs import build_cell


def attribute(text: str):
    comps = H._parse_module(text)
    bytes_by = Counter()
    wire_by = Counter()
    flops_by = Counter()

    def walk(comp, mult, fused):
        for instr in comp.instrs:
            op = instr.opcode
            if op == "while":
                m = H._TRIP_RE.search(instr.line)
                trip = int(m.group(1)) if m else 1
                for key, extra in (("body", trip), ("condition", trip + 1)):
                    cm = H._CALLEE_RES[key].search(instr.line)
                    if cm and cm.group(1) in comps:
                        walk(comps[cm.group(1)], mult * extra, fused)
                continue
            if op in ("fusion", "call"):
                cm = None
                for key in ("calls", "to_apply"):
                    cm = H._CALLEE_RES[key].search(instr.line)
                    if cm:
                        break
                callee = comps.get(cm.group(1)) if cm else None
                if callee:
                    walk(callee, mult, True)
                if not fused:
                    io = H._type_bytes(instr.type_str)
                    operands = H._operand_names(instr)
                    for idx, o in enumerate(operands):
                        t = comp.types.get(o)
                        if not t:
                            continue
                        full = H._type_bytes(t)
                        if callee is not None and idx < len(callee.params):
                            s = H._sliced_param_bytes(callee,
                                                      callee.params[idx])
                            if s is not None:
                                io += min(s, full)
                                continue
                        io += full
                    bytes_by[(op, instr.type_str[:44])] += io * mult
                continue
            if op in H._FREE:
                continue
            base = op.replace("-start", "")
            if base in H._COLLECTIVES and not base.endswith("-done"):
                wire_by[(base, instr.type_str[:44])] += (
                    H._collective_wire(instr, base) * mult)
            if fused:
                if op == "dot":
                    flops_by[(op, instr.type_str[:44])] += (
                        H._dot_flops(instr, comp) * mult)
                continue
            f, b, w, u = H._instr_cost(instr, comp, comps, {},
                                       in_fusion=False)
            bytes_by[(op, instr.type_str[:44])] += b * mult
            if op == "dot":
                flops_by[(op, instr.type_str[:44])] += f * mult

    entry = [c for c in comps.values() if c.is_entry][0]
    walk(entry, 1, False)
    return bytes_by, wire_by, flops_by


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[], dest="overrides")
    ap.add_argument("--top", type=int, default=18)
    args = ap.parse_args()

    overrides = {}
    for kv in args.overrides:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)

    mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    cell = build_cell(args.arch, args.shape, mesh, overrides=overrides or None)
    in_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cell.in_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    out_sh = (jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if s is not None else None,
        cell.out_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        or x is None) if cell.out_specs is not None else None)
    with mesh:
        compiled = jax.jit(cell.step, in_shardings=in_sh,
                           out_shardings=out_sh,
                           donate_argnums=cell.donate).lower(
            *cell.args).compile()
    bytes_by, wire_by, flops_by = attribute(compiled.as_text())

    for title, counter in (("HBM bytes", bytes_by), ("wire bytes", wire_by),
                           ("dot flops", flops_by)):
        total = sum(counter.values())
        print(f"\n=== {title}: total {total:.3e} ===")
        for (op, t), v in counter.most_common(args.top):
            print(f"  {v:.3e} ({100*v/max(total,1):4.1f}%) {op:14s} {t}")


if __name__ == "__main__":
    main()
