"""Architecture registry: --arch <id> -> config + shape table."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    ArchConfig, DINConfig, GNNConfig, MoEConfig, TransformerConfig,
)

_MODULES: Dict[str, str] = {
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "gemma-7b": "repro.configs.gemma_7b",
    "minitron-4b": "repro.configs.minitron_4b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "arctic-480b": "repro.configs.arctic_480b",
    "egnn": "repro.configs.egnn",
    "gcn-cora": "repro.configs.gcn_cora",
    "gatedgcn": "repro.configs.gatedgcn",
    "graphcast": "repro.configs.graphcast",
    "din": "repro.configs.din",
}

ARCH_IDS = tuple(_MODULES)

# shape ids per family (assignment table)
LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")

# long_500k skipped for pure full-attention LM archs (DESIGN.md §6)
SKIPPED_CELLS = tuple(
    (a, "long_500k")
    for a in ("phi4-mini-3.8b", "gemma-7b", "minitron-4b",
              "qwen3-moe-30b-a3b", "arctic-480b"))


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_reduced_config(arch_id: str) -> ArchConfig:
    return importlib.import_module(_MODULES[arch_id]).reduced()


def shapes_for(arch_id: str) -> tuple:
    cfg = get_config(arch_id)
    if isinstance(cfg, TransformerConfig):
        return LM_SHAPES
    if isinstance(cfg, GNNConfig):
        return GNN_SHAPES
    return RECSYS_SHAPES


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) pair in the assignment; skips applied by default."""
    for arch in ARCH_IDS:
        for shape in shapes_for(arch):
            if not include_skipped and (arch, shape) in SKIPPED_CELLS:
                continue
            yield arch, shape
