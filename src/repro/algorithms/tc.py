"""Triangle counting: Σ (L·Lᵀ ⊙ L) with the fused masked BMM (paper §V).

Follows Azad-Buluç/Wolf as in GraphBLAST: L is the strict lower triangle of
the (symmetric) adjacency; the mask fuses the element-wise product and the
global reduction into the mxm — the ``mxm_sum`` registry row
(``GraphMatrix.tri_count``), whose L/Lᵀ operand pair is built once and
memoized on the matrix.
"""

from __future__ import annotations

from typing import Optional

from repro.core.graphblas import GraphMatrix


def triangle_count(g: GraphMatrix, row_chunk: Optional[int] = None) -> int:
    """Number of triangles in the undirected graph of ``g``."""
    return int(g.tri_count(row_chunk=row_chunk))
