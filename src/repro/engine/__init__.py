"""Batched multi-source query engine (DESIGN.md §9, §13).

Turns "millions of users each asking a reachability/ranking question" into
a handful of wide bit-matrix launches: frontier matrices (``queries``),
jitted launch-plan caching (``planner``), request coalescing
(``batcher``), and the fault-tolerant serving front end (``server``:
deadlines, backend fallback, circuit breakers, restart-safe warmup) with
deterministic fault injection (``faults``).
"""

from repro.engine.batcher import (BatchFlushError, QueryBatcher,  # noqa: F401
                                  QueryGroupError, QueryHandle)
from repro.engine.faults import FaultInjector, InjectedFault  # noqa: F401
from repro.engine.planner import (DEFAULT_PLANNER, Plan, PlanCache,  # noqa: F401
                                  PlanKey, plan_key)
from repro.engine.queries import (BatchedPPRResult, MSBFSResult,  # noqa: F401
                                  MSSSSPResult, batched_ppr, ms_sssp,
                                  msbfs, mskhop)
from repro.engine.server import (CircuitBreaker, GraphQueryServer,  # noqa: F401
                                 QueryRejected, ServerConfig, ServerStats)
