"""High-level GraphBLAS matrix object: unified dispatch over B2SR and CSR.

``GraphMatrix`` is what algorithms and models consume. It bundles:
  - the B2SR representation (+ optional transposed B2SR for vxm),
  - the float CSR baseline representation (the GraphBLAST stand-in),
  - padded ELL views for the static-shape TPU kernel path.

``backend`` selects the compute path:
  "b2sr"      jnp word-level bit ops (repro.core.ops)
  "b2sr_pallas"  Pallas kernels (repro.kernels, interpret on CPU)
  "csr"       float CSR baseline (repro.core.csr)

Load balancing: both b2sr backends transparently run the row-bucketed
(SELL-style) path when ``use_buckets`` is on (the default) — ``ell_buckets``
is built lazily from the ELL view on first use, so algorithms/ speed up on
skewed graphs with zero call-site changes (DESIGN.md §2). ``row_chunk``
callers keep the single-ELL path (chunking needs one uniform row axis).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import b2sr as b2sr_mod
from repro.core import csr as csr_mod
from repro.core import ops
from repro.core.b2sr import (B2SR, B2SRBucketedEll, B2SREll, ceil_div,
                             pack_bitvector, pack_frontier_matrix,
                             unpack_frontier_matrix)
from repro.core.semiring import Semiring, ARITHMETIC

BACKENDS = ("b2sr", "b2sr_pallas", "csr")


@dataclasses.dataclass
class GraphMatrix:
    """An immutable homogeneous-graph adjacency matrix, multi-format."""

    n_rows: int
    n_cols: int
    nnz: int
    tile_dim: int
    ell: B2SREll
    ell_t: Optional[B2SREll]          # transpose, for vxm / pull traversal
    csr: csr_mod.CSRMatrix
    csr_t: Optional[csr_mod.CSRMatrix]
    backend: str = "b2sr"
    # row-bucketed (SELL-style) views, built lazily from ell/ell_t; the
    # default compute path on the b2sr backends when ``use_buckets`` is on
    ell_buckets: Optional[B2SRBucketedEll] = None
    ell_buckets_t: Optional[B2SRBucketedEll] = None
    use_buckets: bool = True
    # lazy caches (same pattern as ell_buckets): the out-degree vector, the
    # transposed view, and the structure fingerprint used by engine/planner
    degrees_cache: Optional[jax.Array] = None
    transposed_cache: Optional["GraphMatrix"] = None
    fingerprint_cache: Optional[str] = None

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_coo(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int,
                 tile_dim: int = 32, with_transpose: bool = True,
                 backend: str = "b2sr",
                 max_tiles_per_row: Optional[int] = None) -> "GraphMatrix":
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        mat = b2sr_mod.coo_to_b2sr(rows, cols, n_rows, n_cols, tile_dim)
        ell = b2sr_mod.to_ell(mat, max_tiles_per_row)
        ell_t = None
        csr_t = None
        if with_transpose:
            mt = b2sr_mod.transpose(mat)
            ell_t = b2sr_mod.to_ell(mt, max_tiles_per_row)
            csr_t = csr_mod.from_coo(cols, rows, n_cols, n_rows)
        return GraphMatrix(
            n_rows=n_rows, n_cols=n_cols, nnz=mat.nnz, tile_dim=tile_dim,
            ell=ell, ell_t=ell_t,
            csr=csr_mod.from_coo(rows, cols, n_rows, n_cols), csr_t=csr_t,
            backend=backend,
        )

    @staticmethod
    def from_dense(mat: np.ndarray, tile_dim: int = 32, **kw) -> "GraphMatrix":
        rows, cols = np.nonzero(np.asarray(mat))
        return GraphMatrix.from_coo(rows, cols, mat.shape[0], mat.shape[1],
                                    tile_dim, **kw)

    @staticmethod
    def from_b2sr(mat: B2SR, with_transpose: bool = True,
                  backend: str = "b2sr",
                  max_tiles_per_row: Optional[int] = None) -> "GraphMatrix":
        """Wrap an already-built B2SR (e.g. an mxm output) without re-packing.

        The CSR twin is derived from the same tiles (one unpack), not by a
        second COO -> B2SR conversion.
        """
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        rows, cols = b2sr_mod.b2sr_to_coo(mat)
        ell = b2sr_mod.to_ell(mat, max_tiles_per_row)
        ell_t = None
        csr_t = None
        if with_transpose:
            mt = b2sr_mod.transpose(mat)
            ell_t = b2sr_mod.to_ell(mt, max_tiles_per_row)
            csr_t = csr_mod.from_coo(cols, rows, mat.n_cols, mat.n_rows)
        return GraphMatrix(
            n_rows=mat.n_rows, n_cols=mat.n_cols, nnz=mat.nnz,
            tile_dim=mat.tile_dim, ell=ell, ell_t=ell_t,
            csr=csr_mod.from_coo(rows, cols, mat.n_rows, mat.n_cols),
            csr_t=csr_t, backend=backend,
        )

    def with_backend(self, backend: str) -> "GraphMatrix":
        # the cached transpose carries the old backend; drop it (degrees and
        # the structure fingerprint are backend-independent and survive)
        return dataclasses.replace(self, backend=backend,
                                   transposed_cache=None)

    def with_buckets(self, use_buckets: bool) -> "GraphMatrix":
        """Toggle the bucketed (SELL-style) compute path on the b2sr backends."""
        return dataclasses.replace(self, use_buckets=use_buckets,
                                   transposed_cache=None)

    def transposed(self) -> "GraphMatrix":
        """Aᵀ as a view: swap the stored forward/transposed representations.

        Memoized (like ``ell_buckets``): repeated PageRank/PPR/vxm calls on
        the same graph reuse one transposed view instead of rebuilding it —
        and the view's back-reference makes ``transposed()`` an involution.
        """
        if self.transposed_cache is not None:
            return self.transposed_cache
        if self.ell_t is None:
            raise ValueError("GraphMatrix built without transpose "
                             "(with_transpose=True)")
        # build (and cache on *self*) the transpose's bucketed view before
        # swapping, so the cached view shares it with this instance
        if (self.use_buckets and self.backend != "csr"
                and self.ell_buckets_t is None):
            self.ell_buckets_t = b2sr_mod.to_bucketed(self.ell_t)
        gt = dataclasses.replace(
            self, ell=self.ell_t, ell_t=self.ell, csr=self.csr_t,
            csr_t=self.csr, ell_buckets=self.ell_buckets_t,
            ell_buckets_t=self.ell_buckets, n_rows=self.n_cols,
            n_cols=self.n_rows, degrees_cache=None, transposed_cache=self,
            fingerprint_cache=None)
        self.transposed_cache = gt
        return gt

    def buckets(self) -> B2SRBucketedEll:
        """The bucketed view of ``ell``, built lazily and cached."""
        if self.ell_buckets is None:
            self.ell_buckets = b2sr_mod.to_bucketed(self.ell)
        return self.ell_buckets

    def _bucketed(self, row_chunk: Optional[int] = None) -> bool:
        """Whether this op dispatches to the bucketed path."""
        return self.use_buckets and row_chunk is None

    # -- packed-vector helpers ---------------------------------------------
    def pack(self, x: jax.Array) -> jax.Array:
        """Binarize + bit-pack a column-space vector (paper §IV, Listing 1)."""
        return pack_bitvector(x, self.tile_dim, self.n_cols)

    def pack_rows(self, x: jax.Array) -> jax.Array:
        """Binarize + bit-pack a row-space vector (output/frontier side)."""
        return pack_bitvector(x, self.tile_dim, self.n_rows)

    # -- operations ---------------------------------------------------------
    def mxv(self, x: jax.Array, semiring: Semiring = ARITHMETIC,
            a_value: float = 1.0, mask: Optional[jax.Array] = None,
            complement: bool = False, row_chunk: Optional[int] = None) -> jax.Array:
        """y = A ⊕.⊗ x, full-precision vector (Table II row bin·full→full).

        Any supported semiring (Table IV); with ``mask``, the §V
        mask-at-store form.
        """
        if self.backend == "csr":
            if mask is None:
                return csr_mod.mxv(self.csr, x, semiring, a_value)
            return csr_mod.mxv_masked(self.csr, x, mask, semiring, complement,
                                      a_value)
        if self.backend == "b2sr_pallas":
            from repro.kernels.bmv import ops as bmv_kernel_ops
            if self._bucketed(row_chunk):
                y = bmv_kernel_ops.bmv_bin_full_full_bucketed(
                    self.buckets(), x, semiring, a_value)
            else:
                y = bmv_kernel_ops.bmv_bin_full_full(self.ell, x, semiring,
                                                     a_value)
        elif self._bucketed(row_chunk):
            y = ops.bmv_bin_full_full_bucketed(self.buckets(), x, semiring,
                                               a_value)
        else:
            y = ops.bmv_bin_full_full(self.ell, x, semiring, a_value, row_chunk)
        if mask is not None:
            keep = (mask == 0) if complement else (mask != 0)
            y = jnp.where(keep, y, semiring.identity_for(y.dtype))
        return y

    def mxv_bool(self, x_packed: jax.Array,
                 mask_packed: Optional[jax.Array] = None,
                 complement: bool = True,
                 row_chunk: Optional[int] = None) -> jax.Array:
        """Packed-frontier traversal (Table II row bin·bin→bin, BFS kernel)."""
        if self.backend == "csr":
            t = self.tile_dim
            x = b2sr_mod.unpack_bitvector(x_packed, t, self.n_cols, jnp.float32)
            y = csr_mod.mxv(self.csr, x, ARITHMETIC) > 0
            yp = pack_bitvector(y, t, self.n_rows)
            if mask_packed is not None:
                yp = yp & (~mask_packed if complement else mask_packed)
            return yp
        if self.backend == "b2sr_pallas":
            from repro.kernels.bmv import ops as bmv_kernel_ops
            if self._bucketed(row_chunk):
                return bmv_kernel_ops.bmv_bin_bin_bin_bucketed(
                    self.buckets(), x_packed, mask_packed, complement)
            return bmv_kernel_ops.bmv_bin_bin_bin(
                self.ell, x_packed, mask_packed, complement)
        if self._bucketed(row_chunk):
            if mask_packed is None:
                return ops.bmv_bin_bin_bin_bucketed(self.buckets(), x_packed)
            return ops.bmv_bin_bin_bin_bucketed_masked(
                self.buckets(), x_packed, mask_packed, complement)
        if mask_packed is None:
            return ops.bmv_bin_bin_bin(self.ell, x_packed, row_chunk)
        return ops.bmv_bin_bin_bin_masked(self.ell, x_packed, mask_packed,
                                          complement, row_chunk)

    def spmm_bool(self, f_packed: jax.Array,
                  mask_packed: Optional[jax.Array] = None,
                  complement: bool = True,
                  row_chunk: Optional[int] = None) -> jax.Array:
        """Multi-frontier traversal: ``mxv_bool`` widened to a packed
        frontier *matrix* (engine/ hot path, DESIGN.md §9).

        ``f_packed``: ``uint32[ceil(n_cols/t), t, W]`` from
        ``pack_frontier_matrix``; returns the packed next-frontier matrix
        ``uint32[ceil(n_rows/t), t, W]`` — column ``s`` bit-identical to
        ``mxv_bool`` on frontier ``s``, with A's tiles streamed once for
        all S sources.
        """
        if self.backend == "csr":
            s_pad = f_packed.shape[2] * 32
            x = unpack_frontier_matrix(f_packed, self.n_cols, s_pad,
                                       jnp.float32)
            y = csr_mod.spmm(self.csr, x) > 0
            yp = pack_frontier_matrix(y, self.tile_dim, self.n_rows)
            if mask_packed is not None:
                yp = ops.apply_frontier_mask(yp, mask_packed, complement)
            return yp
        if self.backend == "b2sr_pallas":
            from repro.kernels.spmm import ops as spmm_kernel_ops
            if self._bucketed(row_chunk):
                return spmm_kernel_ops.spmm_bin_bin_bin_bucketed(
                    self.buckets(), f_packed, mask_packed, complement)
            return spmm_kernel_ops.spmm_bin_bin_bin(
                self.ell, f_packed, mask_packed, complement)
        if self._bucketed(row_chunk):
            if mask_packed is None:
                return ops.spmm_bin_bin_bin_bucketed(self.buckets(), f_packed)
            return ops.spmm_bin_bin_bin_bucketed_masked(
                self.buckets(), f_packed, mask_packed, complement)
        if mask_packed is None:
            return ops.spmm_bin_bin_bin(self.ell, f_packed, row_chunk)
        return ops.spmm_bin_bin_bin_masked(self.ell, f_packed, mask_packed,
                                           complement, row_chunk)

    def mxv_count(self, x_packed: jax.Array, out_dtype=jnp.float32,
                  row_chunk: Optional[int] = None) -> jax.Array:
        """Count mxv (Table II row bin·bin→full): y_i = |N(i) ∩ frontier|."""
        if self.backend == "csr":
            x = b2sr_mod.unpack_bitvector(x_packed, self.tile_dim, self.n_cols,
                                          jnp.float32)
            return csr_mod.mxv(self.csr, x, ARITHMETIC).astype(out_dtype)
        if self.backend == "b2sr_pallas":
            from repro.kernels.bmv import ops as bmv_kernel_ops
            if self._bucketed(row_chunk):
                return bmv_kernel_ops.bmv_bin_bin_full_bucketed(
                    self.buckets(), x_packed, out_dtype)
            return bmv_kernel_ops.bmv_bin_bin_full(self.ell, x_packed, out_dtype)
        if self._bucketed(row_chunk):
            return ops.bmv_bin_bin_full_bucketed(self.buckets(), x_packed,
                                                 out_dtype)
        return ops.bmv_bin_bin_full(self.ell, x_packed, out_dtype, row_chunk)

    def vxm(self, x: jax.Array, **kw) -> jax.Array:
        """xᵀ·A, pull direction (Table II via Aᵀ) — uses the stored transpose."""
        return self.transposed().mxv(x, **kw)

    def spmm(self, x: jax.Array, row_chunk: Optional[int] = None) -> jax.Array:
        """Y = A @ X, dense X [n_cols, d] (bin·full→full widened; GNN hot path)."""
        if self.backend == "csr":
            return csr_mod.spmm(self.csr, x)
        if self.backend == "b2sr_pallas":
            from repro.kernels.spmm import ops as spmm_kernel_ops
            if self._bucketed(row_chunk):
                return spmm_kernel_ops.spmm_bucketed(self.buckets(), x)
            return spmm_kernel_ops.spmm(self.ell, x)
        if self._bucketed(row_chunk):
            return ops.spmm_b2sr_bucketed(self.buckets(), x)
        return ops.spmm_b2sr(self.ell, x, row_chunk=row_chunk)

    def mxm(self, other: Optional["GraphMatrix"] = None,
            mask: Optional["GraphMatrix"] = None, complement: bool = False,
            row_chunk: Optional[int] = None,
            with_transpose: bool = True) -> "GraphMatrix":
        """C⟨M⟩ = A ∨.∧ B on the boolean semiring — B2SR SpGEMM (Table III).

        ``other`` defaults to ``self`` (A²: 2-hop reachability). The packed
        output tile grid is computed on-device (jnp word ops or the Pallas
        kernel, per backend); the data-dependent sparse top level is rebuilt
        host-side (``packed_grid_to_b2sr``), so the result is a full
        ``GraphMatrix`` ready for further mxm/mxv — the GraphBLAST-style
        composable form. ``mask``/``complement`` give C⟨M⟩ / C⟨¬M⟩ with a
        structural mask applied right before the store (paper §V).
        """
        other = self if other is None else other
        if self.n_cols != other.n_rows:
            raise ValueError(f"inner-dim mismatch: {self.n_cols} vs "
                             f"{other.n_rows}")
        if mask is not None and (mask.n_rows != self.n_rows
                                 or mask.n_cols != other.n_cols):
            raise ValueError("mask shape must match the output")
        if self.backend == "csr":
            db = jnp.asarray(csr_mod.to_dense(other.csr))
            counts = csr_mod.spmm(self.csr, db)
            out = np.asarray(counts) > 0
            if mask is not None:
                dm = csr_mod.to_dense(mask.csr) > 0
                out = out & (~dm if complement else dm)
            rows, cols = np.nonzero(out)
            return GraphMatrix.from_coo(
                rows, cols, self.n_rows, other.n_cols, self.tile_dim,
                with_transpose=with_transpose, backend=self.backend)
        if self.tile_dim != other.tile_dim:
            raise ValueError(f"tile_dim mismatch: {self.tile_dim} vs "
                             f"{other.tile_dim}")
        if mask is not None and mask.tile_dim != self.tile_dim:
            raise ValueError(f"mask tile_dim mismatch: {mask.tile_dim} vs "
                             f"{self.tile_dim}")
        m_ell = mask.ell if mask is not None else None
        if self.backend == "b2sr_pallas":
            from repro.kernels.spgemm import ops as spgemm_kernel_ops
            if self._bucketed(row_chunk):
                grid = spgemm_kernel_ops.mxm_bucketed(
                    self.buckets(), other.ell, m_ell, complement)
            else:
                grid = spgemm_kernel_ops.mxm(self.ell, other.ell, m_ell,
                                             complement)
        elif self._bucketed(row_chunk):
            grid = ops.mxm_bin_bin_bin_bucketed(self.buckets(), other.ell,
                                                m_ell, complement)
        else:
            grid = ops.mxm_bin_bin_bin(self.ell, other.ell, m_ell,
                                       complement, row_chunk)
        mat = b2sr_mod.packed_grid_to_b2sr(
            np.asarray(grid), self.n_rows, other.n_cols)
        return GraphMatrix.from_b2sr(mat, with_transpose=with_transpose,
                                     backend=self.backend)

    def mxm_count(self, other: Optional["GraphMatrix"] = None,
                  mask: Optional["GraphMatrix"] = None,
                  complement: bool = False,
                  row_chunk: Optional[int] = None) -> jax.Array:
        """C = A +.× B (Table III bin·bin→full): dense common-neighbour counts."""
        other = self if other is None else other
        if self.n_cols != other.n_rows:
            raise ValueError(f"inner-dim mismatch: {self.n_cols} vs "
                             f"{other.n_rows}")
        if mask is not None and (mask.n_rows != self.n_rows
                                 or mask.n_cols != other.n_cols):
            raise ValueError("mask shape must match the output")
        if self.backend == "csr":
            db = jnp.asarray(csr_mod.to_dense(other.csr))
            counts = csr_mod.spmm(self.csr, db)
        elif self._bucketed(row_chunk):
            counts = ops.mxm_bin_bin_full_bucketed(self.buckets(), other.ell)
        else:
            counts = ops.mxm_bin_bin_full(self.ell, other.ell,
                                          row_chunk=row_chunk)
        if mask is not None:
            dm = jnp.asarray(csr_mod.to_dense(mask.csr)) > 0
            keep = ~dm if complement else dm
            counts = jnp.where(keep, counts, 0)
        return counts

    def tri_count(self, row_chunk: Optional[int] = None) -> jax.Array:
        """Σ (L·Lᵀ ⊙ L) where L = strict lower triangle of this matrix.

        Rewired through the mxm subsystem: the b2sr backend uses the masked
        count SpGEMM (``mxm_bin_bin_full_masked``), the Pallas backend the
        fully-fused BMM reduction kernel (its scalar twin), and the CSR
        baseline a dense masked matmul — all compute the same Azad-Buluç
        masked form the paper fuses in Listing 2.
        """
        rows = np.asarray(self.csr.row_idx)
        cols = np.asarray(self.csr.col_idx)
        keep = rows > cols
        lr, lc = rows[keep], cols[keep]
        n = self.n_rows
        if self.backend == "csr":
            L = np.zeros((n, n), np.float32)
            L[lr, lc] = 1.0
            Lj = jnp.asarray(L)
            return jnp.sum((Lj @ Lj.T) * Lj)
        mL = b2sr_mod.coo_to_b2sr(lr, lc, n, n, self.tile_dim)
        eL = b2sr_mod.to_ell(mL)
        eLT = b2sr_mod.to_ell(b2sr_mod.transpose(mL))
        if self.backend == "b2sr_pallas":
            from repro.kernels.bmm import ops as bmm_kernel_ops
            return bmm_kernel_ops.bmm_bin_bin_sum_masked(eL, eLT, eL)
        if self._bucketed(row_chunk):
            counts = ops.mxm_bin_bin_full_masked_bucketed(
                b2sr_mod.to_bucketed(eL), eLT, eL)
        else:
            counts = ops.mxm_bin_bin_full_masked(eL, eLT, eL,
                                                 row_chunk=row_chunk)
        return jnp.sum(counts).astype(jnp.float32)

    # -- batched query entry points (dispatch through engine/) ---------------
    def msbfs(self, sources: Sequence[int], max_iters: Optional[int] = None):
        """Multi-source BFS: per-source hop levels ``int32[n, S]``.

        One wide frontier-matrix traversal for the whole batch (engine/
        queries, plan-cached) — column ``s`` is bit-exact against
        ``algorithms.bfs(g, sources[s])``.
        """
        from repro.engine import queries
        return queries.msbfs(self, sources, max_iters=max_iters)

    def ppr(self, seeds: Sequence[int], alpha: float = 0.85,
            max_iters: int = 10, eps: float = 1e-9):
        """Batched personalized PageRank: per-seed ranks ``f32[n, S]``."""
        from repro.engine import queries
        return queries.batched_ppr(self, seeds, alpha=alpha,
                                   max_iters=max_iters, eps=eps)

    # -- storage -------------------------------------------------------------
    def degrees(self) -> jax.Array:
        """Out-degree vector from the CSR twin (row_ptr diff); memoized."""
        if self.degrees_cache is None:
            ptr = self.csr.row_ptr
            self.degrees_cache = (ptr[1:] - ptr[:-1]).astype(jnp.float32)
        return self.degrees_cache

    def fingerprint(self) -> str:
        """Content hash of the graph structure (the plan-cache key component).

        Hashes the ELL tile layout + bit tiles once per instance (memoized;
        backend/bucket toggles keep it — they are separate plan-key fields).
        """
        if self.fingerprint_cache is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(f"{self.n_rows}:{self.n_cols}:{self.nnz}:"
                     f"{self.tile_dim}".encode())
            h.update(np.asarray(self.ell.tile_col_idx).tobytes())
            h.update(np.asarray(self.ell.bit_tiles).tobytes())
            self.fingerprint_cache = h.hexdigest()
        return self.fingerprint_cache
