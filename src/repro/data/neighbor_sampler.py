"""Layered neighbor sampler (GraphSAGE-style, fanout e.g. 15-10).

Host-side numpy over a CSR adjacency; emits a *static-shape* padded subgraph
(the minibatch_lg contract): seeds + sampled k-hop neighborhood, edge list
(child -> parent direction for aggregation), node/edge masks, and the
local relabeling. Sampling is uniform with replacement when the degree
exceeds the fanout slot count is not required (standard practice).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    node_ids: np.ndarray      # [N_pad] global ids (0-padded)
    node_mask: np.ndarray     # [N_pad] bool
    senders: np.ndarray       # [E_pad] local indices
    receivers: np.ndarray     # [E_pad] local indices
    edge_mask: np.ndarray     # [E_pad] bool
    seed_mask: np.ndarray     # [N_pad] bool (loss nodes)
    n_real_nodes: int
    n_real_edges: int


def sampled_sizes(batch_nodes: int, fanout: Sequence[int]) -> Tuple[int, int]:
    """Static (N_pad, E_pad) for a given seed count and fanout schedule."""
    n = batch_nodes
    total_nodes = batch_nodes
    total_edges = 0
    for f in fanout:
        e = n * f
        total_edges += e
        total_nodes += e
        n = e
    return total_nodes, total_edges


def sample(row_ptr: np.ndarray, col_idx: np.ndarray, seeds: np.ndarray,
           fanout: Sequence[int], seed: int = 0) -> SampledSubgraph:
    rng = np.random.default_rng(seed)
    n_pad, e_pad = sampled_sizes(len(seeds), fanout)

    node_ids: List[int] = list(seeds)
    local = {int(g): i for i, g in enumerate(seeds)}
    senders: List[int] = []
    receivers: List[int] = []
    frontier = list(seeds)

    for f in fanout:
        next_frontier: List[int] = []
        for u in frontier:
            lo, hi = int(row_ptr[u]), int(row_ptr[u + 1])
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            picks = rng.choice(col_idx[lo:hi], size=take, replace=False)
            for v in picks:
                v = int(v)
                if v not in local:
                    local[v] = len(node_ids)
                    node_ids.append(v)
                # aggregation direction: neighbor (v) -> target (u)
                senders.append(local[v])
                receivers.append(local[u])
                next_frontier.append(v)
        frontier = next_frontier

    n_real = len(node_ids)
    e_real = len(senders)
    if n_real > n_pad or e_real > e_pad:
        raise RuntimeError("sampler exceeded static bounds")

    nid = np.zeros(n_pad, np.int64)
    nid[:n_real] = node_ids
    nmask = np.zeros(n_pad, bool)
    nmask[:n_real] = True
    snd = np.zeros(e_pad, np.int32)
    rcv = np.zeros(e_pad, np.int32)
    snd[:e_real] = senders
    rcv[:e_real] = receivers
    emask = np.zeros(e_pad, bool)
    emask[:e_real] = True
    smask = np.zeros(n_pad, bool)
    smask[: len(seeds)] = True
    return SampledSubgraph(nid, nmask, snd, rcv, emask, smask, n_real, e_real)
