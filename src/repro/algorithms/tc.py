"""Triangle counting: Σ (L·Lᵀ ⊙ L) with the fused masked BMM (paper §V).

Follows Azad-Buluç/Wolf as in GraphBLAST: L is the strict lower triangle of
the (symmetric) adjacency; the mask fuses the element-wise product and the
global reduction into the mxm — ``bmm_bin_bin_sum_masked``.
"""

from __future__ import annotations

from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.core import b2sr as b2sr_mod
from repro.core import ops
from repro.core.graphblas import GraphMatrix


def triangle_count(g: GraphMatrix, row_chunk: Optional[int] = None) -> int:
    """Number of triangles in the undirected graph of ``g``."""
    # Build L (strict lower triangle) and Lᵀ in B2SR from the CSR twin.
    rows = np.asarray(g.csr.row_idx)
    cols = np.asarray(g.csr.col_idx)
    keep = rows > cols
    lr, lc = rows[keep], cols[keep]
    t = g.tile_dim
    n = g.n_rows

    if g.backend == "csr":
        # float CSR baseline: gather-intersect via dense masked matmul
        import jax
        L = np.zeros((n, n), np.float32)
        L[lr, lc] = 1.0
        Lj = jnp.asarray(L)
        return int(jnp.sum((Lj @ Lj.T) * Lj))

    mL = b2sr_mod.coo_to_b2sr(lr, lc, n, n, t)
    mLT = b2sr_mod.transpose(mL)
    eL = b2sr_mod.to_ell(mL)
    eLT = b2sr_mod.to_ell(mLT)
    if g.backend == "b2sr_pallas":
        from repro.kernels.bmm import ops as bmm_kernel_ops
        total = bmm_kernel_ops.bmm_bin_bin_sum_masked(eL, eLT, eL)
    else:
        total = ops.bmm_bin_bin_sum_masked(eL, eLT, eL, row_chunk=row_chunk)
    return int(total)
