"""EGNN [Satorras et al. 2102.09844]: E(n)-equivariant message passing.

m_ij   = φ_e([h_i, h_j, ‖x_i − x_j‖²])
x_i'   = x_i + (1/deg_i) Σ_j (x_i − x_j) · φ_x(m_ij)
h_i'   = φ_h([h_i, Σ_j m_ij])

Messages depend on continuous pairwise distances → inherently valued; B2SR
holds only the adjacency structure (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import GNNConfig
from repro.models.gnn.common import (GraphBatch, graph_pool, node_ce_loss,
                                     segment_agg)

Params = Dict[str, Any]


def init_layer(key, d: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "phi_e": nn.mlp_params(ks[0], [2 * d + 1, d, d]),
        "phi_x": nn.mlp_params(ks[1], [d, d, 1]),
        "phi_h": nn.mlp_params(ks[2], [2 * d, d, d]),
    }


def init_params(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 2)
    return {
        "embed": nn.dense_params(ks[0], cfg.d_in, cfg.d_hidden),
        "layers": [init_layer(ks[1 + i], cfg.d_hidden)
                   for i in range(cfg.n_layers)],
        "head": nn.dense_params(ks[-1], cfg.d_hidden, cfg.n_classes),
    }


def forward(params: Params, batch: GraphBatch, cfg: GNNConfig):
    assert batch.coords is not None, "EGNN needs coordinates"
    n = batch.node_feat.shape[0]
    h = nn.dense(params["embed"], batch.node_feat)
    x = batch.coords
    deg = jnp.maximum(jax.ops.segment_sum(
        batch.edge_mask.astype(h.dtype), batch.receivers, num_segments=n), 1.0)

    for lp in params["layers"]:
        hs, hr = h[batch.senders], h[batch.receivers]
        dx = x[batch.receivers] - x[batch.senders]            # x_i - x_j
        d2 = jnp.sum(dx * dx, axis=-1, keepdims=True)
        m = nn.mlp(lp["phi_e"], jnp.concatenate([hr, hs, d2], -1),
                   act=jax.nn.silu, final_act=True)
        w = nn.mlp(lp["phi_x"], m, act=jax.nn.silu)           # [E, 1]
        coord_msg = dx * w
        x = x + segment_agg(coord_msg, batch.receivers, n,
                            batch.edge_mask, "sum") / deg[:, None]
        m_agg = segment_agg(m, batch.receivers, n, batch.edge_mask, "sum")
        h = h + nn.mlp(lp["phi_h"], jnp.concatenate([h, m_agg], -1),
                       act=jax.nn.silu)
    return h, x


def loss_fn(params: Params, batch: GraphBatch, cfg: GNNConfig):
    h, _ = forward(params, batch, cfg)
    if batch.n_graphs > 1:
        pooled = graph_pool(h, batch.graph_ids, batch.n_graphs,
                            batch.node_mask)
        logits = nn.dense(params["head"], pooled)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch.labels[:, None], -1)[:, 0]
        loss = jnp.mean(logz - gold)
    else:
        logits = nn.dense(params["head"], h)
        loss = node_ce_loss(logits, batch.labels, batch.train_mask)
    return loss, {"ce": loss}
