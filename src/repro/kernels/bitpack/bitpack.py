"""Pallas TPU kernel: dense 0/1 matrix -> packed bit tiles (+ bit transpose).

The conversion-time packing kernel (paper §III.B "bit-packing overhead"):
packs a dense [R*t, C*t] 0/1 block into uint32 words, one word per tile
bit-row, LSB-first. The transpose variant packs column-major (the
``__ballot_sync`` + ``__brev`` rotation of the paper, done here as a VPU
shift-reduce because TPU has no warp votes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, out_ref, *, t: int, col_major: bool):
    x = x_ref[...]                                 # [BRt, BCt] 0/1
    br = x.shape[0] // t
    bc = x.shape[1] // t
    tiles = x.reshape(br, t, bc, t).transpose(0, 2, 1, 3)   # [br, bc, t(row), t(col)]
    if col_major:
        tiles = jnp.swapaxes(tiles, -1, -2)
    shifts = jnp.arange(t, dtype=jnp.uint32)
    words = jnp.sum(tiles.astype(jnp.uint32) << shifts, axis=-1,
                    dtype=jnp.uint32)              # [br, bc, t]
    out_ref[...] = words


def pack_dense_pallas(x, *, t: int, block_r: int = 8, block_c: int = 8,
                      col_major: bool = False, interpret: bool = True):
    """x: [R*t, C*t] any-int/float 0/1 -> uint32[R, C, t]."""
    Rt, Ct = x.shape
    R, C = Rt // t, Ct // t
    assert Rt % t == 0 and Ct % t == 0
    assert R % block_r == 0 and C % block_c == 0
    grid = (R // block_r, C // block_c)
    return pl.pallas_call(
        functools.partial(_pack_kernel, t=t, col_major=col_major),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r * t, block_c * t),
                               lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_r, block_c, t), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C, t), jnp.uint32),
        interpret=interpret,
    )(x)


def _pack_rows_kernel(x_ref, out_ref, *, t: int):
    x = x_ref[...]                                 # [BR*t, BD] 0/1
    br = x.shape[0] // t
    tiles = x.reshape(br, t, -1).astype(jnp.uint32)
    shifts = jnp.arange(t, dtype=jnp.uint32)[None, :, None]
    out_ref[...] = jnp.sum(tiles << shifts, axis=1, dtype=jnp.uint32)


def pack_rows_pallas(x, *, t: int, block_r: int = 1, block_d: int = 128,
                     interpret: bool = True):
    """x: [R*t, D] 0/1 -> uint32[R, D]: row-axis-only packing, LSB-first.

    The activation-packing twin of :func:`pack_dense_pallas` — feature
    columns stay unpacked words (the ``BitMatrix`` layout consumed by the
    bin·bin→full spmm rows), only the node axis collapses t-to-1.
    """
    Rt, D = x.shape
    R = Rt // t
    assert Rt % t == 0 and R % block_r == 0 and D % block_d == 0
    grid = (R // block_r, D // block_d)
    return pl.pallas_call(
        functools.partial(_pack_rows_kernel, t=t),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r * t, block_d), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_r, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, D), jnp.uint32),
        interpret=interpret,
    )(x)


def _transpose_kernel(w_ref, out_ref, *, t: int):
    words = w_ref[...]                                    # [B, t]
    shifts = jnp.arange(t, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)  # [B, t, t]
    bits_t = jnp.swapaxes(bits, -1, -2)
    out_ref[...] = jnp.sum(bits_t << shifts, axis=-1, dtype=jnp.uint32)


def bit_transpose_pallas(words, *, t: int, block: int = 64,
                         interpret: bool = True):
    """uint32[N, t] row-major tiles -> column-major packed tiles."""
    N = words.shape[0]
    assert N % block == 0
    return pl.pallas_call(
        functools.partial(_transpose_kernel, t=t),
        grid=(N // block,),
        in_specs=[pl.BlockSpec((block, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, t), jnp.uint32),
        interpret=interpret,
    )(words)
