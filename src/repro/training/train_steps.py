"""Train/serve step builders per model family.

Each builder returns a pure step function (closing over the static config)
suitable for jax.jit with explicit in/out shardings — the single artifact the
launcher, the dry-run, and the real training drivers all consume.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DINConfig, GNNConfig, TransformerConfig
from repro.models import transformer as T
from repro.models.gnn import egnn, gatedgcn, gcn, graphcast
from repro.models.recsys import din as din_mod
from repro.training import optimizer as opt_mod


def _apply(opt_cfg, loss_fn, params, opt_state, *batch):
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, *batch)
    new_params, new_state, opt_metrics = opt_mod.update(
        opt_cfg, grads, opt_state, params)
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------

def lm_train_step(cfg: TransformerConfig, opt_cfg: opt_mod.OptimizerConfig,
                  grad_accum: int = 1) -> Callable:
    def loss_fn(params, tokens, labels):
        return T.loss_fn(params, tokens, labels, cfg)

    def step(params, opt_state, tokens, labels):
        if grad_accum == 1:
            return _apply(opt_cfg, loss_fn, params, opt_state, tokens, labels)
        # microbatched gradient accumulation (scan keeps HLO small)
        B = tokens.shape[0]
        mb = B // grad_accum
        tk = tokens.reshape(grad_accum, mb, -1)
        lb = labels.reshape(grad_accum, mb, -1)

        def acc_body(carry, xs):
            g_acc, l_acc = carry
            t_i, l_i = xs
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, t_i, l_i)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(acc_body, (zeros, 0.0), (tk, lb))
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        new_params, new_state, opt_metrics = opt_mod.update(
            opt_cfg, grads, opt_state, params)
        return new_params, new_state, dict(loss=loss_sum / grad_accum,
                                           **opt_metrics)

    return step


def lm_prefill_step(cfg: TransformerConfig) -> Callable:
    def step(params, tokens):
        logits, cache = T.prefill(params, tokens, cfg, last_only=True)
        return logits, cache

    return step


def lm_decode_step(cfg: TransformerConfig) -> Callable:
    def step(params, token, cache_k, cache_v, cache_len):
        return T.decode_step(params, token, cache_k, cache_v, cache_len, cfg)

    return step


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

_GNN_MODULES = {"gcn": gcn, "gatedgcn": gatedgcn, "egnn": egnn}


def gnn_train_step(cfg: GNNConfig, opt_cfg: opt_mod.OptimizerConfig) -> Callable:
    mod = _GNN_MODULES[cfg.family]

    def loss_fn(params, batch):
        return mod.loss_fn(params, batch, cfg)

    def step(params, opt_state, batch):
        return _apply(opt_cfg, loss_fn, params, opt_state, batch)

    return step


def gnn_infer_step(cfg: GNNConfig) -> Callable:
    mod = _GNN_MODULES[cfg.family]

    def step(params, batch):
        out = mod.forward(params, batch, cfg)
        return out[0] if isinstance(out, tuple) else out

    return step


def graphcast_train_step(cfg: GNNConfig, opt_cfg: opt_mod.OptimizerConfig,
                         mesh_spec) -> Callable:
    def loss_fn(params, feat, target):
        return graphcast.loss_fn(params, feat, target, mesh_spec, cfg)

    def step(params, opt_state, feat, target):
        return _apply(opt_cfg, loss_fn, params, opt_state, feat, target)

    return step


def graphcast_infer_step(cfg: GNNConfig, mesh_spec) -> Callable:
    def step(params, feat):
        return graphcast.forward(params, feat, mesh_spec, cfg)

    return step


# ---------------------------------------------------------------------------
# DIN
# ---------------------------------------------------------------------------

def din_train_step(cfg: DINConfig, opt_cfg: opt_mod.OptimizerConfig) -> Callable:
    def loss_fn(params, batch):
        return din_mod.loss_fn(params, batch, cfg)

    def step(params, opt_state, batch):
        return _apply(opt_cfg, loss_fn, params, opt_state, batch)

    return step


def din_serve_step(cfg: DINConfig) -> Callable:
    def step(params, batch):
        return din_mod.forward(params, batch, cfg)

    return step


def din_retrieval_step(cfg: DINConfig) -> Callable:
    def step(params, batch, cand_items, cand_cates):
        return din_mod.score_candidates(params, batch, cand_items,
                                        cand_cates, cfg)

    return step
