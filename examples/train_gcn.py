"""End-to-end driver: train a GCN with the B2SR binary-SpMM aggregation path.

Demonstrates the full framework stack on CPU:
  - synthetic citation-style graph (block pattern ~ communities),
  - GCN whose neighborhood aggregation runs over the paper's B2SR format,
  - AdamW training loop with checkpointing + restart-from-latest,
  - an injected mid-run failure to exercise fault tolerance.

Run:  PYTHONPATH=src python examples/train_gcn.py [--steps 300]
"""

import argparse
import dataclasses
import itertools
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import full_graph_batch
from repro.training import optimizer as opt_mod
from repro.training import train_steps
from repro.training.trainer import (SimulatedFailure, TrainerConfig,
                                    TrainState, run)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=1024)
    ap.add_argument("--no-b2sr", action="store_true",
                    help="use the float segment-sum aggregation instead")
    args = ap.parse_args()

    cfg = get_config("gcn-cora")
    cfg = dataclasses.replace(cfg, d_in=64, n_classes=7, d_hidden=32,
                              use_b2sr=not args.no_b2sr)
    batch = full_graph_batch(cfg, args.nodes, pattern="block", seed=3)
    print(f"graph: {args.nodes} nodes, {int(batch.senders.shape[0])} edges, "
          f"aggregation={'B2SR binary SpMM' if cfg.use_b2sr else 'segment_sum'}")

    opt_cfg = opt_mod.OptimizerConfig(name="adamw", lr=5e-3)
    key = jax.random.PRNGKey(0)
    from repro.models.gnn import gcn
    params = gcn.init_params(cfg, key)
    opt_state = opt_mod.init(opt_cfg, params)
    step = jax.jit(train_steps.gnn_train_step(cfg, opt_cfg))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=50,
                             ckpt_dir=ckpt_dir, log_every=50,
                             fail_at_step=args.steps // 2)
        data = itertools.repeat((batch,))
        state = TrainState(params=params, opt_state=opt_state)
        try:
            run(tcfg, step, state, data)
            raise AssertionError("injected failure did not fire")
        except SimulatedFailure as e:
            print(f"node failure simulated: {e} — restarting from checkpoint")
        # restart: fresh process state, same ckpt dir -> restores latest
        tcfg2 = dataclasses.replace(tcfg, fail_at_step=None)
        state2 = TrainState(params=params, opt_state=opt_state)  # step 0
        out = run(tcfg2, step, state2, itertools.repeat((batch,)))

    losses = out["losses"]
    print(f"resumed and finished at step {out['final_step']}; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not decrease"

    # accuracy on the training mask — and proof that the aggregation went
    # through the dispatch registry's spmm_bin_full_full row, not a bespoke
    # call path (the forward below runs unjitted, so every mxm resolves)
    from repro.core import dispatch
    from repro.models.gnn import gcn as gcn_mod
    r0 = dispatch.stats["resolves"]
    logits = gcn_mod.forward(out["state"].params, batch, cfg)
    if cfg.use_b2sr:
        assert dispatch.stats["resolves"] - r0 == cfg.n_layers, \
            "expected one registry resolve per GCN layer"
        assert dispatch.last_key[:4] == ("mxm", "dense", "full", "b2sr"), \
            f"aggregation did not dispatch the b2sr row: {dispatch.last_key}"
        print(f"dispatch: {dispatch.stats['resolves'] - r0} registry "
              f"resolves, last row {dispatch.last_key}")
    pred = np.asarray(logits.argmax(-1))
    mask = np.asarray(batch.train_mask)
    acc = (pred[mask] == np.asarray(batch.labels)[mask]).mean()
    print(f"train-mask accuracy: {acc:.3f}")
    print("OK")


if __name__ == "__main__":
    main()
