"""Graph algorithms vs networkx oracles, across backends and tile sizes."""

import networkx as nx
import numpy as np
import pytest

from repro.algorithms import bfs, connected_components, pagerank, sssp, triangle_count
from repro.core import GraphMatrix
from repro.data import graphs as gen


def build(pattern: str, n: int, tile_dim: int = 8, backend: str = "b2sr",
          seed: int = 0):
    rows, cols = gen.PATTERNS[pattern](n, seed=seed)
    g = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=tile_dim,
                             backend=backend)
    nxg = nx.Graph()
    nxg.add_nodes_from(range(n))
    nxg.add_edges_from(zip(rows.tolist(), cols.tolist()))
    return g, nxg


BACKENDS = ["b2sr", "csr", "b2sr_pallas"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("pattern", ["dot", "diagonal", "block"])
def test_bfs_levels(backend, pattern):
    g, nxg = build(pattern, 96, tile_dim=8, backend=backend)
    res = bfs(g, source=0)
    want = nx.single_source_shortest_path_length(nxg, 0)
    got = np.asarray(res.levels)
    for v in range(96):
        if v in want:
            assert got[v] == want[v], f"node {v}"
        else:
            assert got[v] == -1, f"node {v} should be unreachable"


@pytest.mark.parametrize("backend", BACKENDS)
def test_sssp_unit_weights(backend):
    g, nxg = build("hybrid", 80, tile_dim=16, backend=backend)
    res = sssp(g, source=3)
    want = nx.single_source_shortest_path_length(nxg, 3)
    got = np.asarray(res.distances)
    for v in range(80):
        if v in want:
            assert got[v] == want[v]
        else:
            assert np.isinf(got[v])


@pytest.mark.parametrize("backend", BACKENDS)
def test_pagerank_matches_networkx(backend):
    g, nxg = build("block", 64, tile_dim=8, backend=backend)
    res = pagerank(g, alpha=0.85, max_iters=100, eps=1e-12)
    want = nx.pagerank(nxg, alpha=0.85, max_iter=200, tol=1e-12)
    got = np.asarray(res.ranks)
    for v in range(64):
        assert abs(got[v] - want[v]) < 1e-5, f"node {v}: {got[v]} vs {want[v]}"


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("pattern", ["dot", "block", "stripe"])
def test_connected_components(backend, pattern):
    g, nxg = build(pattern, 72, tile_dim=8, backend=backend)
    res = connected_components(g)
    labels = np.asarray(res.labels)
    comps = list(nx.connected_components(nxg))
    # same partition: each nx component maps to exactly one label
    seen = {}
    for comp in comps:
        ls = {int(labels[v]) for v in comp}
        assert len(ls) == 1, f"component split: {ls}"
        l = ls.pop()
        assert l not in seen, "two components merged"
        seen[l] = True
    assert len(seen) == len(comps)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("tile_dim", [4, 8, 32])
def test_triangle_count(backend, tile_dim):
    g, nxg = build("block", 64, tile_dim=tile_dim, backend=backend)
    got = triangle_count(g)
    want = sum(nx.triangles(nxg).values()) // 3
    assert got == want


def test_bfs_pallas_matches_jnp_large():
    g, _ = build("road", 256, tile_dim=32, backend="b2sr")
    r1 = bfs(g, source=0)
    r2 = bfs(g.with_backend("b2sr_pallas"), source=0)
    assert np.array_equal(np.asarray(r1.levels), np.asarray(r2.levels))
