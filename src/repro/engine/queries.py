"""Batched multi-source graph queries over packed frontier matrices.

The single-source algorithms in ``repro.algorithms`` pay one full matrix
sweep per query. Here a batch of S queries shares every sweep: frontiers
live in one bit-packed :class:`~repro.core.operands.FrontierBatch`
(``uint32[tiles, t, W]`` with 32 sources per word) and each iteration is
one generic ``GraphMatrix.mxm`` launch — the FrontierBatch operand selects
the multi-frontier Table row, and A's tiles stream once for the whole
batch. Every query loop is compiled once per (graph, kernel, batch width,
descriptor) and cached by ``engine.planner``. A sharded graph
(``GraphMatrix.shard(mesh)``) routes every iteration through the
shard_map rows — one mesh serves the whole batch per sweep — and the plan
key carries the mesh fingerprint, so plans never leak across mesh shapes
(DESIGN.md §11).

Parity contracts (pinned by tests/test_engine.py):
  - ``msbfs`` / ``mskhop`` / ``ms_sssp`` column ``s`` is **bit-exact**
    against the single-source run on ``sources[s]`` (boolean ops are
    order-insensitive).
  - ``batched_ppr`` column ``s`` is **allclose** against
    ``algorithms.pagerank.ppr`` (the batched spmm sums features in a
    different float order than the scanned bmv).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.b2sr import (SOURCE_WORD_BITS, ceil_div,
                             unpack_frontier_matrix)
from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.operands import FrontierBatch
from repro.engine import planner as planner_mod
from repro.engine.planner import PlanCache, descriptor_key, plan_key

#: The descriptor every masked-traversal loop bakes into its trace: the
#: per-source visited sets as a complement mask (loop-carried, so mask
#: presence is pinned via ``masked=True`` at key time).
_TRAVERSAL_DESC = descriptor_key(Descriptor(complement=True), masked=True)


@dataclasses.dataclass
class MSBFSResult:
    levels: jax.Array        # int32[n, S]; -1 = unreachable from sources[s]
    n_iterations: int        # max over the batch (columns finish together)


@dataclasses.dataclass
class MSSSSPResult:
    distances: jax.Array     # float32[n, S]; +inf = unreachable
    n_iterations: int


@dataclasses.dataclass
class BatchedPPRResult:
    ranks: jax.Array         # float32[n, S]; column s = PPR from seeds[s]
    n_iterations: int


def _check_sources(sources, n: int) -> np.ndarray:
    src = np.asarray(sources, dtype=np.int64).reshape(-1)
    if src.size == 0:
        raise ValueError("need at least one source")
    if src.min() < 0 or src.max() >= n:
        raise ValueError(f"source out of range [0, {n})")
    return src


def _padded_width(n_sources: int) -> int:
    return ceil_div(n_sources, SOURCE_WORD_BITS) * SOURCE_WORD_BITS


def _one_hot_frontier(g: GraphMatrix, src: np.ndarray,
                      s_pad: int) -> FrontierBatch:
    """Packed one-hot frontier matrix [tiles, t, W] for a source batch.

    Built directly in the packed layout — S word-writes instead of
    materialising (and shipping) the dense ``[n, s_pad]`` matrix that
    ``FrontierBatch.pack`` would consume (hot on the serving path).
    """
    t = g.tile_dim
    words = np.zeros((ceil_div(g.n_rows, t), t, s_pad // SOURCE_WORD_BITS),
                     np.uint32)
    idx = np.arange(src.size)
    np.bitwise_or.at(
        words, (src // t, src % t, idx // SOURCE_WORD_BITS),
        np.uint32(1) << (idx % SOURCE_WORD_BITS).astype(np.uint32))
    return FrontierBatch.from_words(jnp.asarray(words), g.n_rows, s_pad, t)


def _planner(planner: Optional[PlanCache]) -> PlanCache:
    return planner_mod.DEFAULT_PLANNER if planner is None else planner


# ---------------------------------------------------------------------------
# multi-source BFS: per-source depth via iteration-stamped updates
# ---------------------------------------------------------------------------

def _build_msbfs_plan(g: GraphMatrix):
    gt = g.transposed()
    n = g.n_rows

    def loop(f0, levels0, max_iters):
        def cond(state):
            frontier, _, _, it = state
            return frontier.any() & (it < max_iters)

        def body(state):
            frontier, visited, levels, it = state
            # FrontierBatch operand -> the multi-frontier bin·bin→bin mxm
            # row, with the per-source visited sets as the §V mask
            nxt = gt.mxm(frontier, desc=Descriptor(mask=visited,
                                                   complement=True))
            new_bits = unpack_frontier_matrix(nxt.words, n, levels.shape[1],
                                              jnp.bool_)
            levels = jnp.where(new_bits & (levels < 0), it + 1, levels)
            return nxt, visited | nxt, levels, it + 1

        _, _, levels, it = jax.lax.while_loop(
            cond, body, (f0, f0, levels0, jnp.int32(0)))
        return levels, it

    return jax.jit(loop)


def msbfs(g: GraphMatrix, sources: Sequence[int],
          max_iters: Optional[int] = None,
          planner: Optional[PlanCache] = None) -> MSBFSResult:
    """Hop levels from every source in one batched traversal (push).

    Column ``s`` of ``levels`` is bit-exact against
    ``algorithms.bfs(g, sources[s]).levels``.
    """
    n = g.n_rows
    src = _check_sources(sources, n)
    max_iters = n if max_iters is None else max_iters
    s_pad = _padded_width(src.size)
    plan = _planner(planner).get(plan_key(g, "msbfs", s_pad,
                                          desc=_TRAVERSAL_DESC),
                                 lambda: _build_msbfs_plan(g))
    f0 = _one_hot_frontier(g, src, s_pad)
    levels0 = jnp.asarray(_stamp_zero(n, s_pad, src))
    levels, it = plan(f0, levels0, jnp.int32(max_iters))
    return MSBFSResult(levels=levels[:, : src.size], n_iterations=int(it))


def _stamp_zero(n: int, s_pad: int, src: np.ndarray) -> np.ndarray:
    lv = np.full((n, s_pad), -1, np.int32)
    lv[src, np.arange(src.size)] = 0
    return lv


# ---------------------------------------------------------------------------
# multi-source k-hop neighborhoods
# ---------------------------------------------------------------------------

def _build_mskhop_plan(g: GraphMatrix):
    gt = g.transposed()

    def loop(f0, k):
        def body(_, state):
            frontier, visited = state
            nxt = gt.mxm(frontier, desc=Descriptor(mask=visited,
                                                   complement=True))
            return nxt, visited | nxt

        _, visited = jax.lax.fori_loop(0, k, body, (f0, f0))
        return visited & ~f0              # exclude the sources themselves

    return jax.jit(loop)


def mskhop(g: GraphMatrix, sources: Sequence[int], k: int,
           planner: Optional[PlanCache] = None) -> jax.Array:
    """<=k-hop neighborhoods of every source, as ``bool[n, S]``.

    Column ``s`` is bit-exact against
    ``algorithms.khop_frontier(g, sources[s], k)``.
    """
    if k < 1:
        raise ValueError("k must be >= 1")
    n = g.n_rows
    src = _check_sources(sources, n)
    s_pad = _padded_width(src.size)
    plan = _planner(planner).get(plan_key(g, "mskhop", s_pad,
                                          desc=_TRAVERSAL_DESC),
                                 lambda: _build_mskhop_plan(g))
    reached = plan(_one_hot_frontier(g, src, s_pad), jnp.int32(k))
    return unpack_frontier_matrix(reached.words, n, src.size, jnp.bool_)


# ---------------------------------------------------------------------------
# multi-source SSSP (uniform edge weight — hop distances × weight)
# ---------------------------------------------------------------------------

def ms_sssp(g: GraphMatrix, sources: Sequence[int], edge_weight: float = 1.0,
            max_iters: Optional[int] = None,
            planner: Optional[PlanCache] = None) -> MSSSSPResult:
    """Batched SSSP on the binary adjacency: ``levels × edge_weight``.

    B2SR edges are unweighted, so min-plus distances are hop counts scaled
    by the uniform weight — one msbfs serves the whole batch. Matches the
    looped ``algorithms.sssp`` exactly for dyadic weights (1.0, 0.5, 2.0,
    ...), where k repeated float adds equal ``k * w``.
    """
    res = msbfs(g, sources, max_iters=max_iters, planner=planner)
    dist = jnp.where(res.levels >= 0,
                     res.levels.astype(jnp.float32) * edge_weight, jnp.inf)
    return MSSSSPResult(distances=dist, n_iterations=res.n_iterations)


# ---------------------------------------------------------------------------
# batched personalized PageRank (arithmetic semiring, per-column restarts)
# ---------------------------------------------------------------------------

def _build_ppr_plan(g: GraphMatrix):
    gt = g.transposed()
    out_deg = g.degrees()
    dangling = out_deg == 0
    safe_deg = jnp.where(dangling, 1.0, out_deg)

    def loop(restart, alpha, eps, max_iters):
        def cond(state):
            _, delta, it = state
            return (delta > eps) & (it < max_iters)

        def body(state):
            pr, _, it = state
            scaled = pr / safe_deg[:, None]           # out-degree division
            contrib = gt.mxm(scaled)                  # [n, S] multi-vector
            dangle = jnp.sum(jnp.where(dangling[:, None], pr, 0.0), axis=0)
            new = alpha * contrib + (alpha * dangle[None, :]
                                     + (1.0 - alpha)) * restart
            delta = jnp.max(jnp.sum(jnp.abs(new - pr), axis=0))
            return new, delta, it + 1

        pr, _, it = jax.lax.while_loop(
            cond, body, (restart, jnp.float32(jnp.inf), jnp.int32(0)))
        return pr, it

    return jax.jit(loop)


def batched_ppr(g: GraphMatrix,
                seeds: Union[Sequence[int], jax.Array, np.ndarray],
                alpha: float = 0.85, max_iters: int = 10, eps: float = 1e-9,
                planner: Optional[PlanCache] = None) -> BatchedPPRResult:
    """Personalized PageRank for S seeds in one multi-vector iteration.

    ``seeds`` is either an int array ``[S]`` (one-hot restarts) or a dense
    restart matrix ``[n, S]`` (per-column restart distributions). Dangling
    mass restarts into each column's own distribution — the same update as
    ``algorithms.pagerank.ppr``, so column ``s`` is allclose against the
    single-seed run. Stops when the worst column's L1 delta is <= ``eps``
    (a batch iterates until its slowest member converges).
    """
    n = g.n_rows
    seeds_arr = np.asarray(seeds)
    if seeds_arr.ndim == 2:
        if seeds_arr.shape[0] != n:
            raise ValueError(f"restart matrix must be [n={n}, S]")
        s = seeds_arr.shape[1]
        s_pad = _padded_width(s)
        restart = np.zeros((n, s_pad), np.float32)
        restart[:, :s] = seeds_arr
    else:
        src = _check_sources(seeds_arr, n)
        s = src.size
        s_pad = _padded_width(s)
        restart = np.zeros((n, s_pad), np.float32)
        restart[src, np.arange(s)] = 1.0
    plan = _planner(planner).get(plan_key(g, "ppr", s_pad),
                                 lambda: _build_ppr_plan(g))
    ranks, it = plan(jnp.asarray(restart), jnp.float32(alpha),
                     jnp.float32(eps), jnp.int32(max_iters))
    return BatchedPPRResult(ranks=ranks[:, :s], n_iterations=int(it))
