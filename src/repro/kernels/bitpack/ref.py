"""Pure-jnp oracle for the bitpack kernels (delegates to core packers)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.b2sr import bit_transpose_words, pack_dense_tiles


def pack_dense(x, t: int, col_major: bool = False):
    words = pack_dense_tiles(x, t)
    if col_major:
        words = bit_transpose_words(words, t)
    return words


def bit_transpose(words, t: int):
    return bit_transpose_words(words, t)
