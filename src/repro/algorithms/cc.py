"""Connected components, FastSV-style linear-algebra formulation (paper §V).

min-plus label propagation with pointer jumping (the FastSV "stochastic
hooking + shortcutting" collapsed to its min-label core, as in the
GraphBLAST implementation the paper follows): every vertex repeatedly takes
the minimum label among {itself, its neighbors' labels}, then shortcuts
through its parent. Converges in O(log n) iterations on typical graphs.

Direction optimization (DESIGN.md §12): CC has no visited mask, so the
pull row doesn't apply — here direction is *operand orientation*. A push
iteration hooks over out-edges (``A``), a pull iteration over in-edges
(``Aᵀ``); on the symmetric adjacency CC semantically assumes the two are
the same matrix, and min is order-insensitive, so every mode is bit-exact.
The changed-vertex set plays the frontier role in the density estimate
(packed + popcounted, same estimator as BFS) and the per-iteration choice
is recorded on ``CCResult.directions``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.algorithms import direction as direction_mod
from repro.algorithms.direction import DirectionConfig
from repro.core.descriptor import Descriptor
from repro.core.graphblas import GraphMatrix
from repro.core.operands import BitVector
from repro.core.semiring import MIN_PLUS


@dataclasses.dataclass
class CCResult:
    labels: jax.Array       # int32[n]: representative (min vertex id) per component
    n_iterations: int
    directions: Tuple[str, ...] = ()


def connected_components(g: GraphMatrix, max_iters: Optional[int] = None,
                         row_chunk: Optional[int] = None,
                         direction: Union[str, DirectionConfig, None] = "auto"
                         ) -> CCResult:
    cfg = direction_mod.as_config(direction)
    n = g.n_rows
    max_iters = n if max_iters is None else max_iters
    # orientation switching needs the stored transpose; a graph built
    # with with_transpose=False keeps the historical push-only loop
    if g.ell_t is None and g.backend != "csr" and cfg.mode != "push":
        cfg = DirectionConfig(mode="push")
    gt = g.transposed() if cfg.mode != "push" else g
    avg_degree = g.nnz / max(n, 1)
    t = g.tile_dim
    f0 = jnp.arange(n, dtype=jnp.float32)

    def hook_push(f):
        # hook: min over neighbors' labels (a_value=0 ⇒ pure min of f_j)
        return g.mxv(f, MIN_PLUS, Descriptor(row_chunk=row_chunk),
                     a_value=0.0)

    def hook_pull(f):
        return gt.mxv(f, MIN_PLUS, Descriptor(row_chunk=row_chunk),
                      a_value=0.0)

    def cond(state):
        _, changed, it, _, _, _ = state
        return changed.any() & (it < max_iters)

    def body(state):
        f, _, it, d, locked, trace = state
        if cfg.mode == "auto":
            neigh = jax.lax.cond(d == direction_mod.PULL, hook_pull,
                                 hook_push, f)
        elif cfg.mode == "pull":
            neigh = hook_pull(f)
        else:
            neigh = hook_push(f)
        f_new = jnp.minimum(f, neigh)
        # shortcut: pointer jumping f[i] <- f[f[i]]
        f_new = f_new[f_new.astype(jnp.int32)]
        changed = BitVector.pack((f_new != f).astype(jnp.float32), t, n)
        trace = direction_mod.record(trace, it, d)
        # the changed set is the "frontier"; CC has no visited set, so the
        # unexplored estimate is the whole edge set (pull while a large
        # fraction of labels is still moving, push for the tail)
        d_next, locked = direction_mod.next_direction(
            cfg, d, locked, direction_mod.nnz_words(changed.words),
            jnp.int32(0), n, avg_degree)
        return f_new, changed, it + 1, d_next, locked, trace

    ones = BitVector.pack(jnp.ones(n, jnp.float32), t, n)
    state = (f0, ones, jnp.int32(0), direction_mod.initial_direction(cfg),
             jnp.bool_(False), direction_mod.empty_trace(max_iters))
    f, _, it, _, _, trace = jax.lax.while_loop(cond, body, state)
    it = int(it)
    dirs = direction_mod.trace_tuple(trace, it)
    direction_mod.observe_trace(dirs, kernel="cc")
    return CCResult(labels=f.astype(jnp.int32), n_iterations=it,
                    directions=dirs)
