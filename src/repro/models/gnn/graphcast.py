"""GraphCast-style encode-process-decode mesh GNN [2212.12794].

Grid nodes (the assignment's n_nodes, with n_vars features) are encoded onto
an icosahedral multimesh (refinement r: 10·4^r + 2 nodes, Σ_l 60·4^l directed
multimesh edges), processed by n_layers of interaction-network message
passing, and decoded back to the grid. Mesh topology is synthesised
deterministically at batch-construction time (we don't ship the real
icosphere tables; cardinalities match — noted in DESIGN.md).

Edges carry learned features → valued messages, B2SR is structural only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import GNNConfig
from repro.core.b2sr import _pytree, static_field

Params = Dict[str, Any]


@_pytree
@dataclasses.dataclass(frozen=True)
class MeshSpec:
    g2m_senders: jax.Array     # grid -> mesh
    g2m_receivers: jax.Array
    mesh_senders: jax.Array    # mesh -> mesh (multimesh)
    mesh_receivers: jax.Array
    m2g_senders: jax.Array     # mesh -> grid
    m2g_receivers: jax.Array
    n_mesh: int = static_field()  # static: used as num_segments


def mesh_sizes(refinement: int):
    n_mesh = 10 * 4 ** refinement + 2
    n_medges = sum(60 * 4 ** l for l in range(refinement + 1))
    return n_mesh, n_medges


def build_mesh(n_grid: int, refinement: int, seed: int = 0) -> MeshSpec:
    """Deterministic synthetic multimesh with the right cardinalities."""
    rng = np.random.default_rng(seed)
    n_mesh, n_medges = mesh_sizes(refinement)
    g2m_s = np.arange(n_grid, dtype=np.int32)
    g2m_r = (g2m_s % n_mesh).astype(np.int32)
    mesh_s = rng.integers(0, n_mesh, n_medges).astype(np.int32)
    mesh_r = ((mesh_s + 1 + rng.integers(0, max(n_mesh - 1, 1), n_medges))
              % n_mesh).astype(np.int32)
    m2g_r = np.repeat(np.arange(n_grid, dtype=np.int32), 3)
    m2g_s = rng.integers(0, n_mesh, 3 * n_grid).astype(np.int32)
    return MeshSpec(
        n_mesh=n_mesh,
        g2m_senders=jnp.asarray(g2m_s), g2m_receivers=jnp.asarray(g2m_r),
        mesh_senders=jnp.asarray(mesh_s), mesh_receivers=jnp.asarray(mesh_r),
        m2g_senders=jnp.asarray(m2g_s), m2g_receivers=jnp.asarray(m2g_r),
    )


def _interaction_layer(key, d: int) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "edge_mlp": nn.mlp_params(ks[0], [3 * d, d, d]),
        "node_mlp": nn.mlp_params(ks[1], [2 * d, d, d]),
    }


def init_params(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 6)
    d = cfg.d_hidden
    return {
        "grid_encoder": nn.mlp_params(ks[0], [cfg.d_in, d, d]),
        "mesh_embed": nn.dense_params(ks[1], d, d),
        "g2m_edge": nn.mlp_params(ks[2], [2 * d, d, d]),
        "layers": [_interaction_layer(ks[3 + i], d)
                   for i in range(cfg.n_layers)],
        "m2g_edge": nn.mlp_params(ks[-3], [2 * d, d, d]),
        "grid_decoder": nn.mlp_params(ks[-2], [2 * d, d, cfg.n_classes]),
    }


def _message_pass(edge_mlp, node_mlp, h_nodes, senders, receivers, e, n):
    inp = jnp.concatenate([h_nodes[senders], h_nodes[receivers], e], -1)
    e_new = e + nn.mlp(edge_mlp, inp, act=jax.nn.silu)
    agg = jax.ops.segment_sum(e_new, receivers, num_segments=n)
    h_new = h_nodes + nn.mlp(node_mlp, jnp.concatenate([h_nodes, agg], -1),
                             act=jax.nn.silu)
    return h_new, e_new


def forward(params: Params, grid_feat: jax.Array, mesh: MeshSpec,
            cfg: GNNConfig) -> jax.Array:
    d = cfg.d_hidden
    n_grid = grid_feat.shape[0]
    hg = nn.mlp(params["grid_encoder"], grid_feat, act=jax.nn.silu)

    # encode: grid -> mesh
    inp = jnp.concatenate([hg[mesh.g2m_senders],
                           jnp.zeros((mesh.g2m_senders.shape[0], d),
                                     hg.dtype)], -1)
    g2m_msg = nn.mlp(params["g2m_edge"], inp, act=jax.nn.silu)
    hm = jax.ops.segment_sum(g2m_msg, mesh.g2m_receivers,
                             num_segments=mesh.n_mesh)
    hm = nn.dense(params["mesh_embed"], hm)

    # process: multimesh interaction layers
    e = jnp.zeros((mesh.mesh_senders.shape[0], d), hm.dtype)
    for lp in params["layers"]:
        hm, e = _message_pass(lp["edge_mlp"], lp["node_mlp"], hm,
                              mesh.mesh_senders, mesh.mesh_receivers, e,
                              mesh.n_mesh)

    # decode: mesh -> grid
    inp = jnp.concatenate([hm[mesh.m2g_senders],
                           hg[mesh.m2g_receivers]], -1)
    m2g_msg = nn.mlp(params["m2g_edge"], inp, act=jax.nn.silu)
    agg = jax.ops.segment_sum(m2g_msg, mesh.m2g_receivers,
                              num_segments=n_grid)
    out = nn.mlp(params["grid_decoder"],
                 jnp.concatenate([hg, agg], -1), act=jax.nn.silu)
    return out


def loss_fn(params: Params, grid_feat: jax.Array, target: jax.Array,
            mesh: MeshSpec, cfg: GNNConfig):
    pred = forward(params, grid_feat, mesh, cfg)
    loss = jnp.mean((pred - target) ** 2)
    return loss, {"mse": loss}
