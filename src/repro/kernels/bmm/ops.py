"""Jitted wrapper for the Pallas masked-BMM kernel."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.b2sr import B2SREll, bit_transpose_words
from repro.kernels import common
from repro.kernels.bmm import bmm as kernels


@partial(jax.jit, static_argnames=("block_r", "interpret"))
def _bmm(a_col, a_tiles, b_col, b_tiles_T, m_col, m_tiles, block_r, interpret):
    t = a_tiles.shape[-1]
    return kernels.bmm_bin_bin_sum_masked_pallas(
        a_col, a_tiles, b_col, b_tiles_T, m_col, m_tiles, t=t,
        block_r=block_r, interpret=interpret)


def bmm_bin_bin_sum_masked(a: B2SREll, b: B2SREll, mask: B2SREll,
                           block_r: int = 8,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Σ mask ⊙ (A·B). ``b`` is given row-major; the column-major packing the
    kernel needs is produced here via the word-level bit transpose (the
    conversion-time path stores it; this wrapper recomputes when absent)."""
    interpret = common.interpret_default() if interpret is None else interpret
    a_col = common.pad_to(a.tile_col_idx, 0, block_r, fill=-1)
    a_tiles = common.pad_to(a.bit_tiles, 0, block_r)
    m_col = common.pad_to(mask.tile_col_idx, 0, block_r, fill=-1)
    m_tiles = common.pad_to(mask.bit_tiles, 0, block_r)
    b_tiles_T = bit_transpose_words(b.bit_tiles, b.tile_dim)
    out = _bmm(a_col, a_tiles, b.tile_col_idx, b_tiles_T, m_col, m_tiles,
               block_r, interpret)
    return out.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Dispatch-registry entry: the fully-fused Σ mask ⊙ (A·B) reduction
# (tri_count's "b2sr_pallas" row; bucketing does not apply to the fused
# kernel, so both flags land on the same implementation — DESIGN.md §10)
# ---------------------------------------------------------------------------

from repro.core.dispatch import BOTH, register  # noqa: E402


@register("mxm_sum", "tri", "full", "b2sr_pallas", bucketed=BOTH, masked=True)
def _tri_sum(g, tri, call):
    return bmm_bin_bin_sum_masked(tri.ell, tri.ell_t, tri.ell)
