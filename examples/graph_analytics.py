"""Graph-analytics suite: all five paper algorithms across pattern families.

Runs BFS, SSSP, PageRank, Connected Components, Triangle Counting, and
2-hop reachability (SpGEMM) on one graph from each Table V pattern category,
on both backends (B2SR bit path vs float CSR), printing results + agreement
— the paper's Tables VII-IX in miniature.

Run:  PYTHONPATH=src python examples/graph_analytics.py [--n 1024]
"""

import argparse
import time

import numpy as np

from repro.algorithms.bfs import bfs
from repro.algorithms.cc import connected_components
from repro.algorithms.khop import khop_reachability
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.algorithms.tc import triangle_count
from repro.core.graphblas import GraphMatrix
from repro.data.graphs import PATTERNS


def run_suite(g: GraphMatrix):
    t0 = time.perf_counter()
    lv = bfs(g, 0)
    d = sssp(g, 0)
    pr = pagerank(g, max_iters=10)
    cc = connected_components(g)
    tc = triangle_count(g)
    hop2 = khop_reachability(g, 2)
    dt = time.perf_counter() - t0
    return {
        "reachable": int((lv.levels >= 0).sum()),
        "max_dist": float(np.asarray(d.distances)[np.isfinite(d.distances)].max()),
        "top_rank": int(pr.ranks.argmax()),
        "top_rank_val": float(pr.ranks.max()),
        "n_components": int(np.unique(np.asarray(cc.labels)).shape[0]),
        "triangles": int(tc),
        "hop2_nnz": int(hop2.reach.nnz),
        "wall_s": dt,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    args = ap.parse_args()

    for name, gen in PATTERNS.items():
        rows, cols = gen(args.n, seed=11)
        n = int(np.sqrt(args.n)) ** 2 if name == "road" else args.n
        g = GraphMatrix.from_coo(rows, cols, n, n, tile_dim=32,
                                 backend="b2sr")
        bit = run_suite(g)
        flt = run_suite(g.with_backend("csr"))
        # top_rank compares by value: symmetric patterns have exactly tied
        # ranks and the two float paths break the tie differently (1-ulp)
        agree = all(bit[k] == flt[k] for k in
                    ("reachable", "n_components", "triangles", "hop2_nnz"))
        agree &= abs(bit["top_rank_val"] - flt["top_rank_val"]) < 1e-6
        print(f"{name:9s} nodes={n:6d} edges={g.nnz:7d} "
              f"| reach={bit['reachable']:6d} comps={bit['n_components']:4d} "
              f"tri={bit['triangles']:7d} "
              f"| b2sr {bit['wall_s']:.2f}s csr {flt['wall_s']:.2f}s "
              f"| agree={agree}")
        assert agree, f"backend disagreement on {name}"
    print("all patterns: backends agree")


if __name__ == "__main__":
    main()
