"""Launch layer: production mesh, dry-run driver, training/serving entry."""
