"""Communication-avoiding sharded execution v2 (ISSUE 10, DESIGN.md §16).

Host-side: the nnz-balanced partitioner's quality contract (balance ≤ 1.1
on skewed R-MAT), the ragged-block round-trip, the static exchange-plan
invariants, the pre-trace ``row_chunk`` rejection, ``shard()`` argument
validation, the partition-quality gauges, and plan-key isolation of the
two comm layouts. Execution parity — every registered sharded row
bit-exact between ``combine="exchange"``, ``combine="gather"`` and the
single-device twin, on both b2sr backends, plus whole algorithms through
``GraphMatrix.shard(..., combine="exchange")`` — needs >1 device and runs
in a subprocess with 8 forced host devices (the dry-run-only rule for
device forcing, same as tests/test_partition.py).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import partition as pm
from repro.core.b2sr import coo_to_b2sr
from repro.data import graphs as G

BALANCE_GATE = 1.1


def _skewed_mat(n=1024, skew=16, tile_dim=8, seed=7):
    rows, cols = G.rmat_graph(n, avg_degree=4 + 2 * skew, seed=seed)
    return coo_to_b2sr(rows % n, cols % n, n, n, tile_dim)


# ---------------------------------------------------------------------------
# partition quality (host-side, meshless)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", (2, 4, 8))
def test_balance_skew16_rmat(n_shards):
    mat = _skewed_mat()
    part = pm.partition_rows(mat, n_shards)
    assert part.balance() <= BALANCE_GATE
    # and the split is doing real work: the v1 equal blocks are worse (or
    # at best equal) on the same skewed graph
    equal = pm.partition_rows(mat, n_shards, balanced=False)
    assert part.balance() <= equal.balance()


@pytest.mark.parametrize("tile_dim", (4, 8, 16, 32))
def test_ragged_roundtrip(tile_dim):
    mat = _skewed_mat(n=320, tile_dim=tile_dim)
    part = pm.partition_rows(mat, 4)
    # the balanced split of a skewed graph is genuinely ragged
    lens = [part.row_starts[p + 1] - part.row_starts[p] for p in range(4)]
    assert len(set(lens)) > 1
    assert part.row_starts[0] == 0
    assert part.row_starts[-1] == part.n_tile_rows
    assert all(a <= b for a, b in zip(part.row_starts, part.row_starts[1:]))
    assert part.rows_per_shard == max(lens)
    back = pm.unpartition(part)
    assert np.array_equal(np.asarray(back.tile_row_ptr),
                          np.asarray(mat.tile_row_ptr))
    assert np.array_equal(np.asarray(back.tile_col_idx),
                          np.asarray(mat.tile_col_idx))
    assert np.array_equal(np.asarray(back.bit_tiles),
                          np.asarray(mat.bit_tiles))


def test_equal_fallback_matches_v1_layout():
    mat = _skewed_mat(n=320)
    part = pm.partition_rows(mat, 4, balanced=False)
    r_eq = -(-mat.n_tile_rows // 4)
    assert part.row_starts == tuple(
        min(p * r_eq, mat.n_tile_rows) for p in range(5))


def test_gather_idx_is_the_stacked_permutation():
    mat = _skewed_mat(n=320)
    part = pm.partition_rows(mat, 4)
    gi = np.asarray(part.gather_idx)
    assert gi.shape == (part.n_tile_rows,)
    # global tile-row i lives at stacked position p*rows_per_shard + local
    for p in range(4):
        lo, hi = part.row_starts[p], part.row_starts[p + 1]
        assert np.array_equal(
            gi[lo:hi], p * part.rows_per_shard + np.arange(hi - lo))


# ---------------------------------------------------------------------------
# exchange plan statics (host-side, meshless)
# ---------------------------------------------------------------------------

def test_exchange_plan_invariants():
    mat = _skewed_mat(n=512)
    part = pm.partition_rows(mat, 4)
    xp = pm.build_exchange_plan(part)
    assert xp.n_shards == 4
    assert xp.n_tc_pad == 4 * xp.c_eq >= part.n_tile_cols
    assert 4 * xp.r_eq >= part.n_tile_rows
    # schedule shapes: one [P, W] index pair per nonempty ring offset
    assert len(xp.rhs_offsets) == len(xp.rhs_send_idx) == len(xp.rhs_recv_pos)
    assert len(xp.out_offsets) == len(xp.out_send_idx) == len(xp.out_recv_pos)
    for si, rp in zip(xp.rhs_send_idx, xp.rhs_recv_pos):
        assert si.shape == rp.shape and si.shape[0] == 4
    # the communication-avoiding claim, statically: scheduled exchange
    # lanes undercut the all-gather lane count on a sparse graph
    assert xp.exchanged_lanes() == xp.rhs_lanes + xp.out_lanes
    assert xp.exchanged_lanes() < xp.gather_lanes


def test_exchange_plan_none_for_single_shard():
    part = pm.partition_rows(_skewed_mat(n=320), 1)
    assert pm.build_exchange_plan(part) is None


# ---------------------------------------------------------------------------
# generic-layer guards + gauges + plan isolation (in-process, 1-device mesh)
# ---------------------------------------------------------------------------

def _one_device_graph(combine="gather"):
    import jax
    from jax.sharding import Mesh
    from repro.core.graphblas import GraphMatrix
    rng = np.random.default_rng(3)
    d = (rng.random((48, 48)) < 0.1).astype(np.uint8)
    g = GraphMatrix.from_dense(d, tile_dim=8)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    return g, g.shard(mesh, ("data",), combine=combine)


def test_row_chunk_rejected_before_trace_with_op_name():
    import jax.numpy as jnp
    from repro.core.operands import BitVector
    from repro.core.semiring import ARITHMETIC
    g, gs = _one_device_graph()
    x = jnp.ones((48,), jnp.float32)
    bv = BitVector.pack(x, 8)
    with pytest.raises(ValueError, match="mxv"):
        gs.mxv(x, ARITHMETIC, row_chunk=16)
    with pytest.raises(ValueError, match="mxv"):
        gs.vxm(bv, row_chunk=16)          # transposed path rejects too
    with pytest.raises(ValueError, match="mxm"):
        gs.mxm(jnp.ones((48, 4), jnp.float32), row_chunk=16)
    with pytest.raises(ValueError, match="mxm_sum"):
        gs.tri_count(row_chunk=16)
    # the unsharded twin still accepts chunked evaluation
    assert g.mxv(x, ARITHMETIC, row_chunk=16) is not None


def test_shard_combine_validation():
    import jax
    from jax.sharding import Mesh
    from repro.core.graphblas import GraphMatrix
    g = GraphMatrix.from_dense(np.eye(16, dtype=np.uint8), tile_dim=8)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="combine"):
        g.shard(mesh, ("data",), combine="broadcast")
    mesh2 = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("a", "b"))
    with pytest.raises(ValueError, match="one mesh axis"):
        g.shard(mesh2, ("a", "b"), combine="exchange")
    # gather over two axes stays allowed (the PR 5 contract)
    assert g.shard(mesh2, ("a", "b")).sharded


def test_partition_quality_gauges_published():
    from repro.obs import metrics
    if not metrics.enabled():
        pytest.skip("metrics disabled via REPRO_OBS_DISABLED")
    _, gs = _one_device_graph()
    reg = metrics.get_registry()
    for name in ("partition_balance", "partition_edge_cut"):
        gauge = reg.get(name)
        assert gauge is not None
        labels = dict(orientation="forward", shards="1")
        key = tuple(labels[k] for k in gauge.labelnames)
        assert key in gauge._series


def test_plan_key_isolates_comm_layouts():
    from repro.engine.planner import plan_key
    _, g_gather = _one_device_graph("gather")
    _, g_exch = _one_device_graph("exchange")
    k1 = plan_key(g_gather, "bfs", 1)
    k2 = plan_key(g_exch, "bfs", 1)
    assert k1.mesh != k2.mesh
    assert k1.mesh[-1] == "gather" and k2.mesh[-1] == "exchange"


# ---------------------------------------------------------------------------
# execution parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.algorithms.bfs import bfs
    from repro.algorithms.cc import connected_components
    from repro.algorithms.pagerank import pagerank
    from repro.core.graphblas import GraphMatrix
    from repro.core.operands import BitVector, FrontierBatch, BitMatrix
    from repro.core.semiring import ARITHMETIC, MIN_PLUS
    from repro.engine.queries import msbfs
    from repro.obs import metrics

    assert len(jax.devices()) == 8

    def ring(p):
        return Mesh(np.asarray(jax.devices()[:p]), ("data",))

    def build(n, t, seed, density=0.08):
        rng = np.random.RandomState(seed)
        d = (rng.random((n, n)) < density).astype(np.uint8)
        d[seed % n] |= (rng.random(n) < 0.6)   # hub rows: ragged split
        return GraphMatrix.from_dense(d, tile_dim=t), d

    # --- every sharded row x tile dims x buckets x backend x combine ------
    for t in (4, 8, 16, 32):
        for backend in ("b2sr", "b2sr_pallas"):
            g, d = build(96, t, seed=t)
            g = g.with_backend(backend)
            rng = np.random.RandomState(100 + t)
            x = jnp.asarray(rng.rand(96).astype(np.float32))
            bv = BitVector.pack(jnp.asarray(rng.rand(96) > 0.5), t)
            mk = BitVector.pack(jnp.asarray(rng.rand(96) > 0.5), t)
            fb = FrontierBatch.pack(jnp.asarray(rng.rand(96, 5) > 0.5), t)
            bm = BitMatrix.pack(
                jnp.asarray(rng.rand(96, 6).astype(np.float32)) - 0.5, t)
            X = jnp.asarray(rng.rand(96, 6).astype(np.float32))
            gg = g.shard(ring(4), combine="gather")
            gx = g.shard(ring(4), combine="exchange")
            for ub in (True, False):
                a = g.with_buckets(ub)
                for b in (gg.with_buckets(ub), gx.with_buckets(ub)):
                    assert np.array_equal(np.asarray(b.mxv(bv).words),
                                          np.asarray(a.mxv(bv).words))
                    assert np.array_equal(
                        np.asarray(b.mxv(bv, mask=mk, complement=True).words),
                        np.asarray(a.mxv(bv, mask=mk, complement=True).words))
                    assert np.array_equal(
                        np.asarray(b.mxv(bv, ARITHMETIC, out_dtype=jnp.int32)),
                        np.asarray(a.mxv(bv, ARITHMETIC, out_dtype=jnp.int32)))
                    assert np.allclose(np.asarray(b.mxv(x)),
                                       np.asarray(a.mxv(x)), atol=1e-5)
                    assert np.array_equal(np.asarray(b.mxv(x, MIN_PLUS)),
                                          np.asarray(a.mxv(x, MIN_PLUS)))
                    assert np.allclose(np.asarray(b.mxm(X)),
                                       np.asarray(a.mxm(X)), atol=1e-4)
                    assert np.array_equal(np.asarray(b.mxm(fb).words),
                                          np.asarray(a.mxm(fb).words))
                    assert np.allclose(np.asarray(b.mxm(bm)),
                                       np.asarray(a.mxm(bm)), atol=1e-4)
                    assert np.array_equal(np.asarray(b.vxm(bv).words),
                                          np.asarray(a.vxm(bv).words))
            # SpGEMM rows + the fused tri reduction (gather/psum combine)
            for b in (gg, gx):
                assert b.mxm(g).nnz == g.mxm(g).nnz
                assert np.array_equal(np.asarray(b.mxm(g, ARITHMETIC)),
                                      np.asarray(g.mxm(g, ARITHMETIC)))
                assert float(b.tri_count()) == float(g.tri_count())
    print("XROWS_OK")

    # --- the comm counters witness the communication-avoiding claim -------
    reg = metrics.get_registry()
    gw = sum(float(v) for v in reg.get("gather_words_total")._series.values())
    xw = sum(float(v)
             for v in reg.get("exchange_words_total")._series.values())
    assert gw > 0 and xw > 0
    # same op mix ran through both layouts above; exchange moved fewer words
    assert xw < gw, (xw, gw)
    print("XCOMM_OK")

    # --- whole algorithms through shard(combine="exchange"), 8 shards -----
    t = 8
    g, d = build(128, t, seed=11)
    gx = g.shard(ring(8), combine="exchange")
    assert gx.xplan is not None and gx.xplan.n_shards == 8
    assert np.array_equal(np.asarray(bfs(gx, 3).levels),
                          np.asarray(bfs(g, 3).levels))
    assert np.allclose(np.asarray(pagerank(gx).ranks),
                       np.asarray(pagerank(g).ranks), atol=1e-7)
    assert np.array_equal(np.asarray(connected_components(gx).labels),
                          np.asarray(connected_components(g).labels))
    srcs = [1, 9, 17, 33]
    assert np.array_equal(np.asarray(msbfs(gx, srcs).levels),
                          np.asarray(msbfs(g, srcs).levels))
    print("XALGOS_OK")
""")

MARKERS = ["XROWS_OK", "XCOMM_OK", "XALGOS_OK"]


@pytest.fixture(scope="module")
def exchange_parity_run():
    return subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=1800, env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.parametrize("marker", MARKERS)
def test_exchange_parity(exchange_parity_run, marker):
    assert exchange_parity_run.returncode == 0, \
        exchange_parity_run.stderr[-4000:]
    assert marker in exchange_parity_run.stdout
