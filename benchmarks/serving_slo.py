"""Closed-loop serving SLO benchmark: deadlines, faults, warm restarts.

Drives mixed bfs/ppr/khop traffic through :class:`GraphQueryServer`
(DESIGN.md §13) on an R-MAT graph and records sustained QPS and per-query
p50/p99 latency for three scenarios:

  **healthy**    the Pallas backend answers everything;
  **faulty**     a seeded :class:`FaultInjector` fails 10% of Pallas
                 launches — the fallback chain answers instead. The run
                 must lose or hang *zero* queries, and every degraded
                 answer is checked **bit-exact** against a replay of the
                 identical launch on the healthy fallback backend;
  **warm-start** cold first-query latency (trace + compile in the request
                 path) vs a restarted server that replayed the persisted
                 warmup recipes first.

Wall-clock on this container is jitted-CPU with interpret-mode Pallas;
the structural claims (no lost queries, bit-exact degradation, warm-start
beating cold) transfer unchanged. Full detail lands in
``results/serving_slo.json``.
"""

from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import BenchRow, save_json
from repro.core import GraphMatrix
from repro.data import graphs as G
from repro.engine import (FaultInjector, GraphQueryServer, PlanCache,
                          ServerConfig, queries)
from repro.obs import cost as obs_cost
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

#: Span names every served query's trace must cover (DESIGN.md §14).
REQUIRED_SPANS = ("queue_wait", "plan_resolve", "launch", "scatter_back")

#: The mixed traffic pattern (cycled) and per-kind params.
TRAFFIC = (
    ("bfs", {"max_iters": None}),
    ("ppr", {"alpha": 0.85, "max_iters": 5, "eps": 0.0}),
    ("khop", {"k": 2}),
    ("bfs", {"max_iters": None}),
)


def _drive(server: GraphQueryServer, g: GraphMatrix, n_queries: int,
           seed: int, budget_s: float, arrival_batch: int = 4,
           inter_arrival_s: float = 0.05
           ) -> Tuple[dict, List[Tuple[str, dict, int, float, object]]]:
    """Submit the traffic pattern closed-loop; returns (metrics, log).

    Arrivals are paced (``inter_arrival_s`` per ``arrival_batch``) so the
    deadline pump actually fires mid-stream instead of everything landing
    in one final flush.
    """
    rng = np.random.default_rng(seed)
    log = []
    t_start = time.monotonic()
    for i in range(n_queries):
        kind, params = TRAFFIC[i % len(TRAFFIC)]
        src = int(rng.integers(0, g.n_rows))
        t0 = time.monotonic()
        h = server.submit(g, kind, src, budget_s=budget_s, **params)
        log.append((kind, params, src, t0, h))
        if (i + 1) % arrival_batch == 0:
            time.sleep(inter_arrival_s)
            server.poll()
    server.flush()
    elapsed = time.monotonic() - t_start

    lat_ms, n_failed, n_degraded, n_hung = [], 0, 0, 0
    for kind, params, src, t0, h in log:
        if not h.done():
            n_hung += 1
            continue
        try:
            h.result()
        except Exception:                    # noqa: BLE001 — counted
            n_failed += 1
            continue
        n_degraded += int(h.degraded)
        if h.completed_at is not None:
            lat_ms.append((h.completed_at - t0) * 1e3)
    metrics = {
        "n_queries": n_queries,
        "elapsed_s": elapsed,
        "qps": n_queries / elapsed,
        "p50_ms": float(np.percentile(lat_ms, 50)) if lat_ms else None,
        "p99_ms": float(np.percentile(lat_ms, 99)) if lat_ms else None,
        "n_failed": n_failed,
        "n_hung": n_hung,
        "n_degraded": n_degraded,
        "stats": dict(server.stats),
    }
    return metrics, log


def _replay(g: GraphMatrix, record, planner: PlanCache):
    """Re-run one logged launch on its (healthy) backend; full [n, S]."""
    gv = g if record.backend == g.backend else g.with_backend(record.backend)
    src = np.asarray(record.sources)
    params = dict(record.params)
    if record.kind == "bfs":
        return queries.msbfs(gv, src, planner=planner, **params).levels
    if record.kind == "khop":
        return queries.mskhop(gv, src, planner=planner, **params)
    if record.kind == "sssp":
        return queries.ms_sssp(gv, src, planner=planner, **params).distances
    return queries.batched_ppr(gv, src, planner=planner, **params).ranks


def _verify_degraded(g: GraphMatrix, server: GraphQueryServer,
                     log) -> Dict[str, int]:
    """Check every degraded answer bit-exact vs a healthy-backend replay.

    A degraded group ran *entirely* on the fallback backend, so replaying
    the identical padded launch there (no faults now) must reproduce the
    served answer bit-for-bit — for the float kinds (ppr/sssp) included,
    because the replay shares the backend, batch width, and reduction
    order. Raises AssertionError on any mismatch.
    """
    by_query: Dict[tuple, list] = {}
    for kind, params, src, _, h in log:
        key = (kind, tuple(sorted(params.items())), src)
        by_query.setdefault(key, []).append(h)
    pc = PlanCache(capacity=8)
    n_checked = 0
    for rec in server.launch_log:
        if not rec.degraded:
            continue
        ref = np.asarray(_replay(g, rec, pc))
        for col, src in enumerate(rec.sources):
            handles = by_query.get((rec.kind, rec.params, src), ())
            for h in handles:
                if h.backend_used != rec.backend:
                    continue
                assert np.array_equal(np.asarray(h.result()), ref[:, col]), \
                    (rec.kind, src, rec.backend)
                n_checked += 1
    return {"n_degraded_launches":
            sum(r.degraded for r in server.launch_log),
            "n_answers_checked": n_checked}


def _first_query_latency(server: GraphQueryServer, g: GraphMatrix) -> float:
    t0 = time.monotonic()
    h = server.bfs(g, 1)
    server.flush()
    h.result()
    return time.monotonic() - t0


def _trace_coverage(log) -> Optional[dict]:
    """Best span coverage over the completed bfs handles of one drive.

    Coverage is the trace's summed exclusive span time over the observed
    submit→complete latency; the acceptance bar is the two agreeing
    within 10% on at least one bfs query, with every required span
    present and the plan_resolve span tagged with its cache verdict.
    """
    best = None
    for kind, params, src, t0, h in log:
        if (kind != "bfs" or h.trace is None or not h.done()
                or h.completed_at is None):
            continue
        observed = h.completed_at - t0
        if observed <= 0:
            continue
        covered = h.trace.total_exclusive_s()
        names = set(h.trace.span_names())
        resolves = h.trace.find("plan_resolve")
        row = {
            "source": src,
            "observed_s": observed,
            "covered_s": covered,
            "coverage": covered / observed,
            "spans": sorted(names),
            "required_spans_present":
                all(s in names for s in REQUIRED_SPANS),
            "plan_resolve_cache_tagged":
                bool(resolves) and all("cache_hit" in s.attrs
                                       for s in resolves),
            "within_10pct": abs(covered - observed) <= 0.10 * observed,
        }
        row["ok"] = (row["required_spans_present"]
                     and row["plan_resolve_cache_tagged"]
                     and row["within_10pct"])
        if best is None or (row["ok"] and not best["ok"]) or (
                row["ok"] == best["ok"]
                and abs(row["coverage"] - 1.0)
                < abs(best["coverage"] - 1.0)):
            best = row
    return best


def run(tiny: bool = False, trace_out: str = "",
        registry: Optional[obs_metrics.MetricsRegistry] = None
        ) -> List[BenchRow]:
    rows: List[BenchRow] = []
    detail: dict = {"mode": "tiny" if tiny else "full"}
    n = 256 if tiny else 1024
    n_queries = 24 if tiny else 96
    budget_s = 0.15
    cfg = ServerConfig(default_budget_s=budget_s, backoff_base_s=0.0,
                       fail_threshold=3, cooldown_s=0.25)

    # isolate this suite's telemetry in a fresh registry and attach HLO
    # cost estimates to every compiled plan (benchmarks pay the AOT
    # lowering gladly; the serving hot path keeps it off by default)
    reg = registry if registry is not None else obs_metrics.MetricsRegistry()
    prev_reg = obs_metrics.set_registry(reg)
    prev_cost = obs_cost.set_cost_accounting(True)
    try:
        return _run_inner(rows, detail, n, n_queries, budget_s, cfg, reg,
                          trace_out)
    finally:
        obs_cost.set_cost_accounting(prev_cost)
        obs_metrics.set_registry(prev_reg)


def _run_inner(rows, detail, n, n_queries, budget_s, cfg, reg,
               trace_out) -> List[BenchRow]:
    r, c = G.rmat_graph(n, avg_degree=8, seed=3, symmetric=False)
    g = GraphMatrix.from_coo(r, c, n, n, tile_dim=8,
                             backend="b2sr_pallas")

    # -- healthy ------------------------------------------------------------
    srv = GraphQueryServer(planner=PlanCache(), config=cfg)
    healthy, log_h = _drive(srv, g, n_queries, seed=11, budget_s=budget_s)
    detail["healthy"] = healthy
    coverage = _trace_coverage(log_h)
    detail["trace_coverage"] = coverage
    if trace_out and obs_metrics.enabled():
        srv.dump_traces(trace_out)
    rows.append(BenchRow("serving/healthy/p50", healthy["p50_ms"] * 1e3,
                         f"qps={healthy['qps']:.1f} "
                         f"p99={healthy['p99_ms']:.0f}ms"))
    warm_path = os.path.join(tempfile.mkdtemp(prefix="serving_slo_"),
                             "warmup.json")
    n_recipes = srv.save_warmup(warm_path)

    # -- 10% Pallas faults --------------------------------------------------
    # 10% transient rate on every Pallas check, plus one scripted
    # double-fault on khop (fault + failed retry) so the run always
    # exercises the full fall-through path, not just retried blips.
    inj = (FaultInjector(seed=7)
           .fail(backend="b2sr_pallas", rate=0.10)
           .fail(op="khop", backend="b2sr_pallas", script=[True, True]))
    inj.install()
    try:
        srv_f = GraphQueryServer(planner=PlanCache(), config=cfg,
                                 fault_injector=inj)
        faulty, log_f = _drive(srv_f, g, n_queries, seed=13,
                               budget_s=budget_s)
    finally:
        inj.uninstall()
    verify = _verify_degraded(g, srv_f, log_f)
    faulty["verify"] = verify
    faulty["injector"] = {"checks": inj.n_checks, "faults": inj.n_faults}
    detail["faulty_pallas_10pct"] = faulty
    rows.append(BenchRow(
        "serving/faulty10/p50", faulty["p50_ms"] * 1e3,
        f"degraded={faulty['n_degraded']} failed={faulty['n_failed']} "
        f"hung={faulty['n_hung']} checked={verify['n_answers_checked']}"))

    # -- cold start vs warm start ------------------------------------------
    srv_cold = GraphQueryServer(planner=PlanCache(), config=cfg)
    t_cold = _first_query_latency(srv_cold, g)

    srv_warm = GraphQueryServer(planner=PlanCache(), config=cfg)
    srv_warm.register(g)
    t0 = time.monotonic()
    n_replayed = srv_warm.warmup(warm_path)
    t_warmup = time.monotonic() - t0
    t_warm = _first_query_latency(srv_warm, g)
    detail["warm_start"] = {
        "recipes_saved": n_recipes,
        "recipes_replayed": n_replayed,
        "warmup_s": t_warmup,
        "cold_first_query_ms": t_cold * 1e3,
        "warm_first_query_ms": t_warm * 1e3,
        "speedup": t_cold / t_warm,
        "warm_hits": srv_warm.planner.hits,
        "warm_misses": srv_warm.planner.misses,
    }
    rows.append(BenchRow("serving/warm_start/first_query", t_warm * 1e6,
                         f"cold={t_cold * 1e6:.0f}us "
                         f"speedup={t_cold / t_warm:.1f}x"))

    # -- telemetry ----------------------------------------------------------
    # the whole suite ran against `reg`: embed the snapshot (launch
    # latency histograms, plan-cache counters, breaker events), the
    # achieved-vs-roofline join, and the aggregate plan-cache hit rate
    snap = reg.snapshot()
    cache_hits = sum(snap["counters"].get("plan_cache_hits_total",
                                          {}).values())
    cache_misses = sum(snap["counters"].get("plan_cache_misses_total",
                                            {}).values())
    lookups = cache_hits + cache_misses
    detail["registry"] = snap
    detail["roofline"] = obs_cost.roofline_table(reg)
    detail["plan_cache_hit_rate"] = (cache_hits / lookups if lookups
                                     else None)

    # -- acceptance ---------------------------------------------------------
    detail["acceptance"] = {
        "zero_lost_or_hung": (faulty["n_failed"] == 0
                              and faulty["n_hung"] == 0
                              and healthy["n_failed"] == 0
                              and healthy["n_hung"] == 0),
        "degraded_answers_bit_exact": verify["n_answers_checked"] > 0,
        "warm_first_query_below_cold": t_warm < t_cold,
        # with observability disabled there are no traces or histograms
        # to check — the serving claims above still gate the run
        "trace_spans_cover_latency":
            (coverage is not None and coverage["ok"])
            if obs_metrics.enabled() else True,
        "launch_latency_recorded":
            bool(snap["histograms"].get("launch_latency_s"))
            if obs_metrics.enabled() else True,
    }
    save_json("serving_slo.json", detail)
    if not all(detail["acceptance"].values()):
        raise AssertionError(f"serving SLO acceptance failed: "
                             f"{detail['acceptance']}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--metrics-out", default="",
                    help="write the suite's metrics registry here "
                         "(.prom -> Prometheus text, else JSON)")
    ap.add_argument("--trace-out", default="",
                    help="write the healthy drive's query traces (JSONL)")
    cli = ap.parse_args()
    _reg = obs_metrics.MetricsRegistry() if cli.metrics_out else None
    for row in run(tiny=cli.tiny, trace_out=cli.trace_out, registry=_reg):
        print(row.csv())
    if _reg is not None:
        from repro.obs import export as obs_export
        obs_export.write_metrics(cli.metrics_out, _reg)
        print(f"wrote metrics to {cli.metrics_out}")
