"""Shared test configuration.

``hypothesis`` is an optional dev dependency (requirements-dev.txt): the
property-based tests skip cleanly when it is absent, while the plain
parametrized tests in the same modules keep running.
"""

from __future__ import annotations

import pytest

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def optional_hypothesis():
    """Return ``(given, settings, st)`` — real, or stubs that skip the test."""
    if HAVE_HYPOTHESIS:
        from hypothesis import given, settings, strategies as st
        return given, settings, st

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    return given, settings, _Strategies()
