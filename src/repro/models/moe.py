"""Mixture-of-Experts FFN with static-capacity sort-based dispatch.

Top-k routing -> sort token-expert assignments by expert -> static-capacity
[E, C] gather -> batched expert matmuls -> weighted scatter-combine. All
shapes static (TPU/pjit friendly); tokens overflowing an expert's capacity
are dropped (standard Switch/GShard semantics, capacity_factor controls it).

Expert weights carry a leading E axis that shards over the "model" mesh axis
(expert parallelism); with tokens sharded over "data", XLA lowers the
gather/scatter to all-to-alls (the dispatch/combine collectives).

Arctic-style dense residual: an always-on dense FFN added to the routed
output (config.moe.dense_residual_d_ff).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.ops import shard_map_compat

Params = Dict[str, Any]


def init_moe_params(cfg, key) -> Params:
    moe = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    e = moe.n_experts
    ff = moe.d_ff_expert
    p: Params = {
        "router": nn.dense_init(ks[0], d, e, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (e, d, ff)) / jnp.sqrt(d),
        "w_up": jax.random.normal(ks[2], (e, d, ff)) / jnp.sqrt(d),
        "w_down": jax.random.normal(ks[3], (e, ff, d)) / jnp.sqrt(ff),
    }
    if moe.dense_residual_d_ff:
        dff = moe.dense_residual_d_ff
        kd = jax.random.split(ks[4], 3)
        p["dense_gate"] = nn.dense_init(kd[0], d, dff)
        p["dense_up"] = nn.dense_init(kd[1], d, dff)
        p["dense_down"] = nn.dense_init(kd[2], dff, d)
    return p


def capacity(n_tokens: int, moe) -> int:
    c = int(n_tokens * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_ffn(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar).

    Dispatch strategy (§Perf, EXPERIMENTS.md): when a mesh with a "model"
    axis is active and ``cfg.moe_shardmap_dispatch`` is set, the routed part
    runs through the shard_map expert-parallel path (local dispatch against
    model-replicated activations + one psum combine); otherwise the global
    sort-based gather/scatter below (GSPMD decides the collectives).
    """
    if getattr(cfg, "moe_shardmap_dispatch", False) and cfg.batch_axes:
        out = _moe_ffn_shardmap(p, x, cfg)
        if out is not None:
            return out
    return _moe_ffn_dense(p, x, cfg)


def _moe_ffn_dense(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    moe = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, K = moe.n_experts, moe.top_k
    C = capacity(N, moe)
    xt = x.reshape(N, d)

    # --- routing (fp32 for numerics) ---
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # [N, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # [N, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- aux losses (Switch load-balance + router z-loss) ---
    me = jnp.mean(probs, axis=0)                                  # [E]
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    lb_loss = E * jnp.sum(me * ce) * moe.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * moe.router_z_loss
    aux = lb_loss + z_loss

    # --- sort-based static dispatch ---
    flat_expert = expert_idx.reshape(-1)                          # [N*K]
    flat_token = jnp.repeat(jnp.arange(N), K)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)                              # stable
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    starts = jnp.searchsorted(se, jnp.arange(E))                  # [E]
    pos_in_e = jnp.arange(N * K) - starts[se]
    keep = pos_in_e < C

    slot_e = jnp.where(keep, se, E)       # overflow -> dropped row E
    slot_c = jnp.where(keep, pos_in_e, 0)
    # token index per (E, C) slot; padded slots point at token 0 with gate 0
    dispatch = jnp.zeros((E + 1, C), jnp.int32).at[slot_e, slot_c].set(
        st.astype(jnp.int32), mode="drop")[:E]
    gates_ec = jnp.zeros((E + 1, C), jnp.float32).at[slot_e, slot_c].set(
        sg, mode="drop")[:E]

    # --- expert compute (batched over E; shards over "model") ---
    dtype = x.dtype
    xe = xt[dispatch]                                             # [E, C, d]
    gg = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dtype))
    uu = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dtype))
    hh = jax.nn.silu(gg) * uu
    ye = jnp.einsum("ecf,efd->ecd", hh, p["w_down"].astype(dtype))
    ye = ye * gates_ec[..., None].astype(dtype)

    # --- combine (scatter-add back to tokens) ---
    y = jnp.zeros((N, d), dtype).at[dispatch.reshape(-1)].add(
        ye.reshape(-1, d))

    if moe.dense_residual_d_ff:
        y = y + (jax.nn.silu(xt @ p["dense_gate"].astype(dtype))
                 * (xt @ p["dense_up"].astype(dtype))) @ p["dense_down"].astype(dtype)
    return y.reshape(B, S, d), aux


def _moe_ffn_shardmap(p: Params, x: jax.Array, cfg):
    """Expert-parallel dispatch without cross-device gathers (§Perf).

    Mesh layout: tokens shard over the batch axes and REPLICATE over
    "model"; experts shard over "model". Device (i, j) therefore already
    holds every token of data-shard i — it routes locally, gathers only the
    tokens bound for ITS expert block (a local gather), runs the expert
    FFNs, scatter-adds into a local [N_loc, d] buffer, and a single
    psum over "model" combines the expert contributions. Per layer wire =
    2·N_loc·d bytes instead of the ~40× that GSPMD's one-hot global
    dispatch emits (measured, EXPERIMENTS.md §Perf qwen3 iteration 2).

    Capacity semantics: per (expert, data-shard) capacity C/n_data —
    standard local-capacity Switch semantics (drop patterns can differ
    from the global-capacity dense path at overflow; equal when nothing
    drops — tests/test_moe_shardmap.py).

    Returns None when the mesh/shape prerequisites don't hold (falls back).
    """
    from jax._src.mesh import thread_resources
    from jax.sharding import PartitionSpec as P

    moe = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, K = moe.n_experts, moe.top_k
    mesh = thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in cfg.batch_axes if a in mesh.axis_names)
    if not data_axes:
        return None
    n_data = 1
    for a in data_axes:
        n_data *= sizes[a]
    n_model = sizes["model"]
    if E % n_model != 0 or B % n_data != 0:
        return None
    E_loc = E // n_model
    C_loc = max(8, -(-capacity(N, moe) // n_data // 8) * 8)
    dtype = x.dtype

    def block(router, wg, wu, wd, xb):
        # xb: [B_loc, S, d]; wg/wu/wd: [E_loc, ...]; router replicated
        B_loc = xb.shape[0]
        N_loc = B_loc * S
        xt = xb.reshape(N_loc, d)

        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
        lb_loss = E * jnp.sum(me * ce) * moe.load_balance_loss
        z_loss = (jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
                  * moe.router_z_loss)
        aux = jax.lax.pmean(lb_loss + z_loss, data_axes)

        # local-expert dispatch: same sort-based scheme, restricted to the
        # E_loc experts this model-shard owns
        j = jax.lax.axis_index("model")
        e_lo = j * E_loc
        flat_expert = expert_idx.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(N_loc), K)
        flat_gate = gate_vals.reshape(-1)
        order = jnp.argsort(flat_expert)
        se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
        starts = jnp.searchsorted(se, jnp.arange(E))
        pos_in_e = jnp.arange(N_loc * K) - starts[se]
        local_e = se - e_lo
        keep = (local_e >= 0) & (local_e < E_loc) & (pos_in_e < C_loc)
        slot_e = jnp.where(keep, local_e, E_loc)
        slot_c = jnp.where(keep, pos_in_e, 0)
        dispatch = jnp.zeros((E_loc + 1, C_loc), jnp.int32).at[
            slot_e, slot_c].set(st.astype(jnp.int32), mode="drop")[:E_loc]
        gates_ec = jnp.zeros((E_loc + 1, C_loc), jnp.float32).at[
            slot_e, slot_c].set(sg, mode="drop")[:E_loc]

        xe = xt[dispatch]                                     # local gather
        gg = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dtype))
        uu = jnp.einsum("ecd,edf->ecf", xe, wu.astype(dtype))
        hh = jax.nn.silu(gg) * uu
        ye = jnp.einsum("ecf,efd->ecd", hh, wd.astype(dtype))
        ye = ye * gates_ec[..., None].astype(dtype)

        y_part = jnp.zeros((N_loc, d), dtype).at[
            dispatch.reshape(-1)].add(ye.reshape(-1, d))
        y = jax.lax.psum(y_part, "model")                     # the combine
        return y.reshape(B_loc, S, d), aux

    xin = jax.lax.with_sharding_constraint(x, P(data_axes, None, None))
    y, aux = shard_map_compat(
        block, mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(data_axes, None, None)),
        out_specs=(P(data_axes, None, None), P()),
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], xin)

    if moe.dense_residual_d_ff:
        xt = x.reshape(N, d)
        y_dense = (jax.nn.silu(xt @ p["dense_gate"].astype(dtype))
                   * (xt @ p["dense_up"].astype(dtype))
                   ) @ p["dense_down"].astype(dtype)
        y = y + y_dense.reshape(B, S, d)
    return y, aux
