"""Semiring definitions for GraphBLAS-style operations (paper Table IV)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Semiring:
    """(⊕, ⊗) with the ⊕-monoid identity. ``add`` must be associative."""

    name: str
    add: Callable          # y = add(a, b)
    mul: Callable          # z = mul(a, b)
    add_identity: float    # identity of ⊕ (cast to the vector dtype)

    def identity_for(self, dtype) -> jnp.ndarray:
        return jnp.asarray(self.add_identity, dtype=dtype)


# Paper Table IV: Boolean {0,1} — BFS, diameter, MIS, GC
BOOLEAN = Semiring(
    name="boolean",
    add=jnp.logical_or,
    mul=jnp.logical_and,
    add_identity=False,
)

# Arithmetic (R, +, ×) — PR, TC, LGC
ARITHMETIC = Semiring(
    name="arithmetic",
    add=jnp.add,
    mul=jnp.multiply,
    add_identity=0.0,
)

# Tropical min-plus (R ∪ {+inf}, min, +) — SSSP, CC
MIN_PLUS = Semiring(
    name="min_plus",
    add=jnp.minimum,
    mul=jnp.add,
    add_identity=float("inf"),
)

# Tropical max-times (R, max, ×) — MIS, GC
MAX_TIMES = Semiring(
    name="max_times",
    add=jnp.maximum,
    mul=jnp.multiply,
    add_identity=-float("inf"),
)

SEMIRINGS = {s.name: s for s in (BOOLEAN, ARITHMETIC, MIN_PLUS, MAX_TIMES)}
