"""Warmup-file persistence: the serialisable identity of hot plans.

What survives a server restart is the **key set** of the plan cache, not
the compiled artifacts: a recipe records everything needed to re-derive a
:class:`~repro.engine.planner.PlanKey` — graph fingerprint, query kind,
params, padded batch width, backend, and layout flags — as a few dozen
bytes of JSON. ``GraphQueryServer.warmup`` replays each recipe as one
dummy launch, which re-traces and re-compiles the exact plan the first
real query would otherwise stall on (the compile storm moves from
first-query latency to startup). Compiled XLA executables are
deliberately *not* persisted: they capture device buffers and are
jax-version/topology-bound, while recipes are stable across restarts,
upgrades, and hardware moves (DESIGN.md §13).

File format (JSON):

    {"version": 1,
     "recipes": [{"graph_fp": "...", "kind": "bfs",
                  "params": {"max_iters": null}, "width": 32,
                  "backend": "b2sr_pallas", "use_buckets": true,
                  "sharded": false}, ...]}
"""

from __future__ import annotations

import json
import os
from typing import Iterable, List

VERSION = 1

_REQUIRED = ("graph_fp", "kind", "params", "width", "backend",
             "use_buckets", "sharded")


def recipe_key(recipe: dict) -> tuple:
    """Dedup identity of one recipe (its PlanKey coordinates)."""
    return (recipe["graph_fp"], recipe["kind"],
            tuple(sorted(recipe["params"].items())), recipe["width"],
            recipe["backend"], recipe["use_buckets"], recipe["sharded"])


def _validate(recipe: dict, where: str) -> dict:
    for field in _REQUIRED:
        if field not in recipe:
            raise ValueError(f"{where}: recipe missing field {field!r}: "
                             f"{recipe!r}")
    if not isinstance(recipe["params"], dict):
        raise ValueError(f"{where}: recipe params must be a dict, got "
                         f"{type(recipe['params']).__name__}")
    if not (isinstance(recipe["width"], int) and recipe["width"] >= 1):
        raise ValueError(f"{where}: recipe width must be an int >= 1, got "
                         f"{recipe['width']!r}")
    return recipe


def save(path: str, recipes: Iterable[dict]) -> int:
    """Write the recipe set to ``path`` (atomically); returns the count."""
    payload = {"version": VERSION,
               "recipes": [_validate(dict(r), path) for r in recipes]}
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return len(payload["recipes"])


def load(path: str) -> List[dict]:
    """Read and validate a warmup file (FileNotFoundError if absent,
    ValueError on a malformed or version-incompatible file)."""
    with open(path) as f:
        try:
            payload = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}: not a warmup file: {e}") from e
    if not isinstance(payload, dict) or payload.get("version") != VERSION:
        raise ValueError(
            f"{path}: unsupported warmup file version "
            f"{payload.get('version') if isinstance(payload, dict) else '?'}"
            f" (expected {VERSION})")
    return [_validate(r, path) for r in payload.get("recipes", [])]
