"""Jitted wrappers around the Pallas BMV kernels (pad + dispatch + unpad)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.b2sr import B2SRBucketedEll, B2SREll, ceil_div
from repro.core.semiring import Semiring, ARITHMETIC
from repro.kernels import common
from repro.kernels.bmv import bmv as kernels


def _padded_ell(ell: B2SREll, block_r: int, block_k: int):
    col = common.pad_to(common.pad_to(ell.tile_col_idx, 0, block_r, fill=-1),
                        1, block_k, fill=-1)
    tiles = common.pad_to(common.pad_to(ell.bit_tiles, 0, block_r), 1, block_k)
    return col, tiles


@partial(jax.jit, static_argnames=("n_rows", "out_dtype", "block_r", "block_k",
                                   "interpret"))
def _bin_bin_full(col, tiles, x_words, n_rows, out_dtype, block_r, block_k,
                  interpret):
    t = tiles.shape[-1]
    out = kernels.bmv_bin_bin_full_pallas(
        col, tiles, x_words, t=t, block_r=block_r, block_k=block_k,
        interpret=interpret)
    return out.reshape(-1)[:n_rows].astype(out_dtype)


def bmv_bin_bin_full(ell: B2SREll, x_packed: jax.Array,
                     out_dtype=jnp.float32, block_r: int = 8,
                     block_k: int = 8, interpret: Optional[bool] = None):
    interpret = common.interpret_default() if interpret is None else interpret
    col, tiles = _padded_ell(ell, block_r, block_k)
    return _bin_bin_full(col, tiles, x_packed, ell.n_rows, out_dtype,
                         block_r, block_k, interpret)


@partial(jax.jit, static_argnames=("complement", "block_r", "block_k", "interpret"))
def _bin_bin_bin(col, tiles, x_words, mask_words, complement, block_r,
                 block_k, interpret):
    t = tiles.shape[-1]
    n_words_out = mask_words.shape[0]
    mask_pad = common.pad_to(mask_words, 0, block_r)
    out = kernels.bmv_bin_bin_bin_pallas(
        col, tiles, x_words, mask_pad, t=t, complement=complement,
        block_r=block_r, block_k=block_k, interpret=interpret)
    return out[:n_words_out]


def bmv_bin_bin_bin(ell: B2SREll, x_packed: jax.Array,
                    mask_packed: Optional[jax.Array] = None,
                    complement: bool = True, block_r: int = 8,
                    block_k: int = 8, interpret: Optional[bool] = None):
    interpret = common.interpret_default() if interpret is None else interpret
    col, tiles = _padded_ell(ell, block_r, block_k)
    n_words = ceil_div(ell.n_rows, ell.tile_dim)
    if mask_packed is None:
        mask_packed = jnp.zeros((n_words,), jnp.uint32)
        complement = True  # ~0 == keep everything
    return _bin_bin_bin(col, tiles, x_packed, mask_packed, complement,
                        block_r, block_k, interpret)


@partial(jax.jit, static_argnames=("complement", "block_r", "block_k",
                                   "interpret"))
def _bin_bin_bin_pull(col, tiles, x_words, mask_words, complement, block_r,
                      block_k, interpret):
    t = tiles.shape[-1]
    n_words_out = mask_words.shape[0]
    mask_pad = common.pad_to(mask_words, 0, block_r)
    out = kernels.bmv_bin_bin_bin_pull_pallas(
        col, tiles, x_words, mask_pad, t=t, complement=complement,
        block_r=block_r, block_k=block_k, interpret=interpret)
    return out[:n_words_out]


def bmv_bin_bin_bin_pull(ell: B2SREll, x_packed: jax.Array,
                         mask_packed: jax.Array, complement: bool = True,
                         block_r: int = 8, block_k: int = 8,
                         interpret: Optional[bool] = None):
    """Fused pull traversal: early-exit kernel, k in VMEM per row block.

    Unlike the push row, the mask is mandatory — pull without a visited
    set has nothing to exit on (the generic layer guarantees this; see
    ``dispatch.MASKED_ONLY_OPS``). Row-padding words beyond ``n_rows``
    get an all-zero mask slot, which under ``complement=True`` means
    "all lanes wanted" — harmless: padded rows have no tiles, the loop
    just runs to the slab end for them, and the words are sliced off.
    """
    interpret = common.interpret_default() if interpret is None else interpret
    col, tiles = _padded_ell(ell, block_r, block_k)
    return _bin_bin_bin_pull(col, tiles, x_packed, mask_packed, complement,
                             block_r, block_k, interpret)


def bmv_bin_bin_bin_pull_bucketed(b: B2SRBucketedEll, x_packed: jax.Array,
                                  mask_packed: jax.Array,
                                  complement: bool = True, block_r: int = 8,
                                  block_k: int = 8,
                                  interpret: Optional[bool] = None):
    """Bucketed pull: per-bucket early-exit slabs with *gathered* masks.

    The push bucketed path ANDs the mask after the scatter-merge; pull
    cannot — the early exit needs the allowed lanes inside the kernel —
    so each bucket gathers its rows' mask words through the same row
    permutation used for the output scatter. Empty tile-rows are in no
    bucket and keep the zero word (OR-identity), which the post-AND also
    preserved, so the two mask placements stay bit-exact.
    """
    out = jnp.zeros((b.n_tile_rows,), jnp.uint32)
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        words = bmv_bin_bin_bin_pull(e, x_packed, mask_packed[rows],
                                     complement, block_r, bk, interpret)
        out = out.at[rows].set(words)
    return out


_MODE = {"arithmetic": "sum", "min_plus": "min_plus", "max_times": "max_times"}


@partial(jax.jit, static_argnames=("mode", "a_value", "ident", "n_rows",
                                   "block_r", "block_k", "interpret"))
def _bin_full_full(col, tiles, x3, n_rows, mode, a_value, ident, block_r,
                   block_k, interpret):
    t = tiles.shape[-1]
    out = kernels.bmv_bin_full_full_pallas(
        col, tiles, x3, t=t, mode=mode, a_value=a_value, ident=ident,
        block_r=block_r, block_k=block_k, interpret=interpret)
    return out.reshape(-1)[:n_rows]


def bmv_bin_full_full(ell: B2SREll, x: jax.Array,
                      semiring: Semiring = ARITHMETIC, a_value: float = 1.0,
                      block_r: int = 8, block_k: int = 8,
                      interpret: Optional[bool] = None):
    """General-semiring mxv. The arithmetic (sum) mode rides the MXU and
    requires finite ``x`` (0·inf would leak NaN through absent edges);
    vectors with ±inf — e.g. SSSP distances — belong on min_plus/max_times,
    which keep the exact select form."""
    interpret = common.interpret_default() if interpret is None else interpret
    if semiring.name not in _MODE:
        raise NotImplementedError(f"kernel path for semiring {semiring.name}")
    mode = _MODE[semiring.name]
    ident = float(semiring.add_identity) if mode != "sum" else 0.0
    t = ell.tile_dim
    n_tc = ell.n_tile_cols
    fill = ident if mode != "sum" else 0.0
    x_pad = jnp.pad(x, (0, n_tc * t - x.shape[0]),
                    constant_values=jnp.asarray(fill, x.dtype))
    x3 = x_pad.reshape(n_tc, t)
    col, tiles = _padded_ell(ell, block_r, block_k)
    return _bin_full_full(col, tiles, x3, ell.n_rows, mode, a_value, ident,
                          block_r, block_k, interpret)


# ---------------------------------------------------------------------------
# Bucketed entry points: one pallas_call per bucket slab (grid sized by the
# bucket's own k_b), outputs scatter-merged through the row permutation.
# ---------------------------------------------------------------------------

def bmv_bin_bin_full_bucketed(b: B2SRBucketedEll, x_packed: jax.Array,
                              out_dtype=jnp.float32, block_r: int = 8,
                              block_k: int = 8,
                              interpret: Optional[bool] = None):
    out = jnp.zeros((b.n_tile_rows, b.tile_dim), out_dtype)
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        vals = bmv_bin_bin_full(e, x_packed, out_dtype, block_r, bk, interpret)
        out = out.at[rows].set(vals.reshape(-1, b.tile_dim))
    return out.reshape(-1)[: b.n_rows]


def bmv_bin_bin_bin_bucketed(b: B2SRBucketedEll, x_packed: jax.Array,
                             mask_packed: Optional[jax.Array] = None,
                             complement: bool = True, block_r: int = 8,
                             block_k: int = 8,
                             interpret: Optional[bool] = None):
    out = jnp.zeros((b.n_tile_rows,), jnp.uint32)
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        words = bmv_bin_bin_bin(e, x_packed, None, True, block_r, bk,
                                interpret)
        out = out.at[rows].set(words)
    # the mask is ANDed after the scatter-merge — still before the caller's
    # store (§V); per-bucket in-kernel masking would need mask gathers
    if mask_packed is not None:
        out = out & (~mask_packed if complement else mask_packed)
    return out


def bmv_bin_full_full_bucketed(b: B2SRBucketedEll, x: jax.Array,
                               semiring: Semiring = ARITHMETIC,
                               a_value: float = 1.0, block_r: int = 8,
                               block_k: int = 8,
                               interpret: Optional[bool] = None):
    if semiring.name not in _MODE:
        raise NotImplementedError(f"kernel path for semiring {semiring.name}")
    mode = _MODE[semiring.name]
    ident = float(semiring.add_identity) if mode != "sum" else 0.0
    out = jnp.full((b.n_tile_rows, b.tile_dim), jnp.asarray(ident, x.dtype))
    for i, rows in enumerate(b.rows):
        e = common.bucket_ell(b, i)
        bk = common.bucket_block_k(e.max_tiles_per_row, block_k)
        vals = bmv_bin_full_full(e, x, semiring, a_value, block_r, bk,
                                 interpret)
        out = out.at[rows].set(vals.reshape(-1, b.tile_dim))
    return out.reshape(-1)[: b.n_rows]


# ---------------------------------------------------------------------------
# Dispatch-registry entries: the "b2sr_pallas" mxv rows (DESIGN.md §10)
# ---------------------------------------------------------------------------

from repro.core.dispatch import apply_output_mask, register  # noqa: E402


@register("mxv", "dense", "full", "b2sr_pallas", bucketed=False, masked=False)
def _mxv_dense(g, x, call):
    return bmv_bin_full_full(g.ell, x, call.semiring, call.a_value)


@register("mxv", "dense", "full", "b2sr_pallas", bucketed=False, masked=True)
def _mxv_dense_masked(g, x, call):
    y = bmv_bin_full_full(g.ell, x, call.semiring, call.a_value)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxv", "dense", "full", "b2sr_pallas", bucketed=True, masked=False)
def _mxv_dense_bucketed(g, x, call):
    return bmv_bin_full_full_bucketed(g.buckets(), x, call.semiring,
                                      call.a_value)


@register("mxv", "dense", "full", "b2sr_pallas", bucketed=True, masked=True)
def _mxv_dense_bucketed_masked(g, x, call):
    y = bmv_bin_full_full_bucketed(g.buckets(), x, call.semiring,
                                   call.a_value)
    return apply_output_mask(y, call.mask, call.complement,
                             call.semiring.identity_for(y.dtype))


@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=False)
def _mxv_bitvec(g, xw, call):
    return bmv_bin_bin_bin(g.ell, xw, call.mask, call.complement)


@register("mxv", "bitvec", "bin", "b2sr_pallas", bucketed=True)
def _mxv_bitvec_bucketed(g, xw, call):
    return bmv_bin_bin_bin_bucketed(g.buckets(), xw, call.mask,
                                    call.complement)


@register("mxv_pull", "bitvec", "bin", "b2sr_pallas", bucketed=False,
          masked=True)
def _mxv_pull(g, xw, call):
    return bmv_bin_bin_bin_pull(g.ell, xw, call.mask, call.complement)


@register("mxv_pull", "bitvec", "bin", "b2sr_pallas", bucketed=True,
          masked=True)
def _mxv_pull_bucketed(g, xw, call):
    return bmv_bin_bin_bin_pull_bucketed(g.buckets(), xw, call.mask,
                                         call.complement)


@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=False, masked=False)
def _mxv_count(g, xw, call):
    return bmv_bin_bin_full(g.ell, xw, call.out_dtype)


@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=False, masked=True)
def _mxv_count_masked(g, xw, call):
    y = bmv_bin_bin_full(g.ell, xw, call.out_dtype)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))


@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=True, masked=False)
def _mxv_count_bucketed(g, xw, call):
    return bmv_bin_bin_full_bucketed(g.buckets(), xw, call.out_dtype)


@register("mxv", "bitvec", "full", "b2sr_pallas", bucketed=True, masked=True)
def _mxv_count_bucketed_masked(g, xw, call):
    y = bmv_bin_bin_full_bucketed(g.buckets(), xw, call.out_dtype)
    return apply_output_mask(y, call.mask, call.complement,
                             jnp.zeros((), call.out_dtype))
