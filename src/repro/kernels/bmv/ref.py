"""Pure-jnp oracle for the BMV kernels: densify the ELL view, then matmul.

Deliberately *independent* of repro.core.ops (which shares word-level tricks
with the kernels): the oracle expands the bit tiles into a dense matrix and
uses plain dense linear algebra.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.b2sr import B2SREll, unpack_bitvector, unpack_tiles
from repro.core.semiring import Semiring, ARITHMETIC


def dense_from_ell(ell: B2SREll, dtype=jnp.float32) -> jnp.ndarray:
    """Expand an ELL view into the dense [n_rows, n_cols] 0/1 matrix."""
    t = ell.tile_dim
    R, K = ell.tile_col_idx.shape
    C = ell.n_tile_cols
    bits = unpack_tiles(ell.bit_tiles, t, dtype)            # [R, K, t, t]
    valid = (ell.tile_col_idx >= 0)
    bits = jnp.where(valid[:, :, None, None], bits, 0)
    cols = jnp.clip(ell.tile_col_idx, 0, C - 1)             # [R, K]
    out = jnp.zeros((R, C, t, t), dtype)
    out = out.at[jnp.arange(R)[:, None], cols].add(bits)
    # (duplicate tile cols cannot occur in a legal ELL view)
    dense = out.transpose(0, 2, 1, 3).reshape(R * t, C * t)
    return dense[: ell.n_rows, : ell.n_cols]


def bmv_bin_bin_full(ell: B2SREll, x_packed, out_dtype=jnp.float32):
    a = dense_from_ell(ell, jnp.float32)
    x = unpack_bitvector(x_packed, ell.tile_dim, ell.n_cols, jnp.float32)
    return (a @ x).astype(out_dtype)


def bmv_bin_bin_bin(ell: B2SREll, x_packed, mask_packed=None, complement=True):
    from repro.core.b2sr import pack_bitvector
    y = bmv_bin_bin_full(ell, x_packed) > 0
    yp = pack_bitvector(y, ell.tile_dim, ell.n_rows)
    if mask_packed is not None:
        yp = yp & (~mask_packed if complement else mask_packed)
    return yp


def bmv_bin_full_full(ell: B2SREll, x, semiring: Semiring = ARITHMETIC,
                      a_value: float = 1.0):
    a = dense_from_ell(ell, jnp.float32)
    ident = semiring.identity_for(x.dtype)
    vals = jnp.where(a > 0, semiring.mul(jnp.asarray(a_value, x.dtype),
                                         x[None, :]), ident)
    if semiring.add is jnp.add:
        return jnp.sum(vals, axis=1)
    if semiring.add is jnp.minimum:
        return jnp.min(vals, axis=1)
    if semiring.add is jnp.maximum:
        return jnp.max(vals, axis=1)
    if semiring.add is jnp.logical_or:
        return jnp.any(vals, axis=1)
    raise NotImplementedError(semiring.name)


def bmv_bin_bin_bin_pull(ell: B2SREll, x_packed, mask_packed,
                         complement: bool = True):
    """Pull-row oracle: pull reorders the scan, never the algebra, so the
    reference answer is the masked push oracle (first-set-bit early exit
    must be unobservable in the output — the property the kernel parity
    tests pin)."""
    return bmv_bin_bin_bin(ell, x_packed, mask_packed, complement)
