"""Graph-query serving driver: closed-loop traffic against GraphQueryServer.

  PYTHONPATH=src python -m repro.launch.serve_queries \
      --n 512 --backend b2sr --queries 96 --budget-ms 100

  # 10% injected Pallas faults + warmup persistence across restarts:
  PYTHONPATH=src python -m repro.launch.serve_queries \
      --backend b2sr_pallas --fault-rate 0.1 \
      --save-warmup /tmp/plans.json --warmup /tmp/plans.json

Drives a mixed bfs/khop/sssp/ppr stream through the fault-tolerant
serving layer (DESIGN.md §13) on an R-MAT graph and prints per-query
latency percentiles, flush/fallback/breaker counters, and — when
``--warmup`` points at an existing file — the warm-start effect on the
first query. The same entry point serves real meshes on TPU slices; the
reduced CPU run exercises the identical code path.
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512, help="graph nodes")
    ap.add_argument("--tile-dim", type=int, default=8)
    ap.add_argument("--backend", default="b2sr",
                    choices=("b2sr", "b2sr_pallas", "csr"))
    ap.add_argument("--queries", type=int, default=96,
                    help="total queries to serve")
    ap.add_argument("--budget-ms", type=float, default=100.0,
                    help="per-query latency budget")
    ap.add_argument("--arrival-batch", type=int, default=4,
                    help="queries admitted between polls")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="injected failure rate on the graph's backend")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warmup", default="",
                    help="warmup file to replay at startup (if it exists)")
    ap.add_argument("--save-warmup", default="",
                    help="persist the served plan recipes here on exit")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry here on exit "
                         "(.prom -> Prometheus text, else JSON)")
    ap.add_argument("--trace-out", default="",
                    help="write completed-query trace spans here (JSONL)")
    ap.add_argument("--stats-every", type=int, default=0,
                    help="print a server stats line every N queries")
    ap.add_argument("--cost-accounting", action="store_true",
                    help="attach HLO cost estimates to compiled plans "
                         "(pays a second AOT lowering per plan)")
    args = ap.parse_args()

    from repro.core import GraphMatrix
    from repro.data import graphs as G
    from repro.engine import (FaultInjector, GraphQueryServer, PlanCache,
                              QueryRejected, ServerConfig)
    from repro.obs import cost as obs_cost
    from repro.obs import export as obs_export
    from repro.obs import metrics as obs_metrics

    if args.cost_accounting:
        obs_cost.set_cost_accounting(True)

    rows, cols = G.rmat_graph(args.n, avg_degree=8, seed=args.seed,
                              symmetric=False)
    g = GraphMatrix.from_coo(rows, cols, args.n, args.n,
                             tile_dim=args.tile_dim, backend=args.backend)

    injector = None
    if args.fault_rate > 0:
        injector = FaultInjector(seed=args.seed).fail(
            backend=args.backend, rate=args.fault_rate)
    server = GraphQueryServer(
        planner=PlanCache(),
        config=ServerConfig(default_budget_s=args.budget_ms / 1e3,
                            backoff_base_s=1e-3),
        fault_injector=injector)
    server.register(g)

    warm_replayed = 0
    if args.warmup and os.path.exists(args.warmup):
        t0 = time.perf_counter()
        warm_replayed = server.warmup(args.warmup)
        print(f"warmup: replayed {warm_replayed} plan recipes in "
              f"{time.perf_counter() - t0:.2f}s from {args.warmup}")

    rng = np.random.default_rng(args.seed)
    kinds = ("bfs", "khop", "sssp", "ppr")
    kind_params = {"bfs": {}, "khop": {"k": 2},
                   "sssp": {"edge_weight": 1.0},
                   "ppr": {"max_iters": 5, "eps": 0.0}}
    submitted = []
    t_first = None
    t_start = time.perf_counter()
    for i in range(args.queries):
        kind = kinds[i % len(kinds)]
        src = int(rng.integers(0, args.n))
        t0 = time.perf_counter()
        try:
            h = server.submit(g, kind, src, **kind_params[kind])
        except QueryRejected as e:
            print(f"rejected: {e}")
            continue
        submitted.append((kind, src, t0, h))
        if (i + 1) % args.arrival_batch == 0:
            server.poll()
        if t_first is None and submitted and submitted[0][3].done():
            t_first = time.perf_counter() - submitted[0][2]
        if args.stats_every and (i + 1) % args.stats_every == 0:
            snap = server.stats()
            c = snap["counters"]
            print(f"[{i + 1}/{args.queries}] completed {c['completed']} | "
                  f"queue {snap['queue_depth']} | "
                  f"degraded {c['degraded_launches']} | "
                  f"plan cache {snap['plan_cache']['hits']}h/"
                  f"{snap['plan_cache']['misses']}m")
    server.flush()
    elapsed = time.perf_counter() - t_start
    if t_first is None and submitted:
        t_first = (submitted[0][3].completed_at or time.perf_counter()) \
            - submitted[0][2]

    lat_ms, degraded, failed = [], 0, 0
    for kind, src, t0, h in submitted:
        try:
            h.result()
        except Exception:                    # noqa: BLE001 — counted below
            failed += 1
            continue
        if h.completed_at is not None:
            lat_ms.append((h.completed_at - t0) * 1e3)
        degraded += int(h.degraded)

    s = server.stats
    print(f"served {s['completed']}/{len(submitted)} queries in "
          f"{elapsed:.2f}s ({s['completed'] / elapsed:.1f} qps) on "
          f"backend={args.backend} fault_rate={args.fault_rate}")
    if lat_ms:
        print(f"latency: first {t_first * 1e3:.1f} ms | "
              f"p50 {np.percentile(lat_ms, 50):.1f} ms | "
              f"p99 {np.percentile(lat_ms, 99):.1f} ms")
    print(f"flushes: {s['flushes']} (deadline {s['deadline_flushes']}, "
          f"fill {s['fill_flushes']}) | deduped {s['deduped']} | "
          f"rejected {s['rejected']}")
    print(f"degraded: {degraded} queries ({s['degraded_launches']} "
          f"launches) | retries {s['retries']} | breaker skips "
          f"{s['breaker_skips']} | failed {failed}")
    print(f"plan cache: {server.planner.misses} compiles, "
          f"{server.planner.hits} hits"
          + (f" (after {warm_replayed} warm-replayed)" if warm_replayed
             else ""))

    if args.save_warmup:
        n = server.save_warmup(args.save_warmup)
        print(f"saved {n} plan recipes to {args.save_warmup}")
    if args.metrics_out:
        obs_export.write_metrics(args.metrics_out)
        print(f"wrote metrics registry snapshot to {args.metrics_out}")
    if args.trace_out:
        if obs_metrics.enabled():
            n = server.dump_traces(args.trace_out)
            print(f"wrote {n} query traces to {args.trace_out}")
        else:
            print("trace-out skipped: observability is disabled")


if __name__ == "__main__":
    main()
