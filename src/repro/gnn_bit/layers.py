"""BitGNN aggregation layers: every neighborhood sum goes through g.mxm.

The one entry point models use is :func:`aggregate` — it wraps a
(possibly traced) :class:`~repro.core.b2sr.B2SREll` in a minimal
:class:`~repro.core.graphblas.GraphMatrix` and dispatches the registry's
``("mxm", "dense"|"bitmat", "full", backend, ...)`` row, so buckets,
backends, sharding and the plan/fault machinery apply to GNN aggregation
exactly as they do to traversal (DESIGN.md §15). The bespoke
``spmm_b2sr_shardmap`` call site that ``models/gnn/gcn.py`` used to carry
is gone: ``axes=...`` routes through the registry's ``sharded`` rows via
a prepared-graph cache instead.

Sharding note: ``GraphMatrix.shard`` partitions host-side (numpy), so a
sharded graph cannot be built from tracers inside a jitted train step.
:func:`prepare_sharded` is therefore called once, host-side, with the
concrete ELL; jitted calls that pass ``axes`` find the prepared graph in
the cache by the ELL's *static* signature (shapes + tile_dim + axes) and
close over its concrete arrays — correct for the full-graph training this
path serves, where the adjacency is a step-invariant constant. A cache
miss under trace falls back to the unsharded registry row (single-device
runs never need to prepare anything).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.b2sr import B2SREll
from repro.core.graphblas import GraphMatrix
from repro.core.operands import BitMatrix
from repro.gnn_bit import binarize as binarize_mod


def graph_from_ell(ell: B2SREll, backend: str = "b2sr",
                   use_buckets: bool = False) -> GraphMatrix:
    """Wrap an ELL view as a minimal mxm-capable GraphMatrix.

    Safe under trace: the wrapped rows touch only ``ell`` (and its lazily
    bucketed view — host-side, hence ``use_buckets`` defaults off here;
    pass a concrete ELL if you turn it on). ``nnz`` is unknowable from a
    traced ELL and never read by mxm; the CSR twin is absent, so only the
    b2sr backends dispatch (the csr fallback path builds real graphs).
    """
    return GraphMatrix(
        n_rows=ell.n_rows, n_cols=ell.n_cols, nnz=-1,
        tile_dim=ell.tile_dim, ell=ell, ell_t=None, csr=None, csr_t=None,
        backend=backend, use_buckets=use_buckets)


# -- prepared sharded graphs (host-side build, traced lookup) ---------------

_SHARDED_CACHE: Dict[tuple, GraphMatrix] = {}


def _signature(ell: B2SREll, axes: Tuple[str, ...], backend: str) -> tuple:
    return (ell.tile_dim, ell.n_rows, ell.n_cols,
            tuple(ell.tile_col_idx.shape), axes, backend)


def _default_mesh(axes: Tuple[str, ...]):
    devs = np.array(jax.devices())
    shape = (-1,) + (1,) * (len(axes) - 1)
    return jax.sharding.Mesh(devs.reshape(shape), axes)


def prepare_sharded(ell: B2SREll, axes, mesh=None, backend: str = "b2sr",
                    use_buckets: bool = False) -> GraphMatrix:
    """Row-partition a concrete ELL once; jitted ``aggregate`` calls hit it.

    Must run outside jit (partitioning is host-side numpy). ``mesh``
    defaults to all local devices on the first axis name.
    """
    axes = tuple(axes)
    if mesh is None:
        mesh = _default_mesh(axes)
    g = graph_from_ell(ell, backend=backend,
                       use_buckets=use_buckets).shard(mesh, axes)
    _SHARDED_CACHE[_signature(ell, axes, backend)] = g
    return g


def _resolve_graph(ell: B2SREll, axes, backend: str,
                   use_buckets: bool) -> GraphMatrix:
    if axes:
        g = _SHARDED_CACHE.get(_signature(ell, tuple(axes), backend))
        if g is not None:
            return g
    return graph_from_ell(ell, backend=backend, use_buckets=use_buckets)


# -- aggregation entry points -----------------------------------------------

def aggregate(ell: B2SREll, x: jax.Array, axes=(), backend: str = "b2sr",
              use_buckets: bool = False) -> jax.Array:
    """A @ x through the registry's spmm_bin_full_full row (GCN hot path)."""
    return _resolve_graph(ell, axes, backend, use_buckets).mxm(x)


def binary_aggregate(ell: B2SREll, bm: BitMatrix, out_dtype=None, axes=(),
                     backend: str = "b2sr",
                     use_buckets: bool = False) -> jax.Array:
    """A @ bits via the packed bin·bin→full row: popcount counts [n, d]."""
    return _resolve_graph(ell, axes, backend, use_buckets).mxm(
        bm, out_dtype=out_dtype)


def signed_aggregate(ell: B2SREll, x: jax.Array, rowsum: jax.Array,
                     axes=(), backend: str = "b2sr",
                     use_buckets: bool = False,
                     alpha: Optional[jax.Array] = None) -> jax.Array:
    """α-scaled ±1 aggregation without ever unpacking the activations.

    ``A @ (α·sign(x)) = α · (2·(A @ bits) − A·1)`` with ``bits = x > 0``:
    one packed popcount mxm plus a rank-1 epilogue (XNOR-Net style; the
    α·popcount reconstruction of DESIGN.md §15). Exact — not approximate —
    whenever ``x`` is already ±1, e.g. downstream of ``ste_sign``.
    ``rowsum`` is A's row-sum (neighbor count per node); α defaults to the
    per-feature mean|x| and can be pinned to 1 for pure sign aggregation.
    """
    if alpha is None:
        alpha = binarize_mod.alpha_scale(x)
    bm = binarize_mod.pack_activations(x, ell.tile_dim)
    counts = binary_aggregate(ell, bm, axes=axes, backend=backend,
                              use_buckets=use_buckets)
    return alpha[None, :] * (2.0 * counts - rowsum[:, None])
