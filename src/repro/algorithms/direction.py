"""Direction-optimizing traversal policy (Beamer-style push/pull switching).

On power-law graphs the dominant algorithmic win over plain push BFS is
switching between *push* (frontier · A: expand the frontier's out-edges)
and *pull* (complement-masked Aᵀ · frontier with per-row early exit: each
unvisited vertex scans its in-edges until it finds a frontier parent) as
the frontier density changes (GraphBLAST; ROADMAP open item 2).

Both sides are one dispatch-registry row apart: push is the masked
bin·bin→bin mxv/mxm the traversal loops always ran; pull is the
``mxv_pull``/``mxm_pull`` row, whose Pallas kernel consumes the k-axis
through an early-exit ``while_loop`` (DESIGN.md §12). This module holds
the *decision*: a popcount density estimator over the packed words and a
hysteresis switch, all in traced jnp so the direction is loop-carried
state inside ``lax.while_loop`` traversal loops.

The heuristic (Beamer et al., "Direction-Optimizing Breadth-First
Search", adapted to the bit-packed estimate):

  push → pull   when  m_f > α · m_u      (frontier edges vs unexplored)
  pull → push   when  nnz_f < n / β      (frontier shrank back down)

with m_f ≈ nnz(frontier) · d̄ and m_u ≈ (n − nnz(visited)) · d̄ estimated
from popcounts (d̄ = nnz/n, exact degrees never gathered — the estimator
must be O(words), not O(edges)). Hysteresis: after the first pull→push
down-switch the direction *locks* to push — a BFS frontier has one hump,
so one pull regime per traversal is the Beamer schedule, and the lock
makes the no-flapping trace property (tests/test_direction.py) hold by
construction rather than by threshold tuning.

Every traversal records a per-iteration direction trace on its result
object (``BFSResult.directions`` etc.) so tests and benchmarks can assert
*which* path ran, not just that the answer matched.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

#: Traced direction encoding (int8 in loop state and traces).
PUSH = 0
PULL = 1

#: User-facing mode strings accepted by bfs()/cc()/msbfs(direction=...).
MODES = ("push", "pull", "auto")

#: Trace-padding value for iterations that never ran.
_NONE = -1


@dataclasses.dataclass(frozen=True)
class DirectionConfig:
    """Switching policy knobs (Descriptor-adjacent: frozen + hashable, so
    a plan key can carry it; see ``engine.queries.msbfs``).

    alpha: push→pull when frontier-edge estimate exceeds ``alpha`` × the
           unexplored-edge estimate. Beamer's tuned CPU value is 1/14;
           the packed estimator undercounts m_f on hub frontiers, so the
           default is slightly more eager.
    beta:  pull→push when frontier nnz drops below n / ``beta``.
    """

    mode: str = "auto"
    alpha: float = 0.07
    beta: float = 24.0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"direction mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if not (self.alpha > 0 and self.beta > 0):
            raise ValueError("alpha and beta must be positive")


def as_config(direction: Union[str, DirectionConfig, None]) -> DirectionConfig:
    """Normalize a ``direction=`` argument: a mode string, a full config,
    or None (meaning the historical push-only behavior)."""
    if direction is None:
        return DirectionConfig(mode="push")
    if isinstance(direction, DirectionConfig):
        return direction
    return DirectionConfig(mode=direction)


def nnz_words(words: jax.Array) -> jax.Array:
    """Popcount density estimator: set bits across packed uint32 words.

    Works unchanged for a ``BitVector``'s ``[n_words]`` and a
    ``FrontierBatch``'s ``[tiles, t, W]`` word arrays — O(words), the
    whole point of estimating density on the packed representation. On a
    sharded graph the frontier words are *replicated* (DESIGN.md §11), so
    every shard computes the same global count and the per-iteration
    direction choice is shard-consistent by construction.
    """
    return jnp.sum(jax.lax.population_count(words), dtype=jnp.int32)


def initial_direction(cfg: DirectionConfig) -> jnp.ndarray:
    """Loop-entry direction: forced modes start forced; auto starts push
    (the iteration-0 frontier is a handful of sources)."""
    return jnp.int8(PULL if cfg.mode == "pull" else PUSH)


def next_direction(cfg: DirectionConfig, cur: jax.Array, locked: jax.Array,
                   nnz_f: jax.Array, nnz_visited: jax.Array, n: int,
                   avg_degree: float, batch: int = 1
                   ) -> Tuple[jax.Array, jax.Array]:
    """One hysteresis step: the direction for the *next* iteration.

    All operands are traced (the estimator runs inside the traversal's
    ``while_loop``); ``cfg``/``n``/``avg_degree``/``batch`` are trace-time
    constants. ``batch`` scales the multi-source counts (``nnz_f`` summed
    over S stacked frontiers) back to per-query magnitudes so one set of
    thresholds serves bfs and msbfs.

    Returns ``(direction, locked)`` — int8 and bool, loop-carried.
    """
    if cfg.mode != "auto":
        return jnp.int8(PULL if cfg.mode == "pull" else PUSH), locked
    m_f = nnz_f.astype(jnp.float32) * (avg_degree / batch)
    unvisited = jnp.maximum(n - nnz_visited.astype(jnp.float32) / batch, 0.0)
    m_u = unvisited * avg_degree
    go_pull = (cur == PUSH) & ~locked & (m_f > cfg.alpha * m_u)
    go_push = (cur == PULL) & (nnz_f.astype(jnp.float32) / batch
                               < n / cfg.beta)
    new = jnp.where(go_pull, jnp.int8(PULL),
                    jnp.where(go_push, jnp.int8(PUSH), cur.astype(jnp.int8)))
    return new, locked | go_push


def empty_trace(max_iters: int) -> jax.Array:
    """Fixed-size loop-carried trace buffer (int8; -1 = iteration not run).

    Sized by the static iteration bound; writes use ``mode='drop'`` so an
    out-of-range stamp (cannot happen — BFS runs ≤ n iterations — but the
    compiler doesn't know that) is a no-op instead of a clamp-corruption.
    """
    return jnp.full((max(int(max_iters), 0),), _NONE, jnp.int8)


def record(trace: jax.Array, it: jax.Array, direction: jax.Array) -> jax.Array:
    """Stamp the direction *used* at iteration ``it`` into the trace."""
    if trace.shape[0] == 0:
        # max_iters=0: the loop body still traces (cond is data-dependent)
        # and indexing a 0-size axis is a trace-time error
        return trace
    return trace.at[it].set(direction.astype(jnp.int8), mode="drop")


def trace_tuple(trace, n_iterations: Optional[int] = None
                ) -> Tuple[str, ...]:
    """Host-side: the trace buffer as ``("push", "pull", ...)`` strings.

    ``n_iterations`` trims the unused tail; padding entries (-1) are
    dropped regardless, so a conservative bound is harmless.
    """
    arr = np.asarray(trace)
    if n_iterations is not None:
        arr = arr[: int(n_iterations)]
    return tuple("pull" if v == PULL else "push" for v in arr if v != _NONE)


def observe_trace(directions: Tuple[str, ...], kernel: str = "bfs",
                  registry=None) -> None:
    """Mirror one traversal's direction trace into the metrics registry.

    Emits ``traversal_iterations_total{direction}`` (one per iteration),
    ``direction_switches_total{transition}`` for each change of regime,
    and one ``direction_switch`` event per switch carrying the iteration
    index — so a serving fleet can see *when* its traversals flip to pull
    without keeping raw traces around (DESIGN.md §14).
    """
    from repro.obs import metrics as obs_metrics
    if not obs_metrics.enabled() or not directions:
        return
    reg = registry if registry is not None else obs_metrics.get_registry()
    iters = reg.counter("traversal_iterations_total",
                        "traversal iterations by direction run",
                        ("direction", "kernel"))
    for d in directions:
        iters.inc(direction=d, kernel=kernel)
    switches = reg.counter("direction_switches_total",
                           "push/pull regime changes", ("transition",))
    for i in range(1, len(directions)):
        if directions[i] != directions[i - 1]:
            t = f"{directions[i - 1]}->{directions[i]}"
            switches.inc(transition=t)
            reg.event("direction_switch", kernel=kernel, iteration=i,
                      transition=t)


def check_monotone(directions: Tuple[str, ...]) -> bool:
    """The hysteresis invariant: the pull iterations form one contiguous
    regime (push* pull* push*) — no flapping. Tests assert this on every
    auto trace; the lock in :func:`next_direction` makes it structural."""
    pulls = [i for i, d in enumerate(directions) if d == "pull"]
    return not pulls or pulls == list(range(pulls[0], pulls[-1] + 1))
