"""Jitted wrapper for the Pallas SpGEMM kernel (pad + dispatch + unpad)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.b2sr import B2SRBucketedEll, B2SREll
from repro.core.ops import apply_grid_mask
from repro.kernels import common
from repro.kernels.spgemm import spgemm as kernels


@partial(jax.jit, static_argnames=("t", "n_tile_cols", "mask_mode", "block_r",
                                   "interpret"))
def _mxm(a_col, a_tiles, b_col, b_tiles, m_col, m_tiles, t, n_tile_cols,
         mask_mode, block_r, interpret):
    return kernels.mxm_bin_bin_bin_pallas(
        a_col, a_tiles, b_col, b_tiles, m_col, m_tiles, t=t,
        n_tile_cols=n_tile_cols, mask_mode=mask_mode, block_r=block_r,
        interpret=interpret)


def mxm(a: B2SREll, b: B2SREll, mask: Optional[B2SREll] = None,
        complement: bool = False, block_r: int = 8,
        interpret: Optional[bool] = None) -> jax.Array:
    """Packed boolean SpGEMM grid uint32[a.n_tile_rows, b.n_tile_cols, t].

    Same contract as ``repro.core.ops.mxm_bin_bin_bin`` (compress with
    ``b2sr.packed_grid_to_b2sr``); the mask, when given, is applied in-kernel
    right before the store.
    """
    if a.tile_dim != b.tile_dim:
        raise ValueError(f"tile_dim mismatch: {a.tile_dim} vs {b.tile_dim}")
    if a.n_cols != b.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {a.n_rows}x{a.n_cols}, "
                         f"B is {b.n_rows}x{b.n_cols}")
    interpret = common.interpret_default() if interpret is None else interpret
    t = a.tile_dim
    R = a.tile_col_idx.shape[0]
    a_col = common.pad_to(a.tile_col_idx, 0, block_r, fill=-1)
    a_tiles = common.pad_to(a.bit_tiles, 0, block_r)
    if mask is None:
        mask_mode = "none"
        m_col = jnp.full((a_col.shape[0], 1), -1, jnp.int32)
        m_tiles = jnp.zeros((a_col.shape[0], 1, t), jnp.uint32)
    else:
        if mask.tile_dim != t:
            raise ValueError("mask tile_dim mismatch")
        mask_mode = "complement" if complement else "keep"
        m_col = common.pad_to(mask.tile_col_idx, 0, block_r, fill=-1)
        m_tiles = common.pad_to(mask.bit_tiles, 0, block_r)
    out = _mxm(a_col, a_tiles, b.tile_col_idx, b.bit_tiles, m_col, m_tiles,
               t, b.n_tile_cols, mask_mode, block_r, interpret)
    return out[:R]


def mxm_bucketed(a: B2SRBucketedEll, b: B2SREll,
                 mask: Optional[B2SREll] = None, complement: bool = False,
                 block_r: int = 8,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Bucketed boolean SpGEMM grid uint32[a.n_tile_rows, b.n_tile_cols, t].

    A's tile-rows run per bucket (one pallas_call each, Ka = the bucket's
    k_b); B stays a single ELL operand gathered in-VMEM. The mask is ANDed
    after the scatter-merge — still right before the caller's store (§V).
    """
    t = a.tile_dim
    if t != b.tile_dim:
        raise ValueError(f"tile_dim mismatch: {t} vs {b.tile_dim}")
    if a.n_cols != b.n_rows:
        raise ValueError(f"inner-dim mismatch: A is {a.n_rows}x{a.n_cols}, "
                         f"B is {b.n_rows}x{b.n_cols}")
    if mask is not None and mask.tile_dim != t:
        raise ValueError("mask tile_dim mismatch")
    out = jnp.zeros((a.n_tile_rows, b.n_tile_cols, t), jnp.uint32)
    for i, rows in enumerate(a.rows):
        grid = mxm(common.bucket_ell(a, i), b, None, False, block_r,
                   interpret)                               # [rows_b, C, t]
        out = out.at[rows].set(grid)
    return apply_grid_mask(out, mask, complement)


# ---------------------------------------------------------------------------
# Dispatch-registry entries: the "b2sr_pallas" SpGEMM rows (DESIGN.md §10).
# The count rows (bin·bin→full) have no Pallas kernel yet — they register
# the jnp schemes, which is where the pre-registry dispatch sent them too.
# ---------------------------------------------------------------------------

from repro.core import ops as core_ops  # noqa: E402
from repro.core.dispatch import register  # noqa: E402


@register("mxm", "graph", "bin", "b2sr_pallas", bucketed=False)
def _mxm_graph(g, other, call):
    m_ell = call.mask.ell if call.mask is not None else None
    return mxm(g.ell, other.ell, m_ell, call.complement)


@register("mxm", "graph", "bin", "b2sr_pallas", bucketed=True)
def _mxm_graph_bucketed(g, other, call):
    m_ell = call.mask.ell if call.mask is not None else None
    return mxm_bucketed(g.buckets(), other.ell, m_ell, call.complement)


@register("mxm", "graph", "full", "b2sr_pallas", bucketed=False, masked=False)
def _mxm_graph_count(g, other, call):
    return core_ops.mxm_bin_bin_full(g.ell, other.ell,
                                     row_chunk=call.row_chunk)


@register("mxm", "graph", "full", "b2sr_pallas", bucketed=False, masked=True)
def _mxm_graph_count_masked(g, other, call):
    return core_ops.mxm_bin_bin_full_masked(g.ell, other.ell, call.mask.ell,
                                            call.complement,
                                            row_chunk=call.row_chunk)


@register("mxm", "graph", "full", "b2sr_pallas", bucketed=True, masked=False)
def _mxm_graph_count_bucketed(g, other, call):
    return core_ops.mxm_bin_bin_full_bucketed(g.buckets(), other.ell)


@register("mxm", "graph", "full", "b2sr_pallas", bucketed=True, masked=True)
def _mxm_graph_count_bucketed_masked(g, other, call):
    return core_ops.mxm_bin_bin_full_masked_bucketed(
        g.buckets(), other.ell, call.mask.ell, call.complement)
