"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 128 experts, top-8, QK-norm."""

from repro.configs.base import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,  # per-expert
    vocab_size=151936,
    activation="swiglu",
    qk_norm=True,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
)


def reduced() -> TransformerConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=256, dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32), max_seq_len=64)
